"""SeriesStore: a bounded ring of scrapes, queried as time series.

The flight recorder (PR 6) made the fabric observable — one
``MetricsRegistry``, one exposition format, one strict parser — but every
consumer so far reads a single scrape: lifetime totals, no rates, no
history. This module is the retention layer the watchdog (``repro.obs.slo``)
evaluates against:

  * ``SeriesStore.ingest`` accepts a whole scrape — exposition text, a
    ``registry.collect()`` dict keyed by ``Series`` tuples, or a flat
    ``counters()`` dict keyed by series strings — stamped with the scrape
    time. Retention is bounded: only the last ``retention`` scrapes are
    kept, older points are dropped per series.
  * ``rate()`` / ``increase()`` are **counter-reset aware** with the exact
    semantics ``SchedulerTelemetry`` already uses on the live path: a
    sample that *decreased* (or a series that vanished and came back)
    means the counter was reset behind our back — live migration folds a
    tenant's ledger out of the source scheduler, a stack hot-swap replaces
    the scheduler wholesale — so the new value becomes the baseline and
    the drop contributes **zero**, never a negative rate. Concretely:
    ``increase`` is the sum of positive adjacent deltas over the window.
  * ``quantile_over_time()`` re-derives a windowed latency quantile from
    exported cumulative ``_bucket`` series: per-bucket reset-aware
    increases over the window, then the same upper-edge rule as
    ``repro.obs.hist.Histogram.quantile`` (rank = max(1, ceil(q*total)),
    answer = the first bucket edge whose cumulative count reaches it).

Unlike Prometheus's ``rate()``, no extrapolation: ``rate`` divides the
windowed increase by the elapsed time between the first and last sample
actually in the window — deterministic, and exact for the two-scrape diff
``tools/nk_top.py`` renders. Stdlib only — importable without jax.
"""
from __future__ import annotations

import bisect
import math
from typing import Dict, List, Mapping, Optional, Tuple, Union

from repro.obs.metrics import (Labels, Series, parse_prometheus_text,
                               parse_series_key)

ScrapeLike = Union[str, Mapping[Series, float], Mapping[str, float]]


def series_key(name: str, **labels) -> Series:
    """The ``Series`` tuple for ``name`` + labels — the key every
    ``SeriesStore`` query takes."""
    return name, tuple(sorted((k, str(v)) for k, v in labels.items()))


def _as_series_dict(scrape: ScrapeLike) -> Dict[Series, float]:
    if isinstance(scrape, str):
        return parse_prometheus_text(scrape)
    out: Dict[Series, float] = {}
    for k, v in scrape.items():
        out[k if isinstance(k, tuple) else parse_series_key(k)] = float(v)
    return out


class SeriesStore:
    """Bounded per-series sample history over periodic scrapes.

    ``retention`` bounds memory by *scrape count*: once more than
    ``retention`` scrapes have been ingested, the oldest falls off and
    every series drops its points from before the oldest retained scrape.
    """

    def __init__(self, retention: int = 512):
        if retention < 2:
            raise ValueError("retention must be >= 2 (rates need a pair)")
        self.retention = int(retention)
        self._times: List[float] = []
        self._data: Dict[Series, List[Tuple[float, float]]] = {}
        self._by_name: Dict[str, List[Series]] = {}   # name -> its series
        self.scrapes = 0              # lifetime scrapes ingested

    # -- ingest -------------------------------------------------------------
    def ingest(self, scrape: ScrapeLike, ts: float) -> None:
        """Add one scrape stamped ``ts`` (seconds; must be strictly after
        the previous scrape — the watchdog runs on a monotonic clock)."""
        t = float(ts)
        if self._times and t <= self._times[-1]:
            raise ValueError(
                f"scrape at ts {t} is not after the previous scrape at "
                f"{self._times[-1]}")
        for series, v in _as_series_dict(scrape).items():
            pts = self._data.get(series)
            if pts is None:
                pts = self._data[series] = []
                self._by_name.setdefault(series[0], []).append(series)
            pts.append((t, v))
        self._times.append(t)
        self.scrapes += 1
        if len(self._times) > self.retention:
            del self._times[: len(self._times) - self.retention]
            floor = self._times[0]
            for series in list(self._data):
                pts = self._data[series]
                i = 0
                while i < len(pts) and pts[i][0] < floor:
                    i += 1
                if i:
                    del pts[:i]
                if not pts:
                    del self._data[series]
                    self._by_name[series[0]].remove(series)
                    if not self._by_name[series[0]]:
                        del self._by_name[series[0]]

    # -- lookups ------------------------------------------------------------
    def times(self) -> Tuple[float, ...]:
        """Timestamps of the retained scrapes, oldest first."""
        return tuple(self._times)

    def names(self) -> List[str]:
        return sorted(self._by_name)

    def series(self, name: Optional[str] = None) -> List[Series]:
        if name is not None:
            return sorted(self._by_name.get(name, ()))
        return sorted(self._data)

    def label_values(self, name: str, label: str) -> List[str]:
        """Distinct values of one label across all series of ``name``."""
        out = {dict(lbl)[label] for _, lbl in self._by_name.get(name, ())
               if label in dict(lbl)}
        return sorted(out, key=lambda s: (len(s), s))

    def latest(self, series: Series) -> Optional[float]:
        pts = self._data.get(series)
        return pts[-1][1] if pts else None

    def window(self, series: Series, window_s: Optional[float] = None,
               now: Optional[float] = None) -> List[Tuple[float, float]]:
        """Samples of ``series`` with ``now - window_s <= ts <= now``
        (both ends inclusive); the whole retained history when
        ``window_s`` is None. ``now`` defaults to the newest scrape."""
        pts = self._data.get(series, [])
        if not pts:
            return []
        hi = (self._times[-1] if self._times else pts[-1][0]) \
            if now is None else float(now)
        lo = -math.inf if window_s is None else hi - float(window_s)
        # points are time-sorted: slice by bisection, don't scan
        i = bisect.bisect_left(pts, (lo,)) if lo > -math.inf else 0
        j = bisect.bisect_right(pts, (hi, math.inf))
        return pts[i:j]

    # -- counter-reset-aware rates ------------------------------------------
    def increase(self, series: Series, window_s: Optional[float] = None,
                 now: Optional[float] = None) -> float:
        """Windowed counter increase: the sum of positive adjacent deltas.

        A decreased sample is a counter reset (migration folded the ledger
        out, a hot-swap replaced the scheduler): the drop contributes 0
        and the new value rebaselines — same discipline as
        ``SchedulerTelemetry.update``. Never negative. 0.0 with fewer
        than two samples in the window."""
        pts = self.window(series, window_s, now)
        total = 0.0
        for (_, a), (_, b) in zip(pts, pts[1:]):
            if b > a:
                total += b - a
        return total

    def rate(self, series: Series, window_s: Optional[float] = None,
             now: Optional[float] = None) -> float:
        """Per-second rate over the window: reset-aware increase divided
        by the elapsed time between the first and last sample actually in
        the window (no extrapolation). 0.0 with fewer than two samples."""
        pts = self.window(series, window_s, now)
        if len(pts) < 2:
            return 0.0
        elapsed = pts[-1][0] - pts[0][0]
        if elapsed <= 0:
            return 0.0
        return self.increase(series, window_s, now) / elapsed

    # -- windowed histogram quantiles ---------------------------------------
    def quantile_over_time(self, family: str, q: float,
                           window_s: Optional[float] = None,
                           now: Optional[float] = None,
                           **labels) -> Optional[float]:
        """Quantile of the samples a histogram family observed *inside the
        window*, from its exported cumulative ``_bucket`` series.

        Per-bucket reset-aware increases give the windowed cumulative
        counts; the answer is the upper edge of the bucket the quantile
        falls in — exactly ``Histogram.quantile``'s rule, so the result is
        bracketed by ``Histogram.quantile_bounds`` on the same samples.
        ``labels`` must match the series' non-``le`` labels exactly.
        None when no bucket series match or the window saw no samples."""
        if not 0.0 <= q <= 1.0:
            raise ValueError("q must be in [0, 1]")
        want = tuple(sorted((k, str(v)) for k, v in labels.items()))
        buckets: List[Tuple[float, float]] = []
        for name, lbl in self._by_name.get(family + "_bucket", ()):
            d = dict(lbl)
            le = d.pop("le", None)
            if le is None or tuple(sorted(d.items())) != want:
                continue
            edge = math.inf if le == "+Inf" else float(le)
            buckets.append((edge,
                            self.increase((name, lbl), window_s, now)))
        if not buckets:
            return None
        buckets.sort()
        # per-series reset clamping can leave tiny non-monotonicities in
        # the cumulative counts; restore monotonicity with a running max
        cum, mono = 0.0, []
        for edge, c in buckets:
            cum = max(cum, c)
            mono.append((edge, cum))
        total = mono[-1][1]
        if total <= 0:
            return None
        rank = max(1, math.ceil(q * total - 1e-9))
        for edge, c in mono:
            if c >= rank:
                return edge
        return mono[-1][0]
