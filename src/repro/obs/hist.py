"""Fixed-bucket latency histograms with quantile estimation.

The fabric needs tail latency per tenant (admit wait, TTFT, e2e) without
keeping every sample: a ``Histogram`` is a fixed vector of log-spaced
bucket counts, cheap to observe into, cheap to merge (tenant migration
carries the counts in the ``TenantState`` payload), and good enough for
p50/p95/p99 — a quantile estimate is always the upper edge of the bucket
the quantile falls in, so it brackets the true sample quantile within one
bucket width (the property test in ``tests/test_obs.py``).

Default buckets span 1 ms .. 100 s with growth 10^(1/8) ≈ 1.33 — eight
buckets per decade, 41 edges — wide enough for the replay's virtual-clock
waits and the wall-clock benches alike. Stdlib only.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

# 1e-3 .. 1e2, 8 buckets/decade: 10**(-3 + k/8) for k = 0..40
DEFAULT_BUCKETS: Tuple[float, ...] = tuple(
    10.0 ** (-3.0 + k / 8.0) for k in range(41))


class Histogram:
    """Cumulative-export histogram over fixed upper-edge buckets.

    ``counts[i]`` is the number of samples with ``value <= edges[i]``
    minus those counted by earlier buckets (i.e. stored non-cumulative,
    exported cumulative per the Prometheus text format); ``overflow``
    holds samples above the last edge (the ``+Inf`` bucket).
    """

    __slots__ = ("edges", "counts", "overflow", "total", "sum", "min", "max")

    def __init__(self, edges: Optional[Sequence[float]] = None):
        self.edges: Tuple[float, ...] = tuple(edges if edges is not None
                                              else DEFAULT_BUCKETS)
        if list(self.edges) != sorted(self.edges) or len(self.edges) < 1:
            raise ValueError("bucket edges must be sorted and non-empty")
        self.counts: List[int] = [0] * len(self.edges)
        self.overflow = 0
        self.total = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    # -- recording ----------------------------------------------------------
    def observe(self, value: float) -> None:
        v = float(value)
        self.total += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        i = self._bucket_index(v)
        if i is None:
            self.overflow += 1
        else:
            self.counts[i] += 1

    def _bucket_index(self, v: float) -> Optional[int]:
        """Smallest i with v <= edges[i], or None for the +Inf bucket."""
        lo, hi = 0, len(self.edges)
        if v > self.edges[-1]:
            return None
        while lo < hi:
            mid = (lo + hi) // 2
            if v <= self.edges[mid]:
                hi = mid
            else:
                lo = mid + 1
        return lo

    # -- queries ------------------------------------------------------------
    @property
    def mean(self) -> float:
        return self.sum / self.total if self.total else 0.0

    def quantile(self, q: float) -> float:
        """Upper edge of the bucket holding quantile ``q`` (0..1); the
        last observed max for the overflow bucket, 0.0 when empty."""
        lo, hi = self.quantile_bounds(q)
        return hi

    def quantile_bounds(self, q: float) -> Tuple[float, float]:
        """(lower, upper) bucket edges bracketing quantile ``q``: the true
        sample quantile lies in (lower, upper]. Overflow samples report
        ``(last_edge, observed max)``."""
        if not 0.0 <= q <= 1.0:
            raise ValueError("q must be in [0, 1]")
        if self.total == 0:
            return 0.0, 0.0
        # rank of the q-th sample, 1-based ceil as in numpy's 'inverted_cdf'
        rank = max(1, math.ceil(q * self.total))
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank:
                lower = self.edges[i - 1] if i else 0.0
                return lower, self.edges[i]
        # quantile falls in the overflow bucket
        return self.edges[-1], (self.max if self.max > -math.inf
                                else math.inf)

    # -- merge / snapshot ---------------------------------------------------
    def merge(self, other: "Histogram") -> None:
        if other.edges != self.edges:
            raise ValueError("cannot merge histograms with different edges")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.overflow += other.overflow
        self.total += other.total
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)

    def copy(self) -> "Histogram":
        h = Histogram(self.edges)
        h.counts = list(self.counts)
        h.overflow = self.overflow
        h.total = self.total
        h.sum = self.sum
        h.min = self.min
        h.max = self.max
        return h

    def since(self, snapshot: "Histogram") -> "Histogram":
        """The histogram of samples observed after ``snapshot`` was taken
        (both must share edges) — how the replay windows its reports."""
        if snapshot.edges != self.edges:
            raise ValueError("snapshot has different edges")
        h = Histogram(self.edges)
        h.counts = [a - b for a, b in zip(self.counts, snapshot.counts)]
        h.overflow = self.overflow - snapshot.overflow
        h.total = self.total - snapshot.total
        h.sum = self.sum - snapshot.sum
        # min/max are lifetime extrema; keep current ones (conservative)
        h.min = self.min
        h.max = self.max
        return h

    # -- wire formats -------------------------------------------------------
    def to_payload(self) -> dict:
        """Plain-dict form carried inside a ``TenantState`` payload."""
        return {"edges": list(self.edges), "counts": list(self.counts),
                "overflow": self.overflow, "total": self.total,
                "sum": self.sum, "min": self.min, "max": self.max}

    @classmethod
    def from_payload(cls, payload: dict) -> "Histogram":
        h = cls(payload["edges"])
        h.counts = list(payload["counts"])
        h.overflow = int(payload["overflow"])
        h.total = int(payload["total"])
        h.sum = float(payload["sum"])
        h.min = float(payload["min"])
        h.max = float(payload["max"])
        return h

    def counters(self, name: str, **labels) -> Dict[str, float]:
        """Prometheus histogram samples: cumulative ``_bucket{le=...}``
        plus ``_sum`` and ``_count``, with any extra labels attached."""
        from repro.obs.metrics import escape_label_value
        base = ",".join(f'{k}="{escape_label_value(v)}"'
                        for k, v in sorted(labels.items()))
        sep = "," if base else ""
        out: Dict[str, float] = {}
        cum = 0
        for edge, c in zip(self.edges, self.counts):
            cum += c
            out[f'{name}_bucket{{{base}{sep}le="{format(edge, ".6g")}"}}']\
                = float(cum)
        out[f'{name}_bucket{{{base}{sep}le="+Inf"}}'] = float(self.total)
        out[f"{name}_sum{{{base}}}" if base else f"{name}_sum"] = self.sum
        out[f"{name}_count{{{base}}}" if base else f"{name}_count"]\
            = float(self.total)
        return out


class TenantHistograms:
    """A family of per-tenant histograms for one latency metric."""

    def __init__(self, name: str,
                 edges: Optional[Sequence[float]] = None):
        self.name = name
        self.edges = tuple(edges if edges is not None else DEFAULT_BUCKETS)
        self.per_tenant: Dict[str, Histogram] = {}

    def observe(self, tenant: str, value: float) -> None:
        h = self.per_tenant.get(tenant)
        if h is None:
            h = self.per_tenant[tenant] = Histogram(self.edges)
        h.observe(value)

    def get(self, tenant: str) -> Histogram:
        return self.per_tenant.get(tenant) or Histogram(self.edges)

    def pop(self, tenant: str) -> Optional[Histogram]:
        return self.per_tenant.pop(tenant, None)

    def absorb(self, tenant: str, hist: Histogram) -> None:
        """Merge a migrated-in histogram into the tenant's local one."""
        h = self.per_tenant.get(tenant)
        if h is None:
            self.per_tenant[tenant] = hist.copy()
        else:
            h.merge(hist)

    def merged(self, other: "TenantHistograms") -> "TenantHistograms":
        out = TenantHistograms(self.name, self.edges)
        for src in (self, other):
            for t, h in src.per_tenant.items():
                out.absorb(t, h)
        return out

    def counters(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for t in sorted(self.per_tenant):
            out.update(self.per_tenant[t].counters(self.name, tenant=t))
        return out
