"""Span tracing for the fabric: Chrome trace-event JSON out of any replay.

One module-level tracer (``TRACER``), swapped with ``set_tracer`` /
``trace_to``. The default is a ``NullTracer`` whose every method is a
no-op — instrumentation sites pay one attribute call when tracing is off
(hot sites additionally guard kwarg construction behind
``if TRACER.enabled:``), which the bench-smoke overhead gate keeps honest.

Event model (Chrome trace-event format, loadable in Perfetto /
chrome://tracing):

  * ``span(track, name, start, end)``      -> one "X" complete event
  * ``instant(track, name, ts)``           -> one "i" instant event
  * ``async_begin/async_end(track, name, id, ts)`` -> "b"/"e" pairs, for
    operations that overlap on one track (migration drains keyed by
    tenant).

Tracks are logical timelines ("engine0", "cluster", "controller", …);
each becomes a tid with an "M" thread_name metadata record. Timestamps
are seconds — the replay's virtual clock or ``time.monotonic()`` — and
export as integer microseconds, so a whole scenario browses as a real
timeline. Stdlib only.
"""
from __future__ import annotations

import json
from contextlib import contextmanager
from typing import Dict, List, Optional


class NullTracer:
    """The disabled tracer: every hook is an attribute call + pass."""

    enabled = False

    def span(self, track, name, start, end, **args) -> None:
        pass

    def instant(self, track, name, ts, **args) -> None:
        pass

    def async_begin(self, track, name, event_id, ts, **args) -> None:
        pass

    def async_end(self, track, name, event_id, ts, **args) -> None:
        pass

    def counters(self) -> Dict[str, float]:
        return {}


class Tracer(NullTracer):
    """Recording tracer: accumulates Chrome trace events in memory.

    ``ts`` values are seconds (virtual or wall; the tracer does not care
    which — callers pass whatever ``now`` they run on). Export multiplies
    into integer microseconds as the trace-event format expects.
    """

    enabled = True
    PID = 1

    def __init__(self):
        self.events: List[dict] = []
        self._tids: Dict[str, int] = {}

    # -- recording ----------------------------------------------------------
    def _tid(self, track: str) -> int:
        tid = self._tids.get(track)
        if tid is None:
            tid = self._tids[track] = len(self._tids) + 1
            self.events.append({
                "name": "thread_name", "ph": "M", "pid": self.PID,
                "tid": tid, "args": {"name": track}})
        return tid

    def _emit(self, ph: str, track: str, name: str, ts: float,
              args: dict, **extra) -> None:
        ev = {"name": name, "ph": ph, "pid": self.PID,
              "tid": self._tid(track), "ts": round(float(ts) * 1e6)}
        if args:
            ev["args"] = args
        ev.update(extra)
        self.events.append(ev)

    def span(self, track, name, start, end, **args) -> None:
        dur = round((float(end) - float(start)) * 1e6)
        self._emit("X", track, name, start, args, dur=max(0, dur))

    def instant(self, track, name, ts, **args) -> None:
        self._emit("i", track, name, ts, args, s="t")

    def async_begin(self, track, name, event_id, ts, **args) -> None:
        self._emit("b", track, name, ts, args, cat=track,
                   id=str(event_id))

    def async_end(self, track, name, event_id, ts, **args) -> None:
        self._emit("e", track, name, ts, args, cat=track,
                   id=str(event_id))

    # -- export -------------------------------------------------------------
    def chrome_trace(self) -> dict:
        return {"traceEvents": list(self.events),
                "displayTimeUnit": "ms"}

    def to_json(self) -> str:
        return json.dumps(self.chrome_trace(), indent=1, sort_keys=True)

    def write(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    def counters(self) -> Dict[str, float]:
        return {"nk_trace_events_total": float(
            sum(1 for e in self.events if e["ph"] != "M"))}


TRACER: NullTracer = NullTracer()


def get_tracer() -> NullTracer:
    return TRACER


def set_tracer(tracer: Optional[NullTracer]) -> NullTracer:
    """Install ``tracer`` (or the null tracer when None); returns the
    previously installed one so callers can restore it."""
    global TRACER
    prev = TRACER
    TRACER = tracer if tracer is not None else NullTracer()
    return prev


@contextmanager
def trace_to(tracer: Optional[Tracer] = None):
    """Install a recording tracer for the duration of a block::

        with trace_to() as tr:
            replay_scenario("migration", ...)
        tr.write("migration.trace.json")
    """
    tr = tracer if tracer is not None else Tracer()
    prev = set_tracer(tr)
    try:
        yield tr
    finally:
        set_tracer(prev)
