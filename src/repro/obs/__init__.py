"""Observability substrate: metrics registry, span tracer, latency
histograms. Pure stdlib — importable without jax (tools and CI scripts
scrape/validate without touching the data plane)."""
from repro.obs.hist import DEFAULT_BUCKETS, Histogram, TenantHistograms
from repro.obs.metrics import (METRIC_HELP, MetricsRegistry,
                               escape_label_value, format_value,
                               parse_prometheus_text, parse_series_key,
                               render_prometheus, render_series)
from repro.obs.tracing import (TRACER, NullTracer, Tracer, get_tracer,
                               set_tracer, trace_to)
from repro.obs.timeseries import SeriesStore, series_key
from repro.obs.slo import (Alert, AlertEngine, AlertRule, AbsenceRule,
                           AdmitWaitSloRule, BurnRateRule,
                           ConservationDriftRule, FabricWatchdog,
                           JainFloorRule, ParkedLeakRule, SloSpec,
                           ThresholdRule, default_rules,
                           read_scrape_sequence, window_mature)

__all__ = [
    "DEFAULT_BUCKETS", "Histogram", "TenantHistograms",
    "METRIC_HELP", "MetricsRegistry", "escape_label_value", "format_value",
    "parse_prometheus_text", "parse_series_key", "render_prometheus",
    "render_series",
    "TRACER", "NullTracer", "Tracer", "get_tracer", "set_tracer",
    "trace_to",
    "SeriesStore", "series_key",
    "Alert", "AlertEngine", "AlertRule", "AbsenceRule", "AdmitWaitSloRule",
    "BurnRateRule", "ConservationDriftRule", "FabricWatchdog",
    "JainFloorRule", "ParkedLeakRule", "SloSpec", "ThresholdRule",
    "default_rules", "read_scrape_sequence", "window_mature",
]
