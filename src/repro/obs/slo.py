"""The fabric watchdog: SLO burn-rate alerts + a continuous invariant auditor.

PR 6 built the visibility half of the paper's operator story — one
``MetricsRegistry``, spans, latency histograms — but nothing watches those
signals: an operator staring at ``nk_top`` is the bottleneck. This module
closes that loop on top of ``repro.obs.timeseries.SeriesStore``:

  * ``AlertRule`` subclasses evaluate the store and return the label-sets
    currently in violation. Three shapes:

      - ``BurnRateRule`` — Google-SRE multi-window burn-rate alerting: an
        ``SloSpec`` budget plus a FAST and a SLOW window that must *both*
        burn faster than ``burn_threshold`` before the rule fires (fast
        window = reacts quickly + resolves quickly; slow window = immune
        to one-scrape blips). The stock instance is **fairness burn**: no
        tenant may own more than ``objective`` of the fleet's contention
        budget, measured as its share of all deferred scheduler polls —
        the signal that separates a 10x hog from merely-busy tenants
        (per-tenant deferral *fractions* do not: on an oversubscribed
        fabric every well-behaved tenant defers constantly).
      - ``ThresholdRule`` — a computed value crosses a bound.
      - ``AbsenceRule`` — a heartbeat counter stalls while the fabric
        keeps scraping ("engine dark", "telemetry stalled"), gated so a
        deliberately-parked engine is not a dead one.

  * The **invariant auditor** rules re-check the fabric's own CI-gated
    claims continuously, from the scrape alone: aggregate served rate
    must respect the controller's capacity (``ConservationDriftRule``),
    windowed Jain fairness must hold on a healthy fabric
    (``JainFloorRule``), per-tenant admit-wait p99 must stay under SLO
    (``AdmitWaitSloRule``), and a parked engine must not sit on a deep
    backlog (``ParkedLeakRule``).

  * ``AlertEngine`` owns alert lifecycle: a violation fires once, stays
    active while it persists, and resolves when it clears — each
    transition emitted as a tracer instant (``alert.fire`` /
    ``alert.resolve`` with rule+severity+labels args) and counted as
    ``nk_alerts_total{rule,severity}`` / ``nk_alerts_active``.

  * ``FabricWatchdog`` is the cadence: scrape the registry, ingest,
    evaluate — one ``tick(now)``. With ``record=True`` it keeps every
    scrape's exposition text so the whole run can be replayed offline by
    ``tools/nk_watch.py`` (no handle on the live cluster, same contract
    as ``nk_top``).

All default thresholds were set empirically against the replay scenarios:
steady fires **zero** alerts, ``adversarial`` fires fairness burn on the
hog (and only the hog), ``failover`` fires and resolves engine-dark —
pinned as bench claim (k). Stdlib only — importable without jax.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import repro.obs.tracing as tracing
from repro.obs.metrics import Labels
from repro.obs.timeseries import SeriesStore, series_key

SEVERITIES = ("info", "ticket", "page")


@dataclass(frozen=True)
class SloSpec:
    """A service-level objective: ``objective`` is the budget — the
    maximum acceptable bad-fraction (or bad-share) of the signal."""
    name: str
    objective: float
    description: str = ""

    def __post_init__(self):
        if not 0.0 < self.objective <= 1.0:
            raise ValueError("objective must be in (0, 1]")


@dataclass
class Alert:
    """One alert instance: a rule firing for one label-set."""
    rule: str
    severity: str
    labels: Labels
    fired_at: float
    value: float                       # the violating value at fire time
    resolved_at: Optional[float] = None

    @property
    def active(self) -> bool:
        return self.resolved_at is None

    def key(self) -> Tuple[str, Labels]:
        return self.rule, self.labels


class AlertRule:
    """One named check over the store. ``evaluate`` returns every
    label-set currently in violation, mapped to the violating value;
    the ``AlertEngine`` diffs consecutive evaluations into fire/resolve
    transitions."""

    def __init__(self, name: str, severity: str = "ticket"):
        if severity not in SEVERITIES:
            raise ValueError(f"severity must be one of {SEVERITIES}")
        self.name = name
        self.severity = severity

    def evaluate(self, store: SeriesStore,
                 now: float) -> Dict[Labels, float]:
        raise NotImplementedError


class BurnRateRule(AlertRule):
    """Multi-window burn-rate over a share-of-fleet SLO.

    For every value of ``key`` (e.g. each tenant) compute its share of
    the fleet-wide reset-aware increase of ``family`` over the fast and
    the slow window; burn = share / objective. Fire when **both** burns
    exceed ``burn_threshold`` — the SRE discipline that makes the fast
    window safe to page on. ``min_events`` is an absolute floor on the
    fleet's fast-window increase: a handful of deferred polls is noise,
    not a hog. Needs at least two distinct key values (a share of a
    one-tenant fleet is vacuously 1)."""

    def __init__(self, name: str, spec: SloSpec, family: str, *,
                 fast_window_s: float, slow_window_s: float,
                 key: str = "tenant", burn_threshold: float = 1.2,
                 min_events: float = 30.0, severity: str = "page"):
        super().__init__(name, severity)
        if slow_window_s < fast_window_s:
            raise ValueError("slow window must be >= fast window")
        self.spec = spec
        self.family = family
        self.key = key
        self.fast_window_s = float(fast_window_s)
        self.slow_window_s = float(slow_window_s)
        self.burn_threshold = float(burn_threshold)
        self.min_events = float(min_events)

    def _shares(self, store: SeriesStore, now: float,
                window_s: float) -> Tuple[Dict[str, float], float]:
        per: Dict[str, float] = {}
        for s in store.series(self.family):
            lbl = dict(s[1])
            if self.key not in lbl:
                continue
            per[lbl[self.key]] = per.get(lbl[self.key], 0.0) \
                + store.increase(s, window_s, now)
        return per, sum(per.values())

    def burn_rates(self, store: SeriesStore,
                   now: float) -> Dict[str, Tuple[float, float]]:
        """Per-key (fast_burn, slow_burn) — what ``nk_watch`` renders."""
        fast, ftot = self._shares(store, now, self.fast_window_s)
        slow, stot = self._shares(store, now, self.slow_window_s)
        out: Dict[str, Tuple[float, float]] = {}
        for v in sorted(set(fast) | set(slow), key=lambda s: (len(s), s)):
            bf = (fast.get(v, 0.0) / ftot if ftot > 0 else 0.0) \
                / self.spec.objective
            bs = (slow.get(v, 0.0) / stot if stot > 0 else 0.0) \
                / self.spec.objective
            out[v] = (bf, bs)
        return out

    def evaluate(self, store: SeriesStore,
                 now: float) -> Dict[Labels, float]:
        fast, ftot = self._shares(store, now, self.fast_window_s)
        if len(fast) < 2 or ftot < self.min_events:
            return {}
        out: Dict[Labels, float] = {}
        for v, (bf, bs) in self.burn_rates(store, now).items():
            burn = min(bf, bs)
            if burn > self.burn_threshold:
                out[((self.key, v),)] = burn
        return out


class ThresholdRule(AlertRule):
    """The latest sample of one series crosses a bound. The generic
    building block for gauge checks ("engines failed > 0", "active
    alerts > N on a meta-registry")."""

    _OPS = {">": lambda a, b: a > b, ">=": lambda a, b: a >= b,
            "<": lambda a, b: a < b, "<=": lambda a, b: a <= b}

    def __init__(self, name: str, series: Tuple[str, Labels], *,
                 bound: float, op: str = ">", severity: str = "ticket"):
        super().__init__(name, severity)
        if op not in self._OPS:
            raise ValueError(f"op must be one of {sorted(self._OPS)}")
        self.series = series
        self.bound = float(bound)
        self.op = op

    def evaluate(self, store: SeriesStore,
                 now: float) -> Dict[Labels, float]:
        v = store.latest(self.series)
        if v is None or not self._OPS[self.op](v, self.bound):
            return {}
        return {(): v}


class AbsenceRule(AlertRule):
    """A heartbeat counter stalled for a whole window while the fabric
    kept scraping. Fires per labeled series of ``family`` whose
    reset-aware increase over ``window_s`` is zero, provided the window
    holds at least ``min_scrapes`` scrapes (the reference clock did
    advance) and the series has ever been seen. ``gate_family`` (same
    ``key`` label) suppresses a series whose gate currently reads > 0 —
    a *parked* engine legitimately stops stepping; a dark one does not."""

    def __init__(self, name: str, family: str, *, window_s: float,
                 key: Optional[str] = None,
                 gate_family: Optional[str] = None,
                 min_scrapes: int = 3, severity: str = "page"):
        super().__init__(name, severity)
        self.family = family
        self.key = key
        self.window_s = float(window_s)
        self.gate_family = gate_family
        self.min_scrapes = int(min_scrapes)

    def evaluate(self, store: SeriesStore,
                 now: float) -> Dict[Labels, float]:
        in_window = [t for t in store.times()
                     if now - self.window_s <= t <= now]
        if len(in_window) < self.min_scrapes:
            return {}
        out: Dict[Labels, float] = {}
        for s in store.series(self.family):
            lbl = dict(s[1])
            if self.key is not None and self.key not in lbl:
                continue
            pts = store.window(s, self.window_s, now)
            if len(pts) < 2 or store.increase(s, self.window_s, now) > 0:
                continue
            if self.gate_family is not None and self.key is not None:
                gate = store.latest(
                    series_key(self.gate_family,
                               **{self.key: lbl[self.key]}))
                if gate is not None and gate > 0:
                    continue
            labels = ((self.key, lbl[self.key]),) if self.key else ()
            out[labels] = 0.0
        return out


# ---------------------------------------------------------------------------
# The invariant auditor: the fabric's CI-gated claims, re-checked live
# ---------------------------------------------------------------------------


def window_mature(store: SeriesStore, now: float, window_s: float,
                  frac: float = 0.75) -> bool:
    """True once the scrapes inside the window actually span (most of)
    it. Windowed invariants must not judge a half-populated window: the
    first scrape pair after startup sees the token buckets' initial
    burst allowance and reads as a conservation breach, and a two-sample
    Jain is noise — the SRE version of "don't page during deploy"."""
    ts = [t for t in store.times() if now - window_s <= t <= now]
    return len(ts) >= 2 and (ts[-1] - ts[0]) >= frac * window_s


class ConservationDriftRule(AlertRule):
    """Aggregate served rate must respect the controller's capacity.

    The replay's physical engine can run at ``headroom``x capacity — it
    is the token buckets that enforce the budget — so sustained
    aggregate throughput above ``capacity * (1 + tol)`` means rate
    enforcement itself broke. Windowed transients reach ~1.3x on the
    stock scenarios; the default ``tol=0.5`` fires only past the
    physical headroom."""

    def __init__(self, *, window_s: float, tol: float = 0.5,
                 family: str = "nk_served_tokens_total",
                 capacity_series: str = "controller_capacity",
                 severity: str = "page",
                 name: str = "conservation_drift"):
        super().__init__(name, severity)
        self.window_s = float(window_s)
        self.tol = float(tol)
        self.family = family
        self.capacity_series = capacity_series

    def evaluate(self, store: SeriesStore,
                 now: float) -> Dict[Labels, float]:
        if not window_mature(store, now, self.window_s):
            return {}
        cap = store.latest(series_key(self.capacity_series))
        if cap is None or cap <= 0:
            return {}
        total = sum(store.rate(s, self.window_s, now)
                    for s in store.series(self.family))
        if total <= cap * (1.0 + self.tol):
            return {}
        return {(): total / cap}


class JainFloorRule(AlertRule):
    """Windowed Jain fairness over per-tenant served rates must stay
    above ``floor`` — on a *healthy* fabric: any window that saw a
    failed engine is skipped (kill-and-restore legitimately starves the
    dark slot's tenants; that is engine-dark's alert, not this one)."""

    def __init__(self, *, window_s: float, floor: float = 0.5,
                 family: str = "nk_served_tokens_total",
                 gate_series: str = "nk_engines_failed",
                 severity: str = "ticket", name: str = "jain_floor"):
        super().__init__(name, severity)
        self.window_s = float(window_s)
        self.floor = float(floor)
        self.family = family
        self.gate_series = gate_series

    def evaluate(self, store: SeriesStore,
                 now: float) -> Dict[Labels, float]:
        if not window_mature(store, now, self.window_s):
            return {}
        gate = store.window(series_key(self.gate_series),
                            self.window_s, now)
        if any(v > 0 for _, v in gate):
            return {}
        rates = [store.rate(s, self.window_s, now)
                 for s in store.series(self.family)]
        rates = [r for r in rates if r > 0]
        n = len(rates)
        if n < 2:
            return {}
        jain = sum(rates) ** 2 / (n * sum(r * r for r in rates))
        return {} if jain >= self.floor else {(): jain}


class AdmitWaitSloRule(AlertRule):
    """Per-tenant windowed admit-wait p99 (via ``quantile_over_time``
    over the exported ``_bucket`` series) must stay under ``slo_s``."""

    def __init__(self, *, window_s: float, slo_s: float = 8.0,
                 family: str = "nk_admit_wait_seconds",
                 key: str = "tenant", severity: str = "ticket",
                 name: str = "admit_wait_p99"):
        super().__init__(name, severity)
        self.window_s = float(window_s)
        self.slo_s = float(slo_s)
        self.family = family
        self.key = key

    def evaluate(self, store: SeriesStore,
                 now: float) -> Dict[Labels, float]:
        out: Dict[Labels, float] = {}
        for v in store.label_values(self.family + "_bucket", self.key):
            p99 = store.quantile_over_time(
                self.family, 0.99, self.window_s, now, **{self.key: v})
            if p99 is not None and math.isfinite(p99) and p99 > self.slo_s:
                out[((self.key, v),)] = p99
        return out


class ParkedLeakRule(AlertRule):
    """An engine stayed parked for the whole window while the fleet's
    queued backlog never dropped below ``queue_floor`` — the autopilot
    is sitting on capacity the tenants need."""

    def __init__(self, *, window_s: float, queue_floor: float = 16.0,
                 parked_series: str = "nk_cluster_parked",
                 queue_family: str = "nk_queue_depth",
                 severity: str = "ticket",
                 name: str = "parked_engine_leak"):
        super().__init__(name, severity)
        self.window_s = float(window_s)
        self.queue_floor = float(queue_floor)
        self.parked_series = parked_series
        self.queue_family = queue_family

    def evaluate(self, store: SeriesStore,
                 now: float) -> Dict[Labels, float]:
        if not window_mature(store, now, self.window_s):
            return {}
        parked = store.window(series_key(self.parked_series),
                              self.window_s, now)
        if len(parked) < 2 or min(v for _, v in parked) < 1:
            return {}
        depth_at: Dict[float, float] = {}
        for s in store.series(self.queue_family):
            for t, v in store.window(s, self.window_s, now):
                depth_at[t] = depth_at.get(t, 0.0) + v
        if not depth_at:
            return {}
        backlog = min(depth_at.values())
        if backlog < self.queue_floor:
            return {}
        return {(): backlog}


# ---------------------------------------------------------------------------
# Lifecycle: fire / stay active / resolve
# ---------------------------------------------------------------------------


class AlertEngine:
    """Diffs rule evaluations into alert lifecycle transitions.

    A (rule, labels) violation fires once, stays active while every
    subsequent evaluation still reports it, and resolves the first time
    it clears. Transitions are traced (``alert.fire``/``alert.resolve``
    instants on the ``watchdog`` track, guarded by the tracer
    null-object) and exported via ``counters()``."""

    def __init__(self, rules: List[AlertRule], *,
                 track: str = "watchdog"):
        names = [r.name for r in rules]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate rule names in {names}")
        self.rules = list(rules)
        self.track = track
        self.active: Dict[Tuple[str, Labels], Alert] = {}
        self.history: List[Alert] = []
        self.fired: Dict[Tuple[str, str], int] = {}   # (rule, sev) -> n

    def evaluate(self, store: SeriesStore,
                 now: float) -> List[Tuple[str, Alert]]:
        """Run every rule; returns this tick's transitions as
        ``("fire"|"resolve", alert)`` pairs."""
        events: List[Tuple[str, Alert]] = []
        for rule in self.rules:
            viol = rule.evaluate(store, now)
            for labels, value in sorted(viol.items()):
                k = (rule.name, labels)
                if k in self.active:
                    self.active[k].value = value
                    continue
                a = Alert(rule.name, rule.severity, labels, now, value)
                self.active[k] = a
                self.history.append(a)
                self.fired[(rule.name, rule.severity)] = \
                    self.fired.get((rule.name, rule.severity), 0) + 1
                events.append(("fire", a))
                if tracing.TRACER.enabled:
                    tracing.TRACER.instant(
                        self.track, "alert.fire", now, rule=a.rule,
                        severity=a.severity, value=round(value, 4),
                        **dict(labels))
            stale = [k for k in self.active
                     if k[0] == rule.name and k[1] not in viol]
            for k in stale:
                a = self.active.pop(k)
                a.resolved_at = now
                events.append(("resolve", a))
                if tracing.TRACER.enabled:
                    tracing.TRACER.instant(
                        self.track, "alert.resolve", now, rule=a.rule,
                        severity=a.severity, **dict(a.labels))
        return events

    def counters(self) -> Dict[str, float]:
        out = {"nk_alerts_active": float(len(self.active))}
        for (rule, sev), n in sorted(self.fired.items()):
            out[f'nk_alerts_total{{rule="{rule}",severity="{sev}"}}'] = \
                float(n)
        return out


def default_rules(interval_s: float = 1.0, *,
                  objective: float = 0.5,
                  burn_threshold: float = 1.2,
                  min_events: float = 30.0,
                  admit_wait_slo_s: float = 8.0,
                  jain_floor: float = 0.5,
                  conservation_tol: float = 0.5,
                  queue_floor: float = 16.0) -> List[AlertRule]:
    """The stock rule catalog, windows sized in scrape intervals: fast =
    3 intervals, slow = 8. ``objective=0.5`` + ``burn_threshold=1.2``
    means fairness pages once a tenant owns > 60% of the fleet's
    deferred polls on both windows — empirically the steady scenario
    peaks at 0.38 per-tenant share while a 10x hog pins 1.0."""
    fast = 3.0 * interval_s
    slow = 8.0 * interval_s
    return [
        BurnRateRule(
            "fairness_burn",
            SloSpec("tenant_contention_share", objective,
                    "max share of fleet deferred polls one tenant may own"),
            "nk_deferred_polls_total",
            fast_window_s=fast, slow_window_s=slow,
            burn_threshold=burn_threshold, min_events=min_events,
            severity="page"),
        AbsenceRule("engine_dark", "nk_engine_heartbeat_total",
                    key="engine", gate_family="nk_engine_parked",
                    window_s=2.0 * interval_s, min_scrapes=3,
                    severity="page"),
        AbsenceRule("telemetry_stalled", "telemetry_updates_total",
                    key="plane", window_s=3.0 * interval_s,
                    min_scrapes=4, severity="page"),
        ConservationDriftRule(window_s=fast, tol=conservation_tol),
        JainFloorRule(window_s=slow, floor=jain_floor),
        AdmitWaitSloRule(window_s=slow, slo_s=admit_wait_slo_s),
        ParkedLeakRule(window_s=slow, queue_floor=queue_floor),
    ]


# ---------------------------------------------------------------------------
# The watchdog: scrape -> ingest -> evaluate, one cadence
# ---------------------------------------------------------------------------

SCRAPE_HEADER = "# SCRAPE ts="
SCRAPE_EOF = "# EOF"


class FabricWatchdog:
    """Owns the scrape cadence over one ``MetricsRegistry``.

    ``tick(now)`` scrapes the registry, ingests into the store, and runs
    the alert engine; with ``record=True`` every scrape's exposition
    text is kept (prefixed ``# SCRAPE ts=<now>``, terminated ``# EOF``)
    so ``write_scrapes`` can dump the run for offline replay by
    ``tools/nk_watch.py``. The watchdog is itself a metrics provider
    (``nk_watchdog_scrapes_total``, ``nk_watchdog_rules``, the alert
    counters) — register it on a *different* registry than the one it
    scrapes, or read ``counters()`` directly."""

    def __init__(self, registry, rules: Optional[List[AlertRule]] = None,
                 *, store: Optional[SeriesStore] = None,
                 record: bool = False, track: str = "watchdog"):
        self.registry = registry
        self.store = store if store is not None else SeriesStore()
        self.alerts = AlertEngine(
            default_rules() if rules is None else rules, track=track)
        self.recorded: Optional[List[Tuple[float, str]]] = \
            [] if record else None
        self.ticks = 0

    def tick(self, now: float) -> List[Tuple[str, Alert]]:
        """One watchdog cycle; returns the alert transitions it caused."""
        if self.recorded is not None:
            text = self.registry.export_prometheus()
            self.recorded.append((float(now), text))
            self.store.ingest(text, now)
        else:
            # skip the text round-trip on the hot path
            self.store.ingest(self.registry.collect(), now)
        self.ticks += 1
        return self.alerts.evaluate(self.store, now)

    def counters(self) -> Dict[str, float]:
        out = {"nk_watchdog_scrapes_total": float(self.ticks),
               "nk_watchdog_rules": float(len(self.alerts.rules))}
        out.update(self.alerts.counters())
        return out

    # -- offline artifact ---------------------------------------------------
    def scrape_sequence(self) -> str:
        """The recorded run as one text artifact: each scrape prefixed
        by its timestamp header and terminated by ``# EOF``."""
        if self.recorded is None:
            raise ValueError("watchdog was not constructed with record=True")
        chunks = []
        for ts, text in self.recorded:
            body = text if text.endswith("\n") else text + "\n"
            chunks.append(f"{SCRAPE_HEADER}{ts}\n{body}{SCRAPE_EOF}\n")
        return "".join(chunks)

    def write_scrapes(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.scrape_sequence())


def read_scrape_sequence(text: str) -> List[Tuple[float, str]]:
    """Parse a recorded scrape-sequence artifact back into
    ``[(ts, exposition_text), ...]`` — the inverse of
    ``FabricWatchdog.scrape_sequence``. Scrapes missing a timestamp
    header are stamped by position."""
    out: List[Tuple[float, str]] = []
    ts: Optional[float] = None
    lines: List[str] = []
    for line in text.splitlines():
        stripped = line.strip()
        if stripped.startswith(SCRAPE_HEADER):
            ts = float(stripped[len(SCRAPE_HEADER):])
            continue
        if stripped == SCRAPE_EOF:
            if lines:
                out.append((float(len(out)) if ts is None else ts,
                            "\n".join(lines) + "\n"))
            ts, lines = None, []
            continue
        lines.append(line)
    if any(l.strip() for l in lines):      # unterminated final scrape
        out.append((float(len(out)) if ts is None else ts,
                    "\n".join(lines) + "\n"))
    return out
