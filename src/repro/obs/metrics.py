"""MetricsRegistry: one scrape surface for every ``counters()`` provider.

Before this module each exporter (``EngineTelemetry``, ``SchedulerTelemetry``,
``RateController``, ``PlacementController``, ``EngineCluster``) rendered its
own Prometheus text with a bare ``"%.6g"`` formatter — five divergent export
paths, no ``# HELP``/``# TYPE`` lines, no label escaping, and a silent
series collision when two sources emitted the same unlabeled name. Here the
export path exists once:

  * ``MetricsRegistry`` — labeled counters / gauges / histograms plus thin
    adapters over the existing ``counters()`` dicts (keys are already
    ``name{label="v"}`` series strings; the registry parses them back into
    (name, labels) pairs). ``collect()`` REFUSES duplicate series: two
    providers emitting the same name+labels is the bug the
    ``telemetry_updates_total`` plane label fixed, not something to merge
    silently.
  * ``render_prometheus`` — the one spec-compliant text formatter: grouped
    families with ``# HELP``/``# TYPE``, label values escaped per the
    exposition-format rules (``\\``, ``"``, newline), ``+Inf``/``-Inf``/
    ``NaN`` rendered as the spec spells them.
  * ``parse_prometheus_text`` — the inverse, used by ``tools/nk_top.py``
    (render a fabric snapshot from a scrape alone) and
    ``tools/check_metrics.py`` (the CI grammar gate).
  * ``METRIC_HELP`` — the metric-name catalog (also the source of the table
    in ``docs/observability.md``).

Stdlib only — no jax anywhere near the scrape path.
"""
from __future__ import annotations

import functools
import math
import re
from typing import Callable, Dict, Iterable, List, Mapping, Optional, Tuple

Labels = Tuple[Tuple[str, str], ...]
Series = Tuple[str, Labels]

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_NAME_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


# ---------------------------------------------------------------------------
# The metric-name catalog (HELP text + type overrides)
# ---------------------------------------------------------------------------

# family name -> one-line HELP. docs/observability.md renders this table;
# render_prometheus emits these lines. Families not listed get a generic
# HELP so the export stays spec-parseable either way.
METRIC_HELP: Dict[str, str] = {
    "telemetry_updates_total":
        "Telemetry sampling intervals completed, labeled by plane",
    "controller_ticks_total": "RateController control intervals completed",
    "controller_capacity": "Enforced bottleneck capacity (units/s)",
    "controller_push_calls_total": "set_rate/update_tenant_rate calls issued",
    "controller_push_skipped_total": "Delta-mode pushes skipped (unchanged)",
    "nk_control_ticks_total": "Controller tick() calls (incl. baselining)",
    "nk_control_tick_seconds_total":
        "Wall seconds spent inside controller ticks",
    "nk_control_tenants": "Tenant population covered by the last tick",
    "nk_allocated_rate": "Per-tenant allocated rate (units/s)",
    "nk_offered_bytes_total": "Collective bytes offered per tenant and axes",
    "nk_deferred_bytes_total": "Over-rate collective bytes deferred",
    "nk_served_bytes_per_s": "EWMA served collective bytes/s per tenant",
    "nk_served_tokens_total": "Tokens billed to a tenant (prompt + decode)",
    "nk_served_tokens_per_s": "EWMA served tokens/s per tenant",
    "nk_queue_depth": "Unadmitted queued requests per tenant",
    "nk_admitted_requests_total": "Requests admitted per tenant",
    "nk_deferred_polls_total": "Bucket-blocked admission polls per tenant",
    "nk_mean_admit_wait_s": "Mean arrival->admission wait per tenant (s)",
    "nk_cluster_engines": "Engines in the cluster",
    "nk_cluster_steps_total": "Cluster steps taken",
    "nk_migrations_started_total": "Live tenant migrations started",
    "nk_migrations_completed_total": "Live tenant migrations finalized",
    "nk_migrations_draining": "Migrations currently draining on a source",
    "nk_migration_info": "Recent migration records (value = started step)",
    "nk_swaps_total": "Live stack-module hot-swaps, labeled by plane",
    "nk_swap_info": "Recent hot-swap records (value = cluster step)",
    "nk_checkpoints_total": "Fabric checkpoints taken",
    "nk_recoveries_total": "Engine kill-and-restore recoveries completed",
    "nk_engines_failed": "Engines currently failed (dark, awaiting recover)",
    "nk_cluster_parked": "Engines currently parked",
    "nk_parked_engine_steps_total": "Engine-steps skipped while parked",
    "nk_cores_saved": "Average engines parked per cluster step",
    "nk_parked_bytes": "Bytes currently freed by suspended engines",
    "nk_bytes_freed_total": "Cumulative bytes freed by suspend()",
    "nk_mem_saved_bytes": "Average bytes freed per cluster step",
    "nk_resident_cache_bytes": "Droppable buffer bytes currently resident",
    "nk_peak_resident_cache_bytes": "Peak resident droppable buffer bytes",
    "nk_placement": "Tenant -> engine index placement map",
    "nk_engine_load": "Per-engine queued + in-flight requests",
    "nk_engine_parked": "1 if the engine is parked",
    "nk_engine_decode_steps_total": "Decode steps taken per engine",
    "nk_placement_ticks_total": "Placement autopilot ticks",
    "nk_placement_plans_applied_total": "Non-empty placement plans applied",
    "nk_placement_moves_total": "Autopilot migrations applied",
    "nk_placement_moves_skipped_cooldown_total":
        "Moves skipped by the per-tenant cooldown gate",
    "nk_placement_moves_skipped_drain_total":
        "Moves skipped by the drain-cost gate",
    "nk_placement_parks_total": "Engines parked by the autopilot",
    "nk_placement_unparks_total": "Engines unparked by the autopilot",
    "nk_admit_wait_seconds": "Arrival->admission wait per tenant (s)",
    "nk_ttft_seconds": "Arrival->first-token latency per tenant (s)",
    "nk_e2e_seconds": "Arrival->completion latency per tenant (s)",
    "nk_trace_events_total": "Trace events recorded by the active tracer",
    "nk_engine_up": "1 while the engine slot is serving, 0 while failed",
    "nk_engine_heartbeat_total": "Cluster steps the engine actually ran",
    "nk_watchdog_scrapes_total": "Scrapes the watchdog ingested",
    "nk_watchdog_rules": "Alert rules the watchdog evaluates",
    "nk_alerts_total": "Alerts fired, labeled by rule and severity",
    "nk_alerts_active": "Alert instances currently firing",
}

# families whose type can't be inferred from the name alone
_TYPE_OVERRIDES: Dict[str, str] = {}


def metric_family(name: str) -> str:
    """The family a sample name belongs to (histogram samples share one)."""
    for suffix in ("_bucket", "_sum", "_count"):
        if name.endswith(suffix):
            return name[: -len(suffix)]
    return name


def metric_type(name: str, families: Optional[Iterable[str]] = None) -> str:
    """Infer the exposition type for one sample name: ``*_total`` is a
    counter, ``*_bucket``/``*_sum``/``*_count`` belong to a histogram
    family (when the family is known to ``families``), everything else a
    gauge."""
    fam = metric_family(name)
    if name in _TYPE_OVERRIDES:
        return _TYPE_OVERRIDES[name]
    if fam != name and (families is None or fam in families):
        return "histogram"
    if name.endswith("_total"):
        return "counter"
    return "gauge"


# ---------------------------------------------------------------------------
# Escaping / formatting / parsing (the exposition text format)
# ---------------------------------------------------------------------------


def escape_label_value(value: str) -> str:
    """Escape a label value per the text format: backslash, double-quote
    and newline."""
    return (str(value).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def unescape_label_value(value: str) -> str:
    out, i = [], 0
    while i < len(value):
        c = value[i]
        if c == "\\" and i + 1 < len(value):
            nxt = value[i + 1]
            out.append({"n": "\n", "\\": "\\", '"': '"'}.get(nxt, nxt))
            i += 2
        else:
            out.append(c)
            i += 1
    return "".join(out)


def format_value(value: float) -> str:
    """Render a sample value: ``+Inf``/``-Inf``/``NaN`` per the text-format
    rules, plain ``%.10g`` otherwise (round-trips every counter we emit)."""
    v = float(value)
    if math.isnan(v):
        return "NaN"
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return format(v, ".10g")


def parse_value(text: str) -> float:
    t = text.strip()
    if t == "+Inf":
        return math.inf
    if t == "-Inf":
        return -math.inf
    if t == "NaN":
        return math.nan
    return float(t)


@functools.lru_cache(maxsize=8192)
def parse_series_key(key: str) -> Series:
    """Parse one ``counters()``-dict key — ``name`` or
    ``name{k="v",k2="v2"}`` — into ``(name, ((k, v), ...))``. Raises
    ``ValueError`` on anything that wouldn't re-render legally.

    Memoized: the watchdog re-parses the same few hundred series
    strings every scrape, and the result is an immutable tuple."""
    key = key.strip()
    if "{" not in key:
        name, body = key, None
    else:
        if not key.endswith("}"):
            raise ValueError(f"malformed series {key!r}")
        name, body = key.split("{", 1)
        body = body[:-1]
    if not _NAME_RE.match(name):
        raise ValueError(f"illegal metric name {name!r}")
    labels: List[Tuple[str, str]] = []
    if body:
        for lname, lval in _iter_labels(body, context=key):
            labels.append((lname, lval))
    return name, tuple(labels)


def _iter_labels(body: str, *, context: str):
    """Yield (name, unescaped value) pairs from a label body, honoring
    escapes inside quoted values."""
    i, n = 0, len(body)
    while i < n:
        eq = body.find("=", i)
        if eq < 0:
            raise ValueError(f"malformed labels in {context!r}")
        lname = body[i:eq].strip().lstrip(",").strip()
        if not _LABEL_NAME_RE.match(lname):
            raise ValueError(f"illegal label name {lname!r} in {context!r}")
        if eq + 1 >= n or body[eq + 1] != '"':
            raise ValueError(f"unquoted label value in {context!r}")
        j, raw = eq + 2, []
        while j < n:
            c = body[j]
            if c == "\\" and j + 1 < n:
                raw.append(body[j:j + 2])
                j += 2
                continue
            if c == '"':
                break
            raw.append(c)
            j += 1
        else:
            raise ValueError(f"unterminated label value in {context!r}")
        yield lname, unescape_label_value("".join(raw))
        i = j + 1
        if i < n and body[i] == ",":
            i += 1


def render_series(name: str, labels: Labels) -> str:
    if not labels:
        return name
    body = ",".join(f'{k}="{escape_label_value(v)}"' for k, v in labels)
    return f"{name}{{{body}}}"


def render_prometheus(counters: Mapping[str, float],
                      help_text: Optional[Mapping[str, str]] = None) -> str:
    """Spec-compliant text rendering of a flat ``counters()`` dict.

    Samples are grouped into families (histogram ``_bucket``/``_sum``/
    ``_count`` triples fold into one), each family prefixed by ``# HELP``
    and ``# TYPE``, label values escaped, ``+Inf``/``NaN`` rendered per
    the exposition format. Input order within a family is preserved.
    """
    parsed: List[Tuple[Series, float]] = [
        (parse_series_key(k), v) for k, v in counters.items()]
    # histogram families exist where a *_bucket sample carries an `le`
    hist_fams = {
        metric_family(name) for (name, labels), _ in parsed
        if name.endswith("_bucket") and any(k == "le" for k, _ in labels)}
    helps = dict(METRIC_HELP)
    helps.update(help_text or {})
    families: List[str] = []
    grouped: Dict[str, List[Tuple[Series, float]]] = {}
    for (name, labels), v in parsed:
        fam = metric_family(name)
        fam = fam if fam in hist_fams else name
        if fam not in grouped:
            grouped[fam] = []
            families.append(fam)
        grouped[fam].append(((name, labels), v))
    out: List[str] = []
    for fam in families:
        ftype = ("histogram" if fam in hist_fams
                 else metric_type(fam))
        out.append(f"# HELP {fam} "
                   f"{helps.get(fam, 'netkernel-repro metric')}")
        out.append(f"# TYPE {fam} {ftype}")
        for (name, labels), v in grouped[fam]:
            out.append(f"{render_series(name, labels)} {format_value(v)}")
    return "\n".join(out) + "\n" if out else ""


def parse_prometheus_text(text: str) -> Dict[Series, float]:
    """Parse exposition text back into ``{(name, labels): value}`` —
    the scrape-side inverse ``tools/nk_top.py`` renders from and
    ``tools/check_metrics.py`` validates with. Raises ``ValueError`` on
    any line the grammar rejects, including duplicate series.

    Tolerated (OpenMetrics-style output, re-wrapped scrapes): blank
    lines, trailing whitespace (including CRLF line endings), and
    ``# EOF`` / other non-HELP/TYPE comment lines — so a recorded
    watchdog scrape round-trips through render->parse->render."""
    out: Dict[Series, float] = {}
    typed: Dict[str, str] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.rstrip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) >= 3 and parts[1] == "TYPE":
                if len(parts) < 4 or parts[3] not in (
                        "counter", "gauge", "histogram", "summary",
                        "untyped"):
                    raise ValueError(f"line {lineno}: malformed TYPE")
                if parts[2] in typed:
                    raise ValueError(
                        f"line {lineno}: duplicate TYPE for {parts[2]}")
                typed[parts[2]] = parts[3]
            elif len(parts) >= 3 and parts[1] == "HELP":
                pass
            continue
        # sample line: series value [timestamp]
        m = re.match(r"^(\S+?)(\{.*\})?\s+(\S+)(\s+-?\d+)?\s*$", line)
        if not m:
            raise ValueError(f"line {lineno}: malformed sample {line!r}")
        name, body, valtext = m.group(1), m.group(2) or "", m.group(3)
        try:
            series = parse_series_key(name + body)
            value = parse_value(valtext)
        except ValueError as e:
            raise ValueError(f"line {lineno}: {e}") from None
        fam = metric_family(series[0])
        if fam in typed and typed[fam] == "histogram":
            pass      # bucket/sum/count share the family's TYPE
        elif series[0] in typed or fam in typed:
            pass
        if series in out:
            raise ValueError(
                f"line {lineno}: duplicate series "
                f"{render_series(*series)}")
        out[series] = value
    return out


# ---------------------------------------------------------------------------
# The registry
# ---------------------------------------------------------------------------


class _Instrument:
    """One directly-owned metric family with labeled children."""

    def __init__(self, registry: "MetricsRegistry", name: str, kind: str,
                 help_text: str):
        self.registry = registry
        self.name = name
        self.kind = kind
        self.help = help_text
        self.values: Dict[Labels, float] = {}

    def _labels(self, labels: Mapping[str, object]) -> Labels:
        return tuple(sorted((k, str(v)) for k, v in labels.items()))

    def collect(self) -> Dict[Series, float]:
        return {(self.name, lb): v for lb, v in self.values.items()}


class Counter(_Instrument):
    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError("counters only go up")
        lb = self._labels(labels)
        self.values[lb] = self.values.get(lb, 0.0) + amount


class Gauge(_Instrument):
    def set(self, value: float, **labels) -> None:
        self.values[self._labels(labels)] = float(value)


class HistogramVec(_Instrument):
    """Labeled histogram family backed by ``repro.obs.hist.Histogram``."""

    def __init__(self, registry, name, help_text, buckets=None):
        super().__init__(registry, name, "histogram", help_text)
        from repro.obs.hist import DEFAULT_BUCKETS, Histogram
        self._hist_cls = Histogram
        self.buckets = tuple(buckets if buckets is not None
                             else DEFAULT_BUCKETS)
        self.children: Dict[Labels, object] = {}

    def observe(self, value: float, **labels) -> None:
        lb = self._labels(labels)
        h = self.children.get(lb)
        if h is None:
            h = self.children[lb] = self._hist_cls(self.buckets)
        h.observe(value)

    def collect(self) -> Dict[Series, float]:
        out: Dict[Series, float] = {}
        for lb, h in self.children.items():
            for k, v in h.counters(self.name).items():
                name, extra = parse_series_key(k)
                out[(name, tuple(sorted(lb + extra)))] = v
        return out


class MetricsRegistry:
    """Labeled instruments + ``counters()``-provider adapters, one scrape.

    ``register_provider`` adapts any object with a ``counters() ->
    Dict[str, float]`` method (or a bare callable returning such a dict):
    its series are parsed and merged at collect time, so live state is
    always scraped fresh. Duplicate series across providers/instruments
    raise — the regression the ``telemetry_updates_total`` plane label
    exists to prevent.
    """

    def __init__(self):
        self._instruments: Dict[str, _Instrument] = {}
        self._providers: List[Tuple[str, Callable[[], Mapping[str, float]]]]\
            = []
        self._help: Dict[str, str] = {}

    # -- direct instruments -------------------------------------------------
    def _add(self, inst: _Instrument) -> _Instrument:
        if inst.name in self._instruments:
            raise ValueError(f"metric {inst.name!r} already registered")
        if not _NAME_RE.match(inst.name):
            raise ValueError(f"illegal metric name {inst.name!r}")
        self._instruments[inst.name] = inst
        if inst.help:
            self._help[inst.name] = inst.help
        return inst

    def counter(self, name: str, help_text: str = "") -> Counter:
        return self._add(Counter(self, name, "counter", help_text))

    def gauge(self, name: str, help_text: str = "") -> Gauge:
        return self._add(Gauge(self, name, "gauge", help_text))

    def histogram(self, name: str, help_text: str = "",
                  buckets=None) -> HistogramVec:
        return self._add(HistogramVec(self, name, help_text, buckets))

    # -- provider adapters --------------------------------------------------
    def register_provider(self, provider, name: Optional[str] = None):
        """Adapt an existing exporter: anything with ``counters()`` or a
        zero-arg callable returning a flat series dict. Returns self for
        chaining."""
        fn = provider.counters if hasattr(provider, "counters") else provider
        if not callable(fn):
            raise TypeError(f"provider {provider!r} has no counters() and "
                            f"is not callable")
        self._providers.append(
            (name or type(provider).__name__, fn))
        return self

    # -- scrape -------------------------------------------------------------
    def collect(self) -> Dict[Series, float]:
        """Merged series from every instrument and provider. Raises on a
        duplicate series (same name AND labels from two sources)."""
        out: Dict[Series, float] = {}
        origin: Dict[Series, str] = {}
        for inst in self._instruments.values():
            for series, v in inst.collect().items():
                out[series] = v
                origin[series] = f"instrument {inst.name}"
        for pname, fn in self._providers:
            for key, v in fn().items():
                series = parse_series_key(key)
                if series in out:
                    raise ValueError(
                        f"duplicate series {render_series(*series)}: "
                        f"emitted by {origin[series]} and provider "
                        f"{pname} — label one of the sources")
                out[series] = float(v)
                origin[series] = f"provider {pname}"
        return out

    def export_prometheus(self) -> str:
        flat = {render_series(name, labels): v
                for (name, labels), v in self.collect().items()}
        return render_prometheus(flat, self._help)
