"""Deterministic synthetic LM data pipeline with sharded per-host feed.

Production posture: each host materializes only its shard of the global
batch (``host_batch_slice``), generation is a pure function of (seed, step)
so restart/replay after failures is bit-exact (the fault-tolerance tests
rely on this), and batches are placed directly into the train step's input
sharding via ``jax.make_array_from_callback`` — no host gather ever occurs.

The generator is a mixture of Zipfian unigrams and shifted-copy spans, which
gives a learnable (loss-decreasing) signal for the examples and tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    copy_span: int = 8      # learnable structure: token[t] = token[t-span]
    copy_prob: float = 0.7
    with_frames: bool = False
    encoder_seq: int = 0
    d_model: int = 0


def _batch_np(dcfg: DataConfig, step: int, lo: int, hi: int) -> Dict[str, np.ndarray]:
    """Rows [lo, hi) of the global batch for ``step``. Pure in (seed, step)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([dcfg.seed, step, 0x5EED]))
    b = dcfg.global_batch
    zipf = rng.zipf(1.3, size=(b, dcfg.seq_len)).astype(np.int64)
    tokens = (zipf % (dcfg.vocab_size - 1)) + 1
    span = dcfg.copy_span
    copy_mask = rng.random((b, dcfg.seq_len)) < dcfg.copy_prob
    for t in range(span, dcfg.seq_len):
        tokens[:, t] = np.where(copy_mask[:, t], tokens[:, t - span],
                                tokens[:, t])
    tokens = tokens.astype(np.int32)
    labels = np.roll(tokens, -1, axis=1)
    labels[:, -1] = 0
    out = {"tokens": tokens[lo:hi], "labels": labels[lo:hi]}
    if dcfg.with_frames:
        out["frames"] = rng.standard_normal(
            (hi - lo, dcfg.encoder_seq, dcfg.d_model)).astype(np.float32) * 0.05
    return out


class DataPipeline:
    """Sharded, restartable batch source."""

    def __init__(self, dcfg: DataConfig, mesh=None, shardings: Optional[Dict] = None):
        self.dcfg = dcfg
        self.mesh = mesh
        self.shardings = shardings

    def batch_at(self, step: int) -> Dict:
        d = self.dcfg
        if self.shardings is None:
            arrs = _batch_np(d, step, 0, d.global_batch)
            return {k: jnp.asarray(v) for k, v in arrs.items()}
        out = {}
        full = _batch_np(d, step, 0, d.global_batch)
        for k, sh in self.shardings.items():
            v = full[k]

            def cb(index, _v=v):
                return _v[index]

            out[k] = jax.make_array_from_callback(v.shape, sh, cb)
        return out

    def __iter__(self) -> Iterator[Dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def for_model(cfg, shape, mesh=None, shardings=None, seed=0) -> DataPipeline:
    return DataPipeline(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
                   global_batch=shape.global_batch, seed=seed,
                   with_frames=bool(cfg.encoder_layers),
                   encoder_seq=cfg.encoder_seq, d_model=cfg.d_model),
        mesh=mesh, shardings=shardings)
