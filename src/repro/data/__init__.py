from repro.data.pipeline import DataConfig, DataPipeline, for_model

__all__ = ["DataConfig", "DataPipeline", "for_model"]
