"""Block composition: (attention | SSD | hybrid | enc | dec) + (MLP | MoE).

One ``apply_block`` entry point per layer, dispatched on a static ``kind``:

  dense         pre-norm attn + MLP                  (llama/internlm/nemotron/
                                                      granite/chameleon)
  moe           pre-norm attn + MoE (+shared/dense)  (arctic, deepseek body)
  dense_prefix  attn + dense MLP w/ prefix d_ff      (deepseek layer 0)
  ssm           Mamba-2 block only                   (mamba2)
  hybrid        parallel attn+SSD heads, then MLP    (hymba)
  enc           bidirectional attn + MLP             (whisper encoder)
  dec           self-attn + cross-attn + MLP         (whisper decoder)

Attention flavor (GQA vs MLA) is chosen by the config. Caches are dicts whose
schema mirrors the block kind (see ``block_cache_schema``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distribution.sharding import ParamDesc, ShardingCtx
from repro.models import attention as attn_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import attn_schema, gqa_attention, mla_attention, mla_schema
from repro.models.layers import apply_mlp, apply_norm, mlp_schema, norm_schema
from repro.models.moe import apply_moe, moe_schema


def _is_mla(cfg: ModelConfig) -> bool:
    return cfg.mla is not None


def block_schema(cfg: ModelConfig, mesh, kind: str) -> Dict:
    d = cfg.d_model
    nk = cfg.norm
    pd = cfg.param_dtype
    s: Dict = {}
    if kind in ("dense", "moe", "dense_prefix", "enc", "dec", "hybrid"):
        s["ln1"] = norm_schema(d, nk, pd)
        s["attn"] = mla_schema(cfg, mesh) if _is_mla(cfg) else \
            attn_schema(cfg, mesh)
    if kind == "dec":
        s["ln_cross"] = norm_schema(d, nk, pd)
        s["cross"] = attn_schema(cfg, mesh, cross=True)
    if kind == "hybrid":
        s["ssm"] = ssm_mod.ssm_schema(cfg, mesh)
        s["attn_out_norm"] = norm_schema(d, nk, pd)
        s["ssm_out_norm"] = norm_schema(d, nk, pd)
    if kind == "ssm":
        s["ln1"] = norm_schema(d, nk, pd)
        s["ssm"] = ssm_mod.ssm_schema(cfg, mesh)
        return s
    # FFN half
    s["ln2"] = norm_schema(d, nk, pd)
    if kind == "moe":
        s["moe"] = moe_schema(cfg, mesh)
    elif kind == "dense_prefix":
        s["mlp"] = mlp_schema(d, cfg.dense_prefix_ff or cfg.d_ff,
                              cfg.activation, pd)
    else:
        s["mlp"] = mlp_schema(d, cfg.d_ff, cfg.activation, pd)
    return s


def block_cache_schema(cfg: ModelConfig, kind: str, batch: int, seq: int,
                       window: int, dtype: str) -> Dict:
    """Cache descriptors for one layer of this kind. ``seq`` = max positions;
    window layers keep a ring buffer of ``window`` slots."""
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    s: Dict = {}
    if kind in ("dense", "moe", "dense_prefix", "dec", "hybrid"):
        if _is_mla(cfg):
            r = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
            s["lat"] = ParamDesc((batch, seq, r), ("batch", "kv_seq", None),
                                 dtype, "zeros")
        else:
            n = min(seq, window) if window else seq
            dims = ("batch", "kv_seq", "kv_heads", "head_dim")
            s["k"] = ParamDesc((batch, n, kv, hd), dims, dtype, "zeros")
            s["v"] = ParamDesc((batch, n, kv, hd), dims, dtype, "zeros")
    if kind == "dec":
        dims = ("batch", None, "kv_heads", "head_dim")
        s["ck"] = ParamDesc((batch, cfg.encoder_seq, kv, hd), dims, dtype, "zeros")
        s["cv"] = ParamDesc((batch, cfg.encoder_seq, kv, hd), dims, dtype, "zeros")
    if kind in ("ssm", "hybrid"):
        s.update(ssm_mod.ssm_cache_schema(cfg, batch, dtype))
    return s


# ---------------------------------------------------------------------------


def _attn(p, x, cfg, shd, rcfg, **kw):
    if _is_mla(cfg):
        kw.pop("window", None)
        kw.pop("kv_x", None)
        kw.pop("causal", None)
        return mla_attention(p, x, cfg, shd, rcfg, **kw)
    return gqa_attention(p, x, cfg, shd, rcfg, **kw)


def apply_block(p, x, cfg: ModelConfig, shd: ShardingCtx, rcfg, kind: str, *,
                positions=None, window: int = 0, cache: Optional[Dict] = None,
                decode_pos=None, enc_out=None, mode: str = "train"):
    """Returns (x', new_cache_or_None, aux_dict)."""
    aux: Dict = {}
    decode = mode == "decode"
    want_cache = mode in ("prefill", "decode")
    new_cache: Dict = {} if want_cache else None

    if kind == "ssm":
        h = apply_norm(p["ln1"], x, cfg.norm)
        y, c2 = ssm_mod.ssm_block(p["ssm"], h, cfg, shd, rcfg,
                                  cache=cache, decode=decode)
        x = x + y
        return x, c2, aux

    # ---- attention half ----
    if kind in ("dense", "moe", "dense_prefix", "enc", "dec", "hybrid"):
        h = apply_norm(p["ln1"], x, cfg.norm)
        akw: Dict = dict(positions=positions, window=window,
                         causal=(kind != "enc"))
        if decode:
            akw.update(cache={k: cache[k] for k in ("k", "v", "lat")
                              if k in cache} if cache else None,
                       decode_pos=decode_pos)
        if want_cache and not decode:
            res = _attn(p["attn"], h, cfg, shd, rcfg, return_cache=True, **akw)
            a, ac = res
            if new_cache is not None:
                new_cache.update(ac)
        elif decode:
            a, ac = _attn(p["attn"], h, cfg, shd, rcfg, **akw)
            new_cache.update(ac)
        else:
            a = _attn(p["attn"], h, cfg, shd, rcfg, **akw)

        if kind == "hybrid":
            sc = None
            if cache is not None:
                sc = {k: cache[k] for k in
                      ("state", "conv_x", "conv_B", "conv_C")}
            sout, sc2 = ssm_mod.ssm_block(p["ssm"], h, cfg, shd, rcfg,
                                          cache=sc, decode=decode)
            a = 0.5 * (apply_norm(p["attn_out_norm"], a, cfg.norm)
                       + apply_norm(p["ssm_out_norm"], sout, cfg.norm))
            if new_cache is not None and sc2 is not None:
                new_cache.update(sc2)
        x = x + a

    # ---- cross attention (whisper decoder) ----
    if kind == "dec":
        h = apply_norm(p["ln_cross"], x, cfg.norm)
        if mode == "decode":
            c, _ = gqa_attention(p["cross"], h, cfg, shd, rcfg,
                                 positions=positions,
                                 cache={"k": cache["ck"], "v": cache["cv"]},
                                 return_cache=True, cross_decode=True)
            new_cache["ck"], new_cache["cv"] = cache["ck"], cache["cv"]
        elif mode == "prefill":
            c, cc = gqa_attention(p["cross"], h, cfg, shd, rcfg,
                                  positions=positions, kv_x=enc_out,
                                  return_cache=True)
            new_cache["ck"], new_cache["cv"] = cc["k"], cc["v"]
        else:
            c = gqa_attention(p["cross"], h, cfg, shd, rcfg,
                              positions=positions, kv_x=enc_out)
        x = x + c

    # ---- FFN half ----
    h = apply_norm(p["ln2"], x, cfg.norm)
    if kind == "moe":
        y, aux = apply_moe(p["moe"], h, cfg, shd, rcfg)
    else:
        y = apply_mlp(p["mlp"], h, cfg.activation, shd)
    x = x + y
    x = shd.constrain_act(x)
    return x, new_cache, aux
