"""Top-level models: layer schedules, parameter/cache schemas, forwards.

A model is a list of *segments*; each segment is ``count`` layers of one
block kind. Homogeneous segments are scanned (``lax.scan`` over stacked
params — one traced body regardless of depth); heterogeneous layers
(deepseek's dense layer 0, hymba's 3 global-attention layers) break the
stack into segments. Caches mirror the segment structure.

Three entry points per model — ``forward_train``, ``forward_prefill``,
``forward_decode`` (= serve_step's body) — all pure functions of
(params, inputs), jit/pjit-ready.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.distribution.sharding import (
    ParamDesc, ShardingCtx, abstract_params, init_params, param_shardings,
)
from repro.models import blocks as blocks_mod
from repro.models.blocks import apply_block, block_cache_schema, block_schema
from repro.models.layers import (
    apply_norm, embed_schema, embed_tokens, lm_logits, norm_schema,
    sinusoid_positions,
)


@dataclass(frozen=True)
class Segment:
    kind: str
    count: int
    scanned: bool
    window: int = 0       # 0 = full attention


def build_schedule(cfg: ModelConfig) -> Tuple[Segment, ...]:
    if cfg.family == "ssm":
        return (Segment("ssm", cfg.num_layers, True),)
    if cfg.family == "encdec":
        return (Segment("dec", cfg.num_layers, True),)
    if cfg.family == "hybrid":
        segs: List[Segment] = []
        i = 0
        while i < cfg.num_layers:
            if i in cfg.global_attn_layers:
                segs.append(Segment("hybrid", 1, False, window=0))
                i += 1
            else:
                j = i
                while j < cfg.num_layers and j not in cfg.global_attn_layers:
                    j += 1
                segs.append(Segment("hybrid", j - i, True,
                                    window=cfg.attn_window))
                i = j
        return tuple(segs)
    if cfg.moe is not None:
        segs = []
        if cfg.dense_layer_prefix:
            segs.append(Segment("dense_prefix", cfg.dense_layer_prefix, False))
        segs.append(Segment("moe", cfg.num_layers - cfg.dense_layer_prefix, True))
        return tuple(segs)
    return (Segment("dense", cfg.num_layers, True),)


def _stack_schema(schema, count: int):
    return jax.tree.map(
        lambda d: dataclasses.replace(d, shape=(count,) + d.shape,
                                      dims=("layers",) + d.dims),
        schema, is_leaf=lambda x: isinstance(x, ParamDesc))


def model_schema(cfg: ModelConfig, mesh) -> Dict:
    s: Dict = {"embed": embed_schema(cfg.vocab_size, cfg.d_model,
                                     cfg.param_dtype, cfg.tie_embeddings),
               "final_norm": norm_schema(cfg.d_model, cfg.norm, cfg.param_dtype)}
    # every segment's params are stacked over its layers (leading dim =
    # count), scanned or not; unscanned segments index into the stack.
    s["segments"] = tuple(
        _stack_schema(block_schema(cfg, mesh, seg.kind), seg.count)
        for seg in build_schedule(cfg))
    if cfg.encoder_layers:
        enc = {"segments": (_stack_schema(block_schema(cfg, mesh, "enc"),
                                          cfg.encoder_layers),),
               "final_norm": norm_schema(cfg.d_model, cfg.norm, cfg.param_dtype)}
        s["encoder"] = enc
    return s


def cache_schema(cfg: ModelConfig, batch: int, max_seq: int,
                 dtype: str = "bfloat16") -> Tuple:
    segs = []
    for seg in build_schedule(cfg):
        sch = block_cache_schema(cfg, seg.kind, batch, max_seq, seg.window,
                                 dtype)
        segs.append(_stack_schema(sch, seg.count))
    return tuple(segs)


# ---------------------------------------------------------------------------
# Segment runner (scan or unroll)
# ---------------------------------------------------------------------------


def _remat(fn, rcfg):
    if rcfg.remat == "none":
        return fn
    pol = (jax.checkpoint_policies.nothing_saveable if rcfg.remat == "full"
           else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=pol)


def run_segment(seg: Segment, p_seg, x, cfg, shd, rcfg, *, mode,
                positions=None, cache_seg=None, decode_pos=None, enc_out=None):
    """Returns (x, new_cache_seg, aux)."""

    def body(xc, per):
        p_l, c_l = per
        y, c2, aux = apply_block(p_l, xc, cfg, shd, rcfg, seg.kind,
                                 positions=positions, window=seg.window,
                                 cache=c_l, decode_pos=decode_pos,
                                 enc_out=enc_out, mode=mode)
        return y, (c2, aux)

    if seg.scanned and seg.count > 1 and not rcfg.force_unroll_segments:
        x, (caches, auxs) = jax.lax.scan(
            _remat(body, rcfg), x, (p_seg, cache_seg))
        aux = (jax.tree.map(lambda a: jnp.mean(a, axis=0), auxs)
               if auxs else {})
        return x, caches, aux
    # unrolled (heterogeneous or single-layer segments; params still stacked)
    new_caches = []
    aux_acc: Dict = {}
    for i in range(seg.count):
        p_l = jax.tree.map(lambda a: a[i], p_seg)
        c_l = (jax.tree.map(lambda a: a[i], cache_seg)
               if cache_seg is not None else None)
        x, (c2, aux) = _remat(body, rcfg)(x, (p_l, c_l))
        new_caches.append(c2)
        for k2, v2 in (aux or {}).items():
            aux_acc[k2] = aux_acc.get(k2, 0.0) + v2 / seg.count
    nc = (jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
          if new_caches and new_caches[0] else None)
    return x, nc, aux_acc


# ---------------------------------------------------------------------------
# Forwards
# ---------------------------------------------------------------------------


def encode(params, frames, cfg: ModelConfig, shd: ShardingCtx, rcfg):
    """Whisper encoder over stub frame embeddings (B, enc_seq, D)."""
    pos = jnp.arange(frames.shape[1])
    x = frames + sinusoid_positions(pos, cfg.d_model)[None].astype(frames.dtype)
    x = shd.constrain_act(x)
    enc = params["encoder"]
    seg = Segment("enc", cfg.encoder_layers, True)
    x, _, _ = run_segment(seg, enc["segments"][0], x, cfg, shd, rcfg,
                          mode="train", positions=pos)
    return apply_norm(enc["final_norm"], x, cfg.norm)


def _embed_in(params, tokens, cfg, shd):
    x = embed_tokens(params["embed"], tokens, jnp.dtype(cfg.dtype))
    if cfg.family == "encdec":   # sinusoidal decoder positions (stub choice)
        pos = jnp.arange(tokens.shape[1])
        x = x + sinusoid_positions(pos, cfg.d_model)[None].astype(x.dtype)
    return shd.constrain_act(x)


def forward_train(params, batch: Dict, cfg: ModelConfig, shd: ShardingCtx,
                  rcfg: RunConfig):
    """batch: tokens (B,S) [+ frames for encdec]. Returns (logits, aux)."""
    tokens = batch["tokens"]
    x = _embed_in(params, tokens, cfg, shd)
    positions = jnp.arange(tokens.shape[1])
    enc_out = None
    if cfg.encoder_layers:
        enc_out = encode(params, batch["frames"], cfg, shd, rcfg)
    aux_all: Dict = {}
    for seg, p_seg in zip(build_schedule(cfg), params["segments"]):
        x, _, aux = run_segment(seg, p_seg, x, cfg, shd, rcfg, mode="train",
                                positions=positions, enc_out=enc_out)
        for k, v in (aux or {}).items():
            aux_all[k] = aux_all.get(k, 0.0) + v
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = lm_logits(params["embed"], x, shd, cfg.logit_softcap)
    return logits, aux_all


def _to_ring(cache_leaf_kv, window: int, seq: int):
    """Convert full prefill k/v (B,S,...) to ring layout (B,W,...)."""
    w = min(window, seq)
    tail = cache_leaf_kv[:, -w:]
    r = seq % w
    if r:
        tail = jnp.roll(tail, r, axis=1)
    return tail


def forward_prefill(params, tokens, cfg: ModelConfig, shd: ShardingCtx,
                    rcfg: RunConfig, *, max_seq: int, frames=None,
                    cache_dtype: str = "bfloat16"):
    """Full-sequence prefill. Returns (last_logits (B,V), caches)."""
    b, s = tokens.shape
    x = _embed_in(params, tokens, cfg, shd)
    positions = jnp.arange(s)
    enc_out = None
    if cfg.encoder_layers:
        enc_out = encode(params, frames, cfg, shd, rcfg)
    schedule = build_schedule(cfg)
    caches_out = []
    for seg, p_seg in zip(schedule, params["segments"]):
        x, cache_new, _ = run_segment(seg, p_seg, x, cfg, shd, rcfg,
                                      mode="prefill", positions=positions,
                                      enc_out=enc_out,
                                      cache_seg=_prefill_cache_placeholder(
                                          cfg, seg, b, cache_dtype))
        caches_out.append(_finalize_prefill_cache(
            cache_new, cfg, seg, s, max_seq, cache_dtype))
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = lm_logits(params["embed"], x[:, -1:], shd, cfg.logit_softcap)
    return logits[:, 0], tuple(caches_out)


def _prefill_cache_placeholder(cfg, seg: Segment, batch: int, dtype: str):
    """SSM blocks need a cache arg at prefill to emit their streaming state."""
    if seg.kind not in ("ssm", "hybrid"):
        return None
    sch = block_cache_schema(cfg, seg.kind, batch, 1, seg.window, dtype)
    sch = {k: v for k, v in sch.items()
           if k in ("state", "conv_x", "conv_B", "conv_C")}
    one = init_params(_stack_schema(sch, seg.count), jax.random.PRNGKey(0))
    return one


def _finalize_prefill_cache(cache_new, cfg, seg: Segment, s: int,
                            max_seq: int, dtype: str):
    """Pad/convert prefill caches to their decode-time layout.

    All cache leaves are stacked over the segment's layers: seq axis = 2.
    Window segments convert to the ring layout; full-attention/MLA caches
    are zero-padded out to ``max_seq`` decode slots.
    """
    if cache_new is None:
        return None
    out = {}
    for k, v in cache_new.items():
        if k in ("k", "v") and seg.window and seg.window < max_seq:
            out[k] = _to_ring_stacked(v, seg.window, s)
        elif k in ("k", "v", "lat"):
            pad = max_seq - s
            if pad > 0:
                width = [(0, 0)] * v.ndim
                width[2] = (0, pad)
                v = jnp.pad(v, width)
            out[k] = v
        else:
            out[k] = v
    return out


def _to_ring_stacked(v, window, s):
    # v: (L, B, S, ...) stacked over layers -> (L, B, W, ...) ring layout
    w = min(window, s)
    tail = v[:, :, -w:]
    r = s % w
    if r:
        tail = jnp.roll(tail, r, axis=2)
    return tail


def forward_decode(params, caches, tokens, pos, cfg: ModelConfig,
                   shd: ShardingCtx, rcfg: RunConfig):
    """One decode step. tokens: (B,1); pos: (B,). Returns (logits, caches')."""
    x = embed_tokens(params["embed"], tokens, jnp.dtype(cfg.dtype))
    if cfg.family == "encdec":
        x = x + jax.vmap(lambda p: sinusoid_positions(p, cfg.d_model))(
            pos)[:, None].astype(x.dtype)
    x = shd.constrain_act(x)
    new_caches = []
    for seg, p_seg, c_seg in zip(build_schedule(cfg), params["segments"], caches):
        x, c2, _ = run_segment(seg, p_seg, x, cfg, shd, rcfg, mode="decode",
                               positions=pos, cache_seg=c_seg,
                               decode_pos=pos)
        new_caches.append(c2)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = lm_logits(params["embed"], x, shd, cfg.logit_softcap)
    return logits[:, 0], tuple(new_caches)


# ---------------------------------------------------------------------------
# Abstract inputs per (cfg, shape): the dry-run contract
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh=None,
                cache_dtype: str = "bfloat16") -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    out: Dict = {}
    if shape.kind == "train":
        out["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        out["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        if cfg.encoder_layers:
            out["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    elif shape.kind == "prefill":
        out["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        if cfg.encoder_layers:
            out["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    else:  # decode
        out["tokens"] = jax.ShapeDtypeStruct((b, 1), i32)
        out["pos"] = jax.ShapeDtypeStruct((b,), i32)
        out["caches"] = abstract_params(cache_schema(cfg, b, s, cache_dtype))
    return out


def build_params(cfg: ModelConfig, mesh, key=None, abstract=False):
    schema = model_schema(cfg, mesh)
    if abstract:
        return abstract_params(schema)
    return init_params(schema, key if key is not None else jax.random.PRNGKey(0))
