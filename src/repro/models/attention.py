"""Attention: GQA (blockwise training path + one-shot decode), windows, MLA.

Design notes (see DESIGN.md §4):

* **Head padding.** When Q-heads don't divide the model axis (llama 24,
  hymba 25, arctic 56, whisper 12 on a 16-way axis), ``padded_heads`` rounds
  the *parameter* head count up. The attention output is multiplied by a
  constant head mask before the out-projection, which provably zeroes both
  the padded heads' contribution and all gradients into their weights
  (masking at ``o`` kills both directions). Waste is reported honestly by the
  roofline "useful-FLOP ratio".

* **GQA mapping.** KV projections keep the true kv-head count (replicated
  over the model axis when kv < tp). Q-head h reads kv head ``map[h]``; the
  map handles padded heads arbitrarily (they are inert).

* **Training/prefill path** is a triangular blockwise (flash-style) softmax:
  python-unrolled q-block loop, each with a *static* kv-block scan range
  (causal and sliding-window limits are static), online (m, l, acc)
  accumulation, rematerialized body. No S^2 tensor is ever materialized and
  causal/window FLOPs are not wasted on masked-out blocks. On TPU the Pallas
  flash kernel (repro/kernels/flash_attention.py) implements this layout.

* **Decode path** is a one-shot masked softmax against the cache; the cache
  is sharded over the model axis on the *sequence* dim (context-parallel
  decode), so XLA lowers the max/sum reductions into the log-sum-exp
  combine across shards (the explicit shard_map variant lives in
  repro/serve/engine.py for the ring stack).
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.distribution.sharding import ParamDesc, ShardingCtx, padded_heads
from repro.models.layers import apply_norm, apply_rope, f32, norm_schema, rope_tables

NEG_INF = -2.0e30


# ---------------------------------------------------------------------------
# Schemas
# ---------------------------------------------------------------------------


def attn_schema(cfg: ModelConfig, mesh, cross: bool = False) -> Dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    hp = padded_heads(h, mesh) if mesh is not None else h
    s = {
        "wq": ParamDesc((d, hp, hd), ("embed", "heads", "head_dim"), cfg.param_dtype),
        "wk": ParamDesc((d, kv, hd), ("embed", "kv_heads", "head_dim"), cfg.param_dtype),
        "wv": ParamDesc((d, kv, hd), ("embed", "kv_heads", "head_dim"), cfg.param_dtype),
        "wo": ParamDesc((hp, hd, d), ("heads", "head_dim", "embed"), cfg.param_dtype),
    }
    if cfg.qk_norm:
        s["q_norm"] = norm_schema(hd, "rmsnorm", cfg.param_dtype)
        s["k_norm"] = norm_schema(hd, "rmsnorm", cfg.param_dtype)
    return s


def mla_schema(cfg: ModelConfig, mesh) -> Dict:
    mla = cfg.mla
    assert mla is not None
    d, h = cfg.d_model, cfg.num_heads
    hp = padded_heads(h, mesh) if mesh is not None else h
    qk_hd = mla.qk_nope_head_dim + mla.qk_rope_head_dim
    r = mla.kv_lora_rank
    return {
        "wq": ParamDesc((d, hp, qk_hd), ("embed", "heads", "head_dim"), cfg.param_dtype),
        "w_dkv": ParamDesc((d, r + mla.qk_rope_head_dim), ("embed", None), cfg.param_dtype),
        "w_uk": ParamDesc((r, hp, mla.qk_nope_head_dim), (None, "heads", "head_dim"), cfg.param_dtype),
        "w_uv": ParamDesc((r, hp, mla.v_head_dim), (None, "heads", "head_dim"), cfg.param_dtype),
        "wo": ParamDesc((hp, mla.v_head_dim, d), ("heads", "head_dim", "embed"), cfg.param_dtype),
        "kv_norm": norm_schema(r, "rmsnorm", cfg.param_dtype),
    }


def head_mask(num_real: int, num_padded: int, dtype):
    return (jnp.arange(num_padded) < num_real).astype(dtype)


def q_to_kv_map(num_q_real: int, num_q_padded: int, num_kv: int) -> jnp.ndarray:
    """Which kv head each (possibly padded) q head reads."""
    grp = max(num_q_real // max(num_kv, 1), 1)
    m = jnp.minimum(jnp.arange(num_q_padded) // grp, num_kv - 1)
    return m.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention: training / prefill
# ---------------------------------------------------------------------------


def _block_ranges(n_q_blocks: int, n_kv_blocks: int, q_block: int,
                  kv_block: int, causal: bool, window: int):
    """Static (lo, hi) kv-block range per q block."""
    out = []
    for iq in range(n_q_blocks):
        q_lo, q_hi = iq * q_block, (iq + 1) * q_block - 1
        hi = min((q_hi // kv_block), n_kv_blocks - 1) if causal else n_kv_blocks - 1
        lo = 0
        if window:
            lo = max(0, (q_lo - window + 1) // kv_block)
        out.append((lo, hi))
    return out


def blockwise_attention(q, k, v, *, kv_map, causal=True, window=0,
                        q_block=512, kv_block=512, q_offset=0,
                        softmax_scale=None, constrain=None):
    """q: (B,S,HP,hd); k,v: (B,T,KV,hd). Returns (B,S,HP,hd).

    ``kv_map``: (HP,) int map q head -> kv head. ``q_offset``: absolute
    position of q[0] (cross-chunk prefill continuation). ``constrain``:
    optional fn(x, dims) pinning the online-softmax carries to the head
    sharding — fresh zeros carry no sharding and the partitioner otherwise
    keeps the whole (B,H,qb,hd) f32 accumulator data-sharded only.
    """
    b, s_real, hq, hd = q.shape
    t_real = k.shape[1]
    scale = softmax_scale or 1.0 / math.sqrt(hd)
    q_block = min(q_block, s_real)
    kv_block = min(kv_block, t_real)
    # pad to block multiples; padded kv positions are masked out below and
    # padded q rows are sliced away at the end.
    s = -(-s_real // q_block) * q_block
    t = -(-t_real // kv_block) * kv_block
    if s != s_real:
        q = jnp.pad(q, ((0, 0), (0, s - s_real), (0, 0), (0, 0)))
    if t != t_real:
        k = jnp.pad(k, ((0, 0), (0, t - t_real), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, t - t_real), (0, 0), (0, 0)))
    nq, nkv = s // q_block, t // kv_block
    ranges = _block_ranges(nq, nkv, q_block, kv_block, causal, window)

    @functools.partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def kv_step(carry, jblk, qi, q_pos):
        m, l, acc = carry
        # slice k/v in-body: no stacked copies, HBM traffic = one block read
        kj = jax.lax.dynamic_slice_in_dim(k, jblk * kv_block, kv_block, 1)
        vj = jax.lax.dynamic_slice_in_dim(v, jblk * kv_block, kv_block, 1)
        kv_pos = jblk * kv_block + jnp.arange(kv_block)
        kj = jnp.take(kj, kv_map, axis=2)          # (B,kvb,HP,hd) expand GQA
        vj = jnp.take(vj, kv_map, axis=2)
        sres = jnp.einsum("bqhd,bthd->bhqt", qi, kj,
                          preferred_element_type=jnp.float32) * scale
        mask = jnp.broadcast_to(kv_pos[None, :] < t_real,
                                (q_block, kv_block))    # mask kv padding
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if window:
            mask &= (q_pos[:, None] - kv_pos[None, :]) < window
        sres = jnp.where(mask[None, None], sres, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(sres, axis=-1))
        p = jnp.exp(sres - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqt,bthd->bhqd", p.astype(q.dtype), vj,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    outs = []
    for iq, (lo, hi) in enumerate(ranges):
        qi = jax.lax.dynamic_slice_in_dim(q, iq * q_block, q_block, axis=1)
        q_pos = q_offset + iq * q_block + jnp.arange(q_block)
        m0 = jnp.full((b, hq, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hq, q_block), jnp.float32)
        a0 = jnp.zeros((b, hq, q_block, v.shape[-1]), jnp.float32)  # v head dim
        if constrain is not None:
            m0 = constrain(m0, ("batch", "heads", None))
            l0 = constrain(l0, ("batch", "heads", None))
            a0 = constrain(a0, ("batch", "heads", None, None))
        (m, l, acc), _ = jax.lax.scan(
            functools.partial(kv_step, qi=qi, q_pos=q_pos),
            (m0, l0, a0), jnp.arange(lo, hi + 1))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        outs.append(out.swapaxes(1, 2).astype(q.dtype))   # (B,qb,HP,hd)
    return jnp.concatenate(outs, axis=1)[:, :s_real]


def naive_attention(q, k, v, *, kv_map, causal=True, window=0, q_offset=0,
                    softmax_scale=None):
    """Reference O(S^2)-memory attention (oracle for tests; 'naive' impl)."""
    b, s, hq, hd = q.shape
    t = k.shape[1]
    scale = softmax_scale or 1.0 / math.sqrt(hd)
    k = jnp.take(k, kv_map, axis=2)
    v = jnp.take(v, kv_map, axis=2)
    sres = jnp.einsum("bqhd,bthd->bhqt", q, k,
                      preferred_element_type=jnp.float32) * scale
    q_pos = q_offset + jnp.arange(s)
    kv_pos = jnp.arange(t)
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= q_pos[:, None] >= kv_pos[None, :]
    if window:
        mask &= (q_pos[:, None] - kv_pos[None, :]) < window
    sres = jnp.where(mask[None, None], sres, NEG_INF)
    p = jax.nn.softmax(sres, axis=-1)
    o = jnp.einsum("bhqt,bthd->bqhd", p.astype(q.dtype), v)
    return o


def decode_attention(q, k_cache, v_cache, pos, *, kv_map, window=0,
                     softmax_scale=None, kv_pos=None, n_real_heads=None):
    """One-token attention against a cache — context-parallel safe.

    q: (B,1,HP,hd); caches: (B,S,KV,hd); pos: (B,) index of the new token
    (cache already contains it at ``pos``). ``kv_pos`` (B,S) gives the
    absolute position held in each cache slot (ring-buffer windows); default
    is the linear layout arange(S). Negative kv_pos marks empty slots.

    The cache is NEVER expanded over q-heads: a jnp.take over the kv-head
    dim makes the partitioner all-gather the seq-sharded cache (measured
    8.3 GB/chip/step on chameleon decode_32k — EXPERIMENTS §Perf). Unpadded
    GQA uses the grouped einsum; padded head counts use an all-(h,kv)-pairs
    einsum + one-hot select (KVx extra MXU work is negligible in the
    memory-bound decode regime, and the cache stays context-parallel).
    """
    b, _, hq, hd = q.shape
    s = k_cache.shape[1]
    kv = k_cache.shape[2]
    scale = softmax_scale or 1.0 / math.sqrt(hd)
    if kv_pos is None:
        kv_pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    mask = (kv_pos <= pos[:, None]) & (kv_pos >= 0)
    if window:
        mask &= (pos[:, None] - kv_pos) < window
    grouped = (hq % kv == 0) and (n_real_heads is None or n_real_heads == hq)
    if grouped:
        g = hq // kv
        qg = q.reshape(b, 1, kv, g, hd)
        sres = jnp.einsum("bqkgd,btkd->bkgqt", qg, k_cache,
                          preferred_element_type=jnp.float32) * scale
        sres = jnp.where(mask[:, None, None, None, :], sres, NEG_INF)
        p = jax.nn.softmax(sres, axis=-1)
        o = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(q.dtype), v_cache)
        return o.reshape(b, 1, hq, hd)
    # padded/uneven mapping: all-pairs scores + one-hot head->kv selection
    sel = jax.nn.one_hot(kv_map, kv, dtype=jnp.float32)        # (HP, KV)
    s_all = jnp.einsum("bqhd,btkd->bhkt", q, k_cache,
                       preferred_element_type=jnp.float32) * scale
    sres = jnp.einsum("bhkt,hk->bht", s_all, sel)
    sres = jnp.where(mask[:, None, :], sres, NEG_INF)
    p = jax.nn.softmax(sres, axis=-1)                          # (B,HP,S)
    pv = jnp.einsum("bht,btkd->bhkd", p.astype(q.dtype), v_cache)
    o = jnp.einsum("bhkd,hk->bhd", pv.astype(jnp.float32),
                   sel).astype(q.dtype)
    return o[:, None].reshape(b, 1, hq, hd)


# ---------------------------------------------------------------------------
# Full GQA attention block (projections + core + out-proj)
# ---------------------------------------------------------------------------


def decode_attention_cp(q, k_c, v_c, pos, *, kv_map, window, n_real_heads,
                        shd, scale=None):
    """Context-parallel flash-decode: shard_map over the model axis.

    Each model shard holds a contiguous seq chunk of the cache, computes its
    local masked partial softmax (scores never leave VMEM-sized chunks) and
    the shards LSE-combine with three tiny psums — the comm pattern the
    Pallas decode kernel's (o, m, l) outputs feed on real TPUs. This removes
    the full-cache f32 score pipeline the one-shot GSPMD path materializes
    (measured 1.5 TB/chip/step HBM traffic on chameleon decode_32k).
    """
    mesh = shd.mesh
    b, _, hq, hd = q.shape
    s = k_c.shape[1]
    tp = shd.axis_sizes.get("model", 1)
    if mesh is None or tp == 1 or s % tp != 0:
        o = decode_attention(q.astype(k_c.dtype), k_c, v_c, pos,
                             kv_map=kv_map, window=window,
                             n_real_heads=n_real_heads, softmax_scale=scale)
        return o
    chunk = s // tp
    scale = scale or 1.0 / math.sqrt(hd)

    def local(qf, kl, vl, posf):
        idx = jax.lax.axis_index("model")
        off = idx * chunk
        lg = jnp.einsum("bqhd,btkd->bhkt", qf, kl,
                        preferred_element_type=jnp.float32)[:, :, :, :] * scale
        sel = jax.nn.one_hot(kv_map, kl.shape[2], dtype=jnp.float32)
        sres = jnp.einsum("bhkt,hk->bht", lg, sel)
        t_pos = off + jnp.arange(chunk)[None, :]
        mask = t_pos <= posf[:, None]
        if window:
            mask &= (posf[:, None] - t_pos) < window
        sres = jnp.where(mask[:, None, :], sres, NEG_INF)
        m = jnp.max(sres, axis=-1)                          # (B,H)
        pr = jnp.exp(sres - m[..., None])
        l = jnp.sum(pr, axis=-1)
        pv = jnp.einsum("bht,btkd->bhkd", pr.astype(qf.dtype), vl)
        o = jnp.einsum("bhkd,hk->bhd", pv.astype(jnp.float32), sel)
        m_all = jax.lax.pmax(m, "model")
        w = jnp.exp(m - m_all) * l
        wsum = jax.lax.psum(w, "model")
        o = jax.lax.psum(o * jnp.exp(m - m_all)[..., None], "model")
        return (o / jnp.maximum(wsum, 1e-30)[..., None]).astype(qf.dtype)

    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    o = shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(None, "model"), P(None, "model"), P()),
        out_specs=P(), axis_names={"model"}, check_vma=False,
    )(q, k_c, v_c, pos)
    return o[:, None] if o.ndim == 3 else o


def gqa_attention(p, x, cfg: ModelConfig, shd: ShardingCtx, rcfg, *,
                  positions, kv_x=None, causal=True, window=0,
                  cache: Optional[Dict] = None, decode_pos=None,
                  return_cache=False, cross_decode=False):
    """Unified GQA attention.

    Training/prefill: ``positions`` is (S,) or (B,S); returns (out[, cache]).
    Decode: pass ``cache`` + ``decode_pos`` (B,); x is (B,1,D).
    Cross-attention: ``kv_x`` is the encoder output (prefill/train);
    ``cross_decode`` reads the cached encoder k/v without updating.
    """
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    hp = p["wq"].shape[1]
    kv_map = q_to_kv_map(h, hp, kv)
    mask = head_mask(h, hp, x.dtype)

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qk_norm:
        q = apply_norm(p["q_norm"], q, "rmsnorm")
    use_rope = cfg.rope_theta > 0 and kv_x is None and not cross_decode

    if cross_decode:
        # cross-attention decode: cache holds encoder k/v; nothing to update
        k_c, v_c = cache["k"].astype(x.dtype), cache["v"].astype(x.dtype)
        o = decode_attention(q, k_c, v_c,
                             jnp.full((x.shape[0],), k_c.shape[1] - 1,
                                      jnp.int32),
                             kv_map=kv_map, n_real_heads=h)
        o = o * mask[None, None, :, None]
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        return (out, cache) if return_cache else out

    src = kv_x if kv_x is not None else x
    knew = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    vnew = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if cfg.qk_norm:
        knew = apply_norm(p["k_norm"], knew, "rmsnorm")

    if cache is None or decode_pos is None:
        # ---- training / prefill / encoder ----
        if use_rope:
            cos, sin = rope_tables(positions, hd, cfg.rope_theta)
            q = apply_rope(q, cos, sin)
            knew = apply_rope(knew, cos, sin)
        # rope's rotate-half concat loses the head sharding; without this
        # constraint the partitioner replicates attention internals over the
        # model axis (measured: +25 GB/chip on nemotron train_4k)
        q = shd.constrain(q, ("batch", None, "heads", None))
        knew = shd.constrain(knew, ("batch", None, "kv_heads", None))
        o = blockwise_attention(
            q, knew, vnew, kv_map=kv_map, causal=causal, window=window,
            q_block=rcfg.attn_q_block, kv_block=rcfg.attn_kv_block,
            constrain=shd.constrain if shd.mesh is not None else None) \
            if rcfg.attention_impl != "naive" else \
            naive_attention(q, knew, vnew, kv_map=kv_map, causal=causal,
                            window=window)
        o = o * mask[None, None, :, None]
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        if return_cache:
            return out, {"k": knew, "v": vnew}
        return out

    # ---- self-attention decode ----
    b = x.shape[0]
    if use_rope:
        cos, sin = rope_tables(decode_pos[:, None], hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        knew = apply_rope(knew, cos, sin)
    n_slots = cache["k"].shape[1]
    ring = bool(window) and n_slots <= window       # ring-buffer window cache
    if ring:
        slot = decode_pos % n_slots
        # absolute position held in each slot after the write
        j = jnp.arange(n_slots)[None, :]
        kv_pos = decode_pos[:, None] - ((decode_pos[:, None] - j) % n_slots)
    else:
        slot = decode_pos
        kv_pos = None
    # one-hot masked update, NOT a scatter: scattering at a traced per-row
    # index on the model-sharded seq dim makes the partitioner all-gather
    # the whole cache every step (measured 8.3 GB/chip on chameleon
    # decode_32k — EXPERIMENTS §Perf). The masked select is elementwise and
    # stays context-parallel.
    wmask = (jnp.arange(n_slots)[None, :] == slot[:, None])[..., None, None]
    k_c = jnp.where(wmask, knew[:, 0][:, None].astype(cache["k"].dtype),
                    cache["k"])
    v_c = jnp.where(wmask, vnew[:, 0][:, None].astype(cache["v"].dtype),
                    cache["v"])
    if not ring:
        # linear cache: context-parallel flash-decode over the model axis
        o = decode_attention_cp(q, k_c.astype(x.dtype), v_c.astype(x.dtype),
                                decode_pos, kv_map=kv_map, window=window,
                                n_real_heads=h, shd=shd)
    else:
        o = decode_attention(q, k_c.astype(x.dtype), v_c.astype(x.dtype),
                             decode_pos, kv_map=kv_map, window=window,
                             kv_pos=kv_pos, n_real_heads=h)
    o = o * mask[None, None, :, None]
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, {"k": k_c, "v": v_c}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): latent KV cache + absorbed-weight decode
# ---------------------------------------------------------------------------


def mla_attention(p, x, cfg: ModelConfig, shd: ShardingCtx, rcfg, *,
                  positions, cache=None, decode_pos=None, return_cache=False):
    mla = cfg.mla
    h = cfg.num_heads
    hp = p["wq"].shape[1]
    mask = head_mask(h, hp, x.dtype)
    nope, rope_d, r = mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.kv_lora_rank
    scale = 1.0 / math.sqrt(nope + rope_d)

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]

    dkv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    c_kv_new, k_pe_new = dkv[..., :r], dkv[..., r:]
    c_kv_new = apply_norm(p["kv_norm"], c_kv_new, "rmsnorm")

    if cache is None or decode_pos is None:
        # ---- train / prefill: explicit k, v ----
        cos, sin = rope_tables(positions, rope_d, cfg.rope_theta)
        q_rope = apply_rope(q_rope, cos, sin)
        k_pe = apply_rope(k_pe_new[:, :, None, :], cos, sin)   # (B,S,1,rope)
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv_new, p["w_uk"])
        v = jnp.einsum("bsr,rhk->bshk", c_kv_new, p["w_uv"])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_pe, k_nope.shape[:3] + (rope_d,))], -1)
        qq = jnp.concatenate([q_nope, q_rope], -1)
        # concat of head-sharded k_nope with head-replicated k_pe loses the
        # head sharding — without these constraints the partitioner
        # replicates q/k/v over the model axis (measured: +57 GB/chip).
        bhd = ("batch", None, "heads", None)
        k = shd.constrain(k, bhd)
        qq = shd.constrain(qq, bhd)
        v = shd.constrain(v, bhd)
        kv_map = jnp.arange(hp, dtype=jnp.int32)
        o = blockwise_attention(
            qq, k, v, kv_map=kv_map, causal=True,
            q_block=rcfg.attn_q_block, kv_block=rcfg.attn_kv_block,
            softmax_scale=scale,
            constrain=shd.constrain if shd.mesh is not None else None)
        o = o * mask[None, None, :, None]
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        if return_cache:
            lat = jnp.concatenate([c_kv_new, k_pe[:, :, 0, :]], -1)  # (B,S,R+rope)
            return out, {"lat": lat}
        return out

    # ---- decode: absorbed form against the latent cache ----
    b = x.shape[0]
    cos, sin = rope_tables(decode_pos[:, None], rope_d, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_pe = apply_rope(k_pe_new[:, :, None, :], cos, sin)[:, :, 0, :]
    new_lat = jnp.concatenate([c_kv_new[:, 0], k_pe[:, 0]], -1)
    # masked update (not scatter) — keeps the latent cache context-parallel
    wmask = (jnp.arange(cache["lat"].shape[1])[None, :]
             == decode_pos[:, None])[..., None]
    lat = jnp.where(wmask, new_lat[:, None].astype(cache["lat"].dtype),
                    cache["lat"])
    latx = lat.astype(x.dtype)
    c_c, pe_c = latx[..., :r], latx[..., r:]
    # scores: q_nope absorbed through w_uk  +  decoupled rope channel
    q_lat = jnp.einsum("bqhk,rhk->bqhr", q_nope, p["w_uk"])
    s_lat = jnp.einsum("bqhr,btr->bhqt", q_lat, c_c,
                       preferred_element_type=jnp.float32)
    s_pe = jnp.einsum("bqhk,btk->bhqt", q_rope, pe_c,
                      preferred_element_type=jnp.float32)
    sres = (s_lat + s_pe) * scale
    t_pos = jnp.arange(lat.shape[1])[None, :]
    valid = t_pos <= decode_pos[:, None]
    sres = jnp.where(valid[:, None, None, :], sres, NEG_INF)
    pr = jax.nn.softmax(sres, axis=-1)
    o_lat = jnp.einsum("bhqt,btr->bqhr", pr.astype(x.dtype), c_c)
    o = jnp.einsum("bqhr,rhk->bqhk", o_lat, p["w_uv"])
    o = o * mask[None, None, :, None]
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, {"lat": lat}
