"""Mamba-2 SSD (state-space duality) block: chunked train path + O(1) decode.

Follows the SSD algorithm of Dao & Gu 2024 (arXiv:2405.21060): quadratic
attention-like compute *within* chunks, linear state recurrence *across*
chunks. The intra-chunk part is the compute hot spot targeted by the Pallas
kernel (repro/kernels/ssd_scan.py); this module is the production JAX path
and the oracle's substrate.

Layout: x (B, L, H, P) heads; B/C (B, L, N) single group; dt (B, L, H).
State: (B, H, P, N).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.distribution.sharding import ParamDesc, ShardingCtx
from repro.models.layers import apply_norm, f32, norm_schema


def ssm_schema(cfg: ModelConfig, mesh) -> Dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.num_heads(d)
    n = s.state_dim
    w = s.conv_width
    pd = cfg.param_dtype
    return {
        "w_x": ParamDesc((d, di), ("embed", "ffn"), pd),
        "w_z": ParamDesc((d, di), ("embed", "ffn"), pd),
        "w_B": ParamDesc((d, n), ("embed", None), pd),
        "w_C": ParamDesc((d, n), ("embed", None), pd),
        "w_dt": ParamDesc((d, nh), ("embed", "ssm_heads"), pd),
        "w_out": ParamDesc((di, d), ("ffn", "embed"), pd),
        "conv_x": ParamDesc((w, di), ("conv", "ffn"), pd, "small_normal", 0.5),
        "conv_B": ParamDesc((w, n), ("conv", None), pd, "small_normal", 0.5),
        "conv_C": ParamDesc((w, n), ("conv", None), pd, "small_normal", 0.5),
        "A_log": ParamDesc((nh,), ("ssm_heads",), "float32", "zeros"),
        "D": ParamDesc((nh,), ("ssm_heads",), "float32", "ones"),
        "dt_bias": ParamDesc((nh,), ("ssm_heads",), "float32", "zeros"),
        "norm": norm_schema(di, "rmsnorm", pd),
    }


# ---------------------------------------------------------------------------
# Causal depthwise conv (width W), train + streaming forms
# ---------------------------------------------------------------------------


def causal_conv(u: jax.Array, w: jax.Array) -> jax.Array:
    """u: (B, L, C); w: (W, C) depthwise. Causal: y[t] = sum_j w[j]*u[t-W+1+j]."""
    W = w.shape[0]
    y = u * w[-1]
    for j in range(W - 1):
        shift = W - 1 - j
        y = y + jnp.pad(u, ((0, 0), (shift, 0), (0, 0)))[:, :-shift] * w[j]
    return y


def conv_step(u_t: jax.Array, state: jax.Array, w: jax.Array):
    """u_t: (B, C); state: (B, W-1, C) past inputs. Returns (y_t, state')."""
    full = jnp.concatenate([state, u_t[:, None]], axis=1)    # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", full, w)
    return y, full[:, 1:]


# ---------------------------------------------------------------------------
# SSD core (chunked)
# ---------------------------------------------------------------------------


def _segsum(x: jax.Array) -> jax.Array:
    """x: (..., T). Returns (..., T, T): sum_{k=j+1..i} x[k] on i>=j, -inf above."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    ii = jnp.arange(T)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xdt, dA, B, C, chunk: int,
                initial_state: Optional[jax.Array] = None):
    """SSD scan. xdt: (b,l,h,p) = x*dt; dA: (b,l,h) = dt*A (negative);
    B, C: (b,l,n). Returns (y (b,l,h,p), final_state (b,h,p,n))."""
    b, l_real, h, p = xdt.shape
    n = B.shape[-1]
    # pad to a chunk multiple: trailing zeros in xdt and dA=0 (decay exp(0)=1)
    # leave the recurrence and final state untouched; outputs are sliced.
    l = -(-l_real // chunk) * chunk
    if l != l_real:
        pad = ((0, 0), (0, l - l_real))
        xdt = jnp.pad(xdt, pad + ((0, 0), (0, 0)))
        dA = jnp.pad(dA, pad + ((0, 0),))
        B = jnp.pad(B, pad + ((0, 0),))
        C = jnp.pad(C, pad + ((0, 0),))
    nc = l // chunk
    xc = xdt.reshape(b, nc, chunk, h, p)
    dAc = dA.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)   # (b,h,c,Q)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)

    A_cum = jnp.cumsum(dAc, axis=-1)                           # (b,h,c,Q)
    L = jnp.exp(_segsum(dAc))                                  # (b,h,c,Q,Q)

    # --- intra-chunk (quadratic, attention-like) ---
    G = jnp.einsum("bcln,bcsn->bcls", Cc, Bc,
                   preferred_element_type=jnp.float32)         # (b,c,Q,Q)
    M = G[:, None] * L                                         # (b,h,c,Q,Q)? no:
    # G is (b,c,Q,Q); L is (b,h,c,Q,Q) -> broadcast over h
    M = jnp.einsum("bcls,bhcls->bhcls", G, L)
    y_diag = jnp.einsum("bhcls,bcshp->bclhp", M.astype(xdt.dtype), xc,
                        preferred_element_type=jnp.float32)

    # --- chunk states ---
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)            # (b,h,c,Q)
    xd = jnp.einsum("bhcl,bclhp->bclhp", decay_states.astype(xdt.dtype), xc)
    states = jnp.einsum("bcln,bclhp->bchpn", Bc, xd,
                        preferred_element_type=jnp.float32)    # (b,c,h,p,n)

    # --- inter-chunk recurrence (linear scan over chunks) ---
    chunk_decay = jnp.exp(A_cum[..., -1])                      # (b,h,c)
    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
          else f32(initial_state))

    def step(carry, xs):
        st, dec = xs                                           # (b,h,p,n),(b,h)
        new = carry * dec[..., None, None] + st
        return new, carry                                      # emit entering state

    sc = states.transpose(1, 0, 2, 3, 4)                       # (c,b,h,p,n)
    dc = chunk_decay.transpose(2, 0, 1)                        # (c,b,h)
    final_state, entering = jax.lax.scan(step, s0, (sc, dc))
    entering = entering.transpose(1, 0, 2, 3, 4)               # (b,c,h,p,n)

    # --- inter-chunk output ---
    state_decay = jnp.exp(A_cum)                               # (b,h,c,Q)
    y_off = jnp.einsum("bcln,bchpn->bclhp", Cc,
                       entering.astype(xdt.dtype),
                       preferred_element_type=jnp.float32)
    y_off = y_off * state_decay.transpose(0, 2, 3, 1)[..., None]
    y = (y_diag + y_off).reshape(b, l, h, p)[:, :l_real]
    return y, final_state


def ssd_decode_step(x_t, dt_t, A, B_t, C_t, state):
    """One-token SSD update. x_t: (b,h,p); dt_t: (b,h); A: (h,) negative;
    B_t, C_t: (b,n); state: (b,h,p,n). Returns (y (b,h,p), state')."""
    dA = jnp.exp(f32(dt_t) * A)                                # (b,h)
    xdt = f32(x_t) * f32(dt_t)[..., None]
    upd = jnp.einsum("bhp,bn->bhpn", xdt, f32(B_t))
    state = f32(state) * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, f32(C_t))
    return y.astype(x_t.dtype), state


# ---------------------------------------------------------------------------
# Full block (projections + conv + SSD + gate + out)
# ---------------------------------------------------------------------------


def ssm_block(p, x, cfg: ModelConfig, shd: ShardingCtx, rcfg, *,
              cache: Optional[Dict] = None, decode: bool = False):
    """x: (B,L,D) (train) or (B,1,D) (decode). Returns (y, cache')."""
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.num_heads(d)
    hp = s.head_dim
    A = -jnp.exp(f32(p["A_log"]))

    z = jnp.einsum("bld,de->ble", x, p["w_z"])
    xs = jnp.einsum("bld,de->ble", x, p["w_x"])
    Bs = jnp.einsum("bld,dn->bln", x, p["w_B"])
    Cs = jnp.einsum("bld,dn->bln", x, p["w_C"])
    dts = jnp.einsum("bld,dh->blh", x, p["w_dt"])
    dt = jax.nn.softplus(f32(dts) + f32(p["dt_bias"]))

    if not decode:
        xs_pre, Bs_pre, Cs_pre = xs, Bs, Cs      # pre-conv streams (cache tails)
        xs = jax.nn.silu(f32(causal_conv(xs, p["conv_x"]))).astype(x.dtype)
        Bs = jax.nn.silu(f32(causal_conv(Bs, p["conv_B"]))).astype(x.dtype)
        Cs = jax.nn.silu(f32(causal_conv(Cs, p["conv_C"]))).astype(x.dtype)
        xh = xs.reshape(*xs.shape[:2], nh, hp)
        xdt = (f32(xh) * dt[..., None]).astype(x.dtype)
        dA = dt * A
        y, state = ssd_chunked(xdt, dA, Bs, Cs, s.chunk)
        yD = y + f32(xh) * f32(p["D"])[None, None, :, None]
        yflat = yD.reshape(*yD.shape[:2], di).astype(x.dtype)
        gated = yflat * jax.nn.silu(f32(z)).astype(x.dtype)
        out = jnp.einsum("ble,ed->bld", apply_norm(p["norm"], gated, "rmsnorm"),
                         p["w_out"])
        new_cache = None
        if cache is not None:
            # preload conv tails (pre-conv streams) for streaming continuation
            w = s.conv_width
            new_cache = {
                "state": state.astype(cache["state"].dtype),
                "conv_x": xs_pre[:, -(w - 1):].astype(cache["conv_x"].dtype),
                "conv_B": Bs_pre[:, -(w - 1):].astype(cache["conv_B"].dtype),
                "conv_C": Cs_pre[:, -(w - 1):].astype(cache["conv_C"].dtype),
            }
        return out, new_cache

    # ---- decode ----
    assert cache is not None
    xc, cx = conv_step(xs[:, 0], cache["conv_x"].astype(x.dtype), p["conv_x"])
    Bc, cB = conv_step(Bs[:, 0], cache["conv_B"].astype(x.dtype), p["conv_B"])
    Cc, cC = conv_step(Cs[:, 0], cache["conv_C"].astype(x.dtype), p["conv_C"])
    xc = jax.nn.silu(f32(xc)).astype(x.dtype)
    Bc = jax.nn.silu(f32(Bc)).astype(x.dtype)
    Cc = jax.nn.silu(f32(Cc)).astype(x.dtype)
    xh = xc.reshape(-1, nh, hp)
    y, state = ssd_decode_step(xh, dt[:, 0], A, Bc, Cc,
                               f32(cache["state"]))
    y = y + f32(xh).astype(x.dtype) * f32(p["D"])[None, :, None].astype(x.dtype)
    yflat = y.reshape(-1, 1, di)
    gated = yflat * jax.nn.silu(f32(z)).astype(x.dtype)
    out = jnp.einsum("ble,ed->bld", apply_norm(p["norm"], gated, "rmsnorm"),
                     p["w_out"])
    new_cache = {"state": state.astype(cache["state"].dtype),
                 "conv_x": cx.astype(cache["conv_x"].dtype),
                 "conv_B": cB.astype(cache["conv_B"].dtype),
                 "conv_C": cC.astype(cache["conv_C"].dtype)}
    return out, new_cache


def ssm_cache_schema(cfg: ModelConfig, batch: int, dtype: str) -> Dict:
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.num_heads(cfg.d_model)
    w = s.conv_width
    return {
        "state": ParamDesc((batch, nh, s.head_dim, s.state_dim),
                           ("batch", "ssm_heads", None, None), "float32", "zeros"),
        "conv_x": ParamDesc((batch, w - 1, di), ("batch", None, "ffn"), dtype, "zeros"),
        "conv_B": ParamDesc((batch, w - 1, s.state_dim), ("batch", None, None), dtype, "zeros"),
        "conv_C": ParamDesc((batch, w - 1, s.state_dim), ("batch", None, None), dtype, "zeros"),
    }
