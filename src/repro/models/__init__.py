from repro.models.model import (
    Segment, build_params, build_schedule, cache_schema, forward_decode,
    forward_prefill, forward_train, input_specs, model_schema,
)

__all__ = [
    "Segment", "build_params", "build_schedule", "cache_schema",
    "forward_decode", "forward_prefill", "forward_train", "input_specs",
    "model_schema",
]
