"""Shared neural layers: norms, MLPs, rotary embeddings, vocab heads.

All parameters are declared as ``ParamDesc`` schemas with *logical* dims;
the sharding layer maps them onto whatever mesh the operator provides
(divisibility-aware). Compute is bf16 with f32 reductions.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distribution.sharding import ParamDesc, ShardingCtx


def f32(x):
    return x.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_schema(d: int, kind: str, dtype: str):
    if kind == "layernorm":
        return {"scale": ParamDesc((d,), ("none",), dtype, "ones"),
                "bias": ParamDesc((d,), ("none",), dtype, "zeros")}
    return {"scale": ParamDesc((d,), ("none",), dtype, "ones")}


def apply_norm(p, x, kind: str, eps: float = 1e-5):
    xf = f32(x)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * f32(p["scale"]) + f32(p["bias"])
    else:  # rmsnorm
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * f32(p["scale"])
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (gated / squared-relu / gelu)
# ---------------------------------------------------------------------------


def mlp_schema(d: int, ff: int, activation: str, dtype: str):
    s = {"w_in": ParamDesc((d, ff), ("embed", "ffn"), dtype),
         "w_out": ParamDesc((ff, d), ("ffn", "embed"), dtype)}
    if activation == "silu_glu":
        s["w_gate"] = ParamDesc((d, ff), ("embed", "ffn"), dtype)
    return s


def apply_mlp(p, x, activation: str, shd: Optional[ShardingCtx] = None):
    h = jnp.einsum("...d,df->...f", x, p["w_in"])
    if activation == "silu_glu":
        g = jnp.einsum("...d,df->...f", x, p["w_gate"])
        h = jax.nn.silu(f32(g)).astype(x.dtype) * h
    elif activation == "relu2":
        h = jnp.square(jax.nn.relu(f32(h))).astype(x.dtype)
    else:  # gelu
        h = jax.nn.gelu(f32(h)).astype(x.dtype)
    if shd is not None:
        h = shd.constrain(h, ("batch",) + (None,) * (h.ndim - 2) + ("ffn",))
    return jnp.einsum("...f,fd->...d", h, p["w_out"])


# ---------------------------------------------------------------------------
# Rotary position embedding (rotate-half convention)
# ---------------------------------------------------------------------------


def rope_tables(positions: jax.Array, head_dim: int, theta: float):
    """positions: (...,) int -> (cos, sin) of shape positions.shape+(head_dim,)."""
    half = head_dim // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freq
    cos = jnp.cos(angles)
    sin = jnp.sin(angles)
    return cos, sin


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array):
    """x: (..., heads, head_dim); cos/sin: broadcastable (..., head_dim//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = jnp.expand_dims(cos, -2)   # broadcast over heads
    s = jnp.expand_dims(sin, -2)
    y1 = f32(x1) * c - f32(x2) * s
    y2 = f32(x2) * c + f32(x1) * s
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def sinusoid_positions(positions: jax.Array, d_model: int):
    """Sinusoidal absolute position embedding (whisper-style stub)."""
    half = d_model // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def embed_schema(vocab: int, d: int, dtype: str, tie: bool):
    s = {"tokens": ParamDesc((vocab, d), ("vocab", "embed"), dtype,
                             init_scale=1.0)}
    if not tie:
        s["head"] = ParamDesc((vocab, d), ("vocab", "embed"), dtype)
    return s


def embed_tokens(p, tokens: jax.Array, dtype):
    return jnp.take(p["tokens"], tokens, axis=0).astype(dtype)


def lm_logits(p, x: jax.Array, shd: Optional[ShardingCtx] = None,
              softcap: float = 0.0):
    w = p.get("head", p["tokens"])
    logits = jnp.einsum("...d,vd->...v", x, w)
    if shd is not None:
        logits = shd.constrain(
            logits, ("batch",) + (None,) * (logits.ndim - 2) + ("vocab",))
    if softcap:
        logits = jnp.tanh(f32(logits) / softcap) * softcap
    return logits
