"""Mixture-of-Experts: top-k routing with sort-based capacity dispatch (EP).

Dispatch is the sort/gather formulation (no (T,E,C) one-hot tensor is ever
materialized, which would be infeasible at prefill_32k scale):

  1. top-k per token, flatten (T*k) assignments,
  2. stable-sort by expert; position-in-expert via cumulative counts,
  3. drop overflow beyond capacity C = ceil(T*k/E * cf),
  4. gather to (E, C, D) — experts sharded over the model axis (EP), so
     this gather IS the dispatch communication (XLA lowers it to the
     all-to-all / gather pattern the roofline's collective term reports),
  5. batched expert GEMMs, weighted scatter-add back.

Supports DeepSeek-V2 shared experts (always-on dense branch of size
num_shared*shared_ff) and Arctic's parallel dense-residual branch.
Aux losses: switch load-balance + router z-loss.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distribution.sharding import ParamDesc, ShardingCtx
from repro.models.layers import apply_mlp, f32, mlp_schema


def moe_schema(cfg: ModelConfig, mesh) -> Dict:
    m = cfg.moe
    d = cfg.d_model
    pd = cfg.param_dtype
    glu = cfg.activation == "silu_glu"
    s: Dict = {
        "router": ParamDesc((d, m.num_experts), ("embed", "experts"), "float32"),
        "w_in": ParamDesc((m.num_experts, d, m.expert_ff),
                          ("experts", "embed", None), pd),
        "w_out": ParamDesc((m.num_experts, m.expert_ff, d),
                           ("experts", None, "embed"), pd),
    }
    if glu:
        s["w_gate"] = ParamDesc((m.num_experts, d, m.expert_ff),
                                ("experts", "embed", None), pd)
    if m.num_shared_experts:
        ff = m.num_shared_experts * (m.shared_ff or m.expert_ff)
        s["shared"] = mlp_schema(d, ff, cfg.activation, pd)
    if m.parallel_dense:
        s["dense"] = mlp_schema(d, cfg.d_ff, cfg.activation, pd)
    return s


def _capacity(tokens: int, m) -> int:
    c = int(tokens * m.top_k * m.capacity_factor / m.num_experts) + 1
    return max(8, min(c, tokens)) if tokens >= 8 else max(1, min(c, tokens))


def route_topk(router_w, x_flat, m) -> Tuple[jax.Array, jax.Array, Dict]:
    """Returns (gate_weights (T,k), expert_idx (T,k), aux metrics)."""
    logits = jnp.einsum("td,de->te", f32(x_flat), f32(router_w))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, m.top_k)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)
    # switch-style load-balance loss + router z-loss
    T, E = probs.shape
    frac = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(1.0) / (T * m.top_k)
    imp = jnp.mean(probs, axis=0)
    lb_loss = E * jnp.sum(frac * imp)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss,
           "moe_max_frac": jnp.max(frac)}
    return gate, eidx, aux


def _dispatch_tables(eidx_g, gate_g, E: int, C: int, T_g: int, k: int):
    """Per-group dispatch: token/weight tables (E*C,) + inverse slots (T_g*k,).

    Sort-based: stable-sort assignments by expert, position-in-expert via
    cumulative counts, truncate at capacity. All shapes are group-local.
    """
    e_flat = eidx_g.reshape(-1)                                 # (T_g*k,)
    tok_flat = jnp.arange(T_g * k, dtype=jnp.int32) // k
    w_flat = gate_g.reshape(-1)
    order = jnp.argsort(e_flat, stable=True)
    e_sorted = e_flat[order]
    counts = jnp.zeros((E,), jnp.int32).at[e_flat].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(T_g * k, dtype=jnp.int32) - starts[e_sorted]
    keep = pos_in_e < C
    slot_sorted = jnp.where(keep, e_sorted * C + pos_in_e, E * C)
    table = jnp.full((E * C + 1,), T_g, jnp.int32).at[slot_sorted].set(
        jnp.where(keep, tok_flat[order], T_g))[:-1]             # (E*C,)
    # inverse map: assignment j -> its slot (E*C = dropped)
    inv = jnp.argsort(order, stable=True)                       # j -> sorted pos
    slot_of = slot_sorted[inv]                                  # (T_g*k,)
    drop = jnp.sum(1.0 - keep.astype(jnp.float32)) / (T_g * k)
    return table, slot_of, w_flat, drop


def apply_moe(p, x, cfg: ModelConfig, shd: ShardingCtx, rcfg) -> Tuple[jax.Array, Dict]:
    """x: (B, S, D) -> (y, aux).

    GShard-style grouped dispatch: tokens are split into G groups (G = data
    axis size), each group routes/sorts/truncates locally, so every
    intermediate carries a leading group dim sharded over 'data' and an
    expert dim sharded over 'model' — nothing is ever replicated. Capacity
    is enforced per group (standard practice).
    """
    m = cfg.moe
    b, s, d = x.shape
    T = b * s
    G = max(shd.axis_sizes.get("data", 1), 1) if shd.mesh is not None else 1
    while T % G:
        G //= 2
    T_g = T // G
    k, E = m.top_k, m.num_experts
    C = _capacity(T_g, m)

    xf = x.reshape(T, d)
    gate, eidx, aux = route_topk(p["router"], xf, m)
    xg = xf.reshape(G, T_g, d)
    gate_g = gate.reshape(G, T_g, k)
    eidx_g = eidx.reshape(G, T_g, k)

    table, slot_of, w_flat, drop = jax.vmap(
        lambda e, w: _dispatch_tables(e, w, E, C, T_g, k))(eidx_g, gate_g)
    # NOTE: dropped slots use clamped indices + masks, never a padding row —
    # a +1 row on a sharded dim makes it unshardable and the partitioner
    # would replicate the whole (G, E*C, d) dispatch buffer on every chip.
    egc = ("expert_group", "experts", None, None)
    xe = jnp.take_along_axis(xg, jnp.minimum(table, T_g - 1)[..., None],
                             axis=1)                            # (G, E*C, d)
    xe = xe * (table < T_g)[..., None].astype(xe.dtype)
    xe = shd.constrain(xe.reshape(G, E, C, d), egc)

    h = jnp.einsum("gecd,edf->gecf", xe, p["w_in"])
    if cfg.activation == "silu_glu":
        g = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])
        h = jax.nn.silu(f32(g)).astype(x.dtype) * h
    elif cfg.activation == "relu2":
        h = jnp.square(jax.nn.relu(f32(h))).astype(x.dtype)
    else:
        h = jax.nn.gelu(f32(h)).astype(x.dtype)
    h = shd.constrain(h, egc)
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_out"])
    ye = shd.constrain(ye, egc)

    # combine: inverse gather (per group), weighted sum over k assignments
    yflat = shd.constrain(ye.reshape(G, E * C, d),
                          ("expert_group", "experts", None))
    picked = jnp.take_along_axis(
        yflat, jnp.minimum(slot_of, E * C - 1)[..., None], axis=1)
    picked = picked * (slot_of < E * C)[..., None].astype(yflat.dtype)
    picked = picked.reshape(G, T_g, k, d)
    y = jnp.sum(f32(picked) * w_flat.reshape(G, T_g, k)[..., None], axis=2)
    y = y.astype(x.dtype).reshape(b, s, d)

    if m.num_shared_experts:
        y = y + apply_mlp(p["shared"], x, cfg.activation, shd)
    if m.parallel_dense:
        y = y + apply_mlp(p["dense"], x, cfg.activation, shd)
    aux["moe_drop_frac"] = jnp.mean(drop)
    return y, aux
