"""deepseek-v2-236b [moe]: MLA + fine-grained MoE (arXiv:2405.04434).

60L d_model=5120 128H (MLA: kv_lora=512, rope 64, nope 128, v 128)
expert d_ff=1536, vocab=102400; 2 shared + 160 routed experts, top-6.
Layer 0 uses a dense FFN (d_ff 12288) per the published config.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,          # MLA: latent cache; kv head count == q heads
    d_ff=1536,
    vocab_size=102400,
    head_dim=128,              # v head dim; qk dims come from MLAConfig
    activation="silu_glu",
    norm="rmsnorm",
    rope_theta=10000.0,
    tie_embeddings=False,
    moe=MoEConfig(
        num_experts=160,
        top_k=6,
        expert_ff=1536,
        num_shared_experts=2,
        shared_ff=1536,
    ),
    dense_layer_prefix=1,
    dense_prefix_ff=12288,
)
