"""Architecture + shape registry.

``get_config(name)`` returns the exact assigned configuration;
``get_smoke_config(name)`` returns the family-preserving reduced config used
by CPU smoke tests. ``iter_cells()`` yields every (arch x shape) cell with
its applicability verdict.
"""
from __future__ import annotations

from typing import Iterator, Tuple

from repro.configs.base import (
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RunConfig,
    ShapeConfig,
    SHAPES,
    SSMConfig,
    reduce_for_smoke,
    shape_applicable,
)

from repro.configs.chameleon_34b import CONFIG as _chameleon
from repro.configs.whisper_small import CONFIG as _whisper
from repro.configs.arctic_480b import CONFIG as _arctic
from repro.configs.deepseek_v2_236b import CONFIG as _deepseek
from repro.configs.mamba2_370m import CONFIG as _mamba2
from repro.configs.llama3_2_3b import CONFIG as _llama
from repro.configs.internlm2_1_8b import CONFIG as _internlm2
from repro.configs.nemotron_4_340b import CONFIG as _nemotron
from repro.configs.granite_8b import CONFIG as _granite
from repro.configs.hymba_1_5b import CONFIG as _hymba

ARCHS = {
    c.name: c
    for c in [
        _chameleon, _whisper, _arctic, _deepseek, _mamba2,
        _llama, _internlm2, _nemotron, _granite, _hymba,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_smoke_config(name: str) -> ModelConfig:
    return reduce_for_smoke(get_config(name))


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


def iter_cells() -> Iterator[Tuple[ModelConfig, ShapeConfig, bool, str]]:
    """All 40 (arch x shape) cells: (cfg, shape, applicable, skip_reason)."""
    for arch in ARCHS.values():
        for shape in SHAPES.values():
            ok, why = shape_applicable(arch, shape)
            yield arch, shape, ok, why


__all__ = [
    "ARCHS", "SHAPES", "ModelConfig", "MoEConfig", "SSMConfig", "MLAConfig",
    "RunConfig", "ShapeConfig", "get_config", "get_smoke_config", "get_shape",
    "iter_cells", "reduce_for_smoke", "shape_applicable",
]
