"""mamba2-370m [ssm]: state-space duality, attention-free (arXiv:2405.21060).

48L d_model=1024 d_ff=0 vocab=50280, ssm_state=128. d_inner = 2*1024 = 2048,
head_dim 64 => 32 SSD heads per layer. No attention, no MLP (Mamba-2 blocks
only). Runs long_500k (constant-size state decode).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    head_dim=64,
    norm="rmsnorm",
    tie_embeddings=True,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4, chunk=256),
)
