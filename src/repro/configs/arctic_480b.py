"""arctic-480b [moe]: Snowflake Arctic dense-MoE hybrid (hf:Snowflake/snowflake-arctic-base).

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128 experts top-2
computed IN PARALLEL with a dense residual FFN branch (Arctic's design).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,                # dense residual branch hidden dim
    vocab_size=32000,
    activation="silu_glu",
    norm="rmsnorm",
    rope_theta=10000.0,
    tie_embeddings=False,
    moe=MoEConfig(
        num_experts=128,
        top_k=2,
        expert_ff=4864,
        parallel_dense=True,
    ),
)
