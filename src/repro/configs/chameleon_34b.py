"""chameleon-34b [vlm]: early-fusion mixed-modal decoder (arXiv:2405.09818).

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536. The 65536-entry
vocabulary includes the VQ image tokens; the modality frontend is a stub
(token ids in, per the assignment). Chameleon uses qk-layernorm for stability.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    activation="silu_glu",
    norm="rmsnorm",
    rope_theta=10000.0,
    qk_norm=True,
    tie_embeddings=False,
    frontend="token",
)
