"""whisper-small [audio]: encoder-decoder ASR backbone (arXiv:2212.04356).

12L (x2: encoder+decoder) d_model=768 12H (kv=12) d_ff=3072 vocab=51865.
The conv frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (batch, 1500, 768).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    num_layers=12,            # decoder layers
    encoder_layers=12,
    encoder_seq=1500,         # mel frames after conv stem (stubbed)
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    activation="gelu",
    norm="layernorm",
    rope_theta=0.0,           # whisper uses learned/sinusoidal positions
    tie_embeddings=True,
    frontend="audio_stub",
)
