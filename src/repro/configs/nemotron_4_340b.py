"""nemotron-4-340b [dense]: GQA + squared-ReLU FFN (arXiv:2402.16819).

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.
The memory-pressure arch: requires ZeRO-3 + bf16 moments + remat at 256 chips.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    head_dim=192,
    activation="relu2",        # squared ReLU
    norm="layernorm",
    rope_theta=10000.0,
    tie_embeddings=False,
)
