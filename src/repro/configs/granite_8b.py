"""granite-8b [dense]: IBM Granite code model, llama-arch (arXiv:2405.04324).

36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
    head_dim=128,
    activation="silu_glu",
    norm="rmsnorm",
    rope_theta=10000000.0,
    tie_embeddings=True,
)
