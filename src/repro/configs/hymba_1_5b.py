"""hymba-1.5b [hybrid]: parallel attention + mamba heads (arXiv:2411.13676).

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Sliding-window attention (1024) everywhere except 3 global layers
{0, mid, last}; the SSM path runs in parallel with attention in every block
(outputs mean-combined after per-path normalization). Meta-tokens omitted
(orthogonal to the comm-stack study; noted in DESIGN.md).
Runs long_500k (window + constant SSM state).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    activation="silu_glu",
    norm="rmsnorm",
    rope_theta=10000.0,
    tie_embeddings=True,
    ssm=SSMConfig(state_dim=16, head_dim=64, expand=2, conv_width=4, chunk=128),
    attn_window=1024,
    global_attn_layers=(0, 15, 31),
)
