"""Config system: architecture + shape + run configuration.

Every assigned architecture is a frozen ``ModelConfig`` built in its own
module (``repro/configs/<arch>.py``) with the exact published dimensions.
Shapes (seq_len x global_batch cells) live here; the registry in
``repro/configs/__init__.py`` exposes ``get_config(name)`` / ``get_shape``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""

    num_experts: int
    top_k: int
    expert_ff: int
    num_shared_experts: int = 0      # deepseek-v2: 2 shared experts
    shared_ff: int = 0               # hidden dim of each shared expert
    capacity_factor: float = 1.25
    router_zloss: float = 1e-3
    # dense residual branch computed in parallel with the MoE branch (arctic)
    parallel_dense: bool = False


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block configuration."""

    state_dim: int            # N
    head_dim: int = 64        # P
    expand: int = 2           # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 256          # SSD chunk length
    dt_min: float = 0.001
    dt_max: float = 0.1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0          # 0 => full-rank q projection
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0                # 0 => d_model // num_heads
    activation: str = "silu_glu"     # silu_glu | relu2 | gelu
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    rope_theta: float = 500000.0
    qk_norm: bool = False            # chameleon uses qk layernorm
    tie_embeddings: bool = True
    logit_softcap: float = 0.0

    # --- MoE ---
    moe: Optional[MoEConfig] = None
    # layer indices that use a plain dense FFN instead of MoE (deepseek: (0,))
    dense_layer_prefix: int = 0
    dense_prefix_ff: int = 0         # d_ff of the dense prefix layers

    # --- SSM / hybrid ---
    ssm: Optional[SSMConfig] = None
    # hybrid (hymba): per-layer attention windows; layers listed here use
    # full/global attention, all others use sliding-window attention.
    attn_window: int = 0             # 0 => full causal attention
    global_attn_layers: Tuple[int, ...] = ()

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0             # fixed source length (1500 audio frames)
    frontend: str = "none"           # none | audio_stub | token

    # --- numerics ---
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))

    # --- derived quantities -------------------------------------------------
    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k cell (per assignment instructions)."""
        return self.family in ("ssm", "hybrid")

    @property
    def mla(self) -> Optional[MLAConfig]:
        return MLA_BY_NAME.get(self.name)

    def attn_params_per_layer(self) -> int:
        d, h, kv, hd = self.d_model, self.num_heads, self.num_kv_heads, self.head_dim
        mla = self.mla
        if mla is not None:
            qk_hd = mla.qk_nope_head_dim + mla.qk_rope_head_dim
            p = d * h * qk_hd                                   # q proj
            p += d * (mla.kv_lora_rank + mla.qk_rope_head_dim)  # down proj
            p += mla.kv_lora_rank * h * (mla.qk_nope_head_dim + mla.v_head_dim)
            p += h * mla.v_head_dim * d                         # out proj
            return p
        return d * h * hd + 2 * d * kv * hd + h * hd * d

    def ffn_params_per_layer(self) -> int:
        if self.moe is not None:
            m = self.moe
            e = m.num_experts * self._expert_ffn(m.expert_ff)
            e += m.num_shared_experts * self._expert_ffn(m.shared_ff or m.expert_ff)
            e += self.d_model * m.num_experts                    # router
            if m.parallel_dense:
                e += self._expert_ffn(self.d_ff)
            return e
        if self.d_ff == 0:
            return 0
        return self._expert_ffn(self.d_ff)

    def _expert_ffn(self, ff: int) -> int:
        mult = 3 if self.activation == "silu_glu" else 2
        return mult * self.d_model * ff

    def ssm_params_per_layer(self) -> int:
        if self.ssm is None:
            return 0
        s = self.ssm
        di = s.d_inner(self.d_model)
        nh = s.num_heads(self.d_model)
        # in_proj produces (x, z, B, C, dt); out_proj back to d_model
        p = self.d_model * (2 * di + 2 * s.state_dim + nh)
        p += di * self.d_model
        p += s.conv_width * (di + 2 * s.state_dim)   # depthwise conv
        p += 2 * nh                                   # A_log, D
        return p

    def ffn_active_params_per_layer(self) -> int:
        if self.moe is None:
            return self.ffn_params_per_layer()
        m = self.moe
        a = m.top_k * self._expert_ffn(m.expert_ff)
        a += m.num_shared_experts * self._expert_ffn(m.shared_ff or m.expert_ff)
        a += self.d_model * m.num_experts
        if m.parallel_dense:
            a += self._expert_ffn(self.d_ff)
        return a

    def _layer_params(self, active: bool) -> int:
        ffn = self.ffn_active_params_per_layer() if active else self.ffn_params_per_layer()
        if self.family == "ssm":
            return self.ssm_params_per_layer() + 2 * self.d_model
        per = ffn + 2 * self.d_model
        if self.family == "hybrid":
            per += self.attn_params_per_layer() + self.ssm_params_per_layer()
        else:
            per += self.attn_params_per_layer()
        return per

    def num_params(self) -> int:
        """Total parameter count (analytic)."""
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        body = 0
        for i in range(self.num_layers):
            if self.moe is not None and i < self.dense_layer_prefix:
                dense = ModelConfig(
                    name="_tmp", family="dense", num_layers=1, d_model=self.d_model,
                    num_heads=self.num_heads, num_kv_heads=self.num_kv_heads,
                    d_ff=self.dense_prefix_ff or self.d_ff, vocab_size=1,
                    activation=self.activation)
                body += dense._layer_params(False) + self.attn_params_per_layer() - dense.attn_params_per_layer()
                continue
            body += self._layer_params(False)
        n += body + self.d_model
        if self.encoder_layers:
            enc_layer = self.attn_params_per_layer() + self._expert_ffn(self.d_ff) + 2 * self.d_model
            cross = self.attn_params_per_layer()
            n += self.encoder_layers * enc_layer + self.num_layers * cross
        return n

    def num_active_params(self) -> int:
        """Active parameters per token (= num_params for non-MoE)."""
        if self.moe is None:
            return self.num_params()
        n = self.num_params()
        n -= self.num_layers_moe() * (self.ffn_params_per_layer() - self.ffn_active_params_per_layer())
        return n

    def num_layers_moe(self) -> int:
        return 0 if self.moe is None else self.num_layers - self.dense_layer_prefix


# MLA is attached per-arch here (keeps ModelConfig generic/flat).
MLA_BY_NAME = {
    "deepseek-v2-236b": MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                                  qk_nope_head_dim=128, qk_rope_head_dim=64,
                                  v_head_dim=128),
    "deepseek-v2-smoke": MLAConfig(kv_lora_rank=32, q_lora_rank=0,
                                   qk_nope_head_dim=16, qk_rope_head_dim=8,
                                   v_head_dim=16),
}


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell is runnable, and why not if skipped."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k requires sub-quadratic attention (skip per assignment)"
    return True, ""


# ---------------------------------------------------------------------------
# Run config (training/serving knobs; the operator-owned side)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RunConfig:
    """Operator-owned knobs: parallelism, NSM policy, numerics, FT."""

    # parallelism
    multi_pod: bool = False
    fsdp: bool = True                       # shard params/opt over 'data'
    seq_parallel_activations: bool = False  # Megatron-SP between blocks
    pipeline_stages: int = 1                # >1: GPipe over 'pod'
    grad_accum: int = 1

    # NetKernel stack policy (the paper's contribution surface)
    nsm_policy: str = "xla"       # xla | ring | hierarchical | compressed | shm-first
    explicit_pod_sync: bool = False  # route cross-pod grad sync through CoreEngine
    # track the int8 error-feedback residual of the gradients each step
    # (metrics["ef_residual_max"]) — the measured signal an EF-aware
    # numerics tolerance derives from (see test_nsm_conformance.py)
    track_ef_residual: bool = False

    # numerics / memory
    remat: str = "full"           # full | dots | none
    rules_variant: str = "2d"     # 2d (FSDP+TP) | fsdp (pure FSDP over mesh)
    grad_accum_dtype: str = "float32"   # float32 | bfloat16 (>=300B models)
    factored_nu: bool = False     # Adafactor-style second moment (>=300B)
    # roofline probes: unroll scanned segments so XLA cost_analysis (which
    # counts a while body once) attributes per-layer cost exactly
    force_unroll_segments: bool = False
    moment_dtype: str = "float32"  # float32 | bfloat16 (>=100B models)
    kv_cache_dtype: str = "bfloat16"  # bfloat16 | int8
    attention_impl: str = "chunked"   # chunked | naive | pallas
    attn_q_block: int = 512
    attn_kv_block: int = 512

    # optimizer
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    z_loss: float = 1e-4

    # fault tolerance
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
    async_checkpoint: bool = True
    straggler_factor: float = 3.0


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Family-preserving reduction for CPU smoke tests (tiny dims)."""
    kw: dict = dict(
        name=cfg.name + "-smoke",
        num_layers=min(cfg.num_layers, 2 + (cfg.dense_layer_prefix or 0)),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=256,
        head_dim=16,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2), expert_ff=64,
            shared_ff=64 if cfg.moe.num_shared_experts else 0)
        kw["dense_prefix_ff"] = 128 if cfg.dense_layer_prefix else 0
        if cfg.dense_layer_prefix:
            kw["num_layers"] = max(kw["num_layers"], cfg.dense_layer_prefix + 2)
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, state_dim=16, head_dim=16, chunk=32)
    if cfg.global_attn_layers:
        kw["global_attn_layers"] = (0,)
        kw["attn_window"] = 32
    if cfg.encoder_layers:
        kw["encoder_layers"] = 2
        kw["encoder_seq"] = 24
    if cfg.name == "deepseek-v2-236b":
        kw["name"] = "deepseek-v2-smoke"   # picks up the smoke MLA config
    return dataclasses.replace(cfg, **kw)
