"""The ``nk_*`` API — the BSD-socket boundary of NetKernel-JAX.

Model and training code calls these functions (inside ``shard_map`` bodies)
and never names a collective implementation. A CoreEngine — owned by the
operator, configured per tenant — resolves each call to an NSM at trace
time, exactly as GuestLib redirects ``send()`` to whichever NSM the operator
attached. Swapping stacks (use case 3) is a config change; model code is
untouched.

When no engine is installed the native stack is used, so the API degrades to
plain ``jax.lax`` semantics.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

from repro.core.engine import CoreEngine
from repro.core.nsm import get_nsm
from repro.core.nqe import FLAG_GRADIENT, FLAG_SERVING

_state = threading.local()


def _current() -> Optional[CoreEngine]:
    return getattr(_state, "engine", None)


@contextlib.contextmanager
def use_engine(engine: CoreEngine):
    """Install a CoreEngine for nk_* calls traced within this context."""
    prev = _current()
    _state.engine = engine
    try:
        yield engine
    finally:
        _state.engine = prev


def current_engine() -> Optional[CoreEngine]:
    return _current()


def _axes_tuple(axes) -> Tuple[str, ...]:
    return (axes,) if isinstance(axes, str) else tuple(axes)


def _dispatch(verb, x, axes, *, tenant_id=0, flags=0, op_data=0, **kw):
    axes = _axes_tuple(axes)
    eng = _current()
    if eng is None:
        import jax
        nsm = get_nsm("xla")
        sizes = {a: 0 for a in axes}   # XlaNsm never reads sizes
        fn = getattr(nsm, verb)
        return fn(x, axes, axis_sizes=sizes, **kw)
    return eng.dispatch(verb, x, axes, tenant_id=tenant_id, flags=flags,
                        op_data=op_data, **kw)


def nk_psum(x, axes, *, tenant_id=0, gradient=False, serving=False, op_data=0):
    flags = (FLAG_GRADIENT if gradient else 0) | (FLAG_SERVING if serving else 0)
    return _dispatch("psum", x, axes, tenant_id=tenant_id, flags=flags,
                     op_data=op_data)


def nk_all_gather(x, axes, *, axis=0, tiled=True, tenant_id=0, op_data=0):
    return _dispatch("all_gather", x, axes, tenant_id=tenant_id,
                     op_data=op_data, axis=axis, tiled=tiled)


def nk_reduce_scatter(x, axes, *, axis=0, tenant_id=0, gradient=False):
    flags = FLAG_GRADIENT if gradient else 0
    return _dispatch("reduce_scatter", x, axes, tenant_id=tenant_id,
                     flags=flags, axis=axis)


def nk_all_to_all(x, axes, *, split_axis, concat_axis, tenant_id=0):
    return _dispatch("all_to_all", x, axes, tenant_id=tenant_id,
                     split_axis=split_axis, concat_axis=concat_axis)


def nk_ppermute(x, axes, *, perm, tenant_id=0):
    return _dispatch("ppermute", x, axes, tenant_id=tenant_id, perm=perm)


def nk_grad_sync(grads, axes, *, tenant_id=0):
    """Synchronize a gradient pytree over ``axes`` through the engine.

    This is the NetKernel-owned "last mile" of training traffic: every leaf
    is a gradient-flagged psum the routing table may send to the compressed /
    hierarchical / ring stack.
    """
    import jax
    return jax.tree.map(
        lambda g: nk_psum(g, axes, tenant_id=tenant_id, gradient=True), grads)
