"""CommOp: the NQE analogue — a fixed-schema communication descriptor.

NetKernel carries socket *semantics* between guest and NSM as 32-byte
NetKernel Queue Elements (NQEs), keeping bulk data out of the control path.
Here, the semantics of a collective (verb, mesh axis, tenant, payload size)
are carried as ``CommOp`` records with an exact 32-byte packed binary
encoding. CoreEngine routes, accounts and rate-limits in terms of CommOps;
bulk tensors stay in HBM (the "hugepages") and never enter this path.

Layout (32 bytes, little-endian), mirroring Figure 3 of the paper:

    1B  verb        (op type)
    1B  tenant_id   (VM ID)
    1B  axis_code   (queue-set ID analog: which mesh axis/axes)
    1B  flags       (reserved: bit0 = gradient, bit1 = serving path)
    4B  tag         (VM socket ID analog: caller-chosen correlation id)
    8B  op_data     (verb-specific: e.g. permutation id, chunk index)
    8B  size_bytes  (data pointer+size analog: payload bytes in HBM)
    4B  shape_crc   (crc32 of shape/dtype string: semantic checksum)
    4B  reserved
"""
from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass, field
from typing import Optional, Tuple

VERBS = (
    "psum",            # all-reduce
    "all_gather",
    "reduce_scatter",
    "all_to_all",
    "ppermute",        # neighbor exchange (rings, pipelines)
    "broadcast",
    "shm_move",        # colocated fast path: sharding-compatible move/elision
)
VERB_CODE = {v: i for i, v in enumerate(VERBS)}

# Mesh axes are encoded as a bitmask so multi-axis ops ("pod"+"data") fit 1B.
AXIS_BITS = {"pod": 1, "data": 2, "model": 4, "stage": 8}
_AXIS_MASK = 0
for _b in AXIS_BITS.values():
    _AXIS_MASK |= _b
_STRUCT = struct.Struct("<BBBBIQQII")
NQE_SIZE = _STRUCT.size
assert NQE_SIZE == 32, NQE_SIZE

FLAG_GRADIENT = 1
FLAG_SERVING = 2


def _axis_code(axes: Tuple[str, ...]) -> int:
    code = 0
    for a in axes:
        try:
            code |= AXIS_BITS[a]
        except KeyError:
            raise ValueError(f"unknown mesh axis {a!r}") from None
    return code


def _axes_from_code(code: int) -> Tuple[str, ...]:
    return tuple(a for a, b in AXIS_BITS.items() if code & b)


@dataclass(frozen=True)
class CommOp:
    """One communication intent. Hashable, fixed-schema, 32-byte packable."""

    verb: str
    axes: Tuple[str, ...]
    tenant_id: int = 0
    tag: int = 0
    op_data: int = 0
    size_bytes: int = 0
    shape_desc: str = ""        # e.g. "bf16[256,4096,3072]"
    flags: int = 0
    # carried wire checksum for ops decoded without their shape_desc: a
    # forwarder's unpack() -> pack() must not replace the original
    # shape_crc with crc32("") and break verification downstream
    wire_crc: Optional[int] = field(default=None, compare=False, repr=False)

    def __post_init__(self):
        if self.verb not in VERB_CODE:
            raise ValueError(f"unknown verb {self.verb!r}")
        if not (0 <= self.tenant_id < 256):
            raise ValueError("tenant_id must fit in 1 byte")
        object.__setattr__(self, "axes", tuple(self.axes))

    # --- 32-byte wire format (the NQE) ---------------------------------
    def pack(self) -> bytes:
        crc = zlib.crc32(self.shape_desc.encode()) & 0xFFFFFFFF \
            if self.shape_desc or self.wire_crc is None else self.wire_crc
        return _STRUCT.pack(
            VERB_CODE[self.verb],
            self.tenant_id,
            _axis_code(self.axes),
            self.flags & 0xFF,
            self.tag & 0xFFFFFFFF,
            self.op_data & 0xFFFFFFFFFFFFFFFF,
            self.size_bytes & 0xFFFFFFFFFFFFFFFF,
            crc,
            0,
        )

    @classmethod
    def unpack(cls, raw: bytes,
               expect_shape: Optional[str] = None) -> "CommOp":
        """Decode a 32-byte NQE. Corrupt records are rejected, not guessed
        at: an out-of-range verb code or unknown axis bit raises ValueError,
        and ``expect_shape`` (the receiver's view of the payload) is checked
        against the carried shape_crc — the semantic checksum that catches a
        descriptor pointing at the wrong tensor."""
        if len(raw) != NQE_SIZE:
            raise ValueError(f"NQE must be {NQE_SIZE} bytes, got {len(raw)}")
        (verb, tenant, axis_code, flags, tag, op_data, size_bytes,
         crc, _rsvd) = _STRUCT.unpack(raw)
        if verb >= len(VERBS):
            raise ValueError(f"invalid verb code {verb}")
        if axis_code & ~_AXIS_MASK:
            raise ValueError(f"unknown axis bits 0x{axis_code:02x}")
        if expect_shape is not None and \
                zlib.crc32(expect_shape.encode()) & 0xFFFFFFFF != crc:
            raise ValueError(
                f"shape_crc mismatch: NQE carries 0x{crc:08x}, "
                f"expected shape {expect_shape!r}")
        return cls(
            verb=VERBS[verb],
            axes=_axes_from_code(axis_code),
            tenant_id=tenant,
            tag=tag,
            op_data=op_data,
            size_bytes=size_bytes,
            flags=flags,
            shape_desc=expect_shape or "",
            wire_crc=crc,
        )

    def matches(self, other: "CommOp") -> bool:
        """Wire-level equivalence (shape_desc only participates via crc,
        which is excluded here: bytes 24:28 of the layout)."""
        return self.pack()[:24] == other.pack()[:24]


def describe(x) -> str:
    """Shape descriptor string for a jax array / ShapeDtypeStruct."""
    try:
        return f"{x.dtype}[{','.join(map(str, x.shape))}]"
    except AttributeError:
        return str(type(x).__name__)


def payload_bytes(x) -> int:
    try:
        import numpy as np
        n = 1
        for d in x.shape:
            n *= int(d)
        return n * np.dtype(x.dtype).itemsize
    except Exception:
        return 0
