"""Network Stack Modules: pluggable collective implementations.

The paper's NSMs are whole TCP/IP stacks (Linux kernel, mTCP, shared-memory)
that serve unmodified applications behind the BSD socket API. Here an NSM is
a whole *collective stack* that serves unmodified model code behind the
``nk_*`` API (repro.core.collectives):

  XlaNsm           "the kernel stack": native jax.lax collectives; XLA owns
                   scheduling. Always correct, operator-default.
  RingNsm          "the mTCP stack": explicit (bidirectional) ring
                   reduce-scatter / all-gather built on lax.ppermute —
                   schedules the wire explicitly so compute/comm overlap and
                   per-step chunking are under framework control.
  HierarchicalNsm  2-level multi-pod stack: reduce-scatter on the fast
                   intra-pod axis, exchange only 1/axis_size of the bytes on
                   the slow pod axis, all-gather back. Cross-pod bytes drop
                   by the intra-pod axis size.
  CompressedNsm    int8-on-the-wire transport for slow axes (gradient
                   compression), composing with either inner stack.
  ShmNsm           the colocated fast path: elides ops whose payload is
                   already reduced/replicated (sharding-compatible), the
                   analog of copying via shared memory instead of TCP.

All methods execute inside ``shard_map`` bodies (manual-collective context).
Mesh axis sizes are passed statically by the CoreEngine.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import compression
from repro.core.nqe import CommOp


class Nsm:
    """Base collective stack. Subclasses implement the verbs they accelerate;
    anything not overridden falls back to the native XLA lowering."""

    name = "base"

    # -- verbs ----------------------------------------------------------
    def psum(self, x, axes: Tuple[str, ...], *, axis_sizes: Dict[str, int],
             op: Optional[CommOp] = None):
        return lax.psum(x, axes if len(axes) > 1 else axes[0])

    def all_gather(self, x, axes, *, axis_sizes, axis: int = 0, tiled=True,
                   op: Optional[CommOp] = None):
        name = axes if len(axes) > 1 else axes[0]
        return lax.all_gather(x, name, axis=axis, tiled=tiled)

    def reduce_scatter(self, x, axes, *, axis_sizes, axis: int = 0,
                       op: Optional[CommOp] = None):
        name = axes if len(axes) > 1 else axes[0]
        return lax.psum_scatter(x, name, scatter_dimension=axis, tiled=True)

    def all_to_all(self, x, axes, *, axis_sizes, split_axis: int,
                   concat_axis: int, op: Optional[CommOp] = None):
        name = axes if len(axes) > 1 else axes[0]
        return lax.all_to_all(x, name, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=True)

    def ppermute(self, x, axes, *, axis_sizes, perm, op: Optional[CommOp] = None):
        return lax.ppermute(x, axes[0], perm)

    def __repr__(self):
        return f"<Nsm:{self.name}>"


class XlaNsm(Nsm):
    """Native stack — jax.lax collectives, XLA-scheduled ("kernel stack")."""

    name = "xla"


# ---------------------------------------------------------------------------
# Ring stack
# ---------------------------------------------------------------------------


def _flatten_pad(x, n: int):
    """Flatten to (n, chunk) with zero padding; returns (chunks, orig_size, shape)."""
    flat = x.reshape(-1)
    size = flat.shape[0]
    chunk = -(-size // n)
    pad = n * chunk - size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(n, chunk), size, x.shape


def _unflatten(chunks, size: int, shape):
    return chunks.reshape(-1)[:size].reshape(shape)


class RingNsm(Nsm):
    """Explicit ring collectives over ``lax.ppermute`` ("the mTCP stack").

    Ring reduce-scatter + ring all-gather with an optional bidirectional
    split (two counter-rotating rings, halving the per-link bytes). On TPU,
    each ppermute is an async ICI hop that XLA can overlap with the
    surrounding compute, which is the point of owning the schedule.
    """

    name = "ring"

    def __init__(self, bidirectional: bool = False):
        self.bidirectional = bidirectional
        if bidirectional:
            self.name = "ring2"

    # --- internals ------------------------------------------------------
    def _ring_reduce_scatter(self, chunks, axis: str, n: int, reverse=False):
        """chunks: (n, chunk). Returns this device's owned reduced chunk."""
        idx = lax.axis_index(axis)
        step = -1 if not reverse else 1
        perm = [(i, (i + 1) % n) for i in range(n)] if not reverse else \
               [(i, (i - 1) % n) for i in range(n)]
        # Explicit unroll (n is a small static mesh-axis size): each hop is an
        # async ICI ppermute XLA can overlap with the neighbouring adds.
        # Device r accumulates the chunk it will own (index r) over n-1 hops.
        acc = jnp.zeros_like(chunks[0])
        for t in range(n - 1):
            send_idx = (idx + step * (t + 1)) % n
            piece = lax.dynamic_index_in_dim(chunks, send_idx, axis=0,
                                             keepdims=False)
            acc = lax.ppermute(acc + piece, axis, perm)
        own = lax.dynamic_index_in_dim(chunks, idx, axis=0, keepdims=False)
        return own + acc

    def _ring_all_gather(self, piece, axis: str, n: int, reverse=False):
        """piece: (chunk,) owned by this device. Returns (n, chunk)."""
        idx = lax.axis_index(axis)
        perm = [(i, (i + 1) % n) for i in range(n)] if not reverse else \
               [(i, (i - 1) % n) for i in range(n)]
        step = -1 if not reverse else 1
        buf = jnp.zeros((n,) + piece.shape, piece.dtype)
        buf = lax.dynamic_update_index_in_dim(buf, piece, idx, axis=0)
        cur = piece
        for t in range(n - 1):
            cur = lax.ppermute(cur, axis, perm)
            src = (idx + step * (t + 1)) % n
            buf = lax.dynamic_update_index_in_dim(buf, cur, src, axis=0)
        return buf

    # --- verbs ----------------------------------------------------------
    def psum(self, x, axes, *, axis_sizes, op=None):
        out = x
        for axis in axes:
            out = self._psum_one(out, axis, axis_sizes[axis])
        return out

    def _psum_one(self, x, axis: str, n: int):
        if n == 1:
            return x
        if not self.bidirectional:
            chunks, size, shape = _flatten_pad(x, n)
            piece = self._ring_reduce_scatter(chunks, axis, n)
            full = self._ring_all_gather(piece, axis, n)
            return _unflatten(full, size, shape)
        # bidirectional: two half-payload counter-rotating rings
        flat = x.reshape(-1)
        half = flat.shape[0] // 2
        a, b = flat[:half], flat[half:]
        ca, sa, _ = _flatten_pad(a, n)
        cb, sb, _ = _flatten_pad(b, n)
        pa = self._ring_reduce_scatter(ca, axis, n, reverse=False)
        pb = self._ring_reduce_scatter(cb, axis, n, reverse=True)
        fa = self._ring_all_gather(pa, axis, n, reverse=False)
        fb = self._ring_all_gather(pb, axis, n, reverse=True)
        out = jnp.concatenate([fa.reshape(-1)[:sa], fb.reshape(-1)[:sb]])
        return out.reshape(x.shape)

    def reduce_scatter(self, x, axes, *, axis_sizes, axis: int = 0, op=None):
        name = axes[0]
        n = axis_sizes[name]
        if n == 1:
            return x
        # move scatter dim to front, chunk it along the ring
        moved = jnp.moveaxis(x, axis, 0)
        assert moved.shape[0] % n == 0, "reduce_scatter dim must divide ring"
        chunks = moved.reshape(n, moved.shape[0] // n, *moved.shape[1:])
        flat = chunks.reshape(n, -1)
        piece = self._ring_reduce_scatter(flat, name, n)
        piece = piece.reshape(moved.shape[0] // n, *moved.shape[1:])
        return jnp.moveaxis(piece, 0, axis)

    def all_gather(self, x, axes, *, axis_sizes, axis: int = 0, tiled=True, op=None):
        name = axes[0]
        n = axis_sizes[name]
        if n == 1:
            return x
        flat = x.reshape(-1)
        buf = self._ring_all_gather(flat, name, n)   # (n, local)
        parts = buf.reshape((n,) + x.shape)
        moved = jnp.moveaxis(parts, 0, axis)
        return moved.reshape(
            x.shape[:axis] + (n * x.shape[axis],) + x.shape[axis + 1:])


class HierarchicalNsm(Nsm):
    """2-level psum for multi-axis reductions (the multi-pod stack).

    psum over ("pod","data"): reduce_scatter over 'data' (fast), psum over
    'pod' carrying only 1/|data| of the payload (slow axis), all_gather over
    'data'. Cross-pod bytes drop by |data| (=16 in the production mesh).
    """

    name = "hierarchical"

    def __init__(self, inner: Optional[Nsm] = None):
        self.inner = inner or XlaNsm()

    def psum(self, x, axes, *, axis_sizes, op=None):
        if len(axes) < 2:
            return self.inner.psum(x, axes, axis_sizes=axis_sizes, op=op)
        # order axes fast->slow: reduce-scatter over all fast axes, psum on
        # the slowest, gather back in reverse order.
        slow, fast = axes[0], tuple(axes[1:])  # convention: axes[0] is slow ('pod')
        n_fast = 1
        for a in fast:
            n_fast *= axis_sizes[a]
        chunks, size, shape = _flatten_pad(x, n_fast)
        fast_name = fast if len(fast) > 1 else fast[0]
        piece = lax.psum_scatter(chunks, fast_name, scatter_dimension=0,
                                 tiled=True)                  # (1, chunk)
        piece = self.inner.psum(piece, (slow,), axis_sizes=axis_sizes, op=op)
        full = lax.all_gather(piece, fast_name, axis=0, tiled=True)
        return _unflatten(full, size, shape)


class CompressedNsm(Nsm):
    """int8-on-the-wire gradient transport for designated (slow) axes.

    psum quantizes to int8 with a globally agreed scale, sums in int32 and
    dequantizes — wire bytes halve vs bf16 (quarter vs f32). Intended for the
    'pod' axis; error feedback is carried by the train loop (see
    repro.train.train_loop). Non-psum verbs pass through the inner stack.
    """

    name = "compressed"

    def __init__(self, inner: Optional[Nsm] = None,
                 compress_axes: Tuple[str, ...] = ("pod",)):
        self.inner = inner or XlaNsm()
        self.compress_axes = tuple(compress_axes)

    def psum(self, x, axes, *, axis_sizes, op=None):
        comp = tuple(a for a in axes if a in self.compress_axes)
        rest = tuple(a for a in axes if a not in self.compress_axes)
        out = x
        if rest:
            out = self.inner.psum(out, rest, axis_sizes=axis_sizes, op=op)
        if comp:
            if not jnp.issubdtype(out.dtype, jnp.floating):
                out = lax.psum(out, comp if len(comp) > 1 else comp[0])
            else:
                out = compression.compressed_psum(
                    out, comp if len(comp) > 1 else comp[0],
                    axis_sizes=tuple(axis_sizes[a] for a in comp))
        return out


class ShmNsm(Nsm):
    """Colocated fast path: elide ops whose payload already satisfies the
    destination sharding (op.op_data bit0 set by the CoreEngine when the
    routing table proves source/destination compatibility)."""

    name = "shm"

    def __init__(self, inner: Optional[Nsm] = None):
        self.inner = inner or XlaNsm()

    def psum(self, x, axes, *, axis_sizes, op=None):
        if op is not None and op.op_data & 1:
            return x                      # already reduced: zero-copy move
        return self.inner.psum(x, axes, axis_sizes=axis_sizes, op=op)

    def all_gather(self, x, axes, *, axis_sizes, axis=0, tiled=True, op=None):
        if op is not None and op.op_data & 1:
            return x                      # already replicated
        return self.inner.all_gather(x, axes, axis_sizes=axis_sizes,
                                     axis=axis, tiled=tiled, op=op)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Nsm] = {}


def register_nsm(nsm: Nsm) -> Nsm:
    _REGISTRY[nsm.name] = nsm
    return nsm


def get_nsm(name: str) -> Nsm:
    if name not in _REGISTRY:
        raise KeyError(f"unknown NSM {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def available_nsms():
    return sorted(_REGISTRY)


register_nsm(XlaNsm())
register_nsm(RingNsm())
register_nsm(RingNsm(bidirectional=True))
register_nsm(HierarchicalNsm())
register_nsm(CompressedNsm())
register_nsm(ShmNsm())
