"""Gradient compression codecs for slow (cross-pod) axes.

int8 block quantization with a shared global scale so that quantized values
can be *summed in the network* (psum over int32) and dequantized once — the
TPU analog of putting a smarter transport under the same socket API. Error
feedback (residual carrying) restores convergence; see test_train_loop.py.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array, scale: jax.Array) -> jax.Array:
    """Symmetric int8 quantization with a given (positive) scale."""
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    return q.astype(jnp.int8)


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum(x: jax.Array, axes, *, axis_sizes: int) -> jax.Array:
    """All-reduce of ``x`` over ``axes`` communicating int8 instead of bf16/f32.

    Protocol (inside shard_map):
      1. agree on a global scale via a tiny max-reduce (O(1) bytes),
      2. quantize locally to int8,
      3. psum the int8 payload as int32 (sums of <=256 int8 fit easily),
      4. dequantize with the shared scale.

    Wire bytes: ~1/2 of bf16, ~1/4 of f32 (plus the scalar scale).
    """
    orig_dtype = x.dtype
    absmax = jax.lax.pmax(jnp.max(jnp.abs(x.astype(jnp.float32))), axes)
    scale = jnp.maximum(absmax, 1e-30) / 127.0
    q = quantize_int8(x.astype(jnp.float32), scale)
    s = jax.lax.psum(q.astype(jnp.int32), axes)
    return dequantize_int8(s, scale, orig_dtype)


def int8_roundtrip_residual(x: jax.Array,
                            scale: Optional[jax.Array] = None) -> jax.Array:
    """``x_hat - x`` for one int8 wire round trip of ``x`` — exactly the
    residual error feedback would carry into the next step.

    ``scale`` defaults to the symmetric absmax/127 scale
    ``compressed_psum`` agrees on; pass the *global* (pmax'd) scale to
    measure the per-shard error of a distributed sum. This is the
    measured quantity an error-feedback-aware tolerance derives from: an
    int8 psum over ``k`` shards is off by at most the sum of the shards'
    round-trip residuals, so ``k * max|residual|`` bounds the absolute
    error without any hand-tuned constant (see
    tests/test_nsm_conformance.py and ``train_loop``'s
    ``track_ef_residual``).
    """
    xf = x.astype(jnp.float32)
    if scale is None:
        scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-30) / 127.0
    q = quantize_int8(xf, scale)
    return dequantize_int8(q, scale) - xf


def ef_compress_decompress(x: jax.Array, residual: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback int8 round trip: returns (x_hat, new_residual).

    ``x_hat`` is what the wire would deliver; ``new_residual`` carries the
    quantization error into the next step (Seide et al. / EF-SGD style).
    """
    y = x.astype(jnp.float32) + residual
    absmax = jnp.maximum(jnp.max(jnp.abs(y)), 1e-30)
    scale = absmax / 127.0
    q = quantize_int8(y, scale)
    y_hat = dequantize_int8(q, scale)
    return y_hat.astype(x.dtype), (y - y_hat)


def compression_ratio(dtype) -> float:
    """Wire-byte ratio of int8 transport vs the original dtype."""
    return jnp.dtype(dtype).itemsize / 1.0
