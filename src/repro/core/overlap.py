"""Overlapped collective-matmul primitives (compute/comm overlap).

The classic TPU "collective matmul" decompositions: instead of a blocking
all-gather (or all-reduce) around a matmul, rotate shards around the ring
with ``ppermute`` while the MXU consumes the shard already in hand. Each hop
is an async ICI transfer XLA overlaps with the concurrent ``dot`` — the
distributed-optimization trick the NetKernel architecture lets the operator
deploy *under* unmodified model code.

Used by the ring NSM policy for the FSDP all-gather -> matmul path and by
the TP matmul -> reduce-scatter path; equivalence-tested in
tests/test_collectives.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def all_gather_matmul(x: jax.Array, w_shard: jax.Array, axis: str, n: int):
    """Compute ``x @ all_gather(w_shard, axis)`` with overlapped ring hops.

    x:        (..., K)      replicated over ``axis``
    w_shard:  (K/n, N)      row-shard of W held by this device
    returns:  (..., N)      == x @ W, identical on every ring member

    At step t the device multiplies the shard it currently holds (owner
    ``(idx + t) % n``) against the matching K-slice of x while the shard is
    forwarded to the next neighbour.
    """
    idx = lax.axis_index(axis)
    perm = [(i, (i - 1) % n) for i in range(n)]   # shard flows upstream
    k_blk = w_shard.shape[0]
    out = jnp.zeros(x.shape[:-1] + (w_shard.shape[1],), x.dtype)
    cur = w_shard
    for t in range(n):
        owner = (idx + t) % n
        x_blk = lax.dynamic_slice_in_dim(x, owner * k_blk, k_blk, axis=-1)
        out = out + jnp.einsum("...k,kn->...n", x_blk, cur)
        if t != n - 1:
            cur = lax.ppermute(cur, axis, perm)
    return out


def matmul_reduce_scatter(x: jax.Array, w_shard: jax.Array, axis: str, n: int):
    """Compute ``reduce_scatter(x @ w_shard, axis)`` with overlapped hops.

    x:        (M, K_local)  K-shard of the activation (TP contraction)
    w_shard:  (K_local, N)  matching row-shard of W
    returns:  (M/n, N)      this device's slice of sum_k x_k @ w_k

    The partial product is computed one M-chunk at a time; the accumulator
    ring-hops so each chunk visits every device exactly once, arriving at its
    owner fully reduced.
    """
    idx = lax.axis_index(axis)
    perm = [(i, (i + 1) % n) for i in range(n)]
    m = x.shape[0]
    assert m % n == 0, "leading dim must divide the ring for reduce-scatter"
    m_blk = m // n
    acc = jnp.zeros((m_blk, w_shard.shape[1]), x.dtype)
    for t in range(n):
        # chunk that, after the remaining (n-1-t) downstream hops, lands on
        # its owner: contribution from device r-j is always chunk r (mod n)
        chunk_idx = (idx - t - 1) % n
        x_blk = lax.dynamic_slice_in_dim(x, chunk_idx * m_blk, m_blk, axis=0)
        acc = acc + jnp.einsum("mk,kn->mn", x_blk, w_shard)
        if t != n - 1:
            acc = lax.ppermute(acc, axis, perm)
    return acc
