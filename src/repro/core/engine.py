"""CoreEngine: the NQE switch — routing, accounting, isolation.

The paper's CoreEngine is a software switch on the hypervisor: it maps each
NQE to the right NSM via a connection table, polls queues round-robin for
basic fairness, and can rate-limit a VM in bytes/s or NQEs/s. Here:

  * routing table   : ordered policy rules ``predicate(CommOp) -> nsm name``;
                      the operator swaps a tenant's whole comm stack by
                      editing rules, never model code (use case 3).
  * ledger          : per-(tenant, verb, axes) op/byte accounting recorded at
                      trace time — the control-plane view of every intent the
                      models issue. The dry-run cross-checks this against the
                      collectives found in compiled HLO.
  * token buckets   : per-tenant rate limiting used by the serving scheduler
                      (paper Fig. 21); round-robin polling lives in
                      repro.serve.scheduler.
"""
from __future__ import annotations

import math
import threading
import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.nqe import CommOp, describe, payload_bytes
from repro.core.nsm import Nsm, get_nsm
from repro.fabric import StackModule, TenantState

Rule = Tuple[str, Callable[[CommOp], bool], str]   # (name, predicate, nsm)


@dataclass
class LedgerEntry:
    ops: int = 0
    bytes: int = 0


class TokenBucket:
    """Classic token bucket: ``rate`` tokens/s, burst up to ``capacity``.

    Tokens are bytes (or request units). ``consume`` returns True if admitted;
    ``wait_time`` reports how long until ``n`` tokens would be available —
    the scheduler uses it for work-conserving backfill.
    """

    def __init__(self, rate: float, capacity: float):
        self.rate = float(rate)
        self.capacity = float(capacity)
        self.tokens = float(capacity)
        self.updated = 0.0

    def _refill(self, now: float):
        if now > self.updated:
            self.tokens = min(self.capacity,
                              self.tokens + (now - self.updated) * self.rate)
            self.updated = now

    def consume(self, n: float, now: Optional[float] = None) -> bool:
        now = time.monotonic() if now is None else now
        self._refill(now)
        if self.tokens >= n:
            self.tokens -= n
            return True
        return False

    def drain(self, n: float, now: Optional[float] = None) -> float:
        """Fluid admission: take up to ``n`` tokens, never going negative.

        Returns the amount actually admitted. CoreEngine enforcement uses
        this (a collective's bytes are a divisible stream, unlike a request,
        which is admitted whole or not at all via ``consume``).
        """
        now = time.monotonic() if now is None else now
        self._refill(now)
        take = min(float(n), max(self.tokens, 0.0))
        self.tokens -= take
        return take

    def wait_time(self, n: float, now: Optional[float] = None) -> float:
        now = time.monotonic() if now is None else now
        self._refill(now)
        if self.tokens >= n:
            return 0.0
        if self.rate <= 0.0:
            return math.inf          # hard-blocked tenant: never admissible
        return (n - self.tokens) / self.rate

    def set_rate(self, rate: float, burst: Optional[float] = None,
                 now: Optional[float] = None) -> None:
        """Retarget the bucket mid-run, preserving accumulated tokens.

        Settles the balance at the old rate first so a controller pushing
        updates does not retroactively re-price the elapsed interval.
        """
        now = time.monotonic() if now is None else now
        self._refill(now)
        self.rate = float(rate)
        if burst is not None:
            self.capacity = float(burst)
            self.tokens = min(self.tokens, self.capacity)

    # -- migration support -------------------------------------------------
    def snapshot(self, now: Optional[float] = None) -> Dict[str, float]:
        """Return the bucket's transferable state: ``{rate, capacity,
        tokens, updated}`` (units/s, units, units, seconds), settling the
        balance at ``now`` first when given (``None`` keeps the last
        settled level and its timestamp).

        The enforcement-point half of live tenant migration: the level a
        tenant has already burned down travels with it, so moving between
        enforcement points can never reopen a fresh burst.
        """
        if now is not None:
            self._refill(now)
        return {"rate": self.rate, "capacity": self.capacity,
                "tokens": self.tokens, "updated": self.updated}

    @classmethod
    def restore(cls, state: Dict[str, float],
                now: Optional[float] = None) -> "TokenBucket":
        """Rebuild a bucket from ``snapshot()`` output, anchored at ``now``
        so refill resumes from the transfer instant. ``None`` keeps the
        snapshot's own timestamp — the right choice when the caller's
        clock is unknown (virtual-clock replays must NOT be anchored to
        the wall clock, which would freeze refill forever)."""
        b = cls(state["rate"], state["capacity"])
        b.tokens = min(float(state["tokens"]), b.capacity)
        b.updated = float(state.get("updated", 0.0)) if now is None \
            else float(now)
        return b


ENFORCEMENT_MODES = ("off", "account", "defer")


class CoreEngine(StackModule):
    """Routes CommOps to NSMs; accounts and isolates tenants.

    Implements the bytes-plane half of the ``StackModule`` protocol
    (repro.fabric): tenant export/import with bucket-level transfer,
    flattened carried counters, and a monotonic ``billed`` ground-truth
    counter that never migrates — the conservation reference
    ``ConservationLedger`` checks carried+live ledgers against.
    """

    plane = "bytes"
    ledger_fields = ("ops", "bytes", "deferred_ops", "deferred_bytes",
                     "admitted_ops", "admitted_bytes", "admit_wait_s")
    conserved_field = "bytes"

    def __init__(self, mesh=None, default_nsm: str = "xla",
                 enforcement: str = "off"):
        self.mesh = mesh
        self.default_nsm = default_nsm
        self.rules: List[Rule] = []
        self.ledger: Dict[Tuple[int, str, Tuple[str, ...]], LedgerEntry] = \
            defaultdict(LedgerEntry)
        # bytes/ops that arrived beyond the tenant's rate (shortfall only)
        self.deferred: Dict[Tuple[int, Tuple[str, ...]], LedgerEntry] = \
            defaultdict(LedgerEntry)
        # per-tenant admission view: ops/bytes admitted within rate, and the
        # cumulative shaping delay (seconds) enforcement charged the tenant —
        # the "admission latency" column the replay harness reads
        self.admitted: Dict[int, LedgerEntry] = defaultdict(LedgerEntry)
        self.admit_wait_s: Dict[int, float] = defaultdict(float)
        # per-tenant bytes ever routed HERE — the bytes plane's billed
        # ground truth. Never exported by a migration (the analog of the
        # serve plane's completed-request records staying on the engine
        # that served them), so carried + live ledgers must equal its sum
        # over all engines at every instant: the conservation invariant.
        self.billed: Dict[int, int] = defaultdict(int)
        self.route_log: List[Tuple[bytes, str]] = []
        self.throttle_log: List[Tuple[int, float, float]] = []
        self.buckets: Dict[int, TokenBucket] = {}
        self.set_enforcement(enforcement)
        self.max_defer_s = 0.05      # wall-clock cap per deferred dispatch
        self._lock = threading.Lock()

    # --- connection-table management ------------------------------------
    def add_rule(self, name: str, predicate: Callable[[CommOp], bool],
                 nsm: str) -> None:
        get_nsm(nsm)  # validate eagerly
        self.rules.append((name, predicate, nsm))

    def clear_rules(self) -> None:
        self.rules.clear()

    def set_tenant_rate(self, tenant_id: int, bytes_per_s: float,
                        burst: Optional[float] = None) -> None:
        self.buckets[tenant_id] = TokenBucket(
            bytes_per_s, burst if burst is not None else bytes_per_s)

    def update_tenant_rate(self, tenant_id: int, bytes_per_s: float,
                           burst: Optional[float] = None,
                           now: Optional[float] = None) -> None:
        """Controller push: retarget a live bucket without dropping its
        token balance (``set_tenant_rate`` would reopen the full burst)."""
        b = self.buckets.get(tenant_id)
        if b is None:
            self.set_tenant_rate(tenant_id, bytes_per_s, burst)
            if now is not None:
                self.buckets[tenant_id].updated = now
        else:
            b.set_rate(bytes_per_s, burst, now)

    def set_enforcement(self, mode: str) -> None:
        """off: buckets are advisory (seed behaviour). account: admit
        everything but meter the over-rate excess. defer: additionally
        sleep (bounded) so wall-clock dispatch rates are actually shaped."""
        if mode not in ENFORCEMENT_MODES:
            raise ValueError(f"enforcement must be one of {ENFORCEMENT_MODES}")
        self.enforcement = mode

    def admit(self, op: CommOp, now: Optional[float] = None) -> float:
        """Consume the tenant's bucket for this op; returns the shaping
        delay in seconds (0.0 = admitted entirely within rate).

        The op's bytes are drained from the bucket as a fluid; any shortfall
        is metered in ``deferred`` + ``throttle_log`` and, in ``defer`` mode
        with a real clock, slept off (capped at ``max_defer_s``).
        """
        b = self.buckets.get(op.tenant_id)
        if self.enforcement == "off":
            return 0.0            # seed fast path: no ledger, no lock
        if b is None:
            with self._lock:
                e = self.admitted[op.tenant_id]
                e.ops += 1
                e.bytes += op.size_bytes
            return 0.0
        admitted = b.drain(op.size_bytes, now)
        shortfall = float(op.size_bytes) - admitted
        if shortfall <= 0.0:
            with self._lock:
                e = self.admitted[op.tenant_id]
                e.ops += 1
                e.bytes += op.size_bytes
            return 0.0
        wait = math.inf if b.rate <= 0.0 else shortfall / b.rate
        with self._lock:
            a = self.admitted[op.tenant_id]
            a.bytes += int(admitted)
            e = self.deferred[(op.tenant_id, op.axes)]
            e.ops += 1
            e.bytes += int(shortfall)
            if math.isfinite(wait):
                self.admit_wait_s[op.tenant_id] += wait
            self.throttle_log.append((op.tenant_id, shortfall, wait))
        if self.enforcement == "defer" and now is None:
            time.sleep(min(wait, self.max_defer_s))
        return wait

    # --- routing ---------------------------------------------------------
    def route(self, op: CommOp) -> Nsm:
        choice = self.default_nsm
        for name, pred, nsm in self.rules:
            if pred(op):
                choice = nsm
                break
        with self._lock:
            e = self.ledger[(op.tenant_id, op.verb, op.axes)]
            e.ops += 1
            e.bytes += op.size_bytes
            self.billed[op.tenant_id] += op.size_bytes
            self.route_log.append((op.pack(), choice))
        return get_nsm(choice)

    def route_batch(self, ops: List[CommOp]) -> List[Nsm]:
        """Batched routing (paper Fig. 11: batching the NQE switch)."""
        return [self.route(op) for op in ops]

    # --- execution helper -------------------------------------------------
    def axis_sizes(self) -> Dict[str, int]:
        if self.mesh is None:
            raise ValueError("CoreEngine needs a mesh to execute collectives")
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))

    def dispatch(self, verb: str, x, axes: Tuple[str, ...], *, tenant_id=0,
                 tag=0, flags=0, op_data=0, now=None, **kw):
        op = CommOp(verb=verb, axes=tuple(axes), tenant_id=tenant_id, tag=tag,
                    flags=flags, op_data=op_data, size_bytes=payload_bytes(x),
                    shape_desc=describe(x))
        self.admit(op, now)
        nsm = self.route(op)
        fn = getattr(nsm, "psum" if verb == "psum" else verb, None)
        if verb == "shm_move":
            return x
        if fn is None:
            raise ValueError(f"NSM {nsm.name} cannot execute {verb}")
        return fn(x, tuple(axes), axis_sizes=self.axis_sizes(), op=op, **kw)

    # --- migration (bytes-plane half of live tenant migration) -----------
    def _live_state(self, tenant_id: int) -> List[str]:
        """Names of the live bytes-plane state a tenant holds here (empty
        = quiesced). Callers hold ``self._lock``."""
        live = []
        if tenant_id in self.buckets:
            live.append("bucket")
        if any(k[0] == tenant_id for k in self.ledger):
            live.append("ledger")
        if any(k[0] == tenant_id for k in self.deferred):
            live.append("deferred")
        if tenant_id in self.admitted:
            live.append("admitted")
        if tenant_id in self.admit_wait_s:
            live.append("admit_wait_s")
        return live

    def has_tenant(self, tenant_id: int) -> bool:
        """True iff the tenant holds ANY live bytes-plane state here —
        the quiesced-destination check a migration runs before its
        destructive export."""
        with self._lock:
            return bool(self._live_state(tenant_id))

    def export_tenant(self, tenant_id: int,
                      now: Optional[float] = None) -> TenantState:
        """Atomically remove a tenant's bytes-plane state and return it.

        Mirrors ``TenantScheduler.export_tenant`` for the collective
        fabric: the tenant's token-bucket *level* travels (a move can
        never reopen a fresh burst of bytes), and the cumulative ledger /
        deferred / admitted counters flatten into ``TenantState.carried``
        for the caller to fold — ``import_tenant`` deliberately does not
        replay them into the destination engine, where the jump would
        read as a rate spike to ``EngineTelemetry`` (the same
        counter-reset discipline the scheduler plane uses). The
        per-(verb, axes) breakdown rides in ``payload`` for audit.
        Conservation: carried + both engines' live counters must be
        unchanged across the move; ``ConservationLedger`` asserts exactly
        that on every plan.
        """
        with self._lock:
            ledger = {}
            for key in [k for k in self.ledger if k[0] == tenant_id]:
                e = self.ledger.pop(key)
                ledger[(key[1], key[2])] = (e.ops, e.bytes)
            deferred = {}
            for key in [k for k in self.deferred if k[0] == tenant_id]:
                e = self.deferred.pop(key)
                deferred[key[1]] = (e.ops, e.bytes)
            adm = self.admitted.pop(tenant_id, None)
            wait = self.admit_wait_s.pop(tenant_id, 0.0)
            state = TenantState(
                plane="bytes",
                bucket=(self.buckets[tenant_id].snapshot(now)
                        if tenant_id in self.buckets else None),
                carried={
                    "ops": sum(o for o, _ in ledger.values()),
                    "bytes": sum(b for _, b in ledger.values()),
                    "deferred_ops": sum(o for o, _ in deferred.values()),
                    "deferred_bytes": sum(b for _, b in deferred.values()),
                    "admitted_ops": adm.ops if adm else 0,
                    "admitted_bytes": adm.bytes if adm else 0,
                    "admit_wait_s": wait,
                },
                payload={
                    "ledger": ledger,               # (verb, axes) -> (ops, b)
                    "deferred": deferred,           # axes -> (ops, bytes)
                    "admitted": (adm.ops, adm.bytes) if adm else (0, 0),
                })
            self.buckets.pop(tenant_id, None)
        return state

    def import_tenant(self, tenant_id: int, state: TenantState,
                      now: Optional[float] = None) -> None:
        """Install a migrated tenant's bytes-plane state.

        Only the enforcement state (the bucket, at its transferred level,
        anchored at ``now``) lands here; the exported counters stay with
        the operator's carried ledger — see ``export_tenant``.

        Refuses a destination holding ANY live state for the tenant —
        not just a bucket: an unbucketed tenant with live ledger or
        deferred entries here would merge silently and corrupt byte
        continuity (the carried+live invariant would double-count its
        history on the next export).
        """
        if state.plane != self.plane:
            # bucket snapshots are shape-identical across planes: without
            # this guard a tokens-denominated level would silently install
            # as a bytes/s bucket
            raise ValueError(
                f"cannot import a {state.plane!r}-plane TenantState into "
                f"the {self.plane} plane")
        with self._lock:
            live = self._live_state(tenant_id)
            if live:
                raise ValueError(
                    f"tenant {tenant_id} has live bytes-plane state on "
                    f"this engine ({', '.join(live)}); migration "
                    f"requires a quiesced destination")
            if state.bucket is not None:
                self.buckets[tenant_id] = TokenBucket.restore(
                    state.bucket, now)

    # --- checkpoint / restore (failover) ----------------------------------
    def snapshot_tenant(self, tenant_id: int,
                        now: Optional[float] = None) -> TenantState:
        """Non-destructive ``export_tenant``: same wire shape, tenant
        keeps routing here. The per-(verb, axes) detail in the payload is
        the restore's source of truth (``restore_tenant`` re-installs it
        entry for entry, unlike a migration import)."""
        with self._lock:
            ledger = {(k[1], k[2]): (e.ops, e.bytes)
                      for k, e in self.ledger.items() if k[0] == tenant_id}
            deferred = {k[1]: (e.ops, e.bytes)
                        for k, e in self.deferred.items()
                        if k[0] == tenant_id}
            adm = self.admitted.get(tenant_id)
            wait = self.admit_wait_s.get(tenant_id, 0.0)
            return TenantState(
                plane="bytes",
                bucket=(self.buckets[tenant_id].snapshot(now)
                        if tenant_id in self.buckets else None),
                carried={
                    "ops": sum(o for o, _ in ledger.values()),
                    "bytes": sum(b for _, b in ledger.values()),
                    "deferred_ops": sum(o for o, _ in deferred.values()),
                    "deferred_bytes": sum(b for _, b in deferred.values()),
                    "admitted_ops": adm.ops if adm else 0,
                    "admitted_bytes": adm.bytes if adm else 0,
                    "admit_wait_s": wait,
                },
                payload={
                    "ledger": ledger,
                    "deferred": deferred,
                    "admitted": (adm.ops, adm.bytes) if adm else (0, 0),
                })

    def restore_tenant(self, tenant_id: int, state: TenantState,
                       now: Optional[float] = None) -> None:
        """Install a checkpoint snapshot onto a crashed engine: the full
        per-(verb, axes) ledger detail, deferred and admitted counters
        come back (unlike ``import_tenant``). Refused on any live state
        for the tenant — a double restore must raise, never re-add.
        Zero-valued entries are skipped: materializing them in the
        defaultdicts would make the tenant read as live forever."""
        if state.plane != self.plane:
            raise ValueError(
                f"cannot restore a {state.plane!r}-plane TenantState into "
                f"the {self.plane} plane")
        with self._lock:
            live = self._live_state(tenant_id)
            if live:
                raise ValueError(
                    f"tenant {tenant_id} has live bytes-plane state on "
                    f"this engine ({', '.join(live)}); restore requires a "
                    f"crashed/quiesced module")
            for (verb, axes), (ops, byts) in \
                    (state.payload.get("ledger") or {}).items():
                if ops or byts:
                    e = self.ledger[(tenant_id, verb, tuple(axes))]
                    e.ops, e.bytes = int(ops), int(byts)
            for axes, (ops, byts) in \
                    (state.payload.get("deferred") or {}).items():
                if ops or byts:
                    e = self.deferred[(tenant_id, tuple(axes))]
                    e.ops, e.bytes = int(ops), int(byts)
            adm_ops, adm_bytes = state.payload.get("admitted", (0, 0))
            if adm_ops or adm_bytes:
                e = self.admitted[tenant_id]
                e.ops, e.bytes = int(adm_ops), int(adm_bytes)
            wait = float(state.carried.get("admit_wait_s", 0.0))
            if wait:
                self.admit_wait_s[tenant_id] = wait
            if state.bucket is not None:
                self.buckets[tenant_id] = TokenBucket.restore(
                    state.bucket, now)

    def ground_truth_map(self) -> Dict[int, float]:
        """Every tenant's billed bytes on this engine — including tenants
        that migrated away but stay billed here."""
        with self._lock:
            return {t: float(b) for t, b in self.billed.items() if b}

    def restore_ground_truth(self, tenant_id: int, value: float) -> None:
        """SET one tenant's billed-bytes ground truth from a checkpoint."""
        with self._lock:
            self.billed[tenant_id] = int(value)

    def crash(self) -> None:
        """Simulated crash: every tenant's enforcement and accounting
        state wiped in place. Routing config (rules, default NSM, mesh,
        enforcement mode) survives — a restarted switch routes the same
        way the moment state is restored."""
        with self._lock:
            self.ledger.clear()
            self.deferred.clear()
            self.admitted.clear()
            self.admit_wait_s.clear()
            self.billed.clear()
            self.route_log.clear()
            self.throttle_log.clear()
            self.buckets.clear()

    def live_counters(self, fld: str) -> Dict[int, float]:
        """Live per-tenant totals for one ``ledger_fields`` entry,
        flattened from the per-(verb, axes) detail under the lock."""
        with self._lock:
            out: Dict[int, float] = defaultdict(float)
            if fld in ("ops", "bytes"):
                for (t, _, _), e in self.ledger.items():
                    out[t] += e.ops if fld == "ops" else e.bytes
            elif fld in ("deferred_ops", "deferred_bytes"):
                for (t, _), e in self.deferred.items():
                    out[t] += e.ops if fld == "deferred_ops" else e.bytes
            elif fld in ("admitted_ops", "admitted_bytes"):
                for t, e in self.admitted.items():
                    out[t] += e.ops if fld == "admitted_ops" else e.bytes
            elif fld == "admit_wait_s":
                for t, w in self.admit_wait_s.items():
                    out[t] += w
            else:
                raise KeyError(f"unknown bytes ledger field {fld!r}")
            return dict(out)

    def live_counter(self, tenant_id: int, fld: str) -> float:
        """One tenant's live total for one field — tallied directly under
        the lock (the migration hot path; no full-dict materialization)."""
        with self._lock:
            if fld in ("ops", "bytes"):
                return float(sum(
                    e.ops if fld == "ops" else e.bytes
                    for (t, _, _), e in self.ledger.items()
                    if t == tenant_id))
            if fld in ("deferred_ops", "deferred_bytes"):
                return float(sum(
                    e.ops if fld == "deferred_ops" else e.bytes
                    for (t, _), e in self.deferred.items()
                    if t == tenant_id))
            if fld in ("admitted_ops", "admitted_bytes"):
                e = self.admitted.get(tenant_id)
                if e is None:
                    return 0.0
                return float(e.ops if fld == "admitted_ops" else e.bytes)
            if fld == "admit_wait_s":
                return float(self.admit_wait_s.get(tenant_id, 0.0))
            raise KeyError(f"unknown bytes ledger field {fld!r}")

    def billed_ground_truth(self, tenant_id: int) -> float:
        """Bytes ever routed for the tenant on THIS engine — monotonic,
        never exported, the migration-invariant conservation reference."""
        with self._lock:
            return float(self.billed.get(tenant_id, 0))

    def inherit_ground_truth(self, old: "CoreEngine") -> None:
        """Adopt a retired engine's billed-bytes ground truth (hot-swap
        only): the replacement keeps serving the same engine slot, so the
        bytes the old stack routed must stay billed *here* or the plane's
        summed ground truth would drop and conservation would break."""
        with old._lock:
            inherited = dict(old.billed)
        with self._lock:
            for t, b in inherited.items():
                self.billed[t] += b

    def suspend(self) -> int:
        """Bytes-plane park: the switch holds no accelerator buffers, so
        suspending only trims the audit scratch (route/throttle logs).
        Enforcement state (buckets, billed ground truth) is untouched."""
        with self._lock:
            self.route_log.clear()
            self.throttle_log.clear()
        return 0

    # --- reporting ---------------------------------------------------------
    def ledger_table(self) -> List[Tuple[int, str, Tuple[str, ...], int, int]]:
        with self._lock:
            return sorted(
                (t, v, a, e.ops, e.bytes)
                for (t, v, a), e in self.ledger.items())

    def total_bytes(self, tenant_id: Optional[int] = None) -> int:
        with self._lock:
            return sum(e.bytes for (t, _, _), e in self.ledger.items()
                       if tenant_id is None or t == tenant_id)

    def snapshot(self) -> Tuple[Dict, Dict]:
        """Consistent copy of (ledger, deferred) counters under the lock —
        the telemetry read path (iterating the live dicts races dispatch)."""
        with self._lock:
            return ({k: (e.ops, e.bytes) for k, e in self.ledger.items()},
                    {k: (e.ops, e.bytes) for k, e in self.deferred.items()})

    def deferred_bytes(self, tenant_id: Optional[int] = None) -> int:
        with self._lock:
            return sum(e.bytes for (t, _), e in self.deferred.items()
                       if tenant_id is None or t == tenant_id)

    def admit_snapshot(self) -> Dict[int, Tuple[int, int, float]]:
        """Per-tenant (admitted_ops, admitted_bytes, cumulative shaping
        delay s) — the engine-side admission-latency ledger."""
        with self._lock:
            return {t: (e.ops, e.bytes, self.admit_wait_s.get(t, 0.0))
                    for t, e in self.admitted.items()}

    def reset_ledger(self) -> None:
        with self._lock:
            self.ledger.clear()
            self.deferred.clear()
            self.admitted.clear()
            self.admit_wait_s.clear()
            self.billed.clear()
            self.route_log.clear()
            self.throttle_log.clear()


# ---------------------------------------------------------------------------
# Stock operator policies (what `RunConfig.nsm_policy` selects)
# ---------------------------------------------------------------------------


def make_engine(mesh, policy: str = "xla") -> CoreEngine:
    """Build a CoreEngine with one of the stock routing policies.

    xla           everything on the native stack (paper-faithful baseline:
                  "the kernel stack NSM").
    ring          large payloads on the explicit ring stack, small ops native
                  (message-size-based stack selection).
    hierarchical  multi-axis reductions 2-level; rest native.
    compressed    gradient-flagged psums on slow axes int8; rest hierarchical.
    shm-first     sharding-compatible moves elided, rest native.
    """
    eng = CoreEngine(mesh=mesh, default_nsm="xla")
    if policy == "xla":
        pass
    elif policy == "ring":
        eng.add_rule("large-to-ring",
                     lambda op: op.size_bytes >= 1 << 20 and op.verb in
                     ("psum", "all_gather", "reduce_scatter"), "ring2")
    elif policy == "hierarchical":
        eng.add_rule("multiaxis-psum",
                     lambda op: op.verb == "psum" and len(op.axes) > 1,
                     "hierarchical")
    elif policy == "compressed":
        eng.add_rule("grad-pod-psum",
                     lambda op: op.verb == "psum" and bool(op.flags & 1)
                     and "pod" in op.axes, "compressed")
        eng.add_rule("multiaxis-psum",
                     lambda op: op.verb == "psum" and len(op.axes) > 1,
                     "hierarchical")
    elif policy == "shm-first":
        eng.add_rule("elide-compatible",
                     lambda op: bool(op.op_data & 1), "shm")
    else:
        raise ValueError(f"unknown nsm policy {policy!r}")
    return eng
