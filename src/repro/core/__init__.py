"""repro.core — the paper's contribution: network stack as infrastructure.

CommOp (NQE), NSMs (pluggable collective stacks), CoreEngine (switch,
accounting, isolation) and the nk_* socket-boundary API.
"""
from repro.core.nqe import CommOp, NQE_SIZE, VERBS
from repro.core.nsm import (
    Nsm, XlaNsm, RingNsm, HierarchicalNsm, CompressedNsm, ShmNsm,
    available_nsms, get_nsm, register_nsm,
)
from repro.core.engine import CoreEngine, TokenBucket, make_engine
from repro.core.collectives import (
    current_engine, nk_all_gather, nk_all_to_all, nk_grad_sync, nk_ppermute,
    nk_psum, nk_reduce_scatter, use_engine,
)

__all__ = [
    "CommOp", "NQE_SIZE", "VERBS",
    "Nsm", "XlaNsm", "RingNsm", "HierarchicalNsm", "CompressedNsm", "ShmNsm",
    "available_nsms", "get_nsm", "register_nsm",
    "CoreEngine", "TokenBucket", "make_engine",
    "current_engine", "use_engine",
    "nk_psum", "nk_all_gather", "nk_reduce_scatter", "nk_all_to_all",
    "nk_ppermute", "nk_grad_sync",
]
