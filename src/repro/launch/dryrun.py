import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes need 512 host placeholder devices.

For every cell this script:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. lowers + compiles the cell's step (train_step / prefill_step /
     serve_step) with full parameter/optimizer/cache shardings,
  3. prints ``compiled.memory_analysis()`` (fits-per-chip proof) and
     ``compiled.cost_analysis()`` (FLOPs/bytes for §Roofline),
  4. parses the compiled HLO for the collective schedule (§Roofline's
     collective term),
  5. [single-pod] runs the layer-differencing cost probes (see roofline.py),
  6. writes a JSON artifact to results/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  python -m repro.launch.dryrun --all                 # every runnable cell
  python -m repro.launch.dryrun --all --multi-pod
  python -m repro.launch.dryrun --report              # assemble tables
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import (
    ARCHS, SHAPES, RunConfig, get_config, get_shape, shape_applicable,
)
from repro.distribution.sharding import (
    ShardingCtx, abstract_params, param_shardings,
)
from repro.launch import roofline as rl
from repro.launch.mesh import data_axes, make_production_mesh
from repro.models.model import (
    build_schedule, cache_schema, forward_decode, forward_prefill,
    input_specs, model_schema,
)
from repro.train.train_loop import (
    batch_shardings, make_train_state, make_train_step, state_shardings,
)

RESULTS = os.path.join(os.path.dirname(__file__), "../../../results/dryrun")


def run_config_for(arch: str, shape_name: str, probe: bool = False) -> RunConfig:
    """Operator-side per-cell parallelism/numerics table (see DESIGN.md §4).

    Small/medium dense archs train pure-FSDP (batch over the whole mesh);
    MoE + the 340B dense train 2D (FSDP x TP) with sequence-parallel
    activations; >=300B models use bf16 moments, factored second moment and
    gradient accumulation to fit 16 GB/chip. Serving shapes always use the
    2D rules (batch over data, KV-sequence context-parallel over model).
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    nparams = cfg.num_params()
    seq = shape.seq_len
    blk = 512 if seq <= 4096 else 2048
    kw: Dict = dict(
        attn_q_block=blk, attn_kv_block=blk, remat="full",
        force_unroll_segments=probe,
    )
    if shape.kind == "train":
        if cfg.moe is not None or nparams > 60e9:
            kw["rules_variant"] = "2d"
            kw["seq_parallel_activations"] = True
        else:
            kw["rules_variant"] = "fsdp"
        if nparams > 100e9:
            kw.update(moment_dtype="bfloat16", factored_nu=True,
                      grad_accum_dtype="bfloat16",
                      grad_accum=16 if nparams > 300e9 else
                      (8 if nparams > 200e9 else 4))
    elif shape.kind == "decode":
        # serving: never gather weights per token — TP when they fit
        # replicated over 'data' (<~60B at 16-way model sharding)
        kw["rules_variant"] = "tp" if nparams < 60e9 else "2d"
    return RunConfig(**kw)


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


def build_cell(cfg, shape, mesh, rcfg):
    """Returns (fn, args, in_shardings, donate) for jit."""
    from repro.distribution.sharding import make_rules
    rules = make_rules(rcfg.rules_variant)
    shd = ShardingCtx(mesh, rules=rules,
                      seq_parallel=rcfg.seq_parallel_activations)
    specs = input_specs(cfg, shape)
    if shape.kind == "train":
        step = make_train_step(cfg, rcfg, mesh)
        state = make_train_state(cfg, rcfg, mesh, abstract=True)
        ssh = state_shardings(cfg, rcfg, mesh)
        bsh = batch_shardings(cfg, mesh, rcfg=rcfg,
                              global_batch=shape.global_batch)
        batch = {k: specs[k] for k in bsh}
        return step, (state, batch), (ssh, bsh), (0,)
    from repro.distribution.sharding import sharding_for
    params = abstract_params(model_schema(cfg, mesh))
    psh = param_shardings(model_schema(cfg, mesh), mesh, rules)
    b = shape.global_batch
    tok_sh = sharding_for((b, 1), ("batch", None), mesh, rules)
    if shape.kind == "prefill":
        def prefill_fn(p, tokens, frames=None):
            return forward_prefill(p, tokens, cfg, shd, rcfg,
                                   max_seq=shape.seq_len, frames=frames)
        args = [params, specs["tokens"]]
        insh = [psh, tok_sh]
        if cfg.encoder_layers:
            args.append(specs["frames"])
            insh.append(sharding_for((b, 1, 1), ("batch", None, None), mesh))
        return prefill_fn, tuple(args), tuple(insh), ()
    # decode (serve_step): one new token against a seq_len cache
    csh = param_shardings(cache_schema(cfg, shape.global_batch,
                                       shape.seq_len), mesh, rules)

    def serve_step(p, caches, tokens, pos):
        return forward_decode(p, caches, tokens, pos, cfg, shd, rcfg)

    pos_sh = sharding_for((b,), ("batch",), mesh, rules)
    return (serve_step,
            (params, specs["caches"], specs["tokens"], specs["pos"]),
            (psh, csh, tok_sh, pos_sh), (1,))


def lower_compile(cfg, shape, mesh, rcfg) -> Tuple[object, float, float]:
    fn, args, insh, donate = build_cell(cfg, shape, mesh, rcfg)
    t0 = time.time()
    lowered = jax.jit(fn, in_shardings=insh,
                      donate_argnums=donate).lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    return compiled, t1 - t0, t2 - t1


# ---------------------------------------------------------------------------
# Layer-differencing probes (single-pod roofline)
# ---------------------------------------------------------------------------


def probe_pair(cfg):
    """(cfgA, cfgB, extra_scanned_layers, scanned_layers_in_prod)."""
    if cfg.family == "hybrid":
        a = dataclasses.replace(cfg, num_layers=5, global_attn_layers=(0, 2, 4))
        b = dataclasses.replace(cfg, num_layers=7, global_attn_layers=(0, 3, 6))
        return a, b, 2, cfg.num_layers - len(cfg.global_attn_layers)
    prefix = cfg.dense_layer_prefix if cfg.moe is not None else 0
    a = dataclasses.replace(cfg, num_layers=prefix + 1)
    b = dataclasses.replace(cfg, num_layers=prefix + 2)
    return a, b, 1, cfg.num_layers - prefix


def run_probes(cfg, shape, mesh) -> Dict:
    """Layer-differencing FLOP probes (bytes come from the full artifact's
    post-fusion HLO accounting instead — XLA CPU cost_analysis reports
    pre-fusion bytes, measured ~10x real traffic)."""
    rcfg = run_config_for(cfg.name, shape.name, probe=True)
    # grad accumulation is a scan: cost_analysis would count one microbatch
    # only. Probes always run the full batch in a single microbatch.
    rcfg = dataclasses.replace(rcfg, grad_accum=1)
    ca, cb, extra, scanned_prod = probe_pair(cfg)
    costs = []
    for c in (ca, cb):
        compiled, tl, tc = lower_compile(c, shape, mesh, rcfg)
        costs.append(compiled.cost_analysis())
    fa, fb = costs[0].get("flops", 0.0), costs[1].get("flops", 0.0)
    per_flops = max(fb - fa, 0.0) / extra
    fixed_flops = max(fa - _probe_scanned_layers(ca, cfg) * per_flops, 0.0)
    return {"flops_per_chip": fixed_flops + scanned_prod * per_flops,
            "per_layer_flops": per_flops, "fixed_flops": fixed_flops,
            "probe_bytes_upper_bound": [costs[0].get("bytes accessed", 0.0),
                                        costs[1].get("bytes accessed", 0.0)]}


def _probe_scanned_layers(probe_cfg, prod_cfg) -> int:
    if prod_cfg.family == "hybrid":
        return probe_cfg.num_layers - len(probe_cfg.global_attn_layers)
    prefix = prod_cfg.dense_layer_prefix if prod_cfg.moe is not None else 0
    return probe_cfg.num_layers - prefix


# ---------------------------------------------------------------------------
# Cell driver
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             with_probes: bool = True, out_dir: Optional[str] = None) -> Dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    ok, why = shape_applicable(cfg, shape)
    rec: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "chips": 512 if multi_pod else 256,
                 "model_flops_global": rl.model_flops(cfg, shape)}
    if not ok:
        rec.update(skipped=True, skip_reason=why)
        _write(rec, out_dir)
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: SKIP ({why})")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    rcfg = run_config_for(arch, shape_name)
    compiled, t_lower, t_compile = lower_compile(cfg, shape, mesh, rcfg)
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    per_chip_gb = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                   + ma.output_size_in_bytes) / 1e9
    txt = compiled.as_text()
    coll_total, coll_kinds = rl.collective_bytes(txt)
    hbm_traffic = rl.hlo_traffic_bytes(txt)
    rec.update(
        skipped=False,
        compile_seconds=t_lower + t_compile,
        memory={"argument_gb": ma.argument_size_in_bytes / 1e9,
                "temp_gb": ma.temp_size_in_bytes / 1e9,
                "output_gb": ma.output_size_in_bytes / 1e9,
                "total_gb": per_chip_gb,
                "fits_16gb": bool(per_chip_gb < rl.HBM_BYTES / 1e9)},
        cost_analysis={"flops": ca.get("flops", 0.0),
                       "bytes_accessed": ca.get("bytes accessed", 0.0)},
        hbm_traffic_bytes_per_chip=hbm_traffic,
        collectives={"payload_bytes_per_chip": coll_total,
                     "by_kind": coll_kinds},
    )
    print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: compiled in "
          f"{t_lower + t_compile:.1f}s")
    print(f"  memory_analysis: {per_chip_gb:.2f} GB/chip "
          f"(args {ma.argument_size_in_bytes / 1e9:.2f} + temp "
          f"{ma.temp_size_in_bytes / 1e9:.2f}) fits16GB="
          f"{per_chip_gb < 16.0}")
    print(f"  cost_analysis: flops/chip={ca.get('flops', 0):.3e} "
          f"bytes/chip={ca.get('bytes accessed', 0):.3e} (scan body once)")
    print(f"  collectives/chip: {coll_total / 1e9:.3f} GB  {coll_kinds}")

    if not with_probes and not multi_pod:
        # refresh pass: reuse previously computed probes if present on disk
        name = f"{arch}__{shape_name}__{mesh_name}.json"
        path = os.path.join(out_dir or RESULTS, name)
        if os.path.exists(path):
            try:
                with open(path) as f:
                    old = json.load(f)
                if "probes" in old and "flops_per_chip" in old["probes"]:
                    rec["probes"] = old["probes"]
                    with_probes = True
            except Exception:
                pass
    if with_probes and not multi_pod:
        probes = rec.get("probes") or run_probes(cfg, shape, mesh)
        rec["probes"] = probes
        cell = rl.RooflineCell(
            arch=arch, shape=shape_name, mesh=mesh_name, chips=rec["chips"],
            flops_per_chip=probes["flops_per_chip"],
            hbm_bytes_per_chip=hbm_traffic,
            coll_bytes_per_chip=coll_total, coll_by_kind=coll_kinds,
            model_flops_global=rec["model_flops_global"],
            memory_per_chip_gb=per_chip_gb,
            compile_seconds=rec["compile_seconds"],
            ideal_bytes_global=rl.ideal_bytes(cfg, shape))
        rec["roofline"] = cell.to_json()
        print(f"  roofline: t_comp={rl.fmt_seconds(cell.t_compute)} "
              f"t_mem={rl.fmt_seconds(cell.t_memory)} "
              f"t_coll={rl.fmt_seconds(cell.t_collective)} "
              f"dominant={cell.dominant} useful={cell.useful_ratio:.2f} "
              f"frac={cell.roofline_fraction:.2%}")
    _write(rec, out_dir)
    return rec


def _write(rec: Dict, out_dir: Optional[str]):
    """Merge-write: refresh passes keep fields they didn't recompute
    (e.g. --no-probes keeps an earlier run's probes/roofline)."""
    out_dir = out_dir or RESULTS
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    path = os.path.join(out_dir, name)
    merged: Dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                merged = json.load(f)
        except Exception:
            merged = {}
    merged.update(rec)
    with open(path, "w") as f:
        json.dump(merged, f, indent=1, default=str)
    rec.clear()
    rec.update(merged)


def report(out_dir: Optional[str] = None) -> str:
    out_dir = out_dir or RESULTS
    cells = []
    recs = []
    for name in sorted(os.listdir(out_dir)):
        if not name.endswith(".json"):
            continue
        with open(os.path.join(out_dir, name)) as f:
            recs.append(json.load(f))
    lines = ["| arch | shape | mesh | compile | mem/chip | fits | "
             "collective GB/chip |", "|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"SKIP: {r['skip_reason'][:40]} | - | - | - |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compile_seconds']:.1f}s | {r['memory']['total_gb']:.2f} GB |"
            f" {'Y' if r['memory']['fits_16gb'] else 'N'} | "
            f"{r['collectives']['payload_bytes_per_chip'] / 1e9:.3f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS))
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--report", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.report:
        print(report(args.out))
        return

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for a, s in cells:
        try:
            run_cell(a, s, args.multi_pod, with_probes=not args.no_probes,
                     out_dir=args.out)
        except Exception:
            failures.append((a, s))
            print(f"[dryrun] FAILED {a} x {s}:\n{traceback.format_exc()}")
    if failures:
        print(f"[dryrun] {len(failures)} failures: {failures}")
        raise SystemExit(1)
    print("[dryrun] all requested cells compiled")


if __name__ == "__main__":
    main()
