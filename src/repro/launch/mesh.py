"""Mesh construction for the production pods and for local tests.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def _make(shape, axes):
    return make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod; multi_pod adds the cross-pod ('pod') axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over host (CPU) devices for tests and smoke runs."""
    if pod:
        return _make((pod, data, model), ("pod", "data", "model"))
    return _make((data, model), ("data", "model"))


def make_single_device_mesh():
    return make_host_mesh(1, 1)


def axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def data_axes(mesh):
    """Axes the global batch is sharded over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
