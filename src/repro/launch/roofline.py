"""Roofline analysis from compiled artifacts (TPU v5e target, CPU container).

Three terms per (arch x shape x mesh) cell — see DESIGN.md §7:

  compute term    = HLO_FLOPs_per_chip / PEAK_FLOPS
  memory term     = HLO_bytes_per_chip / HBM_BW
  collective term = collective_payload_bytes_per_chip / ICI_BW

FLOPs/bytes come from *layer-differencing probes*: XLA's ``cost_analysis``
counts a ``while`` (scan) body once and reports per-device numbers (verified
empirically), so we lower the same step at two unrolled depths and take the
difference as the exact per-layer cost:  total = fixed + sum_seg count*per.

Collective bytes come from walking the compiled HLO text: computations are
parsed, ``while`` bodies are multiplied by their ``known_trip_count`` (XLA
records it in backend_config), and every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute contributes its payload
bytes. The CoreEngine trace-time ledger cross-checks intent counts.
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# --- hardware constants (TPU v5e, per chip) ---
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link
HBM_BYTES = 16 * 1024 ** 3   # 16 GiB

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for tok in dims.split(","):
        tok = tok.strip()
        if tok:
            n *= int(tok)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveOp:
    kind: str
    dtype: str
    bytes: int
    computation: str
    multiplier: int = 1


# NOTE: computation params may be tuple-typed (nested parens) — match
# greedily up to the last ') ->' on the header line.
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->", re.M)
_OP_SHAPE_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([\d,\s]*)\][^\n]*?\b(" + "|".join(COLLECTIVES) + r")\(")
_CALLED_ONE_RE = re.compile(
    r"(?:to_apply|body|condition|calls|true_computation|"
    r"false_computation)=%?([\w\.\-]+)")
_CALLED_LIST_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')


def _called_names(line: str):
    names = _CALLED_ONE_RE.findall(line)
    for group in _CALLED_LIST_RE.findall(line):
        names.extend(n.strip().lstrip("%") for n in group.split(","))
    return [n for n in names if n]


def parse_hlo_collectives(hlo_text: str) -> List[CollectiveOp]:
    """Collectives with trip-count multipliers from compiled HLO text."""
    # split into computations
    comps: Dict[str, List[str]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            if line.strip().startswith("ENTRY"):
                entry = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)

    # call graph with per-edge multiplier (while bodies x trip_count)
    edges: Dict[str, List[Tuple[str, int]]] = defaultdict(list)
    for name, lines in comps.items():
        for line in lines:
            called = _called_names(line)
            if not called:
                continue
            mult = 1
            if re.search(r"\bwhile\(", line):
                t = _TRIP_RE.search(line)
                mult = int(t.group(1)) if t else 1
            for c in called:
                if c in comps:
                    edges[name].append((c, mult))

    # multiplier per computation (DFS from entry; DAG-ish, cycles guarded)
    mults: Dict[str, int] = defaultdict(int)

    def walk(name: str, m: int, depth=0):
        if depth > 50:
            return
        mults[name] += m
        for child, em in edges.get(name, []):
            walk(child, m * em, depth + 1)

    if entry:
        walk(entry, 1)
    else:  # fallback: everything counted once
        for name in comps:
            mults[name] = 1

    out: List[CollectiveOp] = []
    for name, lines in comps.items():
        mult = mults.get(name, 0)
        if mult == 0:
            continue
        for line in lines:
            m = _OP_SHAPE_RE.search(line)
            if m:
                dt, dims, kind = m.groups()
                out.append(CollectiveOp(kind=kind, dtype=dt,
                                        bytes=_shape_bytes(dt, dims),
                                        computation=name, multiplier=mult))
    return out


def collective_bytes(hlo_text: str) -> Tuple[int, Dict[str, int]]:
    ops = parse_hlo_collectives(hlo_text)
    per_kind: Dict[str, int] = defaultdict(int)
    for op in ops:
        per_kind[op.kind] += op.bytes * op.multiplier
    return sum(per_kind.values()), dict(per_kind)


# ---------------------------------------------------------------------------
# Post-fusion HBM traffic from HLO text.
#
# XLA's CPU HloCostAnalysis reports pre-fusion "bytes accessed" (~10x real
# traffic — measured), so we account bytes ourselves on the *optimized*
# module: every op in a non-fused computation contributes its output bytes
# plus its operands' bytes (shapes resolved through a def-map); computations
# reachable only through ``fusion(...)`` calls are interior (free); while
# bodies are multiplied by their known trip count. This mirrors what
# HloCostAnalysis does on TPU, where fusions hide interior traffic.
# ---------------------------------------------------------------------------

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[^=]*?)\s([a-z][a-z0-9\-]*)\(")
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([\d,\s]*)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_SKIP_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast", "constant",
             "after-all", "partition-id", "replica-id", "iota"}


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            n = 1
            for tok in dims.split(","):
                tok = tok.strip()
                if tok:
                    n *= int(tok)
            total += n * _DTYPE_BYTES[dt]
    return total


def hlo_traffic_bytes(hlo_text: str) -> int:
    """Estimated per-chip HBM traffic (bytes/step) from compiled HLO."""
    comps: Dict[str, List[str]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            if line.strip().startswith("ENTRY"):
                entry = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)

    # def-map: value name -> bytes; call edges; fusion-interior set
    sizes: Dict[str, int] = {}
    interior: set = set()
    edges: Dict[str, List[Tuple[str, int]]] = defaultdict(list)
    for name, lines in comps.items():
        for line in lines:
            d = _DEF_RE.match(line)
            if d:
                vname, vtype, op = d.groups()
                sizes[vname] = _type_bytes(vtype)
            called = _called_names(line)
            if called:
                mult = 1
                is_fusion = bool(re.search(r"\bfusion\(", line))
                if re.search(r"\bwhile\(", line):
                    t = _TRIP_RE.search(line)
                    mult = int(t.group(1)) if t else 1
                for c in called:
                    if c in comps:
                        if is_fusion:
                            interior.add(c)
                        else:
                            edges[name].append((c, mult))

    mults: Dict[str, int] = defaultdict(int)

    def walk(nm, m, depth=0):
        if depth > 50:
            return
        mults[nm] += m
        for child, em in edges.get(nm, []):
            walk(child, m * em, depth + 1)

    if entry:
        walk(entry, 1)
    else:
        for nm in comps:
            mults[nm] = 1

    total = 0
    for name, lines in comps.items():
        if name in interior:
            continue
        mult = mults.get(name, 0)
        if mult == 0:
            continue
        for line in lines:
            d = _DEF_RE.match(line)
            if not d:
                continue
            vname, vtype, op = d.groups()
            if op in _SKIP_OPS or op in ("while", "conditional", "call"):
                continue  # control ops: bodies accounted via multipliers
            out_b = sizes.get(vname, 0)
            # operands: names after the op's open paren
            tail = line.split(op + "(", 1)[1] if op + "(" in line else ""
            tail = tail.split("),", 1)[0]
            in_b = sum(sizes.get(o, 0) for o in _OPERAND_RE.findall(tail))
            total += (out_b + in_b) * mult
    return total


# ---------------------------------------------------------------------------
# Roofline assembly
# ---------------------------------------------------------------------------


@dataclass
class RooflineCell:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    hbm_bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_by_kind: Dict[str, int]
    model_flops_global: float
    memory_per_chip_gb: float
    compile_seconds: float
    ideal_bytes_global: float = 0.0
    skipped: bool = False
    skip_reason: str = ""
    notes: str = ""

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        hlo_global = self.flops_per_chip * self.chips
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def t_ideal(self) -> float:
        """Roofline floor: the better of the compute and memory walls for
        the *useful* work (model FLOPs / minimal bytes)."""
        return max(self.model_flops_global / (self.chips * PEAK_FLOPS),
                   self.ideal_bytes_global / (self.chips * HBM_BW))

    @property
    def roofline_fraction(self) -> float:
        """t_ideal / modeled step time (max of the three terms, perfect
        overlap assumed) — the score we hillclimb, meaningful for both
        compute-bound (train) and memory-bound (decode) cells."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t <= 0:
            return 0.0
        return min(self.t_ideal / t, 1.0)

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, dominant=self.dominant,
                 useful_ratio=self.useful_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6*N_active*D (train), 2*N_active*D (prefill),
    2*N_active*B (decode, per step)."""
    n = cfg.num_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch


def cache_bytes_global(cfg, shape, dtype_bytes: int = 2) -> float:
    """Decode-cell KV/state cache size (the floor of decode HBM traffic)."""
    b, s = shape.global_batch, shape.seq_len
    total = 0.0
    mla = cfg.mla
    for i in range(cfg.num_layers):
        window = 0
        if cfg.attn_window and i not in cfg.global_attn_layers:
            window = cfg.attn_window
        n_slots = min(s, window) if window else s
        if cfg.family == "ssm":
            pass
        elif mla is not None:
            total += b * n_slots * (mla.kv_lora_rank + mla.qk_rope_head_dim) \
                * dtype_bytes
        elif cfg.family in ("dense", "moe", "vlm", "encdec", "hybrid"):
            total += 2 * b * n_slots * cfg.num_kv_heads * cfg.head_dim \
                * dtype_bytes
        if cfg.ssm is not None:
            ss = cfg.ssm
            total += b * ss.num_heads(cfg.d_model) * ss.head_dim \
                * ss.state_dim * 4
    return total


def ideal_bytes(cfg, shape) -> float:
    """Global minimal HBM traffic per step (documented floor, not a bound
    proof): weights read fwd(+remat+bwd for train), optimizer state r/w,
    a small per-layer activation budget, plus the full cache for decode."""
    n = cfg.num_active_params()
    n_tot = cfg.num_params()
    b, s = shape.global_batch, shape.seq_len
    act = 6.0 * b * s * cfg.d_model * 2 * cfg.num_layers
    if shape.kind == "train":
        return 3 * 2 * n + 10 * n_tot + act     # weights x3, opt state r/w
    if shape.kind == "prefill":
        return 2 * n + act + cache_bytes_global(cfg, shape)
    act = 6.0 * b * 1 * cfg.d_model * 2 * cfg.num_layers
    return 2 * n + act + cache_bytes_global(cfg, shape)


def fmt_seconds(s: float) -> str:
    if s >= 1:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.2f}ms"
    return f"{s * 1e6:.1f}us"


def markdown_table(cells: List[RooflineCell]) -> str:
    hdr = ("| arch | shape | mesh | dominant | t_compute | t_memory | "
           "t_collective | useful | roofline | mem/chip |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for c in cells:
        if c.skipped:
            rows.append(f"| {c.arch} | {c.shape} | {c.mesh} | SKIP | - | - | "
                        f"- | - | - | - |")
            continue
        rows.append(
            f"| {c.arch} | {c.shape} | {c.mesh} | **{c.dominant}** | "
            f"{fmt_seconds(c.t_compute)} | {fmt_seconds(c.t_memory)} | "
            f"{fmt_seconds(c.t_collective)} | {c.useful_ratio:.2f} | "
            f"{c.roofline_fraction:.2%} | {c.memory_per_chip_gb:.2f} GB |")
    return hdr + "\n".join(rows)
