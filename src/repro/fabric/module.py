"""StackModule: the one tenant-lifecycle protocol both planes implement.

Before this layer the two planes each grew a private copy of the same
interface — ``TenantScheduler.export_tenant``/WFQ/buckets on the serve
plane, ``CoreEngine.export_tenant``/``import_tenant``/ledger on the bytes
plane — stitched together by ``EngineCluster.migrate`` with two parallel
fold paths and two conservation asserts. Here the interface is extracted
once:

  * ``TenantState`` — the uniform transferable unit: a token-bucket
    snapshot, the flattened cumulative counters the operator *carries*
    (never replayed into a destination, where the jump would read as a
    rate spike to telemetry), and a plane-specific payload (the serve
    plane's unserved queue + WFQ weight; the bytes plane's per-(verb,
    axes) ledger detail).
  * ``StackModule`` — the protocol: ``export_tenant`` / ``import_tenant``
    / ``fold`` / ``billed_ground_truth`` / ``tenant_load`` / ``suspend``
    / ``resume`` plus the read surface (``has_tenant``,
    ``live_counters``, ``load``, ``resident_bytes``) the cluster and the
    placement loop consume. A module that holds accelerator buffers
    (KV-cache, slot state) releases them in ``suspend`` and lazily
    re-materializes them after ``resume`` — parking an engine is a real
    memory saving, not just skipped steps.
  * ``ConservationLedger`` — ONE carried-ledger + conservation-assert
    implementation shared by every plane: carried (migrated-away) history
    plus each module's live counters must equal the sum of the modules'
    billed ground truth at every instant. The serve plane's ground truth
    is request-level (prompt+generated tokens over completed and
    in-flight requests); the bytes plane's is the monotonic billed-bytes
    counter that never migrates (the analog of completed-request records
    staying on the engine that served them).

Nothing here imports an engine class: modules are duck-typed, so the
whole lifecycle is unit-testable without a jit anywhere near the test.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.obs import tracing
from repro.obs.hist import TenantHistograms


@dataclass
class TenantState:
    """One tenant's transferable state, exported from a ``StackModule``.

    Attributes:
        plane: the exporting module's plane name ("serve", "bytes", ...).
        bucket: ``TokenBucket.snapshot`` output (rate/capacity/tokens/
            updated), or None when the tenant was uncapped. The *level*
            travels with the tenant so a migration can never reopen a
            fresh burst.
        carried: flattened cumulative counters, keyed by the module's
            ``ledger_fields`` — what ``ConservationLedger.fold`` adds to
            the operator's carried view. Deliberately NOT replayed into a
            destination module.
        payload: plane-specific transfer detail — the serve plane's
            unserved ``queue`` (FIFO list of Requests) and WFQ
            ``weight``; the bytes plane's per-(verb, axes) ``ledger`` /
            ``deferred`` / ``admitted`` breakdown.
    """

    plane: str
    bucket: Optional[Dict[str, float]]
    carried: Dict[str, float]
    payload: Dict[str, Any] = field(default_factory=dict)

    @property
    def bucket_tokens(self) -> float:
        """Token-bucket level travelling with the tenant (0.0 if uncapped)."""
        return (self.bucket or {}).get("tokens", 0.0)

    @property
    def queue(self) -> Sequence:
        """The unserved work moving with the tenant (empty for planes
        that hold no queues)."""
        return self.payload.get("queue", ())


@dataclass(frozen=True)
class TenantLoad:
    """One tenant's instantaneous pressure on one module — the placement
    loop's (and the drain-cost model's) per-tenant signal.

    Units: ``pending``/``inflight`` are requests (queued, resp. decode
    slots held); ``queued_tokens``/``inflight_tokens`` are tokens (the
    drain-cost model's unit: what a migration would start serving at the
    destination vs what it strands draining on the source).
    """

    pending: int = 0
    inflight: int = 0
    queued_tokens: float = 0.0
    inflight_tokens: float = 0.0


class StackModule:
    """The uniform stack-module interface (NetKernel's NSM, as a protocol).

    Concrete planes subclass this (``ServeEngine`` via
    ``SchedulerServeModule``, ``CoreEngine`` directly) and the cluster /
    placement layers operate on it exclusively — no isinstance checks, no
    per-plane fold paths, one conservation assert.

    Class attributes each plane pins:
        plane: short plane name, labels ``TenantState`` and asserts.
        ledger_fields: counter names ``export_tenant`` flattens into
            ``TenantState.carried`` and ``live_counters`` serves.
        conserved_field: the one field conservation is asserted on
            ("served_tokens" for serve, "bytes" for the bytes plane).
    """

    plane: str = "stack"
    ledger_fields: Tuple[str, ...] = ()
    conserved_field: str = ""

    # -- tenant lifecycle (migration) ---------------------------------------
    def export_tenant(self, tenant_id: int,
                      now: Optional[float] = None) -> TenantState:
        """Atomically remove a tenant and return its transferable state."""
        raise NotImplementedError

    def import_tenant(self, tenant_id: int, state: TenantState,
                      now: Optional[float] = None) -> None:
        """Install an exported tenant; raises if the destination is not
        quiesced for it (any live state — a silent merge would corrupt
        continuity)."""
        raise NotImplementedError

    def has_tenant(self, tenant_id: int) -> bool:
        """True iff this module holds ANY live state for the tenant — the
        quiesced-destination check ``migrate`` runs BEFORE the
        destructive export."""
        raise NotImplementedError

    # -- checkpoint lifecycle (failover) ------------------------------------
    def snapshot_tenant(self, tenant_id: int,
                        now: Optional[float] = None) -> TenantState:
        """Non-destructive ``export_tenant``: the same ``TenantState``
        wire shape, but the tenant keeps running here — the checkpoint
        half of failover. Unlike an export, the snapshot also captures
        the module's LIVE cumulative counters in ``carried`` (a restore
        re-installs them so the post-crash ledger picks up exactly where
        the checkpoint left it)."""
        raise NotImplementedError

    def restore_tenant(self, tenant_id: int, state: TenantState,
                       now: Optional[float] = None) -> None:
        """Install a snapshot onto a crashed-and-rebuilt module: full
        state INCLUDING counters, unlike ``import_tenant`` (which carries
        counters in the operator's ledger instead). Refuses a
        destination with any live state for the tenant — restoring twice
        after a failed attempt must raise, never silently re-add."""
        raise NotImplementedError

    def ground_truth_map(self) -> Dict[int, float]:
        """Every tenant's billed ground truth on this module — including
        tenants that migrated away but left their never-migrates history
        (completed records / billed bytes) here. A checkpoint captures
        this whole map; restoring only currently-placed tenants would
        drop the departed tenants' share and break conservation."""
        raise NotImplementedError

    def restore_ground_truth(self, tenant_id: int, value: float) -> None:
        """SET (never add) one tenant's billed-ground-truth share on a
        crashed-and-rebuilt module, from a checkpoint's
        ``ground_truth_map``."""
        raise NotImplementedError

    def crash(self) -> None:
        """Simulated module crash: wipe ALL live state in place —
        queues, slots, counters, ground truth. Routing/config survives
        (a restarted stack keeps its build config); telemetry reads the
        counter drop as a reset (Prometheus discipline)."""
        raise NotImplementedError

    def fold(self, state: TenantState) -> Dict[str, float]:
        """Ledger-field increments an export contributes to the carried
        view. Default: the state's own flattened counters."""
        return dict(state.carried)

    # -- conservation read surface ------------------------------------------
    def live_counters(self, fld: str) -> Dict[int, float]:
        """Live per-tenant counters for one ``ledger_fields`` entry."""
        raise NotImplementedError

    def live_counter(self, tenant_id: int, fld: str) -> float:
        """One tenant's live counter for one field — the migration hot
        path (``ConservationLedger.total`` runs per move); planes
        override with a direct read instead of materializing the full
        per-tenant dict."""
        return self.live_counters(fld).get(tenant_id, 0)

    def billed_ground_truth(self, tenant_id: int) -> float:
        """This module's share of the tenant's ground truth in
        ``conserved_field`` units — state that NEVER migrates (completed
        requests stay where they billed; routed bytes stay billed where
        they were routed), so summing it over all modules is the
        migration-invariant reference the carried+live ledger must equal.
        """
        raise NotImplementedError

    def inherit_ground_truth(self, old: "StackModule") -> None:
        """Adopt a retired module's billed ground truth (hot-swap only).

        A live stack swap replaces a module *in place*: the replacement
        keeps serving the same engine slot, so the retired module's
        never-migrates state (completed-request records, billed-bytes
        counters) must move to the replacement or the plane's summed
        ground truth would drop by everything the old stack ever billed
        and the conservation assert would fire. Default: nothing to
        inherit (a stateless plane)."""
        return None

    # -- placement read surface ---------------------------------------------
    def tenant_load(self, tenant_id: int) -> TenantLoad:
        """One tenant's instantaneous pressure here (zeros for planes
        with no queue/slot machinery)."""
        return TenantLoad()

    def load(self) -> float:
        """Total demand pressure on this module (queued + in-flight
        requests) — the cluster's hot/cool signal."""
        return 0.0

    # -- park lifecycle (the memory-saved claim) ----------------------------
    def suspend(self) -> int:
        """Release droppable buffers (KV-cache, slot state, scratch) for a
        quiesced module; returns the bytes freed. Default: nothing to
        free."""
        return 0

    def resume(self) -> int:
        """Undo ``suspend``: the module can serve again; buffers may
        re-materialize lazily on first use. Returns the bytes made
        resident eagerly (0 when lazy)."""
        return 0

    def resident_bytes(self) -> int:
        """Droppable buffer bytes currently resident (0 while suspended
        or before lazy re-init)."""
        return 0


class SchedulerServeModule(StackModule):
    """Serve-plane ``StackModule`` over the scheduler + slot surface.

    Anything with a ``TenantScheduler`` at ``self.scheduler``, decode
    ``self.slots`` (objects with ``active``/``req``/``remaining``) and a
    ``self.completed`` request list inherits the whole protocol from here
    — the real jitted ``ServeEngine`` and the test-suite's jit-free fake
    share one implementation, so the protocol cannot drift between them.

    Suspend/resume hooks for subclasses holding accelerator buffers:
    ``_cache_bytes()`` (resident droppable bytes), ``_release_buffers()``
    (drop them), ``_make_slots()`` (rebuild the slot table on resume).
    """

    plane = "serve"
    ledger_fields = ("served_tokens", "admitted_requests", "deferred_polls",
                     "admit_wait_sum")
    conserved_field = "served_tokens"
    suspended = False
    # logical trace track this module's request events land on; the
    # cluster renames per engine ("engine0", "engine1", ...)
    trace_name = "engine"

    # -- subclass hooks -----------------------------------------------------
    def _make_slots(self) -> List:
        return []

    def _cache_bytes(self) -> int:
        return 0

    def _release_buffers(self) -> None:
        pass

    # -- lifecycle ----------------------------------------------------------
    def export_tenant(self, tenant_id: int,
                      now: Optional[float] = None) -> TenantState:
        return self.scheduler.export_tenant(tenant_id, now)

    def import_tenant(self, tenant_id: int, state: TenantState,
                      now: Optional[float] = None) -> None:
        self.scheduler.import_tenant(tenant_id, state, now)

    def has_tenant(self, tenant_id: int) -> bool:
        return tenant_id in self.scheduler.queues

    # -- checkpoint lifecycle -----------------------------------------------
    def snapshot_tenant(self, tenant_id: int,
                        now: Optional[float] = None) -> TenantState:
        return self.scheduler.snapshot_tenant(tenant_id, now)

    def restore_tenant(self, tenant_id: int, state: TenantState,
                       now: Optional[float] = None) -> None:
        self.scheduler.restore_tenant(tenant_id, state, now)

    def ground_truth_map(self) -> Dict[int, float]:
        out: Dict[int, float] = dict(self.__dict__.get("_gt_baseline") or {})
        for r in self.completed:
            t = r.tenant_id
            out[t] = out.get(t, 0.0) + len(r.prompt) + len(r.generated)
        for s in self.slots:
            if s.active and s.req is not None:
                t = s.req.tenant_id
                out[t] = out.get(t, 0.0) \
                    + len(s.req.prompt) + len(s.req.generated)
        return out

    def restore_ground_truth(self, tenant_id: int, value: float) -> None:
        # completed Request records died with the crash; the restored
        # share lives in a baseline the billed_ground_truth sum includes
        base = self.__dict__.get("_gt_baseline")
        if base is None:
            base = self._gt_baseline = {}
        base[tenant_id] = float(value)

    def restore_latency(self, snap: Dict[str, Dict[int, dict]]) -> None:
        """Wholesale REPLACE of the engine-side latency families from a
        checkpoint's ``{family: {tenant: Histogram payload}}`` view —
        replace, never merge: re-importing the same snapshot after a
        failed restore attempt must rebaseline the counts, not re-add
        them."""
        from repro.obs.hist import Histogram
        hists = self.latency_hists()
        for fam, th in hists.items():
            th.per_tenant = {
                int(t): Histogram.from_payload(p)
                for t, p in (snap.get(fam) or {}).items()}

    def crash(self) -> None:
        """Wipe the serve module in place: queued + in-flight work lost,
        counters and completed records gone, latency tails gone. The
        scheduler/slot config and compiled stack survive — a restarted
        engine slot serves again the moment state is restored.
        ``decode_steps`` (the perf meter) is kept: wiping it would make
        windowed step diffs negative in replay reports."""
        self.scheduler.wipe()
        self.slots = self._make_slots()
        self.completed.clear()
        self.__dict__.pop("_latency_hists", None)
        self.__dict__.pop("_gt_baseline", None)
        self.suspended = False

    def live_counters(self, fld: str) -> Dict[int, float]:
        if fld not in self.ledger_fields:
            raise KeyError(f"unknown serve ledger field {fld!r}")
        return dict(getattr(self.scheduler, fld))

    def live_counter(self, tenant_id: int, fld: str) -> float:
        if fld not in self.ledger_fields:
            raise KeyError(f"unknown serve ledger field {fld!r}")
        return getattr(self.scheduler, fld).get(tenant_id, 0)

    def billed_ground_truth(self, tenant_id: int) -> float:
        """Prompt+generated tokens over this engine's completed and
        in-flight requests. Completed records stay here forever — they
        are the migration-invariant half of conservation."""
        total = sum(len(r.prompt) + len(r.generated)
                    for r in self.completed if r.tenant_id == tenant_id)
        for s in self.slots:
            if s.active and s.req is not None \
                    and s.req.tenant_id == tenant_id:
                total += len(s.req.prompt) + len(s.req.generated)
        # plus any share restored from a checkpoint (the completed
        # records it summarizes died with the crash)
        base = self.__dict__.get("_gt_baseline")
        if base:
            total += base.get(tenant_id, 0.0)
        return float(total)

    def inherit_ground_truth(self, old: "SchedulerServeModule") -> None:
        """Adopt the retired module's completed-request records (its share
        of the serve-plane ground truth) in order, so the cluster's
        completed-collection cursor for this engine slot stays valid. The
        old module must be quiesced first — in-flight slots are the OTHER
        half of ground truth and cannot be inherited mid-generation."""
        if old.inflight():
            raise RuntimeError(
                f"cannot inherit ground truth: {old.inflight()} slot(s) "
                f"still in flight on the retiring module; quiesce first")
        self.completed.extend(old.completed)
        # a restored-from-checkpoint baseline is ground truth too
        old_base = old.__dict__.get("_gt_baseline")
        if old_base:
            base = self.__dict__.get("_gt_baseline")
            if base is None:
                base = self._gt_baseline = {}
            for t, v in old_base.items():
                base[t] = base.get(t, 0.0) + v
        # engine-local latency tails stay attributed to this engine slot
        # across the swap, like the completed records they describe
        hists = self.latency_hists()
        for fam, th in old.latency_hists().items():
            for t, h in th.per_tenant.items():
                hists[fam].absorb(t, h)

    # -- latency observability ----------------------------------------------
    def latency_hists(self) -> Dict[str, TenantHistograms]:
        """Per-tenant TTFT / e2e histogram families, lazily created per
        instance (this is a mixin without an ``__init__``). Engine-side:
        like completed-request records, they never migrate — a tenant's
        tail is attributed to the engine that served it."""
        h = self.__dict__.get("_latency_hists")
        if h is None:
            h = self._latency_hists = {
                "nk_ttft_seconds": TenantHistograms("nk_ttft_seconds"),
                "nk_e2e_seconds": TenantHistograms("nk_e2e_seconds")}
        return h

    def observe_admitted(self, req) -> None:
        """Record one request's dispatch into a decode slot: TTFT (the
        first token exists the moment prefill ran) + a trace instant."""
        if req.arrival >= 0.0 and req.admit_time >= 0.0:
            self.latency_hists()["nk_ttft_seconds"].observe(
                req.tenant_id, max(req.admit_time - req.arrival, 0.0))
        if tracing.TRACER.enabled and req.admit_time >= 0.0:
            tracing.TRACER.instant(
                self.trace_name, "request.dispatch", req.admit_time,
                tenant=req.tenant_id, req=req.req_id)

    def observe_finished(self, req) -> None:
        """Record one request's completion: e2e latency + a trace
        instant."""
        if req.arrival >= 0.0 and req.finish_time >= 0.0:
            self.latency_hists()["nk_e2e_seconds"].observe(
                req.tenant_id, max(req.finish_time - req.arrival, 0.0))
        if tracing.TRACER.enabled and req.finish_time >= 0.0:
            tracing.TRACER.instant(
                self.trace_name, "request.finish", req.finish_time,
                tenant=req.tenant_id, req=req.req_id,
                generated=len(req.generated))

    def latency(self) -> Dict[str, TenantHistograms]:
        """All three latency families for this module: the scheduler's
        admit-wait (which migrates with its tenants) plus the engine-side
        TTFT / e2e."""
        out = dict(self.latency_hists())
        out["nk_admit_wait_seconds"] = self.scheduler.admit_wait_hist
        return out

    # -- placement signals --------------------------------------------------
    def inflight(self, tenant_id: Optional[int] = None) -> int:
        """Active decode slots held by one tenant (or all, if None).

        The drain signal for live migration: a tenant has left this engine
        once its queue was exported *and* its in-flight slots ran dry —
        in-flight requests finish (and bill) where they were admitted, so
        no token is ever lost or moved mid-generation. Tolerates a slot
        whose ``req`` was cleared concurrently (``s.req is None``).
        """
        return sum(1 for s in self.slots if s.active and s.req is not None
                   and (tenant_id is None or s.req.tenant_id == tenant_id))

    def tenant_load(self, tenant_id: int) -> TenantLoad:
        return TenantLoad(
            pending=self.scheduler.pending(tenant_id),
            inflight=self.inflight(tenant_id),
            queued_tokens=float(self.scheduler.queued_cost(tenant_id)),
            inflight_tokens=float(sum(
                s.remaining for s in self.slots
                if s.active and s.req is not None
                and s.req.tenant_id == tenant_id)))

    def load(self) -> float:
        return float(self.scheduler.pending() + self.inflight())

    # -- park lifecycle -----------------------------------------------------
    def suspend(self) -> int:
        """Drop the KV-cache, slot table and step scratch of a quiesced
        engine. Idempotent; raises if any slot is still in flight (the
        cluster parks only quiesced engines — suspending live work would
        strand it)."""
        if self.suspended:
            return 0
        if self.inflight():
            raise RuntimeError(
                f"cannot suspend: {self.inflight()} slot(s) still in "
                f"flight; drain before parking")
        freed = self.resident_bytes()
        self.slots = []
        self._release_buffers()
        self.suspended = True
        return freed

    def resume(self) -> int:
        """Wake a suspended engine: the slot table comes back now, the
        KV-cache lazily on the first admission (see the subclass's
        ``_release_buffers``/cache re-init). Idempotent."""
        if not self.suspended:
            return 0
        self.suspended = False
        self.slots = self._make_slots()
        return self._cache_bytes()

    def resident_bytes(self) -> int:
        return 0 if self.suspended else self._cache_bytes()


class ConservationLedger:
    """Carried ledger + the ONE conservation assert, for any plane.

    Replaces the per-plane ``_fold``/``_fold_core``, ``merged_ledger``
    and duplicated assert logic the cluster used to carry: every plane is
    a list of ``StackModule``s plus this ledger, and the invariant is the
    same everywhere —

        carried (migrated-away history) + sum of live module counters
            == sum of module billed ground truth

    for the plane's ``conserved_field``, at every instant, including
    across migration windows (``fold`` moves an export's counters into
    ``carried`` at the same moment the live source forgets them).
    """

    def __init__(self, modules: Sequence[StackModule],
                 fields: Optional[Sequence[str]] = None,
                 conserved: Optional[str] = None):
        # a list is kept BY REFERENCE: the owner (e.g. EngineCluster) and
        # this ledger must see the same module set, so appending an engine
        # later cannot silently desync conservation from the live fleet
        self.modules: List[StackModule] = (
            modules if isinstance(modules, list) else list(modules))
        if not self.modules and (fields is None or conserved is None):
            raise ValueError(
                "ConservationLedger needs modules, or explicit fields "
                "AND conserved")
        self.fields: Tuple[str, ...] = tuple(
            fields if fields is not None else self.modules[0].ledger_fields)
        self.conserved: str = (conserved if conserved is not None
                               else self.modules[0].conserved_field)
        self.carried: Dict[str, Dict[int, float]] = \
            {f: {} for f in self.fields}

    def fold(self, tenant_id: int, module: StackModule,
             state: TenantState) -> None:
        """Fold one export into the carried view (the module's ``fold``
        maps its state to per-field increments)."""
        inc = module.fold(state)
        for f in self.fields:
            c = self.carried[f]
            c[tenant_id] = c.get(tenant_id, 0) + inc.get(f, 0)

    def merged(self, fld: str) -> Dict[int, float]:
        """Carried history + live per-module counters for one field —
        the continuous cluster-global view."""
        if fld not in self.fields:
            raise KeyError(f"unknown ledger field {fld!r}")
        out = dict(self.carried[fld])
        for m in self.modules:
            for t, v in m.live_counters(fld).items():
                out[t] = out.get(t, 0) + v
        return out

    def total(self, tenant_id: int, fld: Optional[str] = None) -> float:
        """One tenant's carried + live total for ``fld`` (default: the
        conserved field)."""
        fld = self.conserved if fld is None else fld
        return self.carried[fld].get(tenant_id, 0) + sum(
            m.live_counter(tenant_id, fld) for m in self.modules)

    def ground_truth(self, tenant_id: int) -> float:
        return sum(m.billed_ground_truth(tenant_id) for m in self.modules)

    def assert_conservation(self, tenant_id: int, *,
                            plane: str = "") -> None:
        """No lost units, no double-billing: carried+live must equal the
        modules' summed ground truth exactly."""
        ledger = self.total(tenant_id)
        truth = self.ground_truth(tenant_id)
        if int(round(ledger)) != int(round(truth)):
            raise AssertionError(
                f"tenant {tenant_id} {plane or 'stack'} ledger broke "
                f"conservation: ledger says {ledger} {self.conserved}, "
                f"ground truth accounts for {truth}")


@dataclass
class StackPlane:
    """One plane of a cluster: N ``StackModule``s (one per engine slot)
    plus their shared ``ConservationLedger``."""

    name: str
    modules: List[StackModule]
    ledger: ConservationLedger

    @classmethod
    def build(cls, name: str, modules: Sequence[StackModule]) -> "StackPlane":
        """A list is kept by reference (shared with the caller and the
        ledger), so one module set serves load, lifecycle and
        conservation — growing the fleet later can't desync them."""
        mods = modules if isinstance(modules, list) else list(modules)
        return cls(name=name, modules=mods,
                   ledger=ConservationLedger(mods))
