"""The stack-module fabric: one tenant-lifecycle protocol for every plane.

NetKernel's core claim is that the network stack is a *module* behind a
uniform, swappable interface. This package is that interface for tenant
lifecycle: any engine — the serving plane's ``ServeEngine``/scheduler, the
bytes plane's ``CoreEngine``, a jit-free test double — implements
``StackModule``, and the cluster/placement layers move, fold, conserve,
suspend, resume, checkpoint and restore tenants through it without ever
naming a concrete engine class.
"""
from repro.fabric.checkpoint import (
    FABRIC_SNAPSHOT_VERSION, FabricSnapshot, ModuleSnapshot, PlaneSnapshot,
)
from repro.fabric.module import (
    ConservationLedger, SchedulerServeModule, StackModule, StackPlane,
    TenantLoad, TenantState,
)

__all__ = [
    "FABRIC_SNAPSHOT_VERSION", "FabricSnapshot", "ModuleSnapshot",
    "PlaneSnapshot", "ConservationLedger", "SchedulerServeModule",
    "StackModule", "StackPlane", "TenantLoad", "TenantState",
]
