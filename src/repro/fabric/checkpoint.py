"""FabricSnapshot: the whole fabric as one versioned, serializable value.

NetKernel's premise — the stack is operator-managed infrastructure —
only holds in production if the operator can kill and restore a stack
module without tenants losing or double-billing a unit. This module is
the state half of that claim: a ``FabricSnapshot`` captures everything
``EngineCluster.restore`` / ``recover_engine`` need to re-materialize a
crashed engine —

  * every plane's per-tenant ``TenantState`` per module (bucket
    snapshot, cumulative counters, plane payload — the same wire shape a
    migration moves, captured non-destructively via ``snapshot_tenant``),
  * each module's full billed-ground-truth map (including tenants that
    migrated away but left their never-migrates history behind) and the
    serve plane's engine-side latency histograms,
  * the ``ConservationLedger`` carried view per plane,
  * the cluster's placement/draining maps, park set and swap log,
  * the controller's soft state (capacity, tick count, allocations).

``to_bytes``/``from_bytes`` is a DETERMINISTIC round trip: canonical
JSON (sorted keys, fixed separators, UTF-8), a leading ``version`` field
with strict-reject on anything unknown, and explicit codecs for the two
plane payloads — this is the wire format the fleet layer will reuse for
cross-cluster moves, so ``from_bytes(to_bytes(s)) == s`` exactly and
``to_bytes`` is byte-stable.

Stdlib only; ``Request`` is imported lazily inside the serve codec to
keep ``repro.fabric`` import-cycle-free (serve.scheduler imports
``TenantState`` from here at module load).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List

from repro.fabric.module import TenantState

FABRIC_SNAPSHOT_VERSION = 1


@dataclass
class ModuleSnapshot:
    """One ``StackModule``'s checkpointed state.

    ``tenants`` holds a ``TenantState`` per tenant *placed* on the
    module at checkpoint time. ``ground_truth`` is the module's FULL
    billed-ground-truth map — deliberately wider than ``tenants``:
    departed tenants' completed records / billed bytes stay on the
    module forever, and dropping them in a crash+recover would break
    conservation against the carried ledger. ``latency`` is the serve
    plane's engine-side histogram families (``{family: {tenant:
    Histogram payload}}``; empty for planes without latency state).
    """

    tenants: Dict[int, TenantState] = field(default_factory=dict)
    ground_truth: Dict[int, float] = field(default_factory=dict)
    latency: Dict[str, Dict[int, dict]] = field(default_factory=dict)


@dataclass
class PlaneSnapshot:
    """One plane: a ``ModuleSnapshot`` per engine slot plus the plane's
    ``ConservationLedger`` carried view (``{field: {tenant: value}}``)."""

    name: str
    carried: Dict[str, Dict[int, float]] = field(default_factory=dict)
    modules: List[ModuleSnapshot] = field(default_factory=list)


@dataclass
class FabricSnapshot:
    """The whole fabric at one instant — see the module docstring.

    Field units: ``step`` is cluster steps; ``placement``/``draining``
    map tenant → engine index; ``parked`` is a sorted engine-index list;
    ``controller`` carries {capacity [units/s], ticks, allocations
    {tenant: units/s}}; ``swap_log`` entries are ``SwapRecord`` fields
    as plain dicts.
    """

    version: int = FABRIC_SNAPSHOT_VERSION
    step: int = 0
    placement: Dict[int, int] = field(default_factory=dict)
    draining: Dict[int, int] = field(default_factory=dict)
    parked: List[int] = field(default_factory=list)
    planes: List[PlaneSnapshot] = field(default_factory=list)
    controller: Dict[str, Any] = field(default_factory=dict)
    swap_log: List[dict] = field(default_factory=list)

    # -- serialization ------------------------------------------------------
    def to_bytes(self) -> bytes:
        """Canonical JSON: sorted keys, no whitespace, UTF-8. Two calls
        on equal snapshots produce identical bytes."""
        return json.dumps(_encode_snapshot(self), sort_keys=True,
                          separators=(",", ":")).encode("utf-8")

    @classmethod
    def from_bytes(cls, data: bytes) -> "FabricSnapshot":
        """Strict inverse of ``to_bytes``. Rejects unknown versions by
        value — an old reader must never mis-install a newer layout."""
        doc = json.loads(data.decode("utf-8"))
        version = doc.get("version")
        if version != FABRIC_SNAPSHOT_VERSION:
            raise ValueError(
                f"unknown FabricSnapshot version {version!r} "
                f"(this reader understands {FABRIC_SNAPSHOT_VERSION})")
        return _decode_snapshot(doc)


# ---------------------------------------------------------------------------
# codecs (explicit per payload shape — no generic object hooks, so the
# wire format is exactly what this file spells out)
# ---------------------------------------------------------------------------


def _encode_request(r) -> dict:
    return {"tenant_id": r.tenant_id, "prompt": list(r.prompt),
            "max_new_tokens": r.max_new_tokens, "req_id": r.req_id,
            "arrival": r.arrival, "generated": list(r.generated),
            "admit_time": r.admit_time, "finish_time": r.finish_time}


def _decode_request(d: dict):
    # lazy: repro.serve.scheduler imports TenantState from repro.fabric
    from repro.serve.scheduler import Request
    return Request(tenant_id=int(d["tenant_id"]),
                   prompt=list(d["prompt"]),
                   max_new_tokens=int(d["max_new_tokens"]),
                   req_id=int(d["req_id"]), arrival=float(d["arrival"]),
                   generated=list(d["generated"]),
                   admit_time=float(d["admit_time"]),
                   finish_time=float(d["finish_time"]))


def _encode_tenant_state(s: TenantState) -> dict:
    out = {"plane": s.plane, "bucket": s.bucket,
           "carried": dict(s.carried)}
    payload = dict(s.payload)
    if "queue" in payload:                       # serve plane
        payload["queue"] = [_encode_request(r) for r in payload["queue"]]
    if "ledger" in payload:                      # bytes plane
        payload["ledger"] = sorted(
            [verb, list(axes), ops, byts]
            for (verb, axes), (ops, byts) in payload["ledger"].items())
        payload["deferred"] = sorted(
            [list(axes), ops, byts]
            for axes, (ops, byts) in payload["deferred"].items())
        payload["admitted"] = list(payload.get("admitted", (0, 0)))
    out["payload"] = payload
    return out


def _decode_tenant_state(d: dict) -> TenantState:
    payload = dict(d.get("payload") or {})
    if "queue" in payload:
        payload["queue"] = [_decode_request(r) for r in payload["queue"]]
    if "ledger" in payload:
        payload["ledger"] = {
            (verb, tuple(axes)): (int(ops), int(byts))
            for verb, axes, ops, byts in payload["ledger"]}
        payload["deferred"] = {
            tuple(axes): (int(ops), int(byts))
            for axes, ops, byts in payload["deferred"]}
        payload["admitted"] = tuple(payload.get("admitted", (0, 0)))
    return TenantState(plane=d["plane"], bucket=d.get("bucket"),
                       carried=dict(d.get("carried") or {}),
                       payload=payload)


def _encode_module(m: ModuleSnapshot) -> dict:
    return {
        "tenants": {str(t): _encode_tenant_state(s)
                    for t, s in m.tenants.items()},
        "ground_truth": {str(t): v for t, v in m.ground_truth.items()},
        "latency": {fam: {str(t): p for t, p in per.items()}
                    for fam, per in m.latency.items()},
    }


def _decode_module(d: dict) -> ModuleSnapshot:
    return ModuleSnapshot(
        tenants={int(t): _decode_tenant_state(s)
                 for t, s in (d.get("tenants") or {}).items()},
        ground_truth={int(t): float(v)
                      for t, v in (d.get("ground_truth") or {}).items()},
        latency={fam: {int(t): dict(p) for t, p in per.items()}
                 for fam, per in (d.get("latency") or {}).items()})


def _encode_snapshot(s: FabricSnapshot) -> dict:
    return {
        "version": s.version,
        "step": s.step,
        "placement": {str(t): k for t, k in s.placement.items()},
        "draining": {str(t): k for t, k in s.draining.items()},
        "parked": list(s.parked),
        "planes": [{"name": p.name,
                    "carried": {f: {str(t): v for t, v in d.items()}
                                for f, d in p.carried.items()},
                    "modules": [_encode_module(m) for m in p.modules]}
                   for p in s.planes],
        "controller": _encode_controller(s.controller),
        "swap_log": [dict(r, tenants=list(r.get("tenants", ())))
                     for r in s.swap_log],
    }


def _decode_snapshot(doc: dict) -> FabricSnapshot:
    return FabricSnapshot(
        version=int(doc["version"]),
        step=int(doc.get("step", 0)),
        placement={int(t): int(k)
                   for t, k in (doc.get("placement") or {}).items()},
        draining={int(t): int(k)
                  for t, k in (doc.get("draining") or {}).items()},
        parked=[int(k) for k in doc.get("parked", ())],
        planes=[PlaneSnapshot(
            name=p["name"],
            carried={f: {int(t): v for t, v in d.items()}
                     for f, d in (p.get("carried") or {}).items()},
            modules=[_decode_module(m) for m in p.get("modules", ())])
            for p in doc.get("planes", ())],
        controller=_decode_controller(doc.get("controller") or {}),
        swap_log=[dict(r, tenants=list(r.get("tenants", ())))
                  for r in doc.get("swap_log", ())])


def _encode_controller(c: Dict[str, Any]) -> dict:
    out = dict(c)
    if "allocations" in out:
        out["allocations"] = {str(t): v
                              for t, v in out["allocations"].items()}
    return out


def _decode_controller(c: dict) -> Dict[str, Any]:
    out = dict(c)
    if "allocations" in out:
        out["allocations"] = {int(t): float(v)
                              for t, v in out["allocations"].items()}
    return out
