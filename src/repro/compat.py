"""Version-portable jax surface.

``shard_map`` has moved homes across jax releases: old versions export it
from ``jax.experimental.shard_map`` (with ``auto=``/``check_rep=`` kwargs),
new ones export ``jax.shard_map`` (with ``axis_names=``/``check_vma=``).
Everything in this repo imports it from here so the same call sites —
including partial-manual calls that name their manual axes — run on both.
"""
from __future__ import annotations

import jax

__all__ = ["make_mesh", "shard_map"]


def make_mesh(shape, axes):
    """``jax.make_mesh`` with explicit Auto axis types where supported
    (``AxisType`` only exists on newer jax; older versions are Auto-only)."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            tuple(shape), tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=None):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        if check_vma is not None:
            kw["check_vma"] = check_vma
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)

else:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=None):
        # axis_names is accepted but not narrowed: legacy partial-manual
        # (``auto=``) lowers ``axis_index`` to a PartitionId op that SPMD
        # partitioning rejects (UNIMPLEMENTED) on CPU. Full-manual is
        # semantically equivalent for our call sites — bodies only reference
        # their manual axes and in_specs name no others — at the cost of
        # resharding at the region boundary.
        del axis_names
        kw = {}
        if check_vma is not None:
            kw["check_rep"] = check_vma
        return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, **kw)
