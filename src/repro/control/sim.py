"""Virtual-time harness: tenants offering load through enforced CoreEngines.

The management plane's testbed (and the paper-Fig. 21/22 benchmark driver).
Tenants are open-loop senders — each tick they offer ``demand * dt`` bytes
of ``shm_move`` CommOps through their engine(s), misbehaving or not; the
engines' token buckets admit what fits and meter the shortfall; the
RateController closes the loop every ``control_every`` ticks. Everything
runs on a simulated clock, so runs are deterministic and take milliseconds.

``demand`` may be a constant (bytes/s) or a ``f(t) -> bytes/s`` callable for
time-varying load (bursts, idle periods, the work-conserving scenarios).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.control.congestion import CongestionControl, WaterFill
from repro.control.controller import RateController
from repro.core.engine import CoreEngine

Demand = Union[float, Callable[[float], float]]


class _Payload:
    """Duck-typed array stand-in: bytes on the wire, nothing in memory."""

    __slots__ = ("shape",)
    dtype = np.uint8

    def __init__(self, n: int):
        self.shape = (int(n),)


@dataclass
class SimTenant:
    tenant_id: int
    demand: Demand                    # offered bytes/s (constant or f(t))
    weight: float = 1.0
    # fraction of this tenant's traffic entering each engine; None = even
    engine_split: Optional[Sequence[float]] = None

    def offered_at(self, t: float) -> float:
        d = self.demand(t) if callable(self.demand) else self.demand
        return max(float(d), 0.0)


@dataclass
class SimResult:
    dt: float
    times: List[float]
    served_cum: Dict[int, List[float]]      # cumulative in-rate bytes
    offered_cum: Dict[int, List[float]]
    allocations: List[Dict[int, float]]     # controller history

    def served_rate(self, tenant_id: int, frac_from: float = 0.5,
                    frac_to: float = 1.0) -> float:
        """Mean served rate over a window given as fractions of the run."""
        cum = self.served_cum[tenant_id]
        i = min(int(len(cum) * frac_from), len(cum) - 1)
        j = min(int(len(cum) * frac_to) - 1, len(cum) - 1)
        if j <= i:
            return 0.0
        return (cum[j] - cum[i]) / ((j - i) * self.dt)

    def total_served_rate(self, frac_from: float = 0.5,
                          frac_to: float = 1.0) -> float:
        return sum(self.served_rate(t, frac_from, frac_to)
                   for t in self.served_cum)


class SharedBottleneckSim:
    """N tenants x M engines sharing one bottleneck under a RateController."""

    def __init__(self, tenants: Sequence[SimTenant], capacity: float,
                 *, n_engines: int = 1,
                 algo: Optional[CongestionControl] = None,
                 dt: float = 0.05, control_every: int = 4,
                 axes: Tuple[str, ...] = ("pod",),
                 alpha: float = 0.5, burst_s: float = 0.25,
                 push_mode: str = "full", delta_tol: float = 0.05):
        self.tenants = list(tenants)
        self.capacity = float(capacity)
        self.dt = dt
        self.control_every = control_every
        self.axes = axes
        self.engines = [CoreEngine(enforcement="account")
                        for _ in range(n_engines)]
        if algo is None:
            algo = WaterFill({t.tenant_id: t.weight for t in self.tenants},
                             min_rate=capacity * 1e-3)
        self.controller = RateController(capacity, algo=algo, alpha=alpha,
                                         burst_s=burst_s,
                                         push_mode=push_mode,
                                         delta_tol=delta_tol)
        for eng in self.engines:
            self.controller.attach_engine(eng, axes)
        self._elapsed = 0.0

    def _splits(self, tenant: SimTenant) -> Sequence[float]:
        if tenant.engine_split is not None:
            return tenant.engine_split
        return [1.0 / len(self.engines)] * len(self.engines)

    def _served(self, tenant_id: int) -> float:
        return sum(e.total_bytes(tenant_id) - e.deferred_bytes(tenant_id)
                   for e in self.engines)

    def _offered(self, tenant_id: int) -> float:
        return sum(e.total_bytes(tenant_id) for e in self.engines)

    def run(self, duration: float) -> SimResult:
        steps = max(int(round(duration / self.dt)), 1)
        res = SimResult(dt=self.dt, times=[],
                        served_cum={t.tenant_id: [] for t in self.tenants},
                        offered_cum={t.tenant_id: [] for t in self.tenants},
                        allocations=self.controller.history)
        for k in range(steps):
            now = self._elapsed + (k + 1) * self.dt
            for tenant in self.tenants:
                want = tenant.offered_at(now) * self.dt
                for eng, frac in zip(self.engines, self._splits(tenant)):
                    n = int(round(want * frac))
                    if n > 0:
                        eng.dispatch("shm_move", _Payload(n), self.axes,
                                     tenant_id=tenant.tenant_id, now=now)
            if (k + 1) % self.control_every == 0:
                self.controller.tick(now)
            res.times.append(now)
            for tenant in self.tenants:
                res.served_cum[tenant.tenant_id].append(
                    self._served(tenant.tenant_id))
                res.offered_cum[tenant.tenant_id].append(
                    self._offered(tenant.tenant_id))
        self._elapsed += steps * self.dt
        return res

    def fair_reference(self) -> Dict[int, float]:
        """The weighted max-min fair allocation of the *final* demands —
        what a converged controller should be serving."""
        t_end = self._elapsed if self._elapsed > 0 else 0.0
        demands = {t.tenant_id: t.offered_at(t_end) for t in self.tenants}
        weights = {t.tenant_id: t.weight for t in self.tenants}
        from repro.control.congestion import max_min_fair
        return max_min_fair(self.capacity, demands, weights)
