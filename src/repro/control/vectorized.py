"""Vectorized control plane: one fused tick over the whole tenant population.

The object control plane (``TokenBucket`` instances in dicts, ``_Ewma``
objects per tenant, ``max_min_fair`` over dicts) is fine at 8 tenants and
dead at the 1M-tenant north star: every control tick walks Python objects.
This module refactors the hot per-tenant control state into flat arrays
keyed by a dense tenant index — the Chamelio/Joyride argument that a shared
stack stays fast when the per-tenant fast path is flat state touched by
batched operations:

  * ``TenantIndex`` — tenant id -> dense slot, stable under migration
    (adding/dropping one tenant never moves another tenant's slot), with
    ``compact()`` for defragmentation after churn.
  * ``BucketStore`` + ``StoreBucket`` — every tenant's token-bucket
    level/rate/capacity/updated as four float64 arrays; ``StoreBucket`` is
    the per-tenant view implementing the exact ``TokenBucket`` interface,
    so ``TenantScheduler(bucket_backend="vectorized")`` and the TenantState
    export/import/snapshot/restore wire format work unchanged.
  * ``TelemetryBank`` — EWMA offered/deferred rates as flat arrays with
    Prometheus counter discipline (a decreased/vanished cumulative counter
    rebaselines, never reads as a negative rate); the array backend behind
    ``SchedulerTelemetry``/``EngineTelemetry`` ``backend="vectorized"``.
  * ``VectorizedControlPlane`` — the fused tick: bucket refill + admission
    headroom + EWMA update + weighted max-min water-fill as ONE jitted
    step over the whole population. The water-fill inner loop is a
    fixed-iteration bisection on the water level (``lax.fori_loop`` —
    no data-dependent Python control flow, no O(n log n) sort on the hot
    path); a sort-based exact variant and a Pallas kernel live in
    ``repro.kernels`` behind the ``ops.water_fill`` dispatch.

Numerics: facade state (buckets, telemetry banks) is numpy float64 — the
per-op scalar paths are bit-compatible with the object backend, which is
what the hypothesis equivalence suites pin. The fused tick runs jitted
under ``jax.experimental.enable_x64`` so allocations agree with the scalar
``max_min_fair`` within 1e-6 x capacity even at 100k tenants.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

__all__ = [
    "TenantIndex", "BucketStore", "StoreBucket", "TelemetryBank",
    "VectorizedControlPlane", "waterfill_allocate", "BACKENDS",
    "check_backend",
]

BACKENDS = ("object", "vectorized")


def check_backend(backend: str) -> str:
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, "
                         f"got {backend!r}")
    return backend


def _x64():
    """The x64 trace context: the fused tick must do float64 math even
    when the embedding app runs the default f32 config (model code and
    the Pallas kernels stay f32 — only the control plane opts in)."""
    from jax.experimental import enable_x64
    return enable_x64()


# ---------------------------------------------------------------------------
# Tenant index: id -> dense slot
# ---------------------------------------------------------------------------


class TenantIndex:
    """Dense tenant-id -> slot mapping, stable under migration.

    ``add`` reuses freed slots (LIFO) before growing, ``drop`` frees a
    slot without disturbing any other tenant's slot — a tenant that
    migrates away and back may land on a different slot, but tenants that
    stayed never move, so array state keyed by slot survives arbitrary
    churn. ``compact()`` defragments after heavy churn and returns the
    old-slot -> new-slot map so array owners can gather their state.
    """

    def __init__(self):
        self._slots: Dict[int, int] = {}
        self._ids: List[int] = []          # slot -> tenant id, -1 = free
        self._free: List[int] = []

    def __len__(self) -> int:
        return len(self._slots)

    def __contains__(self, tenant: int) -> bool:
        return tenant in self._slots

    @property
    def size(self) -> int:
        """Allocated slot count (>= len(self); arrays are sized to this)."""
        return len(self._ids)

    def slot(self, tenant: int) -> int:
        return self._slots[tenant]

    def get(self, tenant: int) -> Optional[int]:
        return self._slots.get(tenant)

    def tenant_at(self, slot: int) -> int:
        """Tenant id occupying ``slot`` (-1 if free)."""
        return self._ids[slot]

    def items(self):
        """(tenant, slot) pairs in slot order."""
        return ((t, s) for s, t in enumerate(self._ids) if t >= 0)

    def tenants(self) -> List[int]:
        return [t for t in self._ids if t >= 0]

    def add(self, tenant: int) -> int:
        """Assign a slot (idempotent: an already-indexed tenant keeps its
        slot). Freed slots are reused before the index grows."""
        if tenant in self._slots:
            return self._slots[tenant]
        if self._free:
            slot = self._free.pop()
            self._ids[slot] = tenant
        else:
            slot = len(self._ids)
            self._ids.append(tenant)
        self._slots[tenant] = slot
        return slot

    def drop(self, tenant: int) -> int:
        """Free a tenant's slot (returns it). Other tenants never move."""
        slot = self._slots.pop(tenant)
        self._ids[slot] = -1
        self._free.append(slot)
        return slot

    def compact(self) -> Dict[int, int]:
        """Defragment: re-number slots densely (preserving slot order) and
        return {old_slot: new_slot} for array owners to gather with."""
        remap: Dict[int, int] = {}
        ids: List[int] = []
        for old, t in enumerate(self._ids):
            if t < 0:
                continue
            remap[old] = len(ids)
            self._slots[t] = len(ids)
            ids.append(t)
        self._ids = ids
        self._free = []
        return remap


def _grown(arr: np.ndarray, size: int, fill: float) -> np.ndarray:
    if arr.shape[0] >= size:
        return arr
    new = np.full(max(size, 2 * arr.shape[0]), fill, dtype=arr.dtype)
    new[:arr.shape[0]] = arr
    return new


def _gather(arr: np.ndarray, remap: Dict[int, int], fill: float
            ) -> np.ndarray:
    out = np.full(len(remap), fill, dtype=arr.dtype)
    for old, new in remap.items():
        out[new] = arr[old]
    return out


# ---------------------------------------------------------------------------
# Bucket store: every tenant's token bucket as four flat arrays
# ---------------------------------------------------------------------------


class BucketStore:
    """TokenBucket state (rate, capacity, tokens, updated) as flat float64
    arrays keyed by a ``TenantIndex``.

    Per-tenant access goes through :class:`StoreBucket` views that
    implement the exact ``TokenBucket`` interface (consume / drain /
    wait_time / set_rate / snapshot, plus attribute assignment), so the
    scheduler and the TenantState migration/checkpoint wire format never
    see the difference. Population-wide operations (``refill_all``,
    ``wait_times``) are single numpy expressions.
    """

    def __init__(self):
        self.index = TenantIndex()
        self.rate = np.zeros(0)
        self.capacity = np.zeros(0)
        self.tokens = np.zeros(0)
        self.updated = np.zeros(0)

    def __len__(self) -> int:
        return len(self.index)

    def __contains__(self, tenant: int) -> bool:
        return tenant in self.index

    def _ensure(self, size: int) -> None:
        self.rate = _grown(self.rate, size, 0.0)
        self.capacity = _grown(self.capacity, size, 0.0)
        self.tokens = _grown(self.tokens, size, 0.0)
        self.updated = _grown(self.updated, size, 0.0)

    def add(self, tenant: int, rate: float, capacity: float) -> "StoreBucket":
        """Register (or reset) a tenant's bucket: full at ``capacity``,
        refilling at ``rate`` — the ``TokenBucket(rate, capacity)``
        constructor semantics."""
        slot = self.index.add(tenant)
        self._ensure(self.index.size)
        self.rate[slot] = float(rate)
        self.capacity[slot] = float(capacity)
        self.tokens[slot] = float(capacity)
        self.updated[slot] = 0.0
        return StoreBucket(self, tenant)

    def restore(self, tenant: int, state: Dict[str, float],
                now: Optional[float] = None) -> "StoreBucket":
        """``TokenBucket.restore`` onto the array backend: rebuild from a
        ``snapshot()`` dict, anchored at ``now`` (None keeps the
        snapshot's own timestamp)."""
        b = self.add(tenant, state["rate"], state["capacity"])
        slot = self.index.slot(tenant)
        self.tokens[slot] = min(float(state["tokens"]), self.capacity[slot])
        self.updated[slot] = float(state.get("updated", 0.0)) if now is None \
            else float(now)
        return b

    def drop(self, tenant: int) -> None:
        if tenant in self.index:
            slot = self.index.drop(tenant)
            self.rate[slot] = self.capacity[slot] = 0.0
            self.tokens[slot] = self.updated[slot] = 0.0

    def view(self, tenant: int) -> "StoreBucket":
        if tenant not in self.index:
            raise KeyError(tenant)
        return StoreBucket(self, tenant)

    def compact(self) -> None:
        remap = self.index.compact()
        for name in ("rate", "capacity", "tokens", "updated"):
            setattr(self, name, _gather(getattr(self, name), remap, 0.0))

    # -- population-wide batched operations ---------------------------------
    def refill_all(self, now: float) -> None:
        """Settle every bucket's balance at ``now`` in one expression."""
        dt = np.maximum(now - self.updated, 0.0)
        np.minimum(self.capacity, self.tokens + dt * self.rate,
                   out=self.tokens)
        np.maximum(self.updated, now, out=self.updated)

    def wait_times(self, costs: np.ndarray,
                   now: Optional[float] = None) -> np.ndarray:
        """Vectorized ``wait_time``: seconds until each slot could cover
        ``costs`` (0 when already admissible, inf when rate is 0)."""
        if now is not None:
            self.refill_all(now)
        short = np.maximum(costs - self.tokens, 0.0)
        with np.errstate(divide="ignore", invalid="ignore"):
            wait = np.where(short <= 0.0, 0.0, short / self.rate)
        return np.where((short > 0.0) & (self.rate <= 0.0), np.inf, wait)


class StoreBucket:
    """Per-tenant ``TokenBucket``-interface view over a ``BucketStore``.

    Every method mirrors ``repro.core.engine.TokenBucket`` operation for
    operation in float64, so an arbitrary interleaving of consume / drain
    / wait_time / set_rate / snapshot produces identical results on either
    backend — the property the equivalence suite pins.
    """

    __slots__ = ("store", "tenant_id")

    def __init__(self, store: BucketStore, tenant_id: int):
        self.store = store
        self.tenant_id = tenant_id

    @property
    def _slot(self) -> int:
        return self.store.index.slot(self.tenant_id)

    # TokenBucket exposes plain attributes; mirror them as properties so
    # existing call sites (scheduler set_rate adjusting capacity/updated)
    # keep working against the array backend.
    @property
    def rate(self) -> float:
        return float(self.store.rate[self._slot])

    @rate.setter
    def rate(self, v: float) -> None:
        self.store.rate[self._slot] = float(v)

    @property
    def capacity(self) -> float:
        return float(self.store.capacity[self._slot])

    @capacity.setter
    def capacity(self, v: float) -> None:
        self.store.capacity[self._slot] = float(v)

    @property
    def tokens(self) -> float:
        return float(self.store.tokens[self._slot])

    @tokens.setter
    def tokens(self, v: float) -> None:
        self.store.tokens[self._slot] = float(v)

    @property
    def updated(self) -> float:
        return float(self.store.updated[self._slot])

    @updated.setter
    def updated(self, v: float) -> None:
        self.store.updated[self._slot] = float(v)

    def _refill(self, now: float) -> None:
        s = self._slot
        st = self.store
        if now > st.updated[s]:
            st.tokens[s] = min(st.capacity[s], st.tokens[s]
                               + (now - st.updated[s]) * st.rate[s])
            st.updated[s] = now

    def consume(self, n: float, now: Optional[float] = None) -> bool:
        now = time.monotonic() if now is None else now
        self._refill(now)
        s = self._slot
        if self.store.tokens[s] >= n:
            self.store.tokens[s] -= n
            return True
        return False

    def drain(self, n: float, now: Optional[float] = None) -> float:
        now = time.monotonic() if now is None else now
        self._refill(now)
        s = self._slot
        take = min(float(n), max(float(self.store.tokens[s]), 0.0))
        self.store.tokens[s] -= take
        return take

    def wait_time(self, n: float, now: Optional[float] = None) -> float:
        now = time.monotonic() if now is None else now
        self._refill(now)
        s = self._slot
        if self.store.tokens[s] >= n:
            return 0.0
        if self.store.rate[s] <= 0.0:
            return float("inf")
        return float((n - self.store.tokens[s]) / self.store.rate[s])

    def set_rate(self, rate: float, burst: Optional[float] = None,
                 now: Optional[float] = None) -> None:
        now = time.monotonic() if now is None else now
        self._refill(now)
        s = self._slot
        self.store.rate[s] = float(rate)
        if burst is not None:
            self.store.capacity[s] = float(burst)
            self.store.tokens[s] = min(self.store.tokens[s],
                                       self.store.capacity[s])

    def snapshot(self, now: Optional[float] = None) -> Dict[str, float]:
        if now is not None:
            self._refill(now)
        s = self._slot
        return {"rate": float(self.store.rate[s]),
                "capacity": float(self.store.capacity[s]),
                "tokens": float(self.store.tokens[s]),
                "updated": float(self.store.updated[s])}


# ---------------------------------------------------------------------------
# Telemetry bank: EWMA offered/deferred rates as flat arrays
# ---------------------------------------------------------------------------


class TelemetryBank:
    """EWMA rate state for a telemetry source as flat float64 arrays.

    Tracks, per tenant slot: the EWMA offered and deferred rates (NaN =
    no sample yet) and the previous cumulative counter baselines.
    ``update`` applies one sampling interval with Prometheus counter
    discipline — a cumulative counter that decreased or vanished since
    the last sample was reset behind our back (migration fold, crash
    wipe), so the tenant rebaselines instead of reading a negative rate.
    ``evict`` drops a departed tenant's state entirely: the fix for the
    EWMA-entry leak where dropped/migrated-away tenants kept their
    ``_offered_ewma``/``_deferred_ewma`` entries forever.
    """

    def __init__(self, alpha: float):
        self.alpha = float(alpha)
        self.index = TenantIndex()
        self.ewma_off = np.zeros(0)
        self.ewma_def = np.zeros(0)
        self.prev_off = np.zeros(0)
        self.prev_def = np.zeros(0)
        self.known = np.zeros(0, dtype=bool)   # baseline established

    def _ensure(self, size: int) -> None:
        self.ewma_off = _grown(self.ewma_off, size, np.nan)
        self.ewma_def = _grown(self.ewma_def, size, np.nan)
        self.prev_off = _grown(self.prev_off, size, 0.0)
        self.prev_def = _grown(self.prev_def, size, 0.0)
        self.known = _grown(self.known, size, False)

    def evict(self, tenant: int) -> None:
        """Forget a departed tenant entirely (slot freed for reuse)."""
        if tenant in self.index:
            slot = self.index.drop(tenant)
            self.ewma_off[slot] = self.ewma_def[slot] = np.nan
            self.prev_off[slot] = self.prev_def[slot] = 0.0
            self.known[slot] = False

    def tenants(self) -> List[int]:
        return self.index.tenants()

    def baseline(self, offered: Dict[int, float],
                 deferred: Optional[Dict[int, float]] = None) -> None:
        """First sample (or time stood still): establish counter baselines
        without producing rates."""
        deferred = deferred or {}
        for t in set(offered) | set(deferred):
            slot = self.index.add(t)
            self._ensure(self.index.size)
            self.prev_off[slot] = float(offered.get(t, 0))
            self.prev_def[slot] = float(deferred.get(t, 0))
            self.known[slot] = True

    def update(self, offered: Dict[int, float], dt: float,
               deferred: Optional[Dict[int, float]] = None,
               extra: Optional[Iterable[int]] = None,
               ) -> Tuple[List[int], np.ndarray, np.ndarray, np.ndarray]:
        """One sampling interval.

        Returns ``(tenants, off, dfr, reset)`` aligned lists/arrays: the
        EWMA offered and deferred rates for every tenant in the union of
        current counters, tracked state and ``extra`` (queue-only
        tenants), plus a ``reset`` mask for tenants that rebaselined
        this interval (their rates are NaN: report queue-only obs, like
        the object backend). Counter baselines default to 0 for tenants
        never sampled — the object backends' ``prev.get(t, 0)``.
        Tenants whose counters vanished are evicted.
        """
        deferred = deferred or {}
        tracked = set(self.index.tenants())
        tenants = sorted(set(offered) | set(deferred) | tracked
                         | set(extra or ()))
        n = len(tenants)
        cur_off = np.empty(n)
        cur_def = np.empty(n)
        seen = np.empty(n, dtype=bool)
        slots = np.empty(n, dtype=np.int64)
        for i, t in enumerate(tenants):
            slot = self.index.add(t)
            self._ensure(self.index.size)
            slots[i] = slot
            cur_off[i] = float(offered.get(t, 0))
            cur_def[i] = float(deferred.get(t, 0))
            seen[i] = t in offered or t in deferred
        self._ensure(self.index.size)
        known = self.known[slots]
        d_off = (cur_off - self.prev_off[slots]) / dt
        d_def = (cur_def - self.prev_def[slots]) / dt
        # counter discipline: decreased or vanished => reset, rebaseline
        reset = (d_off < 0) | (d_def < 0) | (known & ~seen)
        prev_off = self.ewma_off[slots]
        prev_def_ewma = self.ewma_def[slots]
        a = self.alpha
        off = np.where(np.isnan(prev_off), d_off,
                       a * d_off + (1.0 - a) * prev_off)
        dfr = np.where(np.isnan(prev_def_ewma), d_def,
                       a * d_def + (1.0 - a) * prev_def_ewma)
        off = np.where(reset, np.nan, off)
        dfr = np.where(reset, np.nan, dfr)
        self.ewma_off[slots] = off
        self.ewma_def[slots] = dfr
        self.prev_off[slots] = cur_off
        self.prev_def[slots] = cur_def
        self.known[slots] = seen
        for i, t in enumerate(tenants):
            if reset[i] and not seen[i]:
                self.evict(t)
        return tenants, off, np.minimum(dfr, off), reset


# ---------------------------------------------------------------------------
# The fused tick
# ---------------------------------------------------------------------------


def _fused_tick_impl(level, brate, bcap, updated, ewma_off, ewma_def,
                     prev_off, prev_def, weight, active,
                     samples, params, iters, scheduler_buckets):
    """Trace-time body of the fused control tick (see ``fused_tick``).

    ``samples`` is the (3, slots) stack [cur_off; cur_def; queue] and
    ``params`` the packed scalar vector [now, prev_t, alpha, capacity,
    headroom, min_rate, burst_s] — one device transfer each per tick
    instead of ten (host->device dispatch dominates the fused tick's
    cost at small populations)."""
    import jax
    import jax.numpy as jnp

    cur_off, cur_def, queue = samples[0], samples[1], samples[2]
    now, prev_t, alpha, capacity = (params[0], params[1], params[2],
                                    params[3])
    headroom, min_rate, burst_s = params[4], params[5], params[6]
    dt = now - prev_t
    # -- EWMA telemetry update (counter discipline: reset => rebaseline) --
    d_off = (cur_off - prev_off) / dt
    d_def = (cur_def - prev_def) / dt
    reset = (d_off < 0) | (d_def < 0)
    off = jnp.where(jnp.isnan(ewma_off), d_off,
                    alpha * d_off + (1.0 - alpha) * ewma_off)
    dfr = jnp.where(jnp.isnan(ewma_def), d_def,
                    alpha * d_def + (1.0 - alpha) * ewma_def)
    off = jnp.where(reset | ~active, jnp.nan, off)
    dfr = jnp.where(reset | ~active, jnp.nan, dfr)
    dfr_obs = jnp.minimum(dfr, off)
    # -- demands: admission headroom vs backlog (WaterFill semantics) -----
    n_active = jnp.maximum(jnp.sum(active), 1)
    eps = 1e-3 * capacity / n_active
    backlogged = (dfr_obs > eps) | (queue > 0)
    d = jnp.where(backlogged, jnp.inf, off * headroom)
    d = jnp.where(active & (d > 0), d, 0.0)
    w = jnp.where(active & (d > 0), weight, 0.0)
    # -- weighted max-min water-fill: fixed-iteration bisection -----------
    r = jnp.where(w > 0, d / jnp.where(w > 0, w, 1.0), 0.0)
    minw = jnp.min(jnp.where(w > 0, w, jnp.inf))
    any_active = jnp.isfinite(minw)
    hi0 = jnp.where(any_active, capacity / jnp.maximum(minw, 1e-300), 0.0)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        s = jnp.sum(w * jnp.minimum(r, mid))
        over = s > capacity
        return jnp.where(over, lo, mid), jnp.where(over, mid, hi)

    _, lvl = jax.lax.fori_loop(0, iters, body,
                               (jnp.zeros_like(hi0), hi0))
    alloc = jnp.where(r <= lvl, d, w * lvl)
    alloc = jnp.where(w > 0, alloc, 0.0)
    alloc = jnp.where(active & (min_rate > 0),
                      jnp.maximum(alloc, min_rate), alloc)
    # tenants whose counters reset report queue-only obs: no allocation
    # change this interval (matches the object backend's rebaseline)
    alloc = jnp.where(reset & active & (queue <= 0), 0.0, alloc)
    # -- bucket retarget: settle at the old rate, then push the new one ---
    level = jnp.minimum(bcap, level + jnp.maximum(now - updated, 0.0)
                        * brate)
    push = active & (w > 0)
    brate2 = jnp.where(push, alloc, brate)
    if scheduler_buckets:
        # scheduler.set_rate(burst=None): keep >= 1s of burst so a raised
        # rate can still cover one whole request
        bcap2 = jnp.where(push, jnp.maximum(bcap, alloc), bcap)
    else:
        # engine.update_tenant_rate: burst = burst_s worth of rate, >= 1
        bcap2 = jnp.where(push, jnp.maximum(alloc * burst_s, 1.0), bcap)
    level = jnp.minimum(level, bcap2)
    updated2 = jnp.where(active, now, updated)
    return (level, brate2, bcap2, updated2, off, dfr, cur_off, cur_def,
            alloc, lvl)


@functools.lru_cache(maxsize=None)
def _fused_tick_jitted():
    import jax
    return jax.jit(_fused_tick_impl,
                   static_argnames=("iters", "scheduler_buckets"))


class VectorizedControlPlane:
    """Whole-population control state + the fused jitted control tick.

    One instance owns the hot per-tenant control state as flat float64
    jax arrays keyed by a :class:`TenantIndex`: bucket level / rate /
    capacity / updated, EWMA offered & deferred rates, previous
    cumulative counter baselines and WFQ weights. ``tick`` consumes one
    interval's cumulative counters (slot-aligned numpy arrays — the shape
    tenant state has when the data plane is itself array-backed) and runs
    refill + EWMA + admission headroom + water-fill + bucket retarget as
    a single jitted step, returning the per-slot allocations.

    ``export_tenant``/``snapshot_tenant``/``restore_tenant`` move one
    tenant through the same ``{rate, capacity, tokens, updated}`` bucket
    wire format the object ``TokenBucket`` uses, so TenantState payloads
    round-trip through the array state unchanged.
    """

    STATE_ARRAYS = ("level", "brate", "bcap", "updated", "ewma_off",
                    "ewma_def", "prev_off", "prev_def", "weight")

    def __init__(self, capacity: float, *, alpha: float = 0.5,
                 headroom: float = 1.25, min_rate: float = 0.0,
                 burst_s: float = 0.25, iters: int = 48,
                 scheduler_buckets: bool = True):
        self.capacity = float(capacity)
        self.alpha = float(alpha)
        self.headroom = float(headroom)
        self.min_rate = float(min_rate)
        self.burst_s = float(burst_s)
        self.iters = int(iters)
        self.scheduler_buckets = bool(scheduler_buckets)
        self.index = TenantIndex()
        self.level = np.zeros(0)
        self.brate = np.zeros(0)
        self.bcap = np.zeros(0)
        self.updated = np.zeros(0)
        self.ewma_off = np.zeros(0)
        self.ewma_def = np.zeros(0)
        self.prev_off = np.zeros(0)
        self.prev_def = np.zeros(0)
        self.weight = np.zeros(0)
        self.active = np.zeros(0, dtype=bool)
        self.prev_t: Optional[float] = None
        self.last_alloc = np.zeros(0)
        self.last_level = 0.0
        self.ticks = 0
        self.tick_seconds_total = 0.0
        # When _device is set, the jnp arrays are authoritative (state
        # stays device-resident across ticks — host copies are the slow
        # path); _sync_host() pulls them back before any host access.
        self._device: Optional[dict] = None

    def _sync_host(self) -> None:
        if self._device is None:
            return
        dev, self._device = self._device, None
        for name in self.STATE_ARRAYS:
            arr = np.asarray(dev[name])
            getattr(self, name)[:arr.shape[0]] = arr

    # -- tenant lifecycle ----------------------------------------------------
    def _ensure(self, size: int) -> None:
        if self.level.shape[0] >= size:
            return
        for name in self.STATE_ARRAYS:
            fill = np.nan if name.startswith("ewma") else 0.0
            setattr(self, name, _grown(getattr(self, name), size, fill))
        self.active = _grown(self.active, size, False)
        self.last_alloc = _grown(self.last_alloc, size, 0.0)
        self._device = None

    def add_tenant(self, tenant: int, weight: float = 1.0,
                   rate: float = 0.0, burst: Optional[float] = None) -> int:
        """Register a tenant; returns its slot. ``rate``/``burst`` seed
        the bucket (full at ``burst``, defaulting to 1 s of rate)."""
        self._sync_host()
        slot = self.index.add(tenant)
        self._ensure(self.index.size)
        cap = float(burst if burst is not None else max(rate, 1.0))
        self.weight[slot] = float(weight)
        self.brate[slot] = float(rate)
        self.bcap[slot] = cap
        self.level[slot] = cap
        self.updated[slot] = 0.0
        self.ewma_off[slot] = self.ewma_def[slot] = np.nan
        self.prev_off[slot] = self.prev_def[slot] = 0.0
        self.active[slot] = True
        self.last_alloc[slot] = 0.0
        self._device = None
        return slot

    def drop_tenant(self, tenant: int) -> None:
        """Evict a tenant entirely: EWMA state, counter baselines and
        bucket are gone; the slot is freed for reuse."""
        if tenant not in self.index:
            return
        self._sync_host()
        slot = self.index.drop(tenant)
        self.active[slot] = False
        self.weight[slot] = self.brate[slot] = self.bcap[slot] = 0.0
        self.level[slot] = self.updated[slot] = 0.0
        self.ewma_off[slot] = self.ewma_def[slot] = np.nan
        self.prev_off[slot] = self.prev_def[slot] = 0.0
        self.last_alloc[slot] = 0.0
        self._device = None

    def compact(self) -> None:
        """Defragment slots after churn (array state is gathered along)."""
        self._sync_host()
        remap = self.index.compact()
        for name in self.STATE_ARRAYS + ("last_alloc",):
            fill = np.nan if name.startswith("ewma") else 0.0
            setattr(self, name, _gather(getattr(self, name), remap, fill))
        self.active = np.ones(len(remap), dtype=bool)
        self._device = None

    # -- TenantState round-trip ---------------------------------------------
    def snapshot_tenant(self, tenant: int,
                        now: Optional[float] = None) -> Dict[str, object]:
        """Non-destructive per-tenant state in the shared wire format:
        ``bucket`` is a ``TokenBucket.snapshot`` dict, ``weight``/EWMA
        ride alongside. Round-trips through ``restore_tenant`` and
        through the object backend's ``TokenBucket.restore``."""
        self._sync_host()
        slot = self.index.slot(tenant)
        if now is not None and now > self.updated[slot]:
            self.level[slot] = min(
                self.bcap[slot],
                self.level[slot] + (now - self.updated[slot])
                * self.brate[slot])
            self.updated[slot] = now
            self._device = None
        return {
            "bucket": {"rate": float(self.brate[slot]),
                       "capacity": float(self.bcap[slot]),
                       "tokens": float(self.level[slot]),
                       "updated": float(self.updated[slot])},
            "weight": float(self.weight[slot]),
            "ewma_offered": float(self.ewma_off[slot]),
            "ewma_deferred": float(self.ewma_def[slot]),
            "prev_offered": float(self.prev_off[slot]),
            "prev_deferred": float(self.prev_def[slot]),
        }

    def export_tenant(self, tenant: int,
                      now: Optional[float] = None) -> Dict[str, object]:
        """Destructive ``snapshot_tenant``: the migration source half."""
        state = self.snapshot_tenant(tenant, now)
        self.drop_tenant(tenant)
        return state

    def restore_tenant(self, tenant: int, state: Dict[str, object],
                       now: Optional[float] = None) -> None:
        """Install an exported/snapshotted tenant (refused on a live
        slot — restore requires a quiesced destination)."""
        if tenant in self.index:
            raise ValueError(f"tenant {tenant} already live in the "
                             f"vectorized control plane")
        slot = self.add_tenant(tenant, weight=state.get("weight", 1.0))
        b = state["bucket"]
        self.brate[slot] = float(b["rate"])
        self.bcap[slot] = float(b["capacity"])
        self.level[slot] = min(float(b["tokens"]), float(b["capacity"]))
        self.updated[slot] = float(b.get("updated", 0.0)) if now is None \
            else float(now)
        self.ewma_off[slot] = float(state.get("ewma_offered", np.nan))
        self.ewma_def[slot] = float(state.get("ewma_deferred", np.nan))
        self.prev_off[slot] = float(state.get("prev_offered", 0.0))
        self.prev_def[slot] = float(state.get("prev_deferred", 0.0))
        self._device = None

    # -- the fused tick ------------------------------------------------------
    def _device_state(self) -> dict:
        """jnp mirrors of the state arrays (rebuilt after host mutation).

        Sliced to ``index.size``: the host arrays carry doubling-growth
        slack for O(1) amortized add, but every slot a tenant can occupy
        is below ``size``, so the fused tick never needs the tail — and
        paying bisection compute over it would be pure waste."""
        if self._device is None:
            import jax.numpy as jnp
            n = self.index.size
            with _x64():
                self._device = {
                    name: jnp.asarray(getattr(self, name)[:n])
                    for name in self.STATE_ARRAYS}
                self._device["active"] = jnp.asarray(self.active[:n])
        return self._device

    def state_bytes(self) -> int:
        """Bytes of control state touched per tick: the device-resident
        state arrays (sliced to the live slot range, matching what the
        fused tick actually reads) plus the per-tick sample stack."""
        n = self.index.size
        state = sum(getattr(self, nm)[:n].nbytes
                    for nm in self.STATE_ARRAYS)
        samples = 3 * n * 8                    # cur_off, cur_def, queue
        return state + self.active[:n].nbytes + samples

    def tick(self, offered: np.ndarray,
             deferred: Optional[np.ndarray] = None,
             queue: Optional[np.ndarray] = None,
             now: Optional[float] = None) -> Optional[np.ndarray]:
        """One fused control interval over the whole population.

        ``offered``/``deferred`` are slot-aligned cumulative counters
        (units ever served / ever deferred per slot), ``queue`` the
        instantaneous per-slot backlog. The first call establishes the
        counter baseline and returns None — exactly the object
        controller's warm-up tick. Subsequent calls return the per-slot
        allocation array (units/s; 0 for inactive slots).
        """
        t0 = time.perf_counter()
        now = time.monotonic() if now is None else float(now)
        n = self.index.size
        offered = np.asarray(offered, dtype=np.float64)
        deferred = np.zeros(n) if deferred is None \
            else np.asarray(deferred, dtype=np.float64)
        queue = np.zeros(n) if queue is None \
            else np.asarray(queue, dtype=np.float64)
        if offered.shape[0] != n:
            raise ValueError(f"offered has {offered.shape[0]} slots, "
                             f"index has {n}")
        if self.prev_t is None or now <= self.prev_t:
            self._sync_host()
            self.prev_off[:n] = offered
            self.prev_def[:n] = deferred
            self.prev_t = now
            self._device = None
            self.ticks += 1
            self.tick_seconds_total += time.perf_counter() - t0
            return None
        import jax.numpy as jnp
        dev = self._device_state()
        # one (3, slots) sample stack + one packed scalar vector: exactly
        # two host->device transfers per tick, whatever the population
        samples = np.stack([offered, deferred, queue])
        params = np.array([now, self.prev_t, self.alpha, self.capacity,
                           self.headroom, self.min_rate, self.burst_s])
        with _x64():
            out = _fused_tick_jitted()(
                dev["level"], dev["brate"], dev["bcap"], dev["updated"],
                dev["ewma_off"], dev["ewma_def"], dev["prev_off"],
                dev["prev_def"], dev["weight"], dev["active"],
                jnp.asarray(samples), jnp.asarray(params),
                iters=self.iters,
                scheduler_buckets=self.scheduler_buckets)
        (level, brate, bcap, updated, off, dfr, prev_off, prev_def,
         alloc, lvl) = out
        # state stays device-resident across ticks; the host arrays
        # refresh lazily on demand (facade access, snapshot, migration)
        self._device = {"level": level, "brate": brate, "bcap": bcap,
                        "updated": updated, "ewma_off": off,
                        "ewma_def": dfr, "prev_off": prev_off,
                        "prev_def": prev_def, "weight": dev["weight"],
                        "active": dev["active"]}
        alloc_np = np.array(alloc)   # np.asarray would be read-only
        self.prev_t = now
        self.last_alloc = alloc_np
        self.last_level = float(lvl)
        self.ticks += 1
        self.tick_seconds_total += time.perf_counter() - t0
        return alloc_np

    def allocations(self) -> Dict[int, float]:
        """The last tick's allocations as a {tenant: rate} dict (the
        object-API view; the array form is ``last_alloc``)."""
        return {t: float(self.last_alloc[s]) for t, s in self.index.items()}

    def obs(self) -> Dict[int, "TenantObs"]:
        """The last tick's telemetry view as TenantObs (facade export)."""
        from repro.control.telemetry import TenantObs
        self._sync_host()
        out = {}
        for t, s in self.index.items():
            off = float(self.ewma_off[s])
            dfr = float(self.ewma_def[s])
            if np.isnan(off):
                out[t] = TenantObs()
                continue
            dfr = 0.0 if np.isnan(dfr) else min(dfr, off)
            out[t] = TenantObs(rate=max(off - dfr, 0.0), offered=off,
                               deferred=dfr)
        return out

    def counters(self) -> Dict[str, float]:
        return {
            "nk_control_ticks_total": self.ticks,
            "nk_control_tick_seconds_total": self.tick_seconds_total,
            "nk_control_tenants": float(len(self.index)),
        }


# ---------------------------------------------------------------------------
# WaterFill facade entry point
# ---------------------------------------------------------------------------


def waterfill_allocate(demands: Dict[int, float], capacity: float,
                       weights: Optional[Dict[int, float]] = None,
                       impl: str = "ref") -> Dict[int, float]:
    """``max_min_fair`` on the array backend: dict in, dict out.

    Builds flat demand/weight arrays and dispatches to the jitted
    ``repro.kernels.ops.water_fill`` (``impl="ref"``: exact sort-based
    progressive fill; ``impl="pallas"``: fixed-iteration bisection
    kernel). Runs under x64 so allocations agree with the scalar
    implementation within 1e-6 x capacity. ``inf`` demand = greedy.
    """
    if capacity <= 0 or not demands:
        return {t: 0.0 for t in demands}
    from repro.kernels.ops import water_fill
    tenants = sorted(demands)
    d = np.asarray([float(demands[t]) for t in tenants])
    w = np.asarray([float(weights.get(t, 1.0)) if weights else 1.0
                    for t in tenants])
    with _x64():
        alloc = np.asarray(water_fill(d, w, float(capacity), impl=impl))
    out: Dict[int, float] = {}
    for i, t in enumerate(tenants):
        # satisfied tenants get their demand *exactly* (the object
        # backend's contract); the array result is within tolerance, so
        # snap to the demand when the fill reached it
        a = float(alloc[i])
        dt_ = float(demands[t])
        if np.isfinite(dt_) and abs(a - dt_) <= 1e-9 * max(abs(dt_), 1.0):
            a = dt_
        out[t] = a
    return out
