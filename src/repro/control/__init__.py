"""repro.control — the NetKernel management plane.

Once the network stack is part of the infrastructure (CoreEngine meters
every CommOp, token buckets shape every tenant), the operator can close the
loop: observe per-tenant rates, run a congestion-control policy over a
shared bottleneck, and push allocations back into the dataplane — the
paper's use case 2 (distributed congestion control / fair bandwidth
sharing, Figs. 21-22) as a subsystem.
"""
from repro.control.congestion import (
    Aimd, CongestionControl, Dctcp, WaterFill, max_min_fair,
)
from repro.control.controller import RateController
from repro.control.placement import (
    PLACEMENT_POLICIES, ClusterView, Consolidate, PlacementController,
    PlacementPlan, PlacementPolicy, PlannedMove, SpreadHot, make_policy,
)
from repro.control.sim import SharedBottleneckSim, SimResult, SimTenant
from repro.control.telemetry import (
    EngineTelemetry, SchedulerTelemetry, TenantObs, merge_obs,
)

__all__ = [
    "Aimd", "CongestionControl", "Dctcp", "WaterFill", "max_min_fair",
    "RateController",
    "PLACEMENT_POLICIES", "ClusterView", "Consolidate",
    "PlacementController", "PlacementPlan", "PlacementPolicy",
    "PlannedMove", "SpreadHot", "make_policy",
    "SharedBottleneckSim", "SimResult", "SimTenant",
    "EngineTelemetry", "SchedulerTelemetry", "TenantObs", "merge_obs",
]
