"""PlacementController: the closed loop from observed load to *where*
tenants run.

``RateController`` closes the rate loop — it decides *how fast* each tenant
goes on a shared bottleneck. This module closes the placement loop — the
paper's other operator win: because the stack is infrastructure, the
operator can multiplex tenants onto fewer network-stack modules to save
cores, and rebalance the mapping when load shifts, without the guests
noticing. A ``PlacementController`` runs on a cadence next to the rate
controller, consumes the same telemetry (per-engine load, per-tenant
tokens/s, queue depth), and emits ``PlacementPlan``s under a pluggable
``PlacementPolicy``:

  * ``consolidate`` — pack tenants onto the fewest engines that fit a
    per-engine load ceiling; engines left empty *park* (the cluster "saves
    cores", the paper's Table-2 multiplexing claim, now closed-loop).
    Parked engines unpark automatically when load returns.
  * ``spread_hot`` — hot-engine detection with hysteresis bands (a move
    needs the hot/cool gap to exceed an entry band AND to actually shrink
    the cluster's max load), so tenants don't ping-pong between engines.

Two gates apply to every planned move, independent of policy:

  * a per-tenant **cooldown** (the hysteresis window): a tenant that just
    moved cannot move again for ``cooldown_s`` virtual seconds — the
    no-ping-pong guarantee is enforced here, centrally;
  * a **drain-cost model**: migration leaves in-flight slots draining on
    the source, so a move whose drain window (in-flight tokens still to be
    generated) exceeds the expected gain (queued tokens that would start
    serving at the destination) is skipped — it would cost more than it
    relieves.

The controller is duck-typed over ``EngineCluster`` (anything with
``engines``, ``placement``, ``draining``, ``parked``, ``engine_load``,
``apply_plan``), and reads per-tenant pressure through the serve module's
``StackModule.tenant_load`` (repro.fabric) — the drain-cost gate prices
moves from the same protocol surface migration uses, never from a
concrete engine's slots — so policies can be unit-tested on a hand-built
``ClusterView`` with no jitted engines anywhere near the test.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Tuple

from repro.control.telemetry import SchedulerTelemetry, merge_obs
from repro.obs import tracing

# an idle tenant still occupies a placement slot: give it a tiny demand so
# bin-packing keeps it *somewhere* instead of dividing by zero around it
_DEMAND_FLOOR = 1e-6


# ---------------------------------------------------------------------------
# The policy input: one consistent snapshot of the cluster
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ClusterView:
    """Everything a placement policy may look at, snapshotted at plan time.

    Units: ``demand`` is tokens/s (EWMA of served rate — the same signal
    ``SchedulerTelemetry`` feeds the rate loop); ``engine_load`` and
    ``pending`` are requests (queued + in-flight — the instantaneous
    pressure ``EngineCluster.engine_load`` reports); ``queued_cost`` and
    ``inflight_remaining`` are tokens (the drain-cost model's unit).
    """

    n_engines: int
    parked: FrozenSet[int]
    placement: Dict[int, int]              # tenant -> engine index
    draining: FrozenSet[int]               # tenants mid-drain (unmovable)
    engine_load: Tuple[float, ...]         # per-engine queued + in-flight
    demand: Dict[int, float]               # tenant -> tokens/s (EWMA)
    pending: Dict[int, int]                # tenant -> queued requests
    queued_cost: Dict[int, float]          # tenant -> queued tokens
    inflight_remaining: Dict[int, float]   # tenant -> tokens still in-flight

    def active_engines(self) -> List[int]:
        return [k for k in range(self.n_engines) if k not in self.parked]

    def tenants_on(self, k: int) -> List[int]:
        return sorted(t for t, e in self.placement.items() if e == k)

    def movable(self, tenant: int) -> bool:
        return tenant not in self.draining


# ---------------------------------------------------------------------------
# The policy output
# ---------------------------------------------------------------------------


@dataclass
class PlannedMove:
    """One tenant relocation a policy wants."""

    tenant: int
    src: int
    dst: int
    reason: str                      # policy name that asked for it
    expected_gain: float = 0.0       # tokens the move starts serving sooner
    drain_cost: float = 0.0          # tokens still draining on the source


@dataclass
class PlacementPlan:
    """A policy's desired delta: moves + park/unpark lifecycle changes.

    ``unpark`` engines wake BEFORE moves apply (a move may target one);
    ``park`` engines sleep AFTER (they must be empty by then). An empty
    plan (no moves, no lifecycle changes) is a no-op the controller does
    not even hand to the cluster.
    """

    moves: List[PlannedMove] = field(default_factory=list)
    park: List[int] = field(default_factory=list)
    unpark: List[int] = field(default_factory=list)

    @property
    def empty(self) -> bool:
        return not (self.moves or self.park or self.unpark)


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------


class PlacementPolicy:
    """Maps one ``ClusterView`` to the ``PlacementPlan`` it wants.

    Policies are pure selection logic: the controller owns the hysteresis
    cooldown and the drain-cost gate, so every policy gets the same
    no-ping-pong guarantee for free.
    """

    name = "noop"

    def plan(self, view: ClusterView, now: float) -> PlacementPlan:
        raise NotImplementedError


class Consolidate(PlacementPolicy):
    """Pack tenants onto the fewest engines that fit ``ceiling`` tokens/s.

    First-fit-decreasing with a stickiness preference: a tenant stays on
    its current engine whenever that engine is open and still fits it, and
    a new bin to open is the tenant's own engine when possible — both keep
    steady state move-free. Engines hosting nothing after the pack are
    parked (cores saved); parked engines are unparked on demand when the
    open set no longer fits the fleet.

    Demand is each tenant's EWMA served rate *plus its backlog pressure*
    (queued tokens / ``queue_horizon_s``). The queue term is what makes
    the loop see through saturation: a fleet packed onto one engine serves
    at that engine's capacity no matter how much load returns, so the
    served rate alone would keep claiming the pack still fits — the
    growing queues are the only signal that it does not.

    Args:
        ceiling: per-engine demand ceiling in tokens/s. A fleet that
            cannot fit under the ceiling even with every engine awake
            overflows onto the least-loaded open engine (placement must
            never refuse a tenant).
        queue_horizon_s: backlog-to-rate conversion window, seconds: a
            queue is priced as the rate needed to clear it this fast.
    """

    name = "consolidate"

    def __init__(self, ceiling: float, queue_horizon_s: float = 4.0):
        if ceiling <= 0:
            raise ValueError("consolidate needs a positive tokens/s ceiling")
        self.ceiling = float(ceiling)
        self.queue_horizon_s = float(queue_horizon_s)

    def plan(self, view: ClusterView, now: float) -> PlacementPlan:
        demand = {t: max(view.demand.get(t, 0.0)
                         + view.queued_cost.get(t, 0.0)
                         / self.queue_horizon_s, _DEMAND_FLOOR)
                  for t in view.placement}
        # draining tenants cannot move: their engine stays open with their
        # demand pre-committed, whatever the pack decides
        fill: Dict[int, float] = {}
        open_bins: List[int] = []
        for t in sorted(view.placement):
            if not view.movable(t):
                k = view.placement[t]
                fill[k] = fill.get(k, 0.0) + demand[t]
                if k not in open_bins:
                    open_bins.append(k)
        target: Dict[int, int] = {}
        order = sorted((t for t in view.placement if view.movable(t)),
                       key=lambda t: (-demand[t], t))

        def fits(k: int, d: float) -> bool:
            return fill.get(k, 0.0) + d <= self.ceiling

        def openable() -> List[int]:
            return [k for k in range(view.n_engines) if k not in open_bins]

        for t in order:
            cur, d = view.placement[t], demand[t]
            if cur in open_bins and fits(cur, d):
                k = cur                              # stickiness: stay put
            else:
                k = next((b for b in open_bins if fits(b, d)), None)
                if k is None:
                    cands = openable()
                    if cands:
                        # opening the tenant's own engine is a free "move"
                        k = cur if cur in cands else cands[0]
                        open_bins.append(k)
                    else:
                        # overload: every engine is open and none fits —
                        # spill onto the least-loaded (placement never
                        # refuses; the rate loop handles the oversubscribe).
                        # Ties prefer the tenant's current engine so an
                        # equal-fill spill does not oscillate tick to tick.
                        k = min(open_bins,
                                key=lambda b: (fill.get(b, 0.0),
                                               b != cur, b))
            fill[k] = fill.get(k, 0.0) + d
            target[t] = k

        plan = PlacementPlan()
        for t, k in sorted(target.items()):
            src = view.placement[t]
            if k != src:
                plan.moves.append(PlannedMove(
                    tenant=t, src=src, dst=k, reason=self.name,
                    expected_gain=view.queued_cost.get(t, 0.0),
                    drain_cost=view.inflight_remaining.get(t, 0.0)))
        used = set(open_bins)
        plan.unpark = sorted(k for k in used if k in view.parked)
        plan.park = sorted(k for k in view.active_engines()
                           if k not in used)
        return plan


class SpreadHot(PlacementPolicy):
    """Move the most-backlogged tenant off a hot engine — with hysteresis.

    An engine is *hot* only when its load clears an absolute floor
    (``min_hot_load`` requests — small-queue jitter never triggers a move)
    AND exceeds the coolest engine by the entry band (``enter_ratio``).

    Ping-pong is prevented by two guards working together:

      * **arming (the hysteresis band)** — every tenant starts *armed*;
        moving it disarms it, and it only re-arms once it is observed on
        an engine whose load fell below the exit band (``exit_load``).
        A hog whose backlog makes every engine it touches hot therefore
        migrates exactly once: its new engine never cools, so it never
        re-arms, and the classic "the maximum moves with the tenant"
        oscillation cannot start.
      * **usefulness** — the move must either relieve a co-located tenant
        (the hot engine hosts someone besides the victim: de-colocation,
        the hog-vs-neighbour case) or improve the balance by a real margin
        (``cool_load + moved_queue <= (1 - improvement) * hot_load``) —
        a lone hog fails both (its queue IS the maximum, wherever it
        sits), so it is never bounced around.

    Args:
        enter_ratio: hot/cool load ratio that opens the band (>= 1).
        min_hot_load: absolute queued+in-flight floor before anything is
            considered hot, in requests.
        exit_load: engine load below which a disarmed tenant placed there
            re-arms (defaults to ``min_hot_load`` — enter high/exit low).
        improvement: required relative drop of the max load for a
            balance-motivated (no co-tenant) move.
    """

    name = "spread_hot"

    def __init__(self, enter_ratio: float = 2.0, min_hot_load: float = 8.0,
                 exit_load: Optional[float] = None,
                 improvement: float = 0.1):
        if enter_ratio < 1.0:
            raise ValueError("enter_ratio must be >= 1")
        self.enter_ratio = float(enter_ratio)
        self.min_hot_load = float(min_hot_load)
        self.exit_load = float(exit_load if exit_load is not None
                               else min_hot_load)
        self.improvement = float(improvement)
        self._disarmed: set = set()

    def _rearm(self, view: ClusterView) -> None:
        for t in list(self._disarmed):
            k = view.placement.get(t)
            if k is None or view.engine_load[k] < self.exit_load:
                self._disarmed.discard(t)

    def _victim(self, view: ClusterView, hot: int) -> Optional[int]:
        cands = [t for t in view.tenants_on(hot)
                 if view.movable(t) and t not in self._disarmed]
        if not cands:
            return None
        # most backlogged wins; ties break to the smaller tenant id
        return max(cands, key=lambda t: (view.pending.get(t, 0), -t))

    def notify_moved(self, tenant: int) -> None:
        """Controller callback: an applied move disarms its tenant until
        the engine it lives on cools below the exit band."""
        self._disarmed.add(tenant)

    def plan(self, view: ClusterView, now: float, *,
             pin_tenant: Optional[int] = None,
             force: bool = False) -> PlacementPlan:
        """``force`` bypasses bands, arming and the usefulness guard —
        the legacy one-shot ``rebalance()`` semantics (hot -> cool,
        unconditionally). ``pin_tenant`` overrides victim selection."""
        self._rearm(view)
        active = view.active_engines()
        if len(active) < 2:
            return PlacementPlan()
        hot = max(active, key=lambda k: (view.engine_load[k], -k))
        cool = min(active, key=lambda k: (view.engine_load[k], k))
        if hot == cool:
            return PlacementPlan()
        hot_load, cool_load = view.engine_load[hot], view.engine_load[cool]
        if not force:
            if hot_load < self.min_hot_load:
                return PlacementPlan()
            if hot_load < self.enter_ratio * max(cool_load, 1.0):
                return PlacementPlan()
        if pin_tenant is not None:
            victim = pin_tenant if view.movable(pin_tenant) else None
        else:
            victim = self._victim(view, hot)
        if victim is None or victim not in view.placement:
            return PlacementPlan()
        if view.placement[victim] != hot and not force:
            return PlacementPlan()
        src = view.placement[victim]
        if src == cool:
            return PlacementPlan()
        if not force:
            # what actually moves is the unserved queue — in-flight slots
            # drain on the source — so the transferable load is pending
            moved = float(view.pending.get(victim, 0))
            relieves_cotenant = len(view.tenants_on(src)) >= 2
            improves_balance = cool_load + moved <= \
                (1.0 - self.improvement) * hot_load
            if not (relieves_cotenant or improves_balance):
                return PlacementPlan()
        mv = PlannedMove(
            tenant=victim, src=src, dst=cool, reason=self.name,
            expected_gain=view.queued_cost.get(victim, 0.0),
            drain_cost=view.inflight_remaining.get(victim, 0.0))
        return PlacementPlan(moves=[mv])


PLACEMENT_POLICIES = {
    Consolidate.name: Consolidate,
    SpreadHot.name: SpreadHot,
}


def make_policy(policy, **kw) -> PlacementPolicy:
    """``policy``: a registry name ('consolidate' needs ``ceiling=``) or
    any object with a ``plan(view, now)`` method (returned as-is; kwargs
    must be empty — they only configure registry construction)."""
    if not isinstance(policy, str):
        if not hasattr(policy, "plan"):
            raise TypeError(f"{policy!r} is not a placement policy "
                            f"(no plan() method)")
        if kw:
            raise ValueError("policy kwargs only apply to registry names")
        return policy
    try:
        cls = PLACEMENT_POLICIES[policy]
    except KeyError:
        raise KeyError(f"unknown placement policy {policy!r}; "
                       f"have {sorted(PLACEMENT_POLICIES)}") from None
    return cls(**kw)


# ---------------------------------------------------------------------------
# The controller: telemetry -> policy -> gated application
# ---------------------------------------------------------------------------


class PlacementController:
    """Closed-loop placement next to the rate loop.

    Ticked by the cluster on a cadence (``EngineCluster(place_every=...)``,
    exactly how the shared ``RateController`` is ticked), or driven
    manually via ``plan_once``. Each tick: sample per-engine scheduler
    telemetry (the same ``SchedulerTelemetry`` the rate loop reads), build
    a ``ClusterView``, ask the policy for a plan, gate its moves through
    the hysteresis cooldown and the drain-cost model, and apply what
    survives via ``cluster.apply_plan`` (every applied move runs through
    ``migrate()``'s ledger-conserving drain-and-transfer).

    Args:
        cluster: an ``EngineCluster`` (or anything duck-typing it).
        policy: a ``PlacementPolicy`` instance or registry name; policy
            constructor kwargs ride in ``**policy_kw`` when a name is
            given (``consolidate`` requires ``ceiling=`` tokens/s).
        cooldown_s: the hysteresis window, virtual seconds — a tenant
            never moves twice within one window (0 disables).
        drain_cost_factor: skip a move when its drain cost exceeds
            ``factor`` x its expected gain (tokens vs tokens; None
            disables the gate). Factor 1.0 = "the move must relieve at
            least as many tokens as it strands draining".
        alpha: EWMA gain for the per-tenant tokens/s demand signal.
    """

    def __init__(self, cluster, policy="spread_hot", *,
                 cooldown_s: float = 3.0,
                 drain_cost_factor: Optional[float] = 1.0,
                 alpha: float = 0.5, **policy_kw):
        self.cluster = cluster
        self.policy = make_policy(policy, **policy_kw)
        self.cooldown_s = float(cooldown_s)
        self.drain_cost_factor = drain_cost_factor
        self._tel = [SchedulerTelemetry(e.scheduler, alpha)
                     for e in cluster.engines]
        self.last_move: Dict[int, float] = {}      # tenant -> virtual time
        self.move_log: List[Tuple[float, PlannedMove]] = []
        self.ticks = 0
        self.plans_applied = 0
        self.moves_applied = 0
        self.moves_skipped_cooldown = 0
        self.moves_skipped_drain = 0
        self.parks = 0
        self.unparks = 0

    # -- observation --------------------------------------------------------
    def view(self, now: Optional[float] = None) -> ClusterView:
        """Sample telemetry and snapshot the cluster for the policy.

        Per-tenant pressure comes from the serve module's
        ``StackModule.tenant_load`` — the same protocol surface migration
        uses — so the controller never reaches into a concrete engine's
        slot machinery."""
        obs = merge_obs([tel.update(now) for tel in self._tel])
        cl = self.cluster
        demand = {t: obs[t].rate if t in obs else 0.0
                  for t in cl.placement}
        pending: Dict[int, int] = {}
        queued: Dict[int, float] = {}
        inflight: Dict[int, float] = {}
        for t, k in cl.placement.items():
            tl = cl.engines[k].tenant_load(t)
            pending[t] = tl.pending
            queued[t] = float(tl.queued_tokens)
            inflight[t] = float(tl.inflight_tokens)
        return ClusterView(
            n_engines=len(cl.engines),
            parked=frozenset(getattr(cl, "parked", ())),
            placement=dict(cl.placement),
            draining=frozenset(cl.draining),
            engine_load=tuple(cl.engine_load(k)
                              for k in range(len(cl.engines))),
            demand=demand, pending=pending, queued_cost=queued,
            inflight_remaining=inflight)

    # -- gates --------------------------------------------------------------
    def _gate(self, plan: PlacementPlan, now: float) -> PlacementPlan:
        """Apply the cooldown + drain-cost gates; lifecycle changes for
        engines that only existed to receive a gated move are dropped."""
        kept: List[PlannedMove] = []
        for mv in plan.moves:
            since = now - self.last_move.get(mv.tenant, -float("inf"))
            if self.cooldown_s > 0 and since < self.cooldown_s:
                self.moves_skipped_cooldown += 1
                continue
            if self.drain_cost_factor is not None and mv.drain_cost > \
                    self.drain_cost_factor * max(mv.expected_gain, 0.0):
                self.moves_skipped_drain += 1
                continue
            kept.append(mv)
        if len(kept) != len(plan.moves):
            # a gated move leaves its tenant where it is: engines the plan
            # wanted to park may no longer be empty, and unparks that only
            # served a gated move may be pointless — recompute both
            staying = {mv.tenant for mv in plan.moves} - \
                {mv.tenant for mv in kept}
            occupied = {self.cluster.placement[t] for t in staying}
            plan = PlacementPlan(
                moves=kept,
                park=[k for k in plan.park if k not in occupied],
                unpark=[k for k in plan.unpark
                        if any(mv.dst == k for mv in kept)])
        return plan

    # -- the loop body ------------------------------------------------------
    def tick(self, now: Optional[float] = None) -> PlacementPlan:
        """One placement interval: observe -> plan -> gate -> apply.

        ``now``: seconds (virtual or wall clock; defaults to the wall
        clock, like ``RateController.tick`` — never a fabricated 0.0,
        which would re-anchor migrated buckets at t=0 and mint a full
        fresh burst for wall-clock callers). Returns the plan that was
        applied (possibly empty)."""
        self.ticks += 1
        now = time.monotonic() if now is None else float(now)
        view = self.view(now)
        plan = self._gate(self.policy.plan(view, now), now)
        if tracing.TRACER.enabled:
            tracing.TRACER.instant(
                "placement", "placement.plan", now,
                policy=self.policy.name, moves=len(plan.moves),
                park=len(plan.park), unpark=len(plan.unpark))
        self._apply(plan, now)
        return plan

    def plan_once(self, now: Optional[float] = None, *,
                  pin_tenant: Optional[int] = None,
                  force: bool = False) -> PlacementPlan:
        """One-shot planning (the deprecated ``rebalance()`` path).

        ``force`` bypasses bands/improvement/cooldown/drain gates —
        byte-for-byte the old operator one-shot semantics. Only
        ``spread_hot`` supports pinning/forcing."""
        now = time.monotonic() if now is None else float(now)
        view = self.view(now)
        if isinstance(self.policy, SpreadHot):
            plan = self.policy.plan(view, now, pin_tenant=pin_tenant,
                                    force=force)
        else:
            plan = self.policy.plan(view, now)
        if not force:
            plan = self._gate(plan, now)
        if tracing.TRACER.enabled:
            tracing.TRACER.instant(
                "placement", "placement.plan", now,
                policy=self.policy.name, moves=len(plan.moves),
                park=len(plan.park), unpark=len(plan.unpark),
                one_shot=True)
        self._apply(plan, now)
        return plan

    def _apply(self, plan: PlacementPlan, now: float) -> None:
        if plan.empty:
            return
        records = self.cluster.apply_plan(plan, now=now)
        applied = {r.tenant for r in records}
        notify = getattr(self.policy, "notify_moved", None)
        for mv in plan.moves:
            if mv.tenant in applied:
                self.last_move[mv.tenant] = now
                self.move_log.append((now, mv))
                self.moves_applied += 1
                if notify is not None:
                    notify(mv.tenant)
        self.parks += len(plan.park)
        self.unparks += len(plan.unpark)
        self.plans_applied += 1

    # -- invariants ---------------------------------------------------------
    def assert_no_ping_pong(self) -> None:
        """No tenant ever moved twice within one hysteresis window — the
        guarantee the cooldown gate enforces, checkable after a run."""
        seen: Dict[int, float] = {}
        for when, mv in self.move_log:
            prev = seen.get(mv.tenant)
            if prev is not None and when - prev < self.cooldown_s:
                raise AssertionError(
                    f"tenant {mv.tenant} ping-ponged: moved at {prev:.3f} "
                    f"and again at {when:.3f} inside the "
                    f"{self.cooldown_s}s hysteresis window")
            seen[mv.tenant] = when

    # -- reporting ----------------------------------------------------------
    def counters(self) -> Dict[str, float]:
        return {
            "nk_placement_ticks_total": float(self.ticks),
            "nk_placement_plans_applied_total": float(self.plans_applied),
            "nk_placement_moves_total": float(self.moves_applied),
            "nk_placement_moves_skipped_cooldown_total":
                float(self.moves_skipped_cooldown),
            "nk_placement_moves_skipped_drain_total":
                float(self.moves_skipped_drain),
            "nk_placement_parks_total": float(self.parks),
            "nk_placement_unparks_total": float(self.unparks),
        }
