"""Pluggable congestion-control algorithms for shared bottlenecks.

Each algorithm maps one control interval's observations (per-tenant
``TenantObs``) plus the bottleneck capacity to per-tenant rate allocations.
Three families, mirroring what operators actually deploy:

  * ``WaterFill`` — weighted max-min fair progressive filling. Backlogged
    tenants are treated as infinitely greedy and split the residual after
    satisfied tenants take their (measured) demand. Converges in one or two
    intervals; the paper's Fig. 21/22 "enforce fair sharing" policy.
  * ``Aimd`` — TCP-style additive-increase / multiplicative-decrease on the
    aggregate congestion signal. No demand estimation needed; converges to
    fair shares the classic sawtooth way.
  * ``Dctcp`` — multiplicative decrease proportional to an EWMA of the
    *fraction* of traffic deferred (the analogue of ECN marking fraction
    driven by queue depth), so the backoff is graded, not binary.
"""
from __future__ import annotations

import math
from typing import Dict, Mapping, Optional

from repro.control.telemetry import TenantObs

INF = math.inf


def max_min_fair(capacity: float, demands: Mapping[int, float],
                 weights: Optional[Mapping[int, float]] = None
                 ) -> Dict[int, float]:
    """Weighted max-min fair allocation by progressive filling.

    Tenants whose demand is below their weighted fair share are fully
    satisfied; the freed capacity is re-divided among the rest (water
    filling). ``inf`` demand = greedy. Allocations sum to at most
    ``capacity`` and exactly to ``capacity`` when demand is sufficient.
    """
    if capacity <= 0 or not demands:
        return {t: 0.0 for t in demands}
    w = {t: (weights.get(t, 1.0) if weights else 1.0) for t in demands}
    alloc = {t: 0.0 for t in demands}
    active = {t for t, d in demands.items() if d > 0 and w[t] > 0}
    remaining = float(capacity)
    # maintained incrementally as tenants are satisfied: each round is
    # O(active), not O(active^2) across rounds
    wsum = sum(w[t] for t in active)
    while active and remaining > 1e-12 and wsum > 1e-300:
        share = remaining / wsum            # capacity per unit weight
        satisfied = {t for t in active if demands[t] <= w[t] * share + 1e-12}
        if not satisfied:
            # everyone is greedy at this water level: split and finish
            for t in active:
                alloc[t] += w[t] * share
            remaining = 0.0
            break
        for t in satisfied:
            alloc[t] = float(demands[t])
            remaining -= demands[t]
            wsum -= w[t]
        active -= satisfied
    return alloc


class CongestionControl:
    """Base: ``allocate(obs, capacity) -> {tenant: rate}``. Stateful —
    algorithms carry per-tenant rates between control intervals."""

    def allocate(self, obs: Dict[int, TenantObs],
                 capacity: float) -> Dict[int, float]:
        raise NotImplementedError

    def reset(self) -> None:
        pass


class WaterFill(CongestionControl):
    """Measured-demand weighted max-min fairness.

    A tenant that experienced deferral (or has queue depth) is backlogged —
    its true demand is unknown, only that it exceeds its allocation — so it
    bids ``inf`` and receives a fair share of the residual. A satisfied
    tenant bids its observed offered rate times ``headroom`` so its
    allocation can track demand growth between intervals.

    ``backend="vectorized"`` runs the fill as one jitted array op
    (``repro.kernels.ops.water_fill``) instead of the scalar loop —
    same allocations within 1e-6 x capacity, flat cost per tenant.
    """

    def __init__(self, weights: Optional[Mapping[int, float]] = None,
                 headroom: float = 1.25, min_rate: float = 0.0,
                 backend: str = "object"):
        from repro.control.vectorized import check_backend
        self.weights = dict(weights or {})
        self.headroom = headroom
        self.min_rate = min_rate
        self.backend = check_backend(backend)

    def allocate(self, obs, capacity):
        # deferral is EWMA-smoothed, so it decays toward zero but never
        # reaches it after a tenant goes quiet; judge it against a noise
        # floor relative to the fair share or the idle tenant would keep
        # bidding inf and pin capacity it no longer uses
        eps = 1e-3 * capacity / max(len(obs), 1)
        demands = {t: (INF if (o.deferred > eps or o.queue > 0)
                       else o.offered * self.headroom)
                   for t, o in obs.items()}
        if self.backend == "vectorized":
            from repro.control.vectorized import waterfill_allocate
            alloc = waterfill_allocate(demands, capacity, self.weights)
        else:
            alloc = max_min_fair(capacity, demands, self.weights)
        if self.min_rate > 0:
            alloc = {t: max(r, self.min_rate) for t, r in alloc.items()}
        return alloc


class Aimd(CongestionControl):
    """Additive increase, multiplicative decrease on aggregate overload.

    Congestion signal: total offered load exceeding ``utilization`` of
    capacity. While uncongested every tenant's rate grows by ``increase``
    units/s per interval; on congestion every rate is cut by ``decrease``.
    """

    def __init__(self, increase: float, decrease: float = 0.5,
                 utilization: float = 0.95, min_rate: float = 1.0):
        assert 0.0 < decrease < 1.0
        self.increase = increase
        self.decrease = decrease
        self.utilization = utilization
        self.min_rate = min_rate
        self.rates: Dict[int, float] = {}

    def allocate(self, obs, capacity):
        total_offered = sum(o.offered for o in obs.values())
        congested = total_offered > self.utilization * capacity
        for t, o in obs.items():
            r = self.rates.get(t, capacity / max(len(obs), 1))
            if congested:
                r = max(r * self.decrease, self.min_rate)
            else:
                r = min(r + self.increase, capacity)
            self.rates[t] = r
        return dict(self.rates)

    def reset(self):
        self.rates.clear()


class Dctcp(CongestionControl):
    """DCTCP-style graded backoff from the deferral ("marking") fraction.

    Per tenant, ``alpha`` is an EWMA (gain ``g``) of the fraction of offered
    traffic that was deferred this interval — the stand-in for the fraction
    of packets ECN-marked beyond the queue threshold K. Rates back off by
    ``alpha/2`` when marked, else grow additively: small standing queues get
    gentle corrections instead of AIMD's halving.
    """

    def __init__(self, increase: float, g: float = 0.125,
                 min_rate: float = 1.0, mark_threshold: float = 0.0):
        self.increase = increase
        self.g = g
        self.min_rate = min_rate
        self.mark_threshold = mark_threshold
        self.alpha: Dict[int, float] = {}
        self.rates: Dict[int, float] = {}

    def allocate(self, obs, capacity):
        for t, o in obs.items():
            frac = 0.0
            if o.offered > 1e-12:
                frac = max(o.deferred - self.mark_threshold, 0.0) / o.offered
            a = (1.0 - self.g) * self.alpha.get(t, 0.0) + self.g * frac
            self.alpha[t] = a
            r = self.rates.get(t, capacity / max(len(obs), 1))
            if frac > 0.0:
                r = max(r * (1.0 - a / 2.0), self.min_rate)
            else:
                r = min(r + self.increase, capacity)
            self.rates[t] = r
        return dict(self.rates)

    def reset(self):
        self.alpha.clear()
        self.rates.clear()
