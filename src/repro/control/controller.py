"""RateController: the closed loop from observed traffic to enforced rates.

One controller owns one shared bottleneck (capacity in units/s) and any
number of enforcement points that draw from it:

  * CoreEngines (possibly several — the distributed case: engines on
    different hosts whose tenants share one cross-pod fabric). Per tick the
    controller merges per-engine telemetry, runs the congestion-control
    algorithm on the merged view, then splits each tenant's global
    allocation across engines in proportion to where that tenant's traffic
    actually showed up (with a small probe floor so an idle engine can
    discover demand).
  * TenantSchedulers (serving bottleneck in tokens/s): allocations are
    split the same way and pushed into the schedulers' admission buckets
    mid-run, preserving each bucket's capacity (requests admit whole).

Rates are pushed with ``update_tenant_rate``/``set_rate`` so live token
balances survive the update — a controller tick must not reopen a fresh
burst for a tenant it is trying to throttle.

``push_mode="delta"`` makes the push phase delta-based: only tenants whose
per-point target moved beyond ``delta_tol`` (relative) since the last issued
push get a call, so steady-state chatter is O(changed tenants), not
O(tenants x enforcement points). ``push_calls``/``push_skipped`` count both
sides and are exported as Prometheus counters.
"""
from __future__ import annotations

import time
from typing import Dict, Iterable, List, Optional, Tuple

from repro.control.congestion import CongestionControl, WaterFill
from repro.control.telemetry import (
    EngineTelemetry, SchedulerTelemetry, TenantObs, format_prometheus,
    merge_obs,
)
from repro.obs import tracing

_PROBE_FRAC = 0.02     # idle-enforcement-point floor, fraction of allocation


class RateController:
    """Distributed congestion control for one shared bottleneck."""

    def __init__(self, capacity: float,
                 algo: Optional[CongestionControl] = None,
                 weights: Optional[Dict[int, float]] = None,
                 alpha: float = 0.5, burst_s: float = 0.25,
                 push_mode: str = "full", delta_tol: float = 0.05,
                 refresh_every: int = 32, backend: str = "object"):
        """``capacity``: the ONE shared bottleneck in units/s — bytes/s
        when the enforcement points are CoreEngines, tokens/s when they
        are TenantSchedulers (don't mix units under one controller).
        ``weights``: per-tenant fair-share weights for the default
        WaterFill ``algo``. ``alpha``: telemetry EWMA gain in (0, 1].
        ``burst_s``: pushed bucket burst, in seconds' worth of the
        allocated rate. ``delta_tol``: relative move that makes a target
        worth pushing in delta mode; ``refresh_every``: ticks between
        delta-mode full re-pushes (soft-state bound). ``backend``:
        "object" keeps per-tenant control state in Python objects,
        "vectorized" in flat arrays (telemetry EWMA banks + the jitted
        array water-fill) — same allocations, flat cost per tenant."""
        from repro.control.vectorized import check_backend
        if push_mode not in ("full", "delta"):
            raise ValueError(f"push_mode must be 'full' or 'delta', "
                             f"got {push_mode!r}")
        self.capacity = float(capacity)
        self.backend = check_backend(backend)
        self.algo = algo if algo is not None \
            else WaterFill(weights, backend=backend)
        self.alpha = alpha
        self.burst_s = burst_s
        # delta mode: only tenants whose per-point allocation moved beyond
        # delta_tol (relative) get a set_rate call — O(changed) control-plane
        # chatter per tick instead of O(tenants x points)
        self.push_mode = push_mode
        self.delta_tol = float(delta_tol)
        # soft-state refresh: every refresh_every ticks delta mode pushes
        # everything anyway, bounding how long a skipped push can diverge
        # from an enforcement point that was reset behind our back
        # (drop_tenant, set_rate(None), a restarted scheduler)
        self.refresh_every = max(int(refresh_every), 1)
        self._last_push: Dict[Tuple[str, int, int], float] = {}
        self.push_calls = 0
        self.push_skipped = 0
        self._engines: List[Tuple[object, EngineTelemetry]] = []
        self._schedulers: List[Tuple[object, SchedulerTelemetry]] = []
        self.allocations: Dict[int, float] = {}
        self.history: List[Dict[int, float]] = []
        self.ticks = 0
        self.tick_calls = 0
        self.tick_seconds_total = 0.0
        self.last_tenants = 0

    # -- wiring -------------------------------------------------------------
    def attach_engine(self, engine, axes: Optional[Iterable[str]] = None):
        """Add a CoreEngine enforcement point (bytes/s bottleneck).
        ``axes``: restrict telemetry to CommOps intersecting these mesh
        axes (None = meter everything). Returns self for chaining."""
        self._engines.append(
            (engine, EngineTelemetry(engine, self.alpha, axes,
                                     backend=self.backend)))
        return self

    def attach_scheduler(self, scheduler):
        """Add a TenantScheduler enforcement point (tokens/s bottleneck).
        Several schedulers may share this controller's one ``capacity`` —
        the multi-engine cluster case. Returns self for chaining."""
        self._schedulers.append(
            (scheduler, SchedulerTelemetry(scheduler, self.alpha,
                                           backend=self.backend)))
        return self

    def detach_scheduler(self, scheduler) -> None:
        """Remove a TenantScheduler enforcement point (live stack swap:
        the retiring module's scheduler must stop receiving pushes).

        Also forgets the delta-push history of every *scheduler* point:
        detaching shifts the remaining schedulers' indices, so keyed
        ``_last_push`` entries would attribute stale targets to the wrong
        point. Unknown schedulers are ignored (idempotent)."""
        kept = [(s, tel) for s, tel in self._schedulers
                if s is not scheduler]
        if len(kept) == len(self._schedulers):
            return
        self._schedulers[:] = kept
        for key in [k for k in self._last_push if k[0] == "scheduler"]:
            del self._last_push[key]

    def invalidate_tenant(self, tenant: int) -> None:
        """Forget delta-push history for one tenant: the next tick pushes
        its rate to *every* enforcement point regardless of ``delta_tol``.

        Required around live migration: moving a tenant resets enforcement
        state (the source drops its bucket, the destination imports a
        transferred one) that ``_last_push`` knows nothing about — without
        invalidation, delta mode would judge the new target "unchanged" and
        skip the push, resurrecting the PR 2 stale-rate bug at cluster
        scale."""
        for key in [k for k in self._last_push if k[2] == tenant]:
            del self._last_push[key]

    def evict_tenant(self, tenant: int) -> None:
        """Drop a departed tenant's control state from every enforcement
        point that no longer holds it (telemetry EWMA + counter baseline
        + push history + allocation). Wired from the cluster's
        drop/migration-finalize paths — without it, telemetry EWMA maps
        grew one entry per tenant that ever existed. Points that still
        hold the tenant (migration source that only moved one of two
        planes, say) keep their live telemetry untouched."""
        self.invalidate_tenant(tenant)
        anywhere = False
        for engine, tel in self._engines:
            holds = getattr(engine, "has_tenant", None)
            if holds is not None and holds(tenant):
                anywhere = True
            else:
                tel.evict_tenant(tenant)
        for scheduler, tel in self._schedulers:
            if tenant in getattr(scheduler, "queues", {}):
                anywhere = True
            else:
                tel.evict_tenant(tenant)
        if not anywhere:
            self.allocations.pop(tenant, None)

    # -- observation --------------------------------------------------------
    def observe(self, now: Optional[float] = None) -> Dict[int, TenantObs]:
        """Sample every attached enforcement point at time ``now`` (seconds)
        and return the merged per-tenant view (units/s summed across
        points — one tenant's traffic through several engines)."""
        per_source = [tel.update(now) for _, tel in self._engines]
        per_source += [tel.update(now) for _, tel in self._schedulers]
        return merge_obs(per_source)

    # -- the loop body ------------------------------------------------------
    def tick(self, now: Optional[float] = None) -> Dict[int, float]:
        """One control interval: observe -> allocate -> push.

        ``now``: seconds (virtual or wall clock; defaults to wall clock).
        Returns the global per-tenant allocations in units/s ({} until the
        first interval with a usable rate signal)."""
        t0 = time.perf_counter()
        now = time.monotonic() if now is None else now
        merged = self.observe(now)
        self.tick_calls += 1
        self.last_tenants = len(merged)
        if not merged or not any(o.offered > 0 or o.queue > 0
                                 for o in merged.values()):
            # no rate signal yet (first tick only baselines the counters):
            # pushing allocations computed from zeros would stall everyone
            self.tick_seconds_total += time.perf_counter() - t0
            return {}
        self.allocations = self.algo.allocate(merged, self.capacity)
        calls_before = self.push_calls
        self._push(now)
        if tracing.TRACER.enabled:
            tracing.TRACER.instant(
                "controller", "rate.push", now,
                tenants=len(self.allocations),
                calls=self.push_calls - calls_before)
        self.history.append(dict(self.allocations))
        self.ticks += 1
        self.tick_seconds_total += time.perf_counter() - t0
        return self.allocations

    def _changed(self, kind: str, idx: int, tenant: int, rate: float) -> bool:
        """Delta gate: has this (enforcement point, tenant) target moved
        beyond tolerance since the last push we actually issued?"""
        if self.push_mode != "delta":
            return True
        prev = self._last_push.get((kind, idx, tenant))
        if prev is None:
            return True
        return abs(rate - prev) > self.delta_tol * max(abs(prev), 1e-9)

    def _push(self, now: float) -> None:
        if self.push_mode == "delta" and \
                self.ticks % self.refresh_every == self.refresh_every - 1:
            self._last_push.clear()        # periodic full refresh
        for tenant, rate in self.allocations.items():
            burst = max(rate * self.burst_s, 1.0)
            for i, ((engine, _tel), share) in enumerate(zip(
                    self._engines, self._shares(tenant, self._engines))):
                if self._changed("engine", i, tenant, rate * share):
                    engine.update_tenant_rate(tenant, rate * share,
                                              burst * share, now)
                    self._last_push[("engine", i, tenant)] = rate * share
                    self.push_calls += 1
                else:
                    self.push_skipped += 1
            # schedulers keep their bucket capacity: requests are admitted
            # whole, so shrinking burst below one request's token cost would
            # head-of-line-block the queue forever
            for i, ((scheduler, _tel), share) in enumerate(zip(
                    self._schedulers, self._shares(tenant, self._schedulers))):
                if self._changed("scheduler", i, tenant, rate * share):
                    scheduler.set_rate(tenant, rate * share, None, now)
                    self._last_push[("scheduler", i, tenant)] = rate * share
                    self.push_calls += 1
                else:
                    self.push_skipped += 1

    @staticmethod
    def _shares(tenant: int, points) -> List[float]:
        """Split one tenant's allocation across enforcement points in
        proportion to where its demand showed up (offered rate + queue)."""
        n = len(points)
        if n == 0:
            return []
        demand = [tel.obs.get(tenant, TenantObs()).offered
                  + tel.obs.get(tenant, TenantObs()).queue
                  for _, tel in points]
        total = sum(demand)
        if total <= 1e-12:
            return [1.0 / n] * n
        # probe floor: a point this tenant is quiet on still gets a sliver
        # so demand arriving there is admitted and becomes visible next tick
        floor = _PROBE_FRAC / n
        raw = [max(d / total, floor) for d in demand]
        norm = sum(raw)
        return [r / norm for r in raw]

    # -- reporting ----------------------------------------------------------
    def counters(self) -> Dict[str, float]:
        out: Dict[str, float] = {"controller_ticks_total": self.ticks,
                                 "controller_capacity": self.capacity,
                                 "controller_push_calls_total":
                                     self.push_calls,
                                 "controller_push_skipped_total":
                                     self.push_skipped,
                                 "nk_control_ticks_total": self.tick_calls,
                                 "nk_control_tick_seconds_total":
                                     self.tick_seconds_total,
                                 "nk_control_tenants":
                                     float(self.last_tenants)}
        for t, r in sorted(self.allocations.items()):
            out[f'nk_allocated_rate{{tenant="{t}"}}'] = r
        for _, tel in self._engines + self._schedulers:
            for k, v in tel.counters().items():
                # labeled totals end in '}', so match on the metric name
                out[k] = out.get(k, 0) + v if "_total" in k else v
        return out

    def export_prometheus(self) -> str:
        return format_prometheus(self.counters())
