"""RateController: the closed loop from observed traffic to enforced rates.

One controller owns one shared bottleneck (capacity in units/s) and any
number of enforcement points that draw from it:

  * CoreEngines (possibly several — the distributed case: engines on
    different hosts whose tenants share one cross-pod fabric). Per tick the
    controller merges per-engine telemetry, runs the congestion-control
    algorithm on the merged view, then splits each tenant's global
    allocation across engines in proportion to where that tenant's traffic
    actually showed up (with a small probe floor so an idle engine can
    discover demand).
  * TenantSchedulers (serving bottleneck in tokens/s): allocations are
    split the same way and pushed into the schedulers' admission buckets
    mid-run, preserving each bucket's capacity (requests admit whole).

Rates are pushed with ``update_tenant_rate``/``set_rate`` so live token
balances survive the update — a controller tick must not reopen a fresh
burst for a tenant it is trying to throttle.
"""
from __future__ import annotations

import time
from typing import Dict, Iterable, List, Optional, Tuple

from repro.control.congestion import CongestionControl, WaterFill
from repro.control.telemetry import (
    EngineTelemetry, SchedulerTelemetry, TenantObs, merge_obs,
)

_PROBE_FRAC = 0.02     # idle-enforcement-point floor, fraction of allocation


class RateController:
    """Distributed congestion control for one shared bottleneck."""

    def __init__(self, capacity: float,
                 algo: Optional[CongestionControl] = None,
                 weights: Optional[Dict[int, float]] = None,
                 alpha: float = 0.5, burst_s: float = 0.25):
        self.capacity = float(capacity)
        self.algo = algo if algo is not None else WaterFill(weights)
        self.alpha = alpha
        self.burst_s = burst_s
        self._engines: List[Tuple[object, EngineTelemetry]] = []
        self._schedulers: List[Tuple[object, SchedulerTelemetry]] = []
        self.allocations: Dict[int, float] = {}
        self.history: List[Dict[int, float]] = []
        self.ticks = 0

    # -- wiring -------------------------------------------------------------
    def attach_engine(self, engine, axes: Optional[Iterable[str]] = None):
        self._engines.append(
            (engine, EngineTelemetry(engine, self.alpha, axes)))
        return self

    def attach_scheduler(self, scheduler):
        self._schedulers.append(
            (scheduler, SchedulerTelemetry(scheduler, self.alpha)))
        return self

    # -- observation --------------------------------------------------------
    def observe(self, now: Optional[float] = None) -> Dict[int, TenantObs]:
        per_source = [tel.update(now) for _, tel in self._engines]
        per_source += [tel.update(now) for _, tel in self._schedulers]
        return merge_obs(per_source)

    # -- the loop body ------------------------------------------------------
    def tick(self, now: Optional[float] = None) -> Dict[int, float]:
        now = time.monotonic() if now is None else now
        merged = self.observe(now)
        if not merged or not any(o.offered > 0 or o.queue > 0
                                 for o in merged.values()):
            # no rate signal yet (first tick only baselines the counters):
            # pushing allocations computed from zeros would stall everyone
            return {}
        self.allocations = self.algo.allocate(merged, self.capacity)
        self._push(now)
        self.history.append(dict(self.allocations))
        self.ticks += 1
        return self.allocations

    def _push(self, now: float) -> None:
        for tenant, rate in self.allocations.items():
            burst = max(rate * self.burst_s, 1.0)
            for (engine, _tel), share in zip(
                    self._engines, self._shares(tenant, self._engines)):
                engine.update_tenant_rate(tenant, rate * share,
                                          burst * share, now)
            # schedulers keep their bucket capacity: requests are admitted
            # whole, so shrinking burst below one request's token cost would
            # head-of-line-block the queue forever
            for (scheduler, _tel), share in zip(
                    self._schedulers, self._shares(tenant, self._schedulers)):
                scheduler.set_rate(tenant, rate * share, None, now)

    @staticmethod
    def _shares(tenant: int, points) -> List[float]:
        """Split one tenant's allocation across enforcement points in
        proportion to where its demand showed up (offered rate + queue)."""
        n = len(points)
        if n == 0:
            return []
        demand = [tel.obs.get(tenant, TenantObs()).offered
                  + tel.obs.get(tenant, TenantObs()).queue
                  for _, tel in points]
        total = sum(demand)
        if total <= 1e-12:
            return [1.0 / n] * n
        # probe floor: a point this tenant is quiet on still gets a sliver
        # so demand arriving there is admitted and becomes visible next tick
        floor = _PROBE_FRAC / n
        raw = [max(d / total, floor) for d in demand]
        norm = sum(raw)
        return [r / norm for r in raw]

    # -- reporting ----------------------------------------------------------
    def counters(self) -> Dict[str, float]:
        out: Dict[str, float] = {"controller_ticks_total": self.ticks,
                                 "controller_capacity": self.capacity}
        for t, r in sorted(self.allocations.items()):
            out[f'nk_allocated_rate{{tenant="{t}"}}'] = r
        for _, tel in self._engines + self._schedulers:
            for k, v in tel.counters().items():
                # labeled totals end in '}', so match on the metric name
                out[k] = out.get(k, 0) + v if "_total" in k else v
        return out

    def export_prometheus(self) -> str:
        return "\n".join(f"{name} {value:.6g}"
                         for name, value in self.counters().items()) + "\n"
