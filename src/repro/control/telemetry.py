"""Telemetry: CoreEngine/TenantScheduler counters -> per-tenant rate signals.

The management plane's eyes. A CoreEngine already meters every CommOp in its
ledger (offered bytes) and — with enforcement on — the over-rate shortfall in
``deferred``. This module turns successive snapshots of those cumulative
counters into EWMA-smoothed per-(tenant, axis) rates:

    served   = offered - deferred        (bytes/s actually admitted in-rate)
    deferred > 0                         (the tenant is backlogged: it wants
                                          more than its current allocation)

which is exactly the observation a congestion-control algorithm needs. The
same interface wraps a TenantScheduler (served decode tokens + queue depth)
so one controller implementation manages both the collective-bytes and the
serving-tokens bottlenecks.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Tuple

from repro.obs import tracing
from repro.obs.metrics import render_prometheus


def format_prometheus(counters: Dict[str, float]) -> str:
    """Render a ``counters()`` dict in Prometheus text format — the one
    formatter every exporter (telemetry, controller, cluster) shares.
    Delegates to :func:`repro.obs.metrics.render_prometheus`, which emits
    ``# HELP``/``# TYPE`` lines, escapes label values and renders
    ``+Inf``/``NaN`` per the exposition-format rules."""
    return render_prometheus(counters)


@dataclass
class TenantObs:
    """One control interval's view of one tenant (units/s; units = bytes
    for engine bottlenecks, tokens for serving bottlenecks)."""

    rate: float = 0.0        # served (in-allocation) rate
    offered: float = 0.0     # served + deferred: what the tenant asked for
    deferred: float = 0.0    # over-allocation shortfall rate
    queue: float = 0.0       # instantaneous queue depth (units)

    @property
    def backlogged(self) -> bool:
        return self.deferred > 1e-9 or self.queue > 1e-9

    def merge(self, other: "TenantObs") -> "TenantObs":
        return TenantObs(rate=self.rate + other.rate,
                         offered=self.offered + other.offered,
                         deferred=self.deferred + other.deferred,
                         queue=self.queue + other.queue)


class _Ewma:
    def __init__(self, alpha: float):
        self.alpha = alpha
        self.value: Optional[float] = None

    def update(self, sample: float) -> float:
        if self.value is None:
            self.value = float(sample)
        else:
            self.value = self.alpha * float(sample) \
                + (1.0 - self.alpha) * self.value
        return self.value


class EngineTelemetry:
    """EWMA per-(tenant, axes) rate estimates from one CoreEngine's ledger.

    ``axes_filter`` restricts accounting to CommOps whose axes intersect the
    bottleneck's axes (None = count everything), so one engine can feed
    several controllers, each watching its own shared resource.

    Cumulative counters get Prometheus counter discipline: a tenant whose
    offered/deferred counter decreased or vanished since the last sample
    was exported/reset behind our back (live migration folds its ledger
    out of this engine), so its EWMA resets and the new value becomes the
    baseline instead of being read as a hugely negative rate.

    ``backend="vectorized"`` keeps the EWMA state in flat arrays
    (:class:`repro.control.vectorized.TelemetryBank`) instead of
    per-tenant ``_Ewma`` objects — same observations, flat cost.
    """

    def __init__(self, engine, alpha: float = 0.5,
                 axes_filter: Optional[Iterable[str]] = None,
                 backend: str = "object"):
        from repro.control.vectorized import TelemetryBank, check_backend
        self.engine = engine
        self.alpha = alpha
        self.axes_filter = None if axes_filter is None else set(axes_filter)
        self.backend = check_backend(backend)
        self._prev_offered: Dict[int, int] = {}
        self._prev_deferred: Dict[int, int] = {}
        self._prev_t: Optional[float] = None
        self._offered_ewma: Dict[int, _Ewma] = {}
        self._deferred_ewma: Dict[int, _Ewma] = {}
        self._bank = TelemetryBank(alpha) if backend == "vectorized" \
            else None
        self.obs: Dict[int, TenantObs] = {}
        self.updates = 0

    def evict_tenant(self, tenant: int) -> None:
        """Forget a departed tenant's EWMA/baseline state. Without this,
        ``_offered_ewma``/``_deferred_ewma`` entries for dropped or
        migrated-away tenants lived forever (the eviction leak)."""
        self._prev_offered.pop(tenant, None)
        self._prev_deferred.pop(tenant, None)
        self._offered_ewma.pop(tenant, None)
        self._deferred_ewma.pop(tenant, None)
        self.obs.pop(tenant, None)
        if self._bank is not None:
            self._bank.evict(tenant)

    def tracked_tenants(self) -> set:
        """Tenants with live EWMA/baseline state (leak regression hook)."""
        if self._bank is not None:
            return set(self._bank.tenants())
        return (set(self._prev_offered) | set(self._offered_ewma)
                | set(self._deferred_ewma))

    def _axes_match(self, axes: Tuple[str, ...]) -> bool:
        if self.axes_filter is None:
            return True
        return not self.axes_filter.isdisjoint(axes) or not axes

    def _cumulative(self) -> Tuple[Dict[int, int], Dict[int, int]]:
        ledger, deferred_raw = self.engine.snapshot()
        offered: Dict[int, int] = {}
        deferred: Dict[int, int] = {}
        for (t, _verb, axes), (_ops, nbytes) in ledger.items():
            if self._axes_match(axes):
                offered[t] = offered.get(t, 0) + nbytes
        for (t, axes), (_ops, nbytes) in deferred_raw.items():
            if self._axes_match(axes):
                deferred[t] = deferred.get(t, 0) + nbytes
        return offered, deferred

    def update(self, now: Optional[float] = None) -> Dict[int, TenantObs]:
        """Sample the engine ledger at time ``now`` (seconds; defaults to
        the wall clock) and return per-tenant ``TenantObs`` in bytes/s."""
        now = time.monotonic() if now is None else now
        offered, deferred = self._cumulative()
        if self._prev_t is None or now <= self._prev_t:
            # first sample (or time stood still): establish the baseline
            self._prev_offered, self._prev_deferred = offered, deferred
            self._prev_t = now
            if self._bank is not None:
                self._bank.baseline(offered, deferred)
            self.obs = {t: TenantObs() for t in offered}
            return self.obs
        dt = now - self._prev_t
        self.obs = {}
        if self._bank is not None:
            union = set(offered) | set(self._prev_offered)
            tenants, offs, dfrs, reset = self._bank.update(
                offered, dt, deferred=deferred)
            for i, t in enumerate(tenants):
                if t not in union:
                    continue
                if reset[i]:
                    if t in offered:
                        self.obs[t] = TenantObs()
                    continue
                off, dfr = float(offs[i]), float(dfrs[i])
                self.obs[t] = TenantObs(rate=max(off - dfr, 0.0),
                                        offered=off, deferred=dfr)
        else:
            for t in set(offered) | set(self._prev_offered):
                d_off = (offered.get(t, 0)
                         - self._prev_offered.get(t, 0)) / dt
                d_def = (deferred.get(t, 0)
                         - self._prev_deferred.get(t, 0)) / dt
                vanished = t not in offered and t in self._prev_offered
                if d_off < 0 or d_def < 0 or vanished:
                    # counter reset (migration fold / crash wipe):
                    # rebaseline instead of reading a negative rate
                    self._offered_ewma.pop(t, None)
                    self._deferred_ewma.pop(t, None)
                    if t in offered:
                        self.obs[t] = TenantObs()
                    continue
                off = self._offered_ewma.setdefault(t, _Ewma(self.alpha)) \
                    .update(d_off)
                dfr = self._deferred_ewma.setdefault(t, _Ewma(self.alpha)) \
                    .update(d_def)
                dfr = min(dfr, off)
                self.obs[t] = TenantObs(rate=max(off - dfr, 0.0),
                                        offered=off, deferred=dfr)
        self._prev_offered, self._prev_deferred = offered, deferred
        self._prev_t = now
        self.updates += 1
        if tracing.TRACER.enabled:
            tracing.TRACER.instant("telemetry", "telemetry.tick", now,
                                   plane="bytes", tenants=len(self.obs))
        return self.obs

    # -- exportable counters ------------------------------------------------
    def counters(self) -> Dict[str, float]:
        ledger, deferred = self.engine.snapshot()
        out: Dict[str, float] = {
            'telemetry_updates_total{plane="bytes"}': self.updates}
        for (t, _verb, axes), (_ops, nbytes) in sorted(ledger.items()):
            if self._axes_match(axes):
                key = f'tenant="{t}",axes="{"+".join(axes) or "none"}"'
                out[f"nk_offered_bytes_total{{{key}}}"] = \
                    out.get(f"nk_offered_bytes_total{{{key}}}", 0) + nbytes
        for (t, axes), (_ops, nbytes) in sorted(deferred.items()):
            if self._axes_match(axes):
                key = f'tenant="{t}",axes="{"+".join(axes) or "none"}"'
                out[f"nk_deferred_bytes_total{{{key}}}"] = \
                    out.get(f"nk_deferred_bytes_total{{{key}}}", 0) + nbytes
        for t, o in sorted(self.obs.items()):
            out[f'nk_served_bytes_per_s{{tenant="{t}"}}'] = o.rate
        return out

    def export_prometheus(self) -> str:
        return format_prometheus(self.counters())


class SchedulerTelemetry:
    """Same interface over a TenantScheduler: served tokens/s + queue depth.

    ``served_tokens`` is treated with Prometheus counter discipline: a
    tenant whose cumulative counter *decreased* (or vanished) since the last
    sample was exported/reset behind our back — live migration folds a
    tenant's ledger out of the source scheduler mid-run — so its EWMA is
    reset and the new counter value becomes the baseline instead of being
    read as a hugely negative rate.

    ``backend="vectorized"`` keeps the EWMA state in flat arrays
    (:class:`repro.control.vectorized.TelemetryBank`) instead of
    per-tenant ``_Ewma`` objects — same observations, flat cost.
    """

    def __init__(self, scheduler, alpha: float = 0.5,
                 backend: str = "object"):
        """``scheduler``: a live TenantScheduler; ``alpha``: EWMA gain in
        (0, 1] — 1.0 = no smoothing, use the raw per-interval rate."""
        from repro.control.vectorized import TelemetryBank, check_backend
        self.scheduler = scheduler
        self.alpha = alpha
        self.backend = check_backend(backend)
        self._prev_served: Dict[int, int] = {}
        self._prev_t: Optional[float] = None
        self._ewma: Dict[int, _Ewma] = {}
        self._bank = TelemetryBank(alpha) if backend == "vectorized" \
            else None
        self.obs: Dict[int, TenantObs] = {}
        self.updates = 0

    def evict_tenant(self, tenant: int) -> None:
        """Forget a departed tenant's EWMA/baseline state. Without this,
        the EWMA map kept entries for dropped or migrated-away tenants
        forever (the eviction leak)."""
        self._prev_served.pop(tenant, None)
        self._ewma.pop(tenant, None)
        self.obs.pop(tenant, None)
        if self._bank is not None:
            self._bank.evict(tenant)

    def tracked_tenants(self) -> set:
        """Tenants with live EWMA/baseline state (leak regression hook)."""
        if self._bank is not None:
            return set(self._bank.tenants())
        return set(self._prev_served) | set(self._ewma)

    def update(self, now: Optional[float] = None) -> Dict[int, TenantObs]:
        """Sample the scheduler's ledgers at time ``now`` (seconds; defaults
        to the wall clock) and return per-tenant ``TenantObs`` in tokens/s
        (rates) and tokens (queue depth)."""
        now = time.monotonic() if now is None else now
        served = dict(self.scheduler.served_tokens)
        queues = {t: float(self.scheduler.pending(t))
                  for t in self.scheduler.queues}
        if self._prev_t is None or now <= self._prev_t:
            self._prev_served, self._prev_t = served, now
            if self._bank is not None:
                self._bank.baseline(served)
            self.obs = {t: TenantObs(queue=queues.get(t, 0.0))
                        for t in set(served) | set(queues)}
            return self.obs
        dt = now - self._prev_t
        self.obs = {}
        if self._bank is not None:
            union = set(served) | set(self._prev_served) | set(queues)
            tenants, offs, _dfrs, reset = self._bank.update(
                served, dt, extra=queues)
            for i, t in enumerate(tenants):
                if t not in union:
                    continue
                if reset[i]:
                    if t in served or t in queues:
                        self.obs[t] = TenantObs(queue=queues.get(t, 0.0))
                    continue
                r = float(offs[i])
                self.obs[t] = TenantObs(rate=r, offered=r,
                                        queue=queues.get(t, 0.0))
        else:
            for t in set(served) | set(self._prev_served) | set(queues):
                raw = served.get(t, 0) - self._prev_served.get(t, 0)
                if raw < 0 or (t not in served and t in self._prev_served):
                    # counter reset: tenant migrated/dropped; rebaseline
                    self._ewma.pop(t, None)
                    if t in served or t in queues:
                        self.obs[t] = TenantObs(queue=queues.get(t, 0.0))
                    continue
                r = self._ewma.setdefault(t, _Ewma(self.alpha)) \
                    .update(raw / dt)
                q = queues.get(t, 0.0)
                self.obs[t] = TenantObs(rate=r, offered=r, queue=q)
        self._prev_served, self._prev_t = served, now
        self.updates += 1
        if tracing.TRACER.enabled:
            tracing.TRACER.instant("telemetry", "telemetry.tick", now,
                                   plane="serve", tenants=len(self.obs))
        return self.obs

    def counters(self) -> Dict[str, float]:
        out: Dict[str, float] = {
            'telemetry_updates_total{plane="serve"}': self.updates}
        for t, n in sorted(self.scheduler.served_tokens.items()):
            out[f'nk_served_tokens_total{{tenant="{t}"}}'] = n
        for t, o in sorted(self.obs.items()):
            out[f'nk_served_tokens_per_s{{tenant="{t}"}}'] = o.rate
            out[f'nk_queue_depth{{tenant="{t}"}}'] = o.queue
        for t, row in sorted(self.scheduler.ledger().items()):
            out[f'nk_admitted_requests_total{{tenant="{t}"}}'] = \
                row["admitted_requests"]
            out[f'nk_deferred_polls_total{{tenant="{t}"}}'] = \
                row["deferred_polls"]
            out[f'nk_mean_admit_wait_s{{tenant="{t}"}}'] = \
                row["mean_admit_wait_s"]
        return out

    def export_prometheus(self) -> str:
        return format_prometheus(self.counters())


def merge_obs(per_source: List[Dict[int, TenantObs]]) -> Dict[int, TenantObs]:
    """Sum observations across sources (the distributed case: one tenant's
    traffic through several engines sharing the bottleneck)."""
    out: Dict[int, TenantObs] = {}
    for obs in per_source:
        for t, o in obs.items():
            out[t] = out[t].merge(o) if t in out else o
    return out
