"""Pallas TPU flash attention (forward): VMEM-tiled online softmax.

TPU-native layout (B*H, S, d): the grid walks (batch*head, q blocks); each
program streams kv blocks of its row through VMEM with (m, l, acc) carried
in VMEM scratch. Causal/window blocks that are fully masked are skipped
with ``pl.when`` (no MXU cycles spent). Block shapes are MXU-aligned
(multiples of 128 on the lane dim; q/kv blocks of 128-512 rows keep the
working set q + k + v + acc well under ~16 MB VMEM:
    512x128 q (bf16)   128 KB
    512x128 k,v (bf16) 256 KB
    512x512 s (f32)      1 MB
    512x128 acc (f32)  256 KB
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                  causal: bool, window: int, scale: float, kv_block: int,
                  kv_len: int):
    iq = pl.program_id(1)
    jk = pl.program_id(2)
    q_block = q_ref.shape[0]

    @pl.when(jk == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_lo = iq * q_block
    k_lo = jk * kv_block
    # static-shape test for whether this (q,kv) block pair can contribute
    def compute():
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 1)
        s = jax.lax.dot_general(
            q_ref[...], k_ref[...], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        mask = k_pos < kv_len
        if causal:
            mask &= q_pos >= k_pos
        if window:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=1)
        acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot(
            p.astype(v_ref.dtype), v_ref[...],
            preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    # skip fully-masked block pairs (saves the MXU work the triangular /
    # banded structure allows)
    live = True
    if causal:
        live = q_lo + q_block - 1 >= k_lo
    if window:
        live = jnp.logical_and(live, k_lo + kv_block - 1 > q_lo - window) \
            if not isinstance(live, bool) else \
            (k_lo + kv_block - 1 > q_lo - window)
    if isinstance(live, bool):
        if live:
            compute()
    else:
        pl.when(live)(compute)

    @pl.when(jk == pl.num_programs(2) - 1)
    def _finish():
        o_ref[...] = (acc_sc[...] /
                      jnp.maximum(l_sc[...], 1e-30)[:, None]
                      ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    q_block=256, kv_block=256, interpret=True):
    """q: (BH, S, d); k, v: (BH, T, d). Returns (BH, S, d)."""
    bh, s, d = q.shape
    t = k.shape[1]
    scale = scale or 1.0 / math.sqrt(d)
    q_block = min(q_block, s)
    kv_block = min(kv_block, t)
    s_pad = -(-s // q_block) * q_block
    t_pad = -(-t // kv_block) * kv_block
    if s_pad != s:
        q = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0)))
    if t_pad != t:
        k = jnp.pad(k, ((0, 0), (0, t_pad - t), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, t_pad - t), (0, 0)))
    grid = (bh, s_pad // q_block, t_pad // kv_block)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, causal=causal, window=window,
                          scale=scale, kv_block=kv_block, kv_len=t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, q_block, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, kv_block, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, kv_block, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, q_block, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :s]
