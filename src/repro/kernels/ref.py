"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``*_ref`` mirrors its kernel's semantics exactly; tests sweep shapes and
dtypes asserting allclose between kernel (interpret=True on CPU) and oracle.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    """q: (B,H,S,d); k,v: (B,H,T,d). Full softmax attention."""
    b, h, s, d = q.shape
    t = k.shape[2]
    scale = scale or 1.0 / math.sqrt(d)
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= (q_pos - k_pos) < window
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def decode_attention_ref(q, k, v, pos, *, scale=None):
    """q: (B,H,d); k,v: (B,T,H,d); pos: (B,). Returns (o, m, l) — partial
    softmax stats so shards can LSE-combine (context-parallel decode)."""
    b, h, d = q.shape
    t = k.shape[1]
    scale = scale or 1.0 / math.sqrt(d)
    logits = jnp.einsum("bhd,bthd->bht", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = jnp.arange(t)[None, :] <= pos[:, None]
    logits = jnp.where(mask[:, None, :], logits, -1e30)
    m = jnp.max(logits, axis=-1)
    p = jnp.exp(logits - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bht,bthd->bhd", p, v.astype(jnp.float32))
    return (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype), m, l


def ssd_chunk_ref(xdt, dA, B, C):
    """One SSD chunk (intra-chunk quadratic part + chunk state).

    xdt: (Q,H,P) = x*dt; dA: (Q,H); B, C: (Q,N).
    Returns (y_diag (Q,H,P), state (H,P,N), chunk_decay (H,)).
    """
    Q, H, P = xdt.shape
    cs = jnp.cumsum(dA.astype(jnp.float32), axis=0)           # (Q,H)
    diff = cs[:, None, :] - cs[None, :, :]                    # (Q,Q,H)
    ii = jnp.arange(Q)
    L = jnp.where((ii[:, None] >= ii[None, :])[..., None],
                  jnp.exp(diff), 0.0)                         # (Q,Q,H)
    G = jnp.einsum("ln,sn->ls", C.astype(jnp.float32),
                   B.astype(jnp.float32))                     # (Q,Q)
    M = G[..., None] * L
    y = jnp.einsum("lsh,shp->lhp", M, xdt.astype(jnp.float32))
    decay_state = jnp.exp(cs[-1][None, :] - cs)               # (Q,H)
    state = jnp.einsum("sn,sh,shp->hpn", B.astype(jnp.float32),
                       decay_state, xdt.astype(jnp.float32))
    return y.astype(xdt.dtype), state, jnp.exp(cs[-1])


def quantize_int8_ref(x, block: int):
    """Blockwise symmetric int8: x (R, C) -> (q int8 (R,C), scales (R, C/block))."""
    r, c = x.shape
    xb = x.astype(jnp.float32).reshape(r, c // block, block)
    absmax = jnp.max(jnp.abs(xb), axis=-1)
    scale = jnp.maximum(absmax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127)
    return q.reshape(r, c).astype(jnp.int8), scale


def dequantize_int8_ref(q, scale, block: int, dtype=jnp.float32):
    r, c = q.shape
    xb = q.astype(jnp.float32).reshape(r, c // block, block)
    return (xb * scale[..., None]).reshape(r, c).astype(dtype)


def water_fill_ref(demands, weights, capacity):
    """Weighted max-min water-fill, exact sort-based progressive fill.

    demands, weights: (n,); capacity: scalar. Returns alloc (n,) with
    sum(alloc) <= capacity + eps. Tenants sorted by demand/weight ratio:
    the affordable prefix is satisfied exactly (alloc == demand), the
    rest split the leftover capacity by weight at one common water
    level. ``inf`` demand = greedy (never satisfied, always at level).
    Slots with demand <= 0 or weight <= 0 get 0 — that is how the fused
    tick parks inactive tenant slots.
    """
    d = jnp.asarray(demands)
    w = jnp.asarray(weights)
    cap = jnp.asarray(capacity, dtype=d.dtype)
    active = (d > 0) & (w > 0)
    w = jnp.where(active, w, 0.0)
    r = jnp.where(active, d / jnp.where(active, w, 1.0), jnp.inf)
    order = jnp.argsort(r)
    rs = r[order]
    ws = w[order]
    ds = jnp.where(active, d, 0.0)[order]
    fin = jnp.isfinite(rs) & (ws > 0)
    sat_demand = jnp.cumsum(jnp.where(fin, ds, 0.0))
    cum_w = jnp.cumsum(ws)
    tot_w = cum_w[-1] if ws.shape[0] else jnp.asarray(0.0, d.dtype)
    # water needed to satisfy tenants through sorted position i: their
    # demands outright, everyone after held at level r_i
    fill_at = sat_demand + jnp.where(fin, rs, 0.0) * (tot_w - cum_w)
    sat = fin & (fill_at <= cap * (1 + 1e-12) + 1e-12)
    k = jnp.sum(sat)
    last = jnp.maximum(k - 1, 0)
    used_d = jnp.where(k > 0, sat_demand[last], 0.0)
    used_w = jnp.where(k > 0, cum_w[last], 0.0)
    w_rem = tot_w - used_w
    lvl = jnp.where(w_rem > 0, (cap - used_d) / w_rem, jnp.inf)
    lvl_safe = jnp.maximum(jnp.where(jnp.isfinite(lvl), lvl, 0.0), 0.0)
    alloc_sorted = jnp.where(sat, ds, ws * lvl_safe)
    return jnp.zeros_like(alloc_sorted).at[order].set(alloc_sorted)
