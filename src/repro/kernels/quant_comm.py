"""Pallas TPU kernels for the compressed-transport hot path (int8 codec).

The CompressedNsm quantizes gradients before they cross the pod axis; on
real hardware the quantize/dequantize sits on the critical path of every
cross-pod reduction, so it gets a kernel: blockwise symmetric int8 with one
f32 scale per (row, block). Grid walks row blocks; each program quantizes a
(rows_block, C) tile held in VMEM (256x8192 bf16 = 4 MB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref, *, block: int):
    x = x_ref[...].astype(jnp.float32)            # (rb, C)
    rb, c = x.shape
    xb = x.reshape(rb, c // block, block)
    absmax = jnp.max(jnp.abs(xb), axis=-1)
    scale = jnp.maximum(absmax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127)
    q_ref[...] = q.reshape(rb, c).astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, o_ref, *, block: int):
    q = q_ref[...].astype(jnp.float32)
    rb, c = q.shape
    scale = s_ref[...]
    o = (q.reshape(rb, c // block, block) * scale[..., None]).reshape(rb, c)
    o_ref[...] = o.astype(o_ref.dtype)


def quantize_int8(x, *, block: int = 256, rows_block: int = 256,
                  interpret=True):
    """x: (R, C) with C % block == 0 -> (q int8 (R,C), scales f32 (R, C/block))."""
    r, c = x.shape
    assert c % block == 0, (c, block)
    rb = min(rows_block, r)
    r_pad = -(-r // rb) * rb
    if r_pad != r:
        x = jnp.pad(x, ((0, r_pad - r), (0, 0)))
    q, s = pl.pallas_call(
        functools.partial(_quant_kernel, block=block),
        grid=(r_pad // rb,),
        in_specs=[pl.BlockSpec((rb, c), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rb, c), lambda i: (i, 0)),
                   pl.BlockSpec((rb, c // block), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((r_pad, c), jnp.int8),
                   jax.ShapeDtypeStruct((r_pad, c // block), jnp.float32)],
        interpret=interpret,
    )(x)
    return q[:r], s[:r]


def dequantize_int8(q, scales, *, block: int = 256, rows_block: int = 256,
                    dtype=jnp.float32, interpret=True):
    r, c = q.shape
    rb = min(rows_block, r)
    r_pad = -(-r // rb) * rb
    if r_pad != r:
        q = jnp.pad(q, ((0, r_pad - r), (0, 0)))
        scales = jnp.pad(scales, ((0, r_pad - r), (0, 0)))
    o = pl.pallas_call(
        functools.partial(_dequant_kernel, block=block),
        grid=(r_pad // rb,),
        in_specs=[pl.BlockSpec((rb, c), lambda i: (i, 0)),
                  pl.BlockSpec((rb, c // block), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rb, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r_pad, c), dtype),
        interpret=interpret,
    )(q, scales)
    return o[:r]
