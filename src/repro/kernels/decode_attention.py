"""Pallas TPU decode attention: one query token vs a (sharded) KV cache.

Grid walks (batch, kv blocks); the query row (H, d) stays resident in VMEM
while cache blocks stream through. Emits per-shard partial stats (o, m, l)
so the context-parallel decode path can LSE-combine across the model axis
(the ``psum`` the serve engine's distributed decode performs) — the kernel
is the *local* half of distributed flash-decode.

VMEM working set per program: q (H,d) + k/v blocks (kvb, H*d slice) + acc —
with H<=128, d<=192, kvb=512: ~3 MB.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   m_sc, l_sc, acc_sc, *, scale: float, kv_block: int,
                   kv_len: int):
    jk = pl.program_id(1)

    @pl.when(jk == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    pos = pos_ref[0]
    k_lo = jk * kv_block
    q = q_ref[...]                       # (H, d)
    kb = k_ref[...]                      # (kvb, H, d)
    vb = v_ref[...]
    # per-head scores: contract d with h as a shared (batch-like) dim
    s = jnp.einsum("hd,thd->ht", q.astype(jnp.float32),
                   kb.astype(jnp.float32)) * scale      # (H, kvb)
    t_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = (t_pos <= pos) & (t_pos < kv_len)
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=1)
    acc_sc[...] = acc_sc[...] * corr[:, None] + jnp.einsum(
        "ht,thd->hd", p, vb.astype(jnp.float32))
    m_sc[...] = m_new

    @pl.when(jk == pl.num_programs(1) - 1)
    def _finish():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[...] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)
        m_ref[...] = m_sc[...]
        l_ref[...] = l_sc[...]


def decode_attention(q, k, v, pos, *, scale=None, kv_block=512,
                     interpret=True):
    """q: (B,H,d); k,v: (B,T,H,d) (kv already GQA-expanded or H==KV);
    pos: (B,). Returns (o (B,H,d), m (B,H), l (B,H))."""
    b, h, d = q.shape
    t = k.shape[1]
    scale = scale or 1.0 / math.sqrt(d)
    kv_block = min(kv_block, t)
    t_pad = -(-t // kv_block) * kv_block
    if t_pad != t:
        k = jnp.pad(k, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
    grid = (b, t_pad // kv_block)
    o, m, l = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, kv_block=kv_block,
                          kv_len=t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((None, h, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, kv_block, h, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, kv_block, h, d), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, h, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, h), lambda i, j: (i, 0)),
            pl.BlockSpec((None, h), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, d), q.dtype),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h, d), jnp.float32),
        ],
        interpret=interpret,
    )(pos, q, k, v)
    return o, m, l
