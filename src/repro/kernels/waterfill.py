"""Pallas kernel for the weighted max-min water-fill inner loop.

The fused control tick allocates capacity across the whole tenant
population every interval; at fleet scale (100k+ tenants) the water-fill
is the only super-linear step if done by sorting. This kernel does it in
O(iters x n): a fixed-iteration bisection on the common water level L —
S(L) = sum_t w_t * min(demand_t / w_t, L) is concave nondecreasing in L,
so the level where S(L) == capacity brackets in [0, capacity / min_w]
and halves every iteration. No sort, no data-dependent control flow;
the whole population is one (rows, 128) VMEM tile reduced per iteration.

Semantics match ``repro.kernels.ref.water_fill_ref`` (and the scalar
``max_min_fair``): slots with demand <= 0 or weight <= 0 are parked at 0,
``inf`` demand = greedy, satisfied tenants (ratio <= level) take their
demand exactly, the rest sit at weight x level.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 128


def _waterfill_kernel(d_ref, w_ref, c_ref, a_ref, l_ref, *, iters: int):
    d = d_ref[...]                                   # (rows, 128)
    w = w_ref[...]
    cap = c_ref[0, 0]
    active = (d > 0) & (w > 0)
    w = jnp.where(active, w, 0.0)
    r = jnp.where(active, d / jnp.where(active, w, 1.0), 0.0)
    min_w = jnp.min(jnp.where(active, w, jnp.inf))
    # cap / min_w upper-bounds the true level: any tenant with ratio
    # above it would alone absorb the whole capacity
    hi0 = jnp.where(jnp.isfinite(min_w),
                    cap / jnp.maximum(min_w, jnp.asarray(1e-30, d.dtype)),
                    jnp.asarray(0.0, d.dtype))

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        filled = jnp.sum(w * jnp.minimum(r, mid))
        over = filled > cap
        return jnp.where(over, lo, mid), jnp.where(over, mid, hi)

    _, lvl = jax.lax.fori_loop(0, iters, body, (jnp.zeros_like(hi0), hi0))
    a_ref[...] = jnp.where(active,
                           jnp.where(r <= lvl, d, w * lvl), 0.0)
    l_ref[0, 0] = lvl


def water_fill_pallas(demands, weights, capacity, *, iters: int = 48,
                      rows_block: int = 8, interpret=True):
    """demands, weights: (n,) -> alloc (n,). Pads n up to a multiple of
    ``rows_block * 128`` (padding parks as weight-0 slots)."""
    d = jnp.asarray(demands)
    w = jnp.asarray(weights, dtype=d.dtype)
    n = d.shape[0]
    tile = rows_block * _LANES
    n_pad = max(-(-n // tile) * tile, tile)
    if n_pad != n:
        d = jnp.pad(d, (0, n_pad - n))
        w = jnp.pad(w, (0, n_pad - n))
    rows = n_pad // _LANES
    cap = jnp.full((1, 1), capacity, dtype=d.dtype)
    alloc, _ = pl.pallas_call(
        functools.partial(_waterfill_kernel, iters=iters),
        out_shape=[jax.ShapeDtypeStruct((rows, _LANES), d.dtype),
                   jax.ShapeDtypeStruct((1, 1), d.dtype)],
        interpret=interpret,
    )(d.reshape(rows, _LANES), w.reshape(rows, _LANES), cap)
    return alloc.reshape(n_pad)[:n]
