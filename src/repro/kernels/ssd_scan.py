"""Pallas TPU kernel for the Mamba-2 SSD intra-chunk compute.

The SSD hot spot is the quadratic intra-chunk part: per (batch, chunk), the
masked decay matrix L = exp(segsum(dA)), the Gram matrix G = C B^T, the
chunk output Y = (G .* L) X and the outgoing chunk state. The inter-chunk
recurrence is O(chunks) and stays in jnp (repro/models/ssm.py).

Grid: (batch*chunks, head blocks). Per-program VMEM (Q=256, hb=8, P=64,
N=128, f32): L (Q,Q,hb) 2 MB + x (Q,hb,P) 0.5 MB + state (hb,P,N) 0.25 MB —
comfortably inside VMEM with MXU-aligned last dims.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_chunk_kernel(xdt_ref, dA_ref, b_ref, c_ref, y_ref, st_ref, dec_ref):
    xdt = xdt_ref[...].astype(jnp.float32)      # (Q, hb, P)
    dA = dA_ref[...].astype(jnp.float32)        # (Q, hb)
    B = b_ref[...].astype(jnp.float32)          # (Q, N)
    C = c_ref[...].astype(jnp.float32)          # (Q, N)
    Q = xdt.shape[0]

    cs = jnp.cumsum(dA, axis=0)                                  # (Q, hb)
    diff = cs[:, None, :] - cs[None, :, :]                       # (Q, Q, hb)
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where((ii >= jj)[..., None], jnp.exp(diff), 0.0)     # (Q, Q, hb)
    G = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, Q)
    M = G[..., None] * L                                         # (Q, Q, hb)
    y = jnp.einsum("lsh,shp->lhp", M, xdt)                       # (Q, hb, P)

    decay_state = jnp.exp(cs[-1][None, :] - cs)                  # (Q, hb)
    st = jnp.einsum("sn,sh,shp->hpn", B, decay_state, xdt)       # (hb, P, N)

    y_ref[...] = y.astype(y_ref.dtype)
    st_ref[...] = st
    dec_ref[...] = jnp.exp(cs[-1])


def ssd_chunk_scan(xdt, dA, B, C, *, head_block=8, interpret=True):
    """Intra-chunk SSD over all chunks.

    xdt: (nb, nc, Q, H, P); dA: (nb, nc, Q, H); B, C: (nb, nc, Q, N).
    Returns (y_diag (nb,nc,Q,H,P), states (nb,nc,H,P,N), decay (nb,nc,H)).
    """
    nb, nc, Q, H, P = xdt.shape
    N = B.shape[-1]
    hb = min(head_block, H)
    assert H % hb == 0, (H, hb)
    grid = (nb * nc, H // hb)
    xdt_f = xdt.reshape(nb * nc, Q, H, P)
    dA_f = dA.reshape(nb * nc, Q, H)
    B_f = B.reshape(nb * nc, Q, N)
    C_f = C.reshape(nb * nc, Q, N)
    y, st, dec = pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, Q, hb, P), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((None, Q, hb), lambda i, j: (i, 0, j)),
            pl.BlockSpec((None, Q, N), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, Q, N), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, Q, hb, P), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((None, hb, P, N), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, hb), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb * nc, Q, H, P), xdt.dtype),
            jax.ShapeDtypeStruct((nb * nc, H, P, N), jnp.float32),
            jax.ShapeDtypeStruct((nb * nc, H), jnp.float32),
        ],
        interpret=interpret,
    )(xdt_f, dA_f, B_f, C_f)
    return (y.reshape(nb, nc, Q, H, P), st.reshape(nb, nc, H, P, N),
            dec.reshape(nb, nc, H))
