"""jit'd public wrappers for the Pallas kernels (impl dispatch + layout).

``interpret`` defaults to True so everything validates on CPU; on a real
TPU deployment the flag flips to False via RunConfig.attention_impl
plumbing — model code never changes (the NetKernel property, applied to
kernels: the operator owns the implementation behind a stable call).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.quant_comm import dequantize_int8 as _dq_pallas
from repro.kernels.quant_comm import quantize_int8 as _q_pallas
from repro.kernels.ssd_scan import ssd_chunk_scan as _ssd_pallas
from repro.kernels.waterfill import water_fill_pallas as _wf_pallas


@functools.partial(jax.jit, static_argnames=("causal", "window", "impl",
                                             "q_block", "kv_block"))
def mha_forward(q, k, v, *, causal=True, window=0, impl="pallas",
                q_block=256, kv_block=256):
    """q,k,v: (B, H, S, d) -> (B, H, S, d)."""
    b, h, s, d = q.shape
    if impl == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, -1, d)
    vf = v.reshape(b * h, -1, d)
    o = _flash_pallas(qf, kf, vf, causal=causal, window=window,
                      q_block=q_block, kv_block=kv_block, interpret=True)
    return o.reshape(b, h, s, d)


@functools.partial(jax.jit, static_argnames=("impl", "kv_block"))
def decode_step_attention(q, k, v, pos, *, impl="pallas", kv_block=512):
    """q: (B,H,d); k,v: (B,T,H,d); pos: (B,). Returns (o, m, l)."""
    if impl == "ref":
        return ref.decode_attention_ref(q, k, v, pos)
    return _decode_pallas(q, k, v, pos, kv_block=kv_block, interpret=True)


@functools.partial(jax.jit, static_argnames=("impl", "head_block"))
def ssd_intra_chunk(xdt, dA, B, C, *, impl="pallas", head_block=8):
    """(nb, nc, Q, H, P) SSD intra-chunk. Returns (y, states, decay)."""
    if impl == "ref":
        f = jax.vmap(jax.vmap(
            lambda x, a, b_, c_: ref.ssd_chunk_ref(x, a, b_, c_)))
        return f(xdt, dA, B, C)
    return _ssd_pallas(xdt, dA, B, C, head_block=head_block, interpret=True)


@functools.partial(jax.jit, static_argnames=("block", "impl"))
def quantize(x, *, block=256, impl="pallas"):
    if impl == "ref":
        return ref.quantize_int8_ref(x, block)
    return _q_pallas(x, block=block, interpret=True)


@functools.partial(jax.jit, static_argnames=("impl", "iters"))
def water_fill(demands, weights, capacity, *, impl="pallas", iters=48):
    """demands, weights: (n,); capacity scalar -> alloc (n,).

    Weighted max-min water-fill over the whole tenant population — the
    control plane's allocation inner loop. impl="ref" is the exact
    sort-based progressive fill; impl="pallas" the fixed-iteration
    bisection kernel (no sort on the hot path)."""
    if impl == "ref":
        return ref.water_fill_ref(demands, weights, capacity)
    return _wf_pallas(demands, weights, capacity, iters=iters,
                      interpret=True)


@functools.partial(jax.jit, static_argnames=("block", "impl", "dtype"))
def dequantize(q, scales, *, block=256, impl="pallas", dtype=jnp.float32):
    if impl == "ref":
        return ref.dequantize_int8_ref(q, scales, block, dtype)
    return _dq_pallas(q, scales, block=block, dtype=dtype, interpret=True)
