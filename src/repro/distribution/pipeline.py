"""GPipe-style pipeline parallelism over the 'pod' axis (optional).

Stages live on pod-axis members; microbatches flow stage-to-stage through
``ppermute`` hops (NetKernel's ppermute verb — the pipeline's "wire" is
routable like any other collective). Schedule: plain GPipe fill/drain,
T = n_micro + n_stages - 1 ticks; each tick every stage processes the
microbatch it holds and forwards the result downstream.

This is the forward pipeline (inference / activation flow). It composes
with jax.grad (XLA differentiates through the ppermute ring), which is
exercised by tests/test_pipeline.py's loss-equivalence check.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map


def pipeline_forward(stage_params, x, stage_fn: Callable, *, mesh,
                     n_micro: int, axis: str = "pod"):
    """Run ``x`` through ``n_stages = |axis|`` stages of ``stage_fn``.

    stage_params: pytree with leading dim = n_stages (sharded over ``axis``).
    x: (B, ...) global batch; B % n_micro == 0.
    stage_fn(params_slice, x_mb) -> y_mb (same shape as x_mb).
    Returns y: (B, ...) — the last stage's outputs in microbatch order.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_stages = sizes[axis]
    b = x.shape[0]
    assert b % n_micro == 0, (b, n_micro)
    mb = x.reshape((n_micro, b // n_micro) + x.shape[1:])

    fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def local(params_local, mbs):
        # params_local: leading dim 1 (this stage); mbs: all microbatches
        # (replicated across the axis — only stage 0 consumes them).
        idx = jax.lax.axis_index(axis)
        p = jax.tree.map(lambda a: a[0], params_local)
        ticks = n_micro + n_stages - 1
        hold = jnp.zeros_like(mbs[0])            # microbatch in flight here
        outs = jnp.zeros_like(mbs)               # filled by the last stage

        def tick(carry, t):
            hold, outs = carry
            # stage 0 ingests microbatch t (if any); others use what arrived
            take = jnp.where(t < n_micro, t, 0)
            incoming = jnp.where((idx == 0) & (t < n_micro),
                                 mbs[take], hold)
            y = stage_fn(p, incoming)
            # last stage emits microbatch (t - n_stages + 1)
            out_idx = t - (n_stages - 1)
            emit = (idx == n_stages - 1) & (out_idx >= 0)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(emit, y, outs[jnp.maximum(out_idx, 0)]),
                jnp.maximum(out_idx, 0), axis=0)
            # forward activations downstream
            hold = jax.lax.ppermute(y, axis, fwd_perm)
            return (hold, outs), None

        (hold, outs), _ = jax.lax.scan(tick, (hold, outs),
                                       jnp.arange(ticks))
        # broadcast the last stage's outputs to everyone (masked psum:
        # ppermute is a strict permutation, it cannot fan out)
        outs = jax.lax.psum(
            jnp.where(idx == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    y = shard_map(local, mesh=mesh,
                  in_specs=(pspec, P()), out_specs=P(),
                  axis_names={axis}, check_vma=False)(stage_params, mb)
    return y.reshape((b,) + x.shape[1:])
