"""Logical-axis sharding rules with divisibility-aware fallback.

Every parameter/activation dimension is named with a *logical* axis
("batch", "heads", "ffn", ...). ``spec_for`` maps logical axes to mesh axes
by priority, dropping any candidate whose mesh size does not divide the
actual dimension (the assigned archs have head counts 12/24/25/56 against a
16-way model axis — see DESIGN.md §4). Models therefore never name mesh
axes; the operator owns the mapping, models own only semantics — the same
division of labor NetKernel imposes on the network stack.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

# logical axis -> ordered candidates; each candidate is a mesh axis or a
# tuple of mesh axes (used together). First candidate that (a) exists in the
# mesh and (b) divides the dim size wins; otherwise the dim is replicated.
LOGICAL_RULES: Dict[str, Tuple] = {
    "batch": (("pod", "data"), "data"),
    "embed": ("data",),           # FSDP: parameter rows sharded over data
    "embed_tp": ("model",),       # output-proj rows: TP contraction dim
    "heads": ("model",),
    "kv_heads": (),               # replicated (kv < tp in most assigned archs)
    "head_dim": (),
    "ffn": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "expert_group": ("data",),    # MoE dispatch group dim (GShard 2D layout)
    "expert_cap": ("data",),      # MoE (E, C, D) capacity dim
    "expert_ff": (),
    "seq": (),
    "seq_sp": ("model",),         # Megatron-SP activation sharding
    "kv_seq": ("model",),         # context-parallel decode cache
    "ssm_heads": ("model",),
    "ssm_state": (),
    "conv": (),
    "layers": (),                 # stacked-layer leading dim (scan)
    "stage": ("pod",),            # pipeline stages
    "none": (),
}


# Pure-FSDP variant: the whole mesh acts as one data/param-sharding axis
# (right for small/medium dense models where TP only wastes the model axis);
# MoE/TP archs keep the 2D rules. The operator picks per arch (dryrun
# run_config_for) — models never change.
FSDP_RULES: Dict[str, Tuple] = dict(
    LOGICAL_RULES,
    batch=(("pod", "data", "model"), ("data", "model"), "data"),
    embed=(("data", "model"), "data"),
    heads=(), ffn=(), vocab=(), experts=(), ssm_heads=(),
    seq_sp=(),
)

# Serving/TP variant: weights live model-sharded and are NEVER gathered —
# decode all-gathering FSDP weights costs ~170 MB x n_layers per step
# (measured 8.3 GB/chip/step on chameleon decode_32k); TP swaps that for
# tiny (B,1,D) activation psums. Weights replicate over 'data', so this is
# for models whose weights fit HBM/model_axis (<~60B at 16-way TP).
TP_RULES: Dict[str, Tuple] = dict(
    LOGICAL_RULES,
    embed=(),
)

RULE_VARIANTS = {"2d": LOGICAL_RULES, "fsdp": FSDP_RULES, "tp": TP_RULES}


def make_rules(variant: str) -> Dict[str, Tuple]:
    return RULE_VARIANTS[variant]


def mesh_axis_sizes(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def strip_axes_from_rules(axes: Tuple[str, ...],
                          rules: Optional[Dict[str, Tuple]] = None
                          ) -> Dict[str, Tuple]:
    """Rules with the given mesh axes removed (e.g. inside a shard_map that
    is manual over 'pod', constraints may only name the auto axes)."""
    rules = dict(rules or LOGICAL_RULES)
    out: Dict[str, Tuple] = {}
    for k, cands in rules.items():
        new = []
        for c in cands:
            if isinstance(c, tuple):
                c = tuple(a for a in c if a not in axes)
                if len(c) == 1:
                    c = c[0]
                if not c:
                    continue
            elif c in axes:
                continue
            new.append(c)
        out[k] = tuple(new)
    return out


def _candidate_size(cand, sizes: Dict[str, int]) -> Optional[int]:
    if isinstance(cand, tuple):
        n = 1
        for a in cand:
            if a not in sizes:
                return None
            n *= sizes[a]
        return n
    return sizes.get(cand)


def resolve_dim(logical: Optional[str], dim_size: int, sizes: Dict[str, int],
                rules: Optional[Dict[str, Tuple]] = None):
    """Mesh axis (or axes tuple) for one dimension, or None (replicate)."""
    if logical is None or logical == "none":
        return None
    rules = rules or LOGICAL_RULES
    if logical not in rules:
        raise KeyError(f"unknown logical axis {logical!r}")
    for cand in rules[logical]:
        n = _candidate_size(cand, sizes)
        if n is None or n == 0:
            continue
        if dim_size % n == 0:
            return cand
    return None


def spec_for(shape: Sequence[int], dims: Sequence[Optional[str]], mesh,
             rules: Optional[Dict[str, Tuple]] = None) -> P:
    assert len(shape) == len(dims), (shape, dims)
    sizes = mesh_axis_sizes(mesh)
    used: set = set()
    entries = []
    for size, logical in zip(shape, dims):
        cand = resolve_dim(logical, size, sizes, rules)
        # a mesh axis may appear at most once per spec
        flat = cand if isinstance(cand, tuple) else (cand,) if cand else ()
        if any(a in used for a in flat):
            cand = None
            flat = ()
        used.update(flat)
        entries.append(cand)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def sharding_for(shape, dims, mesh, rules=None) -> NamedSharding:
    return NamedSharding(mesh, spec_for(shape, dims, mesh, rules))


def constrain(x, dims, mesh, rules=None):
    """with_sharding_constraint by logical dims (no-op off-mesh dims)."""
    return jax.lax.with_sharding_constraint(
        x, sharding_for(x.shape, dims, mesh, rules))


def pad_to_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def padded_heads(num_heads: int, mesh) -> int:
    """Q-heads padded up to the model-axis multiple (inert-head scheme:
    see models/attention.py — the padded heads are provably zero in both
    directions)."""
    tp = mesh_axis_sizes(mesh).get("model", 1)
    if num_heads % tp == 0:
        return num_heads
    return pad_to_multiple(num_heads, tp)


# ---------------------------------------------------------------------------
# Parameter schema: models declare shapes + logical dims; the operator-side
# code derives shardings / abstract values / initial values from the schema.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamDesc:
    shape: Tuple[int, ...]
    dims: Tuple[Optional[str], ...]
    dtype: str = "bfloat16"
    init: str = "normal"       # normal | zeros | ones | small_normal
    init_scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.dims), (self.shape, self.dims)


def abstract_params(schema):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype)),
        schema, is_leaf=lambda x: isinstance(x, ParamDesc))


def param_shardings(schema, mesh, rules=None):
    return jax.tree.map(
        lambda d: sharding_for(d.shape, d.dims, mesh, rules),
        schema, is_leaf=lambda x: isinstance(x, ParamDesc))


def init_params(schema, key, on_mesh=None):
    """Materialize parameters (smoke/test scale; dry-run never calls this)."""
    leaves, treedef = jax.tree.flatten(
        schema, is_leaf=lambda x: isinstance(x, ParamDesc))
    keys = jax.random.split(key, len(leaves))
    vals = []
    for k, d in zip(keys, leaves):
        dt = jnp.dtype(d.dtype)
        if d.init == "zeros":
            v = jnp.zeros(d.shape, dt)
        elif d.init == "ones":
            v = jnp.ones(d.shape, dt)
        else:
            fan_in = d.shape[0] if d.shape else 1
            scale = d.init_scale / max(1.0, float(fan_in)) ** 0.5
            v = (jax.random.normal(k, d.shape, jnp.float32) * scale).astype(dt)
        vals.append(v)
    return jax.tree.unflatten(treedef, vals)


@dataclass
class ShardingCtx:
    """Threaded through model code: resolves logical dims on a given mesh."""

    mesh: object
    rules: Optional[Dict[str, Tuple]] = None
    seq_parallel: bool = False

    def spec(self, shape, dims) -> P:
        return spec_for(shape, dims, self.mesh, self.rules)

    def constrain(self, x, dims):
        if self.mesh is None:
            return x
        return constrain(x, dims, self.mesh, self.rules)

    def constrain_act(self, x, with_seq_dim=1):
        """Standard activation constraint (batch[, seq-SP])."""
        dims: list = [None] * x.ndim
        dims[0] = "batch"
        if self.seq_parallel and x.ndim > with_seq_dim:
            dims[with_seq_dim] = "seq_sp"
        return self.constrain(x, dims)

    @property
    def axis_sizes(self) -> Dict[str, int]:
        return mesh_axis_sizes(self.mesh)

    @property
    def tp(self) -> int:
        return self.axis_sizes.get("model", 1)
