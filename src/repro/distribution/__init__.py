from repro.distribution.sharding import (
    LOGICAL_RULES, ParamDesc, ShardingCtx, abstract_params, constrain,
    init_params, padded_heads, param_shardings, sharding_for, spec_for,
)

__all__ = [
    "LOGICAL_RULES", "ParamDesc", "ShardingCtx", "abstract_params",
    "constrain", "init_params", "padded_heads", "param_shardings",
    "sharding_for", "spec_for",
]
