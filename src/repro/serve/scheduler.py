"""Multi-tenant request scheduling: the CoreEngine control plane, serving.

Implements the paper's isolation/fairness mechanisms at the request level:

  * round-robin polling across tenant queues (CoreEngine's baseline),
  * weighted fair queueing (virtual-time WFQ) so a tenant issuing 64
    concurrent requests gets the same decode share as one issuing 8
    (use case 2 — entity-level, not flow-level, fairness),
  * per-tenant token buckets in tokens/s (Fig. 21 rate caps), with
    work-conserving backfill: capped tenants release capacity to others.
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

from repro.core.engine import TokenBucket
from repro.fabric import TenantState
from repro.obs import tracing
from repro.obs.hist import Histogram, TenantHistograms


@dataclass
class Request:
    tenant_id: int
    prompt: List[int]
    max_new_tokens: int
    req_id: int = 0
    arrival: float = -1.0      # < 0: unknown (excluded from wait ledger)
    # filled by the engine
    generated: List[int] = field(default_factory=list)
    admit_time: float = -1.0
    finish_time: float = -1.0


class TenantScheduler:
    """Fair multi-tenant admission: WFQ + optional token buckets + RR."""

    def __init__(self, policy: str = "wfq", charge_prompt: bool = False,
                 bucket_backend: str = "object"):
        from repro.control.vectorized import BucketStore, check_backend
        assert policy in ("wfq", "rr")
        self.policy = policy
        # bucket_backend="vectorized" keeps every tenant's bucket state in
        # one BucketStore (flat float64 arrays); self.buckets then holds
        # StoreBucket views with the identical TokenBucket interface
        self.bucket_backend = check_backend(bucket_backend)
        self._bucket_store = BucketStore() \
            if bucket_backend == "vectorized" else None
        # charge_prompt: buckets price a request at prompt + decode tokens
        # instead of decode only, so admission rates, telemetry (which sees
        # served prompt+decode tokens) and controller capacity share one
        # unit. The e2e replay harness turns this on; default keeps the
        # decode-only pricing.
        self.charge_prompt = charge_prompt
        self.queues: Dict[int, Deque[Request]] = {}
        self.weights: Dict[int, float] = {}
        self.buckets: Dict[int, TokenBucket] = {}
        self.vtime: Dict[int, float] = {}
        self.served_tokens: Dict[int, int] = {}
        # admission ledger (what the replay harness reads): requests admitted,
        # polls where a queued tenant was blocked by its bucket, and the
        # summed arrival->admission wait (needs ``now`` passed through)
        self.admitted_requests: Dict[int, int] = {}
        self.deferred_polls: Dict[int, int] = {}
        self.admit_wait_sum: Dict[int, float] = {}
        # per-tenant arrival->admission wait distribution (log buckets);
        # migrates with the tenant (export/import carry the counts)
        self.admit_wait_hist = TenantHistograms("nk_admit_wait_seconds")
        # trace track this scheduler's admission events land on; the
        # owning engine/cluster renames it ("engine0", ...)
        self.trace_track = "scheduler"
        # quiesce gate for live stack swaps: while True, next_request
        # admits nothing (and doesn't scan — no deferred_polls noise in
        # the ledger), queued work stays put, in-flight slots keep
        # stepping until they drain on the old module
        self.paused = False
        self._rr = itertools.count()
        self._rr_order: List[int] = []

    # -- bucket backend ------------------------------------------------------
    def _new_bucket(self, tenant_id: int, rate: float, burst: float):
        if self._bucket_store is not None:
            return self._bucket_store.add(tenant_id, rate, burst)
        return TokenBucket(rate, burst)

    def _restore_bucket(self, tenant_id: int, snap, now):
        if self._bucket_store is not None:
            return self._bucket_store.restore(tenant_id, snap, now)
        return TokenBucket.restore(snap, now)

    def _drop_bucket(self, tenant_id: int) -> None:
        self.buckets.pop(tenant_id, None)
        if self._bucket_store is not None:
            self._bucket_store.drop(tenant_id)

    # -- tenant management -------------------------------------------------
    def add_tenant(self, tenant_id: int, weight: float = 1.0,
                   rate_tokens_per_s: Optional[float] = None,
                   burst: Optional[float] = None):
        """Register a tenant: WFQ ``weight`` (dimensionless share), optional
        admission cap ``rate_tokens_per_s`` with ``burst`` in tokens
        (defaults to 1 s worth of rate). Resets any existing state."""
        self.queues[tenant_id] = deque()
        self.weights[tenant_id] = weight
        self.vtime[tenant_id] = 0.0
        self.served_tokens[tenant_id] = 0
        self._rr_order.append(tenant_id)
        if rate_tokens_per_s is not None:
            self.buckets[tenant_id] = self._new_bucket(
                tenant_id, rate_tokens_per_s, burst or rate_tokens_per_s)

    def set_rate(self, tenant_id: int,
                 rate_tokens_per_s: Optional[float],
                 burst: Optional[float] = None,
                 now: Optional[float] = None):
        """Controller push: retarget a tenant's admission rate mid-run.

        Preserves the live bucket's token balance (a tick must not reopen a
        fresh burst for a tenant it is throttling). ``None`` lifts the cap.

        Rate-only: a tenant unknown to this scheduler gets a bucket but NO
        queue registration. Controllers probe every enforcement point for
        every tenant, so registering here would grow ghost tenants — empty
        queues that WFQ/RR scan forever and whose stale rate entry would
        greet the tenant whenever it first shows up (see ``drop_tenant``).
        """
        if rate_tokens_per_s is None:
            self._drop_bucket(tenant_id)
            return
        b = self.buckets.get(tenant_id)
        if b is None:
            self.buckets[tenant_id] = b = self._new_bucket(
                tenant_id, rate_tokens_per_s, burst or rate_tokens_per_s)
            if now is not None:
                b.updated = now
        else:
            b.set_rate(rate_tokens_per_s, burst, now)
            if burst is None:
                # requests admit whole: keep >= 1s of burst so a raised rate
                # can actually cover a request (a capacity stuck below one
                # request's cost would starve the queue no matter the rate)
                b.capacity = max(b.capacity, float(rate_tokens_per_s))

    def set_weight(self, tenant_id: int, weight: float):
        """Set a tenant's WFQ weight (dimensionless; 2.0 = twice the decode
        share of a weight-1.0 tenant), registering it if unknown."""
        if tenant_id not in self.queues:
            self.add_tenant(tenant_id, weight=weight)
        self.weights[tenant_id] = weight

    def drop_tenant(self, tenant_id: int):
        """Forget a departed tenant entirely: queue state AND rate entry.

        Regression guard: a tenant with zero queued requests used to keep a
        stale bucket (last pushed rate) forever after ``set_rate``; a tenant
        returning much later was admitted against that stale rate instead of
        starting uncapped.
        """
        self.queues.pop(tenant_id, None)
        self.weights.pop(tenant_id, None)
        self._drop_bucket(tenant_id)
        self.vtime.pop(tenant_id, None)
        self.served_tokens.pop(tenant_id, None)
        self.admitted_requests.pop(tenant_id, None)
        self.deferred_polls.pop(tenant_id, None)
        self.admit_wait_sum.pop(tenant_id, None)
        self.admit_wait_hist.pop(tenant_id)
        if tenant_id in self._rr_order:
            self._rr_order.remove(tenant_id)

    # -- migration ----------------------------------------------------------
    def _live_state(self, tenant_id: int) -> List[str]:
        """Names of the live serve-plane state a tenant holds here (empty
        = quiesced destination).

        Deliberately does NOT include ``buckets``: controllers push
        rate-only buckets to every enforcement point (``set_rate``), so a
        pushed rate must not make a destination look live. But any
        counter a ``ConservationLedger.fold`` already carried
        (``served_tokens`` & co.) MUST: a freshly constructed replacement
        module whose counters were pre-seeded from the retiring module
        (e.g. via ``account`` replay) would otherwise pass the old
        queue-only guard, and the next export would fold those counters a
        second time — the double-fold / counter-replay edge the hot-swap
        path exercises.
        """
        live = []
        if tenant_id in self.queues:
            live.append("queue")
        for fld in ("served_tokens", "admitted_requests", "deferred_polls",
                    "admit_wait_sum", "vtime"):
            if getattr(self, fld).get(tenant_id):
                live.append(fld)
        if tenant_id in self.admit_wait_hist.per_tenant:
            live.append("admit_wait_hist")
        return live

    def export_tenant(self, tenant_id: int,
                      now: Optional[float] = None) -> TenantState:
        """Atomically remove a tenant and return its transferable state.

        The source half of live migration — the serve plane's
        ``StackModule.export_tenant`` body. Returns a ``TenantState``
        whose payload carries the tenant's unserved ``queue`` (list of
        Requests, FIFO order) and WFQ ``weight``, whose ``bucket`` is a
        ``TokenBucket.snapshot`` settled at ``now`` (None if uncapped),
        and whose ``carried`` counters are the cumulative ledger entries
        (``served_tokens`` [tokens], ``admitted_requests``,
        ``deferred_polls``, ``admit_wait_sum`` [s]). The carried entries
        are for the *operator* to fold — ``import_tenant`` deliberately
        does not replay them into the destination, where a sudden counter
        jump would read as a rate spike to telemetry.
        """
        state = TenantState(
            plane="serve",
            bucket=(self.buckets[tenant_id].snapshot(now)
                    if tenant_id in self.buckets else None),
            carried={
                "served_tokens": self.served_tokens.get(tenant_id, 0),
                "admitted_requests":
                    self.admitted_requests.get(tenant_id, 0),
                "deferred_polls": self.deferred_polls.get(tenant_id, 0),
                "admit_wait_sum": self.admit_wait_sum.get(tenant_id, 0.0),
            },
            payload={
                "queue": list(self.queues.get(tenant_id, ())),
                "weight": self.weights.get(tenant_id, 1.0),
            })
        wait_hist = self.admit_wait_hist.per_tenant.get(tenant_id)
        if wait_hist is not None:
            # the wait distribution travels with the tenant (unlike the
            # carried counters it IS replayed into the destination — a
            # histogram merge cannot read as a rate spike to telemetry)
            state.payload["admit_wait_hist"] = wait_hist.to_payload()
        self.drop_tenant(tenant_id)
        return state

    def import_tenant(self, tenant_id: int, state: TenantState,
                      now: Optional[float] = None) -> None:
        """Install a migrated tenant from ``export_tenant`` state.

        The unserved queue arrives in order; the bucket resumes at its
        transferred token balance anchored at ``now`` (migration can never
        reopen a fresh burst); the WFQ virtual time re-joins at the
        destination's current minimum so the migrant competes fairly from
        now instead of replaying a zero-vtime catch-up burst.
        """
        if state.plane != "serve":
            # bucket snapshots are shape-identical across planes: without
            # this guard a bytes-denominated level would silently install
            # as a tokens/s bucket
            raise ValueError(
                f"cannot import a {state.plane!r}-plane TenantState into "
                f"the serve plane")
        live = self._live_state(tenant_id)
        if live:
            raise ValueError(
                f"tenant {tenant_id} has live serve-plane state on the "
                f"destination ({', '.join(live)}); migration requires a "
                f"quiesced destination")
        self.add_tenant(tenant_id,
                        weight=state.payload.get("weight", 1.0))
        self.queues[tenant_id].extend(state.payload.get("queue", ()))
        others = [v for t, v in self.vtime.items() if t != tenant_id]
        self.vtime[tenant_id] = min(others) if others else 0.0
        if state.bucket is not None:
            self.buckets[tenant_id] = self._restore_bucket(
                tenant_id, state.bucket, now)
        hist_payload = state.payload.get("admit_wait_hist")
        if hist_payload is not None:
            self.admit_wait_hist.absorb(
                tenant_id, Histogram.from_payload(hist_payload))

    # -- checkpoint / restore (failover) ------------------------------------
    @staticmethod
    def _copy_request(r: Request) -> Request:
        """A request copy that shares nothing mutable: the checkpoint must
        not alias live ``generated`` lists, or post-checkpoint decode
        would silently inflate the snapshot's ground truth."""
        return Request(tenant_id=r.tenant_id, prompt=list(r.prompt),
                       max_new_tokens=r.max_new_tokens, req_id=r.req_id,
                       arrival=r.arrival, generated=list(r.generated),
                       admit_time=r.admit_time, finish_time=r.finish_time)

    def snapshot_tenant(self, tenant_id: int,
                        now: Optional[float] = None) -> TenantState:
        """Non-destructive ``export_tenant``: same ``TenantState`` wire
        shape, tenant keeps running here. Two deliberate differences:
        queued Requests are deep-copied (no aliasing with the live
        queue), and the payload additionally records the WFQ ``vtime`` —
        a restore resumes competition exactly where the checkpoint left
        it instead of re-joining at the destination minimum."""
        state = TenantState(
            plane="serve",
            bucket=(self.buckets[tenant_id].snapshot(now)
                    if tenant_id in self.buckets else None),
            carried={
                "served_tokens": self.served_tokens.get(tenant_id, 0),
                "admitted_requests":
                    self.admitted_requests.get(tenant_id, 0),
                "deferred_polls": self.deferred_polls.get(tenant_id, 0),
                "admit_wait_sum": self.admit_wait_sum.get(tenant_id, 0.0),
            },
            payload={
                "queue": [self._copy_request(r)
                          for r in self.queues.get(tenant_id, ())],
                "weight": self.weights.get(tenant_id, 1.0),
                "vtime": self.vtime.get(tenant_id, 0.0),
            })
        wait_hist = self.admit_wait_hist.per_tenant.get(tenant_id)
        if wait_hist is not None:
            state.payload["admit_wait_hist"] = wait_hist.to_payload()
        return state

    def restore_tenant(self, tenant_id: int, state: TenantState,
                       now: Optional[float] = None) -> None:
        """Install a checkpoint snapshot onto a crashed-and-wiped
        scheduler: FULL state including cumulative counters (unlike
        ``import_tenant``, which leaves counters to the operator's
        carried ledger). Refused on any live state — restoring the same
        tenant twice after a failed attempt must raise, never re-add."""
        if state.plane != "serve":
            raise ValueError(
                f"cannot restore a {state.plane!r}-plane TenantState into "
                f"the serve plane")
        live = self._live_state(tenant_id)
        if live:
            raise ValueError(
                f"tenant {tenant_id} has live serve-plane state on the "
                f"restore target ({', '.join(live)}); restore requires a "
                f"crashed/quiesced module")
        self.add_tenant(tenant_id,
                        weight=state.payload.get("weight", 1.0))
        # queue copies in: the snapshot stays byte-identical and reusable
        # even if this restored timeline mutates the requests
        self.queues[tenant_id].extend(
            self._copy_request(r) for r in state.payload.get("queue", ()))
        self.vtime[tenant_id] = float(state.payload.get("vtime", 0.0))
        self.served_tokens[tenant_id] = \
            int(state.carried.get("served_tokens", 0))
        self.admitted_requests[tenant_id] = \
            int(state.carried.get("admitted_requests", 0))
        self.deferred_polls[tenant_id] = \
            int(state.carried.get("deferred_polls", 0))
        self.admit_wait_sum[tenant_id] = \
            float(state.carried.get("admit_wait_sum", 0.0))
        if state.bucket is not None:
            # now=None keeps the snapshot's own timestamp (virtual-clock
            # safe: no free refill between checkpoint and restore)
            self.buckets[tenant_id] = self._restore_bucket(
                tenant_id, state.bucket, now)
        hist_payload = state.payload.get("admit_wait_hist")
        if hist_payload is not None:
            # REPLACE, never absorb: a re-restore after a failed attempt
            # must rebaseline the counts, not double them
            self.admit_wait_hist.per_tenant[tenant_id] = \
                Histogram.from_payload(hist_payload)

    def wipe(self) -> None:
        """Simulated crash: every tenant's queue, counters and bucket are
        gone in place. Telemetry reads the counter drop as a reset
        (Prometheus discipline), so a live controller survives it."""
        self.queues.clear()
        self.weights.clear()
        self.buckets.clear()
        if self._bucket_store is not None:
            from repro.control.vectorized import BucketStore
            self._bucket_store = BucketStore()
        self.vtime.clear()
        self.served_tokens.clear()
        self.admitted_requests.clear()
        self.deferred_polls.clear()
        self.admit_wait_sum.clear()
        self.admit_wait_hist.per_tenant.clear()
        self._rr_order.clear()
        self.paused = False

    def submit(self, req: Request):
        """Enqueue one request; an unknown tenant is auto-registered at
        weight 1.0 (uncapped until a controller pushes a rate)."""
        if req.tenant_id not in self.queues:
            self.add_tenant(req.tenant_id)
        self.queues[req.tenant_id].append(req)
        if tracing.TRACER.enabled and req.arrival >= 0.0:
            tracing.TRACER.instant(self.trace_track, "request.arrival",
                                   req.arrival, tenant=req.tenant_id,
                                   req=req.req_id)

    def pending(self, tenant_id: Optional[int] = None) -> int:
        """Unadmitted queued requests for one tenant (or all, if None)."""
        if tenant_id is not None:
            return len(self.queues.get(tenant_id, ()))
        return sum(len(q) for q in self.queues.values())

    def queued_cost(self, tenant_id: int) -> int:
        """Token price of a tenant's unadmitted queue (the bucket unit:
        prompt + decode under ``charge_prompt``, decode only otherwise).
        The placement autopilot's expected-gain signal: tokens that would
        start serving at a migration destination."""
        return sum(self._cost(r) for r in self.queues.get(tenant_id, ()))

    # -- admission ----------------------------------------------------------
    def _admissible(self, t: int, now: Optional[float]) -> bool:
        if not self.queues[t]:
            return False
        b = self.buckets.get(t)
        if b is None:
            return True
        head = self.queues[t][0]
        # admissible iff the bucket can cover the whole request NOW
        ok = b.wait_time(self._cost(head), now) <= 0.0
        if not ok:
            self.deferred_polls[t] = self.deferred_polls.get(t, 0) + 1
            if tracing.TRACER.enabled and now is not None:
                tracing.TRACER.instant(self.trace_track, "request.defer",
                                       now, tenant=t, req=head.req_id)
        return ok

    def next_request(self, now: Optional[float] = None) -> Optional[Request]:
        """Pick the next request to admit (or None; always None while
        ``paused`` — the hot-swap quiesce window)."""
        if self.paused:
            return None
        cands = [t for t in self.queues if self._admissible(t, now)]
        if not cands:
            return None
        if self.policy == "rr":
            # rotate round-robin order
            for _ in range(len(self._rr_order)):
                t = self._rr_order.pop(0)
                self._rr_order.append(t)
                if t in cands:
                    return self._take(t, now)
            return None
        # WFQ: smallest virtual time wins; vtime advances by served work
        t = min(cands, key=lambda q: (self.vtime[q], q))
        return self._take(t, now)

    def _cost(self, req: Request) -> int:
        return req.max_new_tokens + \
            (len(req.prompt) if self.charge_prompt else 0)

    def _take(self, t: int, now) -> Request:
        req = self.queues[t].popleft()
        b = self.buckets.get(t)
        if b is not None:
            b.consume(self._cost(req), now)
        self.admitted_requests[t] = self.admitted_requests.get(t, 0) + 1
        if now is not None and req.arrival >= 0.0:
            wait = max(now - req.arrival, 0.0)
            self.admit_wait_sum[t] = \
                self.admit_wait_sum.get(t, 0.0) + wait
            self.admit_wait_hist.observe(t, wait)
            if tracing.TRACER.enabled:
                tracing.TRACER.instant(self.trace_track, "request.admit",
                                       now, tenant=t, req=req.req_id,
                                       wait_s=round(wait, 6))
        return req

    # -- accounting (engine reports completed work) -------------------------
    def account(self, tenant_id: int, tokens: int):
        """Bill ``tokens`` (prompt and/or generated tokens — the unit the
        buckets and telemetry share) to a tenant and advance its WFQ
        virtual time by tokens/weight."""
        self.served_tokens[tenant_id] = \
            self.served_tokens.get(tenant_id, 0) + tokens
        w = max(self.weights.get(tenant_id, 1.0), 1e-9)
        self.vtime[tenant_id] = self.vtime.get(tenant_id, 0.0) + tokens / w

    def shares(self) -> Dict[int, float]:
        """Each tenant's fraction of all tokens served so far (sums to 1)."""
        tot = max(sum(self.served_tokens.values()), 1)
        return {t: n / tot for t, n in self.served_tokens.items()}

    def ledger(self) -> Dict[int, Dict[str, float]]:
        """Per-tenant admission ledger: the replay harness's source of truth
        (served tokens, admitted/deferred counts, mean admission wait)."""
        out: Dict[int, Dict[str, float]] = {}
        for t in set(self.served_tokens) | set(self.admitted_requests) \
                | set(self.deferred_polls):
            admitted = self.admitted_requests.get(t, 0)
            out[t] = {
                "served_tokens": float(self.served_tokens.get(t, 0)),
                "admitted_requests": float(admitted),
                "deferred_polls": float(self.deferred_polls.get(t, 0)),
                "queued": float(self.pending(t)),
                "mean_admit_wait_s": (self.admit_wait_sum.get(t, 0.0)
                                      / admitted if admitted else 0.0),
            }
        return out
