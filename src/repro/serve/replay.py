"""End-to-end fairness replay: Trace -> real Requests -> live ServeEngine.

``fair_replay`` (repro.serve.multiplex) validates the paper's Fig. 21/22
claims as a fluid-flow model; this module closes the gap to the actual
datapath. A ``TraceReplayer`` takes the same ``Trace`` vocabulary (bursty,
adversarial 10x-misbehaver, correlated-burst, ramp, steady), converts each
interval's per-tenant load into real ``Request`` objects, and feeds them to
a live ``ServeEngine`` — jitted prefill/decode, slot-based continuous
batching, WFQ admission — with a ``RateController`` attached to the
scheduler's token buckets (the tokens/s bottleneck). Everything runs on a
virtual clock: one engine step advances time by a fixed ``step_dt`` chosen
so the engine's raw throughput is ``headroom`` x the enforced capacity, so
the *management plane*, not the slots, is the binding constraint.

All metrics are read from real ledgers, never from the model:

  * achieved tokens/s   TenantScheduler.served_tokens (prompt + decode)
  * admission latency   arrival -> admission wait, scheduler ledger
  * defer pressure      bucket-blocked poll counts
  * Jain index          over achieved per-weight rates of contending tenants
  * control chatter     RateController push_calls / push_skipped

The scheduler runs with ``charge_prompt=True`` so bucket pricing, telemetry
observation and the served-token ledger share one unit and the controller's
``capacity`` is directly comparable to measured rates.

The same replayer drives a multi-engine ``EngineCluster`` (N ServeEngines,
one shared controller, operator-controlled placement) unchanged — see
``make_replay_cluster`` and the ``migration`` scenario, where a live
tenant migration lands mid-replay via ``run(events=...)``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence

import numpy as np

from repro.control.congestion import max_min_fair
from repro.serve.multiplex import Trace, jain_index
from repro.serve.scheduler import Request, TenantScheduler


@dataclass
class TenantReport:
    """One tenant's end-to-end outcome, straight from the ledgers.

    The percentile columns are histogram estimates (upper edge of the
    bucket the quantile falls in — within one log-bucket width of the
    true sample quantile, see ``repro.obs.hist``), windowed to this run
    like every other counter. NaN when the window observed no samples
    (a zero-request tenant in a short scenario): "no data" must not
    read as "p99 = 0". Renderers show it as ``-``."""

    demand_rate: float            # offered load, tokens/s
    achieved_rate: float          # served tokens/s over the replay window
    served_tokens: float
    admitted_requests: int
    completed_requests: int
    deferred_polls: int
    mean_admit_wait_s: float
    weight: float = 1.0
    p50_admit_wait_s: float = 0.0
    p99_admit_wait_s: float = 0.0
    p99_ttft_s: float = 0.0
    p99_e2e_s: float = 0.0


@dataclass
class ReplayReport:
    """Everything a fairness claim needs, measured on the real datapath.

    ``engines``/``migrations``/``placement`` surface the cluster view when
    the replay drove an ``EngineCluster``: how many engines shared the
    bottleneck, how many live migrations finalized inside this window, and
    where each tenant ended up (tenant -> engine index).

    ``cores_saved``/``max_parked``/``autopilot_moves`` surface the
    placement loop when an autopilot drove the cluster: average engines
    parked per step inside this window (the closed-loop core savings),
    the peak engines asleep at once, and how many moves the autopilot
    applied.

    ``mem_saved_bytes``/``max_parked_bytes``/``peak_resident_cache_bytes``
    surface the park suspend/resume lifecycle, all windowed to this run:
    average bytes freed per cluster step (the memory analog of
    ``cores_saved``), the peak bytes simultaneously freed by suspended
    engines, and the peak resident droppable-buffer footprint
    (KV-caches + slot state across awake engines) observed inside the
    window."""

    duration_s: float
    capacity: float               # enforced bottleneck, tokens/s
    per_tenant: Dict[int, TenantReport]
    decode_steps: int
    set_rate_calls: int = 0
    push_skipped: int = 0
    engines: int = 1
    migrations: int = 0
    swaps: int = 0                # live stack hot-swaps inside this window
    placement: Optional[Dict[int, int]] = None
    cores_saved: float = 0.0      # avg engines parked per cluster step
    max_parked: int = 0           # peak engines asleep at once
    autopilot_moves: int = 0      # placement-loop migrations this window
    mem_saved_bytes: float = 0.0  # avg bytes freed per cluster step
    max_parked_bytes: int = 0     # peak bytes freed by suspended engines
    peak_resident_cache_bytes: int = 0   # lifetime peak resident buffers
    checkpoints: int = 0          # fabric checkpoints inside this window
    recoveries: int = 0           # kill-and-restore recoveries this window
    # the watchdog view when the replay ran with one attached: alert
    # instances that fired inside this window (``repro.obs.slo.Alert``,
    # in fire order), how many of those resolved before the window
    # closed, how many were still firing at the end — and the watchdog
    # itself, so callers can dump its recorded scrape sequence
    alerts: Optional[Sequence] = None
    alerts_fired: int = 0
    alerts_resolved: int = 0
    alerts_active: int = 0
    watchdog: Optional[object] = None

    def alerts_by_rule(self) -> Dict[str, int]:
        """Fired-alert counts per rule name inside this window."""
        out: Dict[str, int] = {}
        for a in self.alerts or ():
            out[a.rule] = out.get(a.rule, 0) + 1
        return out

    def rates(self) -> Dict[int, float]:
        return {t: r.achieved_rate for t, r in self.per_tenant.items()}

    def total_rate(self) -> float:
        return sum(r.achieved_rate for r in self.per_tenant.values())

    def contending(self) -> Sequence[int]:
        """Tenants whose demand exceeded their fair share — the ones a
        fairness index is actually about."""
        ref = self.fair_reference()
        return [t for t, r in self.per_tenant.items()
                if r.demand_rate > ref[t] * 1.01]

    def jain(self, tenants: Optional[Sequence[int]] = None) -> float:
        ts = list(tenants) if tenants is not None else list(self.contending())
        if not ts:
            ts = list(self.per_tenant)
        return jain_index([self.per_tenant[t].achieved_rate
                           / self.per_tenant[t].weight for t in ts])

    def fair_reference(self) -> Dict[int, float]:
        """Weighted max-min fair allocation of the tenants' offered loads
        over the enforced capacity — the paper's Fig. 21 target."""
        demands = {t: r.demand_rate for t, r in self.per_tenant.items()}
        weights = {t: r.weight for t, r in self.per_tenant.items()}
        return max_min_fair(self.capacity, demands, weights)

    def max_min_deviation(self) -> float:
        """Worst relative gap between achieved rate and the max-min fair
        reference, over tenants with non-trivial fair share."""
        ref = self.fair_reference()
        worst = 0.0
        for t, want in ref.items():
            if want <= 1e-9:
                continue
            worst = max(worst,
                        abs(self.per_tenant[t].achieved_rate - want) / want)
        return worst


# canonical request shape for the e2e scenarios — the one place the
# request's token price (prompt + decode) is defined; bench_fairness --e2e
# and tests derive from these instead of re-hardcoding them
PROMPT_LEN = 2
MAX_NEW_TOKENS = 6
TOKENS_PER_REQUEST = PROMPT_LEN + MAX_NEW_TOKENS


class TraceReplayer:
    """Drives a ServeEngine — or a whole EngineCluster — through a Trace
    on a virtual clock.

    Args:
        engine: a live ``ServeEngine`` or ``EngineCluster`` (anything with
            the engine driving surface: ``B``, ``submit``, ``step``,
            ``completed``, ``decode_steps``, ``scheduler``,
            ``controller``). A cluster's ledger facade makes per-tenant
            counters continuous across live migrations.
        capacity: the enforced bottleneck in tokens/s (the controller's
            capacity — cluster-wide when driving a cluster).
        interval_s: seconds of virtual time per trace interval.
        prompt_len / max_new_tokens: request shape in tokens.
        headroom: raw engine throughput as a multiple of ``capacity``; > 1
            keeps the management plane, not the slots, the binding
            constraint.
        weights: per-tenant WFQ weights (dimensionless), default 1.0.
        watchdog: a ``repro.obs.slo.FabricWatchdog`` to tick on the
            virtual clock — once before the first interval (the rate
            baseline) and once at each interval boundary — so every
            replay doubles as an alert-precision fixture. Its alert
            activity lands in the report's ``alerts*`` fields.
    """

    def __init__(self, engine, *, capacity: float,
                 interval_s: float = 1.0, prompt_len: int = PROMPT_LEN,
                 max_new_tokens: int = MAX_NEW_TOKENS, headroom: float = 1.5,
                 weights: Optional[Dict[int, float]] = None,
                 watchdog=None):
        self.engine = engine
        self.watchdog = watchdog
        self.capacity = float(capacity)
        self.interval_s = float(interval_s)
        self.prompt_len = int(prompt_len)
        self.max_new_tokens = int(max_new_tokens)
        self.weights = dict(weights or {})
        self.tokens_per_request = self.prompt_len + self.max_new_tokens
        # raw engine throughput at full slots is B*(p+n)/n tokens per step;
        # pick step_dt so that equals headroom * capacity: enforcement binds
        raw_per_step = engine.B * self.tokens_per_request / self.max_new_tokens
        self.step_dt = raw_per_step / (headroom * self.capacity)
        self._req_id = 0
        self._vt = 0.0

    # ------------------------------------------------------------------
    def _submit(self, tenant: int, now: float):
        self._req_id += 1
        self.engine.submit(Request(
            tenant_id=tenant, prompt=list(range(1, self.prompt_len + 1)),
            max_new_tokens=self.max_new_tokens, req_id=self._req_id,
            arrival=now))

    def run(self, trace: Trace, *, unit: str = "requests",
            events: Optional[Sequence] = None) -> ReplayReport:
        """Replay ``trace`` (per-tenant loads per interval). ``unit`` is
        what a load value means: "requests" (requests/s, the multiplexing
        vocabulary) or "tokens" (tokens/s, divided by request cost).

        ``events``: optional sequence of ``(interval_index, fn)`` operator
        actions; ``fn(engine, now)`` runs at the start of that (0-based)
        interval — how a live migration lands mid-replay."""
        loads = np.asarray(trace.loads, float)
        if unit == "tokens":
            loads = loads / self.tokens_per_request
        elif unit != "requests":
            raise ValueError(f"unknown unit {unit!r}")
        n, T = loads.shape
        sched: TenantScheduler = self.engine.scheduler
        for i in range(n):
            if i not in sched.queues:
                sched.add_tenant(i, weight=self.weights.get(i, 1.0))
            else:
                sched.set_weight(i, self.weights.get(i, 1.0))
        start_vt = self._vt
        served0 = {i: sched.served_tokens.get(i, 0) for i in range(n)}
        admitted0 = {i: sched.admitted_requests.get(i, 0) for i in range(n)}
        deferred0 = {i: sched.deferred_polls.get(i, 0) for i in range(n)}
        wait0 = {i: sched.admit_wait_sum.get(i, 0.0) for i in range(n)}
        completed0 = len(self.engine.completed)
        ctrl = self.engine.controller
        calls0 = getattr(ctrl, "push_calls", 0)
        skip0 = getattr(ctrl, "push_skipped", 0)
        steps0 = self.engine.decode_steps
        migrations0 = getattr(self.engine, "migrations_completed", 0)
        swaps0 = len(getattr(self.engine, "swap_log", ()))
        ckpt0 = getattr(self.engine, "checkpoints_total", 0)
        recov0 = getattr(self.engine, "recoveries_total", 0)
        cl_steps0 = getattr(self.engine, "steps", 0)
        parked0 = getattr(self.engine, "parked_engine_steps", 0)
        mem0 = getattr(self.engine, "mem_saved_byte_steps", 0)
        pilot = getattr(self.engine, "autopilot", None)
        pilot_moves0 = getattr(pilot, "moves_applied", 0)
        # window the latency histograms like every other counter: snapshot
        # per-tenant counts now, diff at the end (engine and cluster both
        # expose latency() -> {metric: TenantHistograms})
        lat_fn = getattr(self.engine, "latency", None)
        lat0: Dict[str, Dict[int, object]] = {}
        if lat_fn is not None:
            for mname, th in lat_fn().items():
                lat0[mname] = {t: h.copy()
                               for t, h in th.per_tenant.items()}

        ev: Dict[int, list] = {}
        for idx, fn in (events or ()):
            if not 0 <= int(idx) < T:
                # a silently dropped event breaks the scenario's contract
                # (e.g. "includes a live migration") in confusing ways
                raise ValueError(f"event interval {idx} out of range for a "
                                 f"{T}-interval trace")
            ev.setdefault(int(idx), []).append(fn)
        wd = self.watchdog
        alerts0 = len(wd.alerts.history) if wd is not None else 0
        if wd is not None and (not wd.store.times()
                               or start_vt > wd.store.times()[-1]):
            # the pre-traffic baseline scrape: window rates at interval 0
            # diff against quiet counters instead of an empty store
            wd.tick(start_vt)
        frac = np.zeros(n)
        # per-window peaks of engines asleep / bytes freed (the cluster's
        # own high-water marks are lifetime; this report is windowed)
        max_parked = 0
        max_parked_bytes = 0
        peak_resident = 0
        parked_bytes = getattr(self.engine, "parked_bytes", None)
        resident_bytes = getattr(self.engine, "resident_bytes", None)
        for t in range(T):
            for fn in ev.get(t, ()):
                fn(self.engine, self._vt)
            interval_end = self._vt + self.interval_s
            for i in range(n):
                want = loads[i, t] * self.interval_s + frac[i]
                k = int(want)
                frac[i] = want - k
                for _ in range(k):
                    self._submit(i, self._vt)
            while self._vt < interval_end - 1e-9:
                self.engine.step(now=self._vt)
                self._vt += self.step_dt
                max_parked = max(max_parked,
                                 len(getattr(self.engine, "parked", ())))
                if parked_bytes is not None:
                    max_parked_bytes = max(max_parked_bytes,
                                           parked_bytes())
                if resident_bytes is not None:
                    peak_resident = max(peak_resident, resident_bytes())
            if wd is not None:
                wd.tick(self._vt)

        duration = self._vt - start_vt
        completed: Dict[int, int] = {}
        for req in self.engine.completed[completed0:]:
            completed[req.tenant_id] = completed.get(req.tenant_id, 0) + 1
        lat_now = lat_fn() if lat_fn is not None else {}

        def _q(mname: str, tenant: int, q: float) -> float:
            # NaN, not 0.0, when the window has no samples: a tenant that
            # never admitted a request has UNKNOWN latency, not a perfect
            # p99 (renderers show it as '-')
            th = lat_now.get(mname)
            h = th.per_tenant.get(tenant) if th is not None else None
            if h is None:
                return float("nan")
            snap = lat0.get(mname, {}).get(tenant)
            win = h.since(snap) if snap is not None else h
            return win.quantile(q) if win.total else float("nan")

        per_tenant: Dict[int, TenantReport] = {}
        for i in range(n):
            # every counter is windowed to THIS run: repeated run() calls on
            # one replayer (phased scenarios) must not leak prior pressure
            served = sched.served_tokens.get(i, 0) - served0[i]
            adm = sched.admitted_requests.get(i, 0) - admitted0[i]
            wait = sched.admit_wait_sum.get(i, 0.0) - wait0[i]
            per_tenant[i] = TenantReport(
                demand_rate=float(loads[i].mean()) * self.tokens_per_request,
                achieved_rate=served / duration,
                served_tokens=float(served),
                admitted_requests=adm,
                completed_requests=completed.get(i, 0),
                deferred_polls=sched.deferred_polls.get(i, 0) - deferred0[i],
                mean_admit_wait_s=wait / adm if adm else 0.0,
                weight=self.weights.get(i, 1.0),
                p50_admit_wait_s=_q("nk_admit_wait_seconds", i, 0.50),
                p99_admit_wait_s=_q("nk_admit_wait_seconds", i, 0.99),
                p99_ttft_s=_q("nk_ttft_seconds", i, 0.99),
                p99_e2e_s=_q("nk_e2e_seconds", i, 0.99),
            )
        placement = getattr(self.engine, "placement", None)
        cl_steps = getattr(self.engine, "steps", 0) - cl_steps0
        parked_steps = getattr(self.engine, "parked_engine_steps", 0) \
            - parked0
        mem_steps = getattr(self.engine, "mem_saved_byte_steps", 0) - mem0
        return ReplayReport(
            duration_s=duration, capacity=self.capacity,
            per_tenant=per_tenant,
            decode_steps=self.engine.decode_steps - steps0,
            set_rate_calls=getattr(ctrl, "push_calls", 0) - calls0,
            push_skipped=getattr(ctrl, "push_skipped", 0) - skip0,
            engines=len(getattr(self.engine, "engines", ())) or 1,
            migrations=getattr(self.engine, "migrations_completed", 0)
            - migrations0,
            swaps=len(getattr(self.engine, "swap_log", ())) - swaps0,
            placement=dict(placement) if placement is not None else None,
            cores_saved=parked_steps / cl_steps if cl_steps else 0.0,
            max_parked=max_parked,
            autopilot_moves=getattr(pilot, "moves_applied", 0)
            - pilot_moves0,
            mem_saved_bytes=mem_steps / cl_steps if cl_steps else 0.0,
            max_parked_bytes=max_parked_bytes,
            peak_resident_cache_bytes=peak_resident,
            checkpoints=getattr(self.engine, "checkpoints_total", 0) - ckpt0,
            recoveries=getattr(self.engine, "recoveries_total", 0) - recov0,
            alerts=(list(wd.alerts.history[alerts0:])
                    if wd is not None else None),
            alerts_fired=(len(wd.alerts.history) - alerts0
                          if wd is not None else 0),
            alerts_resolved=(sum(1 for a in wd.alerts.history[alerts0:]
                                 if a.resolved_at is not None)
                             if wd is not None else 0),
            alerts_active=len(wd.alerts.active) if wd is not None else 0,
            watchdog=wd,
        )


# ---------------------------------------------------------------------------
# Canonical scenarios (the shared vocabulary with bench_fairness/multiplex)
# ---------------------------------------------------------------------------


def make_replay_engine(*, capacity: float, batch_slots: int = 4,
                       max_seq: int = 32, control_every: int = 4,
                       push_mode: str = "full", delta_tol: float = 0.05,
                       model: str = "llama3.2-3b", weights=None, mesh=None,
                       backend: str = "object"):
    """A smoke-scale ServeEngine + WFQ scheduler + attached RateController,
    wired the way the e2e scenarios expect (charge_prompt pricing, tokens/s
    bottleneck = ``capacity``). ``backend="vectorized"`` selects the
    array-backed control plane end to end (scheduler buckets, telemetry
    EWMA banks, jitted water-fill) — same behavior, flat per-tenant cost."""
    from repro.configs import RunConfig, get_smoke_config
    from repro.control.controller import RateController
    from repro.launch.mesh import make_single_device_mesh
    from repro.serve.engine import ServeEngine

    sched = TenantScheduler(policy="wfq", charge_prompt=True,
                            bucket_backend=backend)
    ctrl = RateController(capacity, weights=weights, alpha=0.6,
                          push_mode=push_mode, delta_tol=delta_tol,
                          backend=backend)
    ctrl.attach_scheduler(sched)
    eng = ServeEngine(get_smoke_config(model),
                      RunConfig(attn_q_block=16, attn_kv_block=16),
                      mesh if mesh is not None else make_single_device_mesh(),
                      batch_slots=batch_slots, max_seq=max_seq,
                      scheduler=sched, controller=ctrl,
                      control_every=control_every)
    return eng


def make_replay_cluster(*, capacity: float, engines: int = 3,
                        batch_slots: int = 4, max_seq: int = 32,
                        control_every: int = 4, push_mode: str = "full",
                        delta_tol: float = 0.05, model: str = "llama3.2-3b",
                        weights=None, mesh=None, autopilot=None,
                        place_every: int = 8, autopilot_kw=None,
                        core_plane: bool = False, backend: str = "object"):
    """N smoke-scale ServeEngines behind ONE shared RateController — the
    multi-engine fabric the e2e scenarios drive.

    ``capacity`` is the single tokens/s bottleneck spanning the whole
    cluster (the controller splits each tenant's allocation across engines
    by observed demand). Engine replicas share model weights and the
    compiled prefill/decode, so a cluster costs one compilation.

    ``autopilot`` closes the placement loop: a policy name
    ('consolidate'/'spread_hot') builds a ``PlacementController`` over the
    cluster (extra policy/controller kwargs ride in ``autopilot_kw``;
    'consolidate' defaults its ceiling to ``0.375 * capacity`` tokens/s —
    between one and two equal shares of a 4-tenant fleet, so a busy fleet
    spreads and an idle one packs), or pass a ready controller instance.
    ``core_plane`` pairs each ServeEngine with a bytes-plane ``CoreEngine``
    so migrations move collective-traffic state in the same plan.
    """
    from repro.configs import RunConfig, get_smoke_config
    from repro.control.controller import RateController
    from repro.launch.mesh import make_single_device_mesh
    from repro.serve.cluster import EngineCluster
    from repro.serve.engine import ServeEngine

    mesh = mesh if mesh is not None else make_single_device_mesh()
    ctrl = RateController(capacity, weights=weights, alpha=0.6,
                          push_mode=push_mode, delta_tol=delta_tol,
                          backend=backend)
    cfg = get_smoke_config(model)
    rcfg = RunConfig(attn_q_block=16, attn_kv_block=16)
    engs = []
    for _ in range(int(engines)):
        sched = TenantScheduler(policy="wfq", charge_prompt=True,
                                bucket_backend=backend)
        eng = ServeEngine(cfg, rcfg, mesh,
                          params=engs[0].params if engs else None,
                          batch_slots=batch_slots, max_seq=max_seq,
                          scheduler=sched, controller=None)
        if engs:
            # identical config and cache shapes: replicas reuse the first
            # engine's jitted prefill/decode (tenants already share the
            # weights — the shared-memory story — so the cluster also
            # shares one compiled stack and compiles once)
            eng._prefill, eng._decode = engs[0]._prefill, engs[0]._decode
        engs.append(eng)
    cores = None
    if core_plane:
        from repro.core.engine import CoreEngine
        cores = [CoreEngine(enforcement="account") for _ in engs]
    cluster = EngineCluster(engs, ctrl, control_every=control_every,
                            core_engines=cores, place_every=place_every)
    if autopilot is not None:
        from repro.control.placement import PlacementController
        if isinstance(autopilot, str):
            kw = dict(autopilot_kw or {})
            if autopilot == "consolidate":
                kw.setdefault("ceiling", 0.375 * float(capacity))
            autopilot = PlacementController(cluster, policy=autopilot, **kw)
        cluster.attach_autopilot(autopilot, place_every=place_every)
    return cluster


def make_watchdog(engine, *, interval_s: float = 1.0, rules=None,
                  record: bool = False):
    """A ``FabricWatchdog`` wired over ``engine``'s live metrics.

    Builds a fresh ``MetricsRegistry``, registers the engine's own
    exporter (a cluster's ``counters`` folds controller + autopilot +
    latency; a single engine contributes its controller's merged view)
    plus the cluster ``health`` liveness provider when one exists, and
    returns the watchdog running the stock rule catalog with windows
    sized to ``interval_s`` (the replay's scrape cadence). ``record=True``
    keeps every scrape's text for the offline ``nk_watch`` artifact.

    The store's retention is bounded at 64 scrapes — far past the widest
    stock rule window (8 intervals), and it bounds the per-tick
    evaluation cost instead of letting window scans grow with uptime
    (the recorded artifact is kept separately, so ``record=True`` still
    retains the whole run)."""
    from repro.obs.metrics import MetricsRegistry
    from repro.obs.slo import FabricWatchdog, default_rules
    from repro.obs.timeseries import SeriesStore

    reg = MetricsRegistry()
    if hasattr(engine, "migrate"):              # a cluster fabric
        reg.register_provider(engine, name="cluster")
        reg.register_provider(engine.health, name="health")
    else:
        ctrl = getattr(engine, "controller", None)
        if ctrl is None:
            raise ValueError("engine has no controller to scrape; pass a "
                             "cluster or a controller-attached engine")
        reg.register_provider(ctrl, name="controller")
        lat_fn = getattr(engine, "latency", None)
        if lat_fn is not None:
            def latency_counters():
                out = {}
                for th in lat_fn().values():
                    out.update(th.counters())
                return out
            reg.register_provider(latency_counters, name="latency")
    return FabricWatchdog(
        reg, default_rules(interval_s) if rules is None else rules,
        store=SeriesStore(retention=64), record=record)


# every name scenario_spec accepts (trace vocabulary + the cluster-only
# scenarios layered on top of it)
SCENARIOS = ("steady", "adversarial", "migration", "correlated", "ramp",
             "bursty", "consolidation", "hotspot", "stack_swap", "failover")

# scenarios that need an EngineCluster (engines >= 2) to mean anything,
# with the autopilot policy each one runs by default (None = operator-
# driven: the migration scenario fires a one-shot operator_rebalance
# event — plan_once(force=True) —, the stack_swap scenario fires two
# live swap_module events, one per plane, and the failover scenario runs
# a checkpoint/kill/recover drill — instead)
CLUSTER_SCENARIOS = {"migration": None, "consolidation": "consolidate",
                     "hotspot": "spread_hot", "stack_swap": None,
                     "failover": None}


def scenario_spec(name: str, *, n_tenants: int = 4, intervals: int = 20,
                  capacity: Optional[float] = None, seed: int = 0):
    """(trace, enforced capacity) for one named scenario — the single
    source of truth shared by ``replay_scenario``, ``bench_fairness --e2e``
    and the scenario tests.

    Loads are generated in requests/s by the shared trace vocabulary
    (``repro.serve.multiplex.TRACES``) and capacities chosen so aggregate
    demand oversubscribes the bottleneck where the scenario calls for it.
    """
    from repro.serve import multiplex as mx

    per_req = TOKENS_PER_REQUEST
    if name == "steady":
        trace = mx.steady_trace(n_tenants, intervals, rps=3.0)
        demand = 3.0 * per_req * n_tenants
        cap = capacity or demand * 0.7            # mild, stable contention
    elif name in ("adversarial", "migration", "stack_swap", "failover"):
        # one spec, four drivers: "migration" is the same adversarial
        # fleet but on a multi-engine cluster, with a mid-window rebalance
        # (a live migration the Jain/isolation bounds must survive),
        # "stack_swap" hot-swaps a serve and a bytes stack module
        # mid-burst, and "failover" kills and restores an engine mid-burst
        # on a checkpoint cadence — sharing the branch keeps the hog-free
        # baseline comparable by design
        trace = mx.adversarial_trace(n_tenants, intervals, base=1.0,
                                     hog_factor=10.0)
        cap = capacity or 1.0 * per_req * (n_tenants + 3)
    elif name == "correlated":
        trace = mx.correlated_burst_trace(n_tenants, intervals, seed=seed,
                                          base=1.0, burst=6.0, period=8,
                                          width=2)
        cap = capacity or float(trace.loads.sum(axis=0).mean()) * per_req * 0.8
    elif name == "ramp":
        trace = mx.ramp_trace(n_tenants, intervals, base=2.0, peak=8.0)
        cap = capacity or float(trace.loads.sum(axis=0).mean()) * per_req * 0.7
    elif name == "bursty":
        trace = mx.bursty_trace(n_tenants, intervals, seed=seed, base=2.0,
                                burst=8.0)
        cap = capacity or float(trace.loads.sum(axis=0).mean()) * per_req * 0.7
    elif name == "consolidation":
        # busy -> shared idle window -> busy: the closed placement loop
        # should pack the idle fleet onto one engine and park the rest
        trace = mx.idle_window_trace(n_tenants, intervals, base=3.0,
                                     idle_level=0.2)
        demand = 3.0 * per_req * n_tenants
        cap = capacity or demand * 0.7            # mild, stable contention
    elif name == "hotspot":
        # everyone equal, then one tenant turns 10x mid-run: the autopilot
        # must detect the heating engine and migrate the hog on its own
        trace = mx.hotspot_trace(n_tenants, intervals, base=1.0,
                                 hog_factor=10.0)
        cap = capacity or 1.0 * per_req * (n_tenants + 3)
    else:
        raise KeyError(f"unknown scenario {name!r}; have {SCENARIOS}")
    return trace, cap


def operator_rebalance(cluster, now=None, *, pin_tenant=None):
    """One operator-triggered hot->cool rebalance, as a replay event.

    The modern spelling of the deprecated ``EngineCluster.rebalance()``
    (which delegates here, so the legacy semantics exist once): a
    one-shot ``PlacementController.plan_once(force=True)`` over the
    ``spread_hot`` policy (no bands, no cooldown, no drain gate).
    ``pin_tenant`` overrides victim selection. Returns the
    ``MigrationRecord`` of the move that landed, or None if the cluster
    was already balanced."""
    from repro.control.placement import PlacementController
    pc = PlacementController(cluster, policy="spread_hot",
                             cooldown_s=0.0, drain_cost_factor=None)
    before = len(cluster.migration_log)
    pc.plan_once(now=now, pin_tenant=pin_tenant, force=True)
    if len(cluster.migration_log) == before:
        return None
    return cluster.migration_log[before]


class MaintenanceWindow:
    """Scripted engine maintenance as replay events: drain the coolest
    engine (migrate its tenants off), park it once quiesced, unpark it a
    couple of intervals later.

    The migration scenario runs one of these so a single replay exercises
    the *whole* stack-module lifecycle — migrate, drain, finalize, park
    (suspend), unpark (resume) — and its Chrome trace shows every phase
    on one timeline. ``park`` is safe to schedule on consecutive
    intervals: it no-ops until the drained engine's in-flight slots ran
    dry, and again once the engine is asleep."""

    def __init__(self):
        self.engine: Optional[int] = None
        self.parked = False

    def drain(self, cluster, now=None):
        """Pick the coolest engine and migrate every tenant off it."""
        self.engine = k = cluster.coolest_engine()
        for t, e in sorted(cluster.placement.items()):
            if e == k and t not in cluster.draining:
                dst = min((j for j in cluster.active_engines() if j != k),
                          key=lambda j: (cluster.engine_load(j), j))
                cluster.migrate(t, dst, now=now)
        return k

    def park(self, cluster, now=None):
        if self.engine is None or self.parked:
            return
        if cluster.parkable(self.engine):
            cluster.park(self.engine, now=now)
            self.parked = True

    def unpark(self, cluster, now=None):
        if self.parked:
            cluster.unpark(self.engine, now=now)
            self.parked = False


def migration_events(intervals: int):
    """The migration scenario's operator script: the mid-window
    hot->cool rebalance, then (window permitting) a maintenance
    park/unpark of the coolest engine near the end."""
    half = max(intervals // 2, 1)
    events = [(half, operator_rebalance)]
    if intervals >= half + 5:
        mw = MaintenanceWindow()
        events += [(intervals - 4, mw.drain),
                   (intervals - 3, mw.park),
                   (intervals - 2, mw.park),      # retry if still draining
                   (intervals - 1, mw.unpark)]
    return events


def swap_live_stack(cluster, plane: str, *, engine=None, now=None):
    """One live stack hot-swap, as a replay operator event — the paper's
    kernel-TCP -> mTCP move under traffic.

    On the **serve** plane the hottest engine's module is replaced by a
    variant running the OTHER scheduler policy (wfq <-> rr), sharing the
    retired module's weights and compiled prefill/decode (a swap costs
    zero recompiles). On the **bytes** plane the same engine slot's
    ``CoreEngine`` flips its default transport between the native ``xla``
    stack and the int8 ``compressed`` one. ``engine`` pins the slot.
    Returns the ``SwapRecord``.
    """
    from repro.core.engine import CoreEngine

    if plane == "serve":
        k = cluster.hottest_engine() if engine is None else int(engine)
        old = cluster.engines[k]
        policy = "rr" if old.scheduler.policy == "wfq" else "wfq"
        if hasattr(old, "cfg"):                # a real jitted ServeEngine
            from repro.serve.engine import ServeEngine

            def factory():
                sched = TenantScheduler(
                    policy=policy,
                    charge_prompt=old.scheduler.charge_prompt)
                eng = ServeEngine(old.cfg, old.rcfg, old.mesh,
                                  params=old.params, batch_slots=old.B,
                                  max_seq=old.max_seq, scheduler=sched,
                                  controller=None)
                # same config and cache shapes: the replacement reuses the
                # retired stack's jitted prefill/decode — a live swap
                # never pays a compile
                eng._prefill, eng._decode = old._prefill, old._decode
                return eng
        else:                                  # a jit-free test double
            def factory():
                eng = type(old)(batch_slots=old.B)
                eng.scheduler = TenantScheduler(
                    policy=policy,
                    charge_prompt=old.scheduler.charge_prompt)
                return eng
    elif plane == "bytes":
        cores = getattr(cluster, "core_engines", None)
        if not cores:
            raise KeyError("the cluster has no bytes plane attached; "
                           "build it with core_plane=True")
        # swap beneath the hottest serve engine's paired core: placement
        # routes that slot the most collective traffic too
        k = cluster.hottest_engine() if engine is None else int(engine)
        old = cores[k]
        nsm = "compressed" if old.default_nsm != "compressed" else "xla"

        def factory():
            return CoreEngine(mesh=old.mesh, default_nsm=nsm,
                              enforcement=old.enforcement)
    else:
        raise KeyError(f"unknown plane {plane!r}; have 'serve'/'bytes'")
    return cluster.swap_module(k, plane, factory, now=now)


def _byte_pump_event(cluster, now=None, *, size_bytes: int = 4096):
    """Per-interval bytes-plane traffic for the stack_swap scenario: one
    collective op per placed tenant, routed through its engine's paired
    core — so the bytes-plane swap happens under real traffic and its
    conservation assert is non-trivial."""
    from repro.core.nqe import CommOp

    cores = getattr(cluster, "core_engines", None)
    if not cores:
        return
    t_now = 0.0 if now is None else float(now)
    failed = getattr(cluster, "failed", ())
    for t, k in sorted(cluster.placement.items()):
        if k in failed:
            continue       # a dark slot takes no collective traffic
        op = CommOp(verb="psum", axes=("pod",), tenant_id=t,
                    size_bytes=size_bytes)
        cores[k].admit(op, t_now)
        cores[k].route(op)


def stack_swap_events(intervals: int):
    """The stack_swap scenario's operator script: collective traffic every
    interval, a live serve-plane swap a third of the way in (mid-burst,
    on the hottest engine), and a bytes-plane swap (native xla ->
    compressed int8 transport) two thirds in."""
    serve_at = max(intervals // 3, 1)
    bytes_at = max(2 * intervals // 3, serve_at + 1)
    events = [(i, _byte_pump_event) for i in range(intervals)]
    events += [
        (serve_at, lambda cl, now=None: swap_live_stack(cl, "serve",
                                                        now=now)),
        (bytes_at, lambda cl, now=None: swap_live_stack(cl, "bytes",
                                                        now=now)),
    ]
    return events


class FailoverDrill:
    """Scripted kill-and-restore failover as replay events: checkpoint
    the whole fabric on a fixed cadence, crash the hottest engine
    mid-burst, recover it from the last ``FabricSnapshot`` two intervals
    later — the admission gap buffered in between replays on recovery.

    Cadence ticks that land while the slot is dark (or mid-drain) are
    skipped: ``EngineCluster.checkpoint`` refuses both, by contract."""

    def __init__(self):
        self.snapshot = None
        self.engine: Optional[int] = None

    def checkpoint(self, cluster, now=None):
        if getattr(cluster, "failed", None) or cluster.draining:
            return
        self.snapshot = cluster.checkpoint(now=now)

    def fail(self, cluster, now=None):
        if self.snapshot is None:
            raise RuntimeError(
                "failover drill fired fail before any checkpoint")
        self.engine = cluster.hottest_engine()
        cluster.fail_engine(self.engine, now=now)

    def recover(self, cluster, now=None):
        cluster.recover_engine(self.engine, self.snapshot, now=now)


# checkpoint cadence of the failover drill, in trace intervals — "one
# checkpoint interval", the unit the token-loss bound is stated in
FAILOVER_CHECKPOINT_EVERY = 3


def failover_events(intervals: int, *, pump=None):
    """The failover scenario's operator script: collective traffic every
    interval, a fabric checkpoint every ``FAILOVER_CHECKPOINT_EVERY``
    intervals, a crash of the hottest engine ~2/5 of the way in — nudged
    OFF the checkpoint cadence, so real work lands between the last
    snapshot and the kill and the measured token loss is non-trivial —
    and recovery from that snapshot two intervals later. ``pump``
    overrides the per-interval bytes-plane traffic event (the bench
    passes an instrumented pump that counts what it routed)."""
    drill = FailoverDrill()
    every = FAILOVER_CHECKPOINT_EVERY
    events = [(i, pump or _byte_pump_event) for i in range(intervals)]
    events += [(i, drill.checkpoint) for i in range(1, intervals, every)]
    fail_at = max(2 * intervals // 5, 2)
    if (fail_at - 1) % every == 0:      # keep the kill off the cadence
        fail_at += 1
    recover_at = min(fail_at + 2, intervals - 1)
    events += [(fail_at, drill.fail), (recover_at, drill.recover)]
    return events


# row index of the misbehaver in the adversarial trace (multiplex's default)
ADVERSARIAL_HOG = -1


def adversarial_baseline(trace: Trace) -> Trace:
    """The adversarial fleet with the misbehaver removed — the hog-free
    baseline isolation claims compare against. One definition, so the hog
    row index can never silently diverge between bench and tests."""
    return Trace(loads=np.delete(trace.loads, ADVERSARIAL_HOG, axis=0))


def replay_scenario(name: str, *, n_tenants: int = 4, intervals: int = 20,
                    capacity: Optional[float] = None, engine=None,
                    push_mode: str = "full", weights=None,
                    seed: int = 0, engines: Optional[int] = None,
                    autopilot=None, core_plane: bool = False,
                    trace_path=None, watch=None,
                    backend: str = "object") -> ReplayReport:
    """Run one named scenario end-to-end and return the measured report.

    ``engines`` > 1 drives an ``EngineCluster`` (N ServeEngines behind one
    shared controller) instead of a single engine; None picks the
    scenario's natural scale (3 engines for the cluster scenarios, 1
    otherwise). The ``migration`` scenario requires a cluster: mid-window
    the operator rebalances the hottest engine, and near the end a
    maintenance window drains, parks and unparks the coolest one — one
    replay exercises the whole stack-module lifecycle. The ``stack_swap``
    scenario hot-swaps live stack modules mid-burst (a serve-plane
    scheduler variant a third of the way in, a bytes-plane native ->
    compressed transport two thirds in) with collective traffic pumped
    every interval; it forces ``core_plane=True``. The ``failover``
    scenario checkpoints the fabric every third interval, kills the
    hottest engine mid-burst and recovers it from the last snapshot two
    intervals later (gap replayed, conservation asserted on every
    plane); it also forces ``core_plane=True`` so the crash spans both
    planes.

    ``autopilot`` closes the placement loop on the cluster (policy name or
    a ``PlacementController``); the ``consolidation`` and ``hotspot``
    scenarios run their natural policy by default — no operator events,
    the loop finds the moves itself. ``core_plane`` attaches a bytes-plane
    CoreEngine per ServeEngine so every move carries both planes.

    ``trace_path``: write the run's flight-recorder timeline (Chrome
    trace-event JSON, loadable in Perfetto) to this path. A recording
    tracer is installed for the duration of the run and restored after.

    ``watch``: attach the fabric watchdog so the scenario doubles as an
    alert-precision fixture. ``True`` builds the stock one over the
    engine (``make_watchdog``); or pass a ready ``FabricWatchdog``
    (e.g. one constructed with ``record=True`` to keep the scrape
    sequence). The registry is scraped at every interval boundary and
    the report's ``alerts*`` fields carry the outcome — steady fires
    zero, adversarial fires fairness burn on the hog, failover fires
    and resolves engine-dark (bench claim (k) pins all three).

    ``backend="vectorized"`` runs the whole control plane on the array
    backend (scheduler bucket store, telemetry EWMA banks, jitted
    water-fill); every scenario claim must hold unchanged — the e2e
    parity gate CI pins.
    """
    from repro.obs.tracing import trace_to

    # fail fast, before any engine construction (jit compiles are minutes)
    needs_cluster = name in CLUSTER_SCENARIOS
    if engines is None:
        engines = 3 if (needs_cluster and engine is None) else 1
    if needs_cluster and (engines < 2 if engine is None
                          else not hasattr(engine, "migrate")):
        raise ValueError(f"the {name} scenario needs a cluster: "
                         f"pass engines >= 2 (or an EngineCluster)")
    if autopilot is None:
        autopilot = CLUSTER_SCENARIOS.get(name)
    if name in ("stack_swap", "failover"):
        # stack_swap swaps one module per plane and failover crashes both
        # planes at once, so the bytes plane must exist (and carry
        # traffic — see the scenarios' shared byte pump)
        core_plane = True
    trace, cap = scenario_spec(name, n_tenants=n_tenants,
                               intervals=intervals, capacity=capacity,
                               seed=seed)
    eng = engine
    if eng is None:
        if engines > 1:
            eng = make_replay_cluster(capacity=cap, engines=engines,
                                      push_mode=push_mode, weights=weights,
                                      autopilot=autopilot,
                                      core_plane=core_plane,
                                      backend=backend)
        else:
            eng = make_replay_engine(capacity=cap, push_mode=push_mode,
                                     weights=weights, backend=backend)
    elif autopilot is not None and getattr(eng, "autopilot", None) is None \
            and hasattr(eng, "attach_autopilot"):
        from repro.control.placement import PlacementController
        if isinstance(autopilot, str):
            kw = {"ceiling": 0.375 * cap} if autopilot == "consolidate" \
                else {}
            autopilot = PlacementController(eng, policy=autopilot, **kw)
        eng.attach_autopilot(autopilot)
    events = None
    if name == "migration":
        events = migration_events(intervals)
    elif name == "stack_swap":
        events = stack_swap_events(intervals)
    elif name == "failover":
        events = failover_events(intervals)
    rep = TraceReplayer(eng, capacity=cap, weights=weights)
    wd = watch
    if wd is True or wd == "record":
        # the replayer's clock overshoots each interval by up to one
        # step_dt, so the *effective* scrape period is what the rule
        # windows must be sized to — else a "3-interval" window holds
        # fewer scrapes than designed and the absence rules go blind
        wd = make_watchdog(eng,
                           interval_s=rep.interval_s + rep.step_dt,
                           record=(wd == "record"))
    rep.watchdog = wd or None
    if trace_path is None:
        return rep.run(trace, events=events)
    with trace_to() as tr:
        report = rep.run(trace, events=events)
    tr.write(trace_path)
    return report
