"""Multiplexing economics: the paper's use case 1, in chips.

The paper's Table 2: 3 bursty application gateways each peak-provisioned at
4 cores are served by one 5-core NSM + 1-core CoreEngine — 9 cores instead
of 12, and in general >40% core savings across a fleet of bursty tenants.

Here the shared resource is decode capacity (tokens/s per chip-group).
``chip_accounting`` compares:
  dedicated :  sum_i ceil(peak_i / cap)      (per-tenant peak provisioning)
  shared    :  ceil(peak_t sum_i(load_i(t)) / cap) + engine overhead
on bursty traces (anti-correlated bursts, like the paper's AGs serving
different customer populations). ``bench_multiplexing`` also replays a trace
through a real ServeEngine to show per-tenant RPS is preserved.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.control.congestion import max_min_fair


@dataclass
class Trace:
    """Per-tenant load in requests/s over time (1 value per interval)."""

    loads: np.ndarray     # (tenants, T)

    @property
    def peaks(self) -> np.ndarray:
        return self.loads.max(axis=1)

    @property
    def aggregate_peak(self) -> float:
        return float(self.loads.sum(axis=0).max())


def bursty_trace(n_tenants: int, intervals: int = 60, seed: int = 0,
                 base: float = 8.0, burst: float = 40.0,
                 burst_prob: float = 0.08) -> Trace:
    """Bursty, mostly-idle tenants (paper Fig. 7: AG utilization is very low
    most of the time, with short uncorrelated bursts)."""
    rng = np.random.default_rng(seed)
    loads = rng.gamma(2.0, base / 2.0, size=(n_tenants, intervals))
    bursts = rng.random((n_tenants, intervals)) < burst_prob
    loads = loads + bursts * rng.gamma(2.0, burst / 2.0,
                                       size=(n_tenants, intervals))
    # stagger burst phases so tenants are not synchronized
    for i in range(n_tenants):
        loads[i] = np.roll(loads[i], rng.integers(0, intervals))
    return Trace(loads=loads)


def steady_trace(n_tenants: int, intervals: int = 60,
                 rps: float = 10.0) -> Trace:
    """Constant equal demand — the steady-state control-plane baseline
    (delta-push should go near-silent on this one)."""
    return Trace(loads=np.full((n_tenants, intervals), float(rps)))


def adversarial_trace(n_tenants: int, intervals: int = 60,
                      base: float = 8.0, hog_factor: float = 10.0,
                      hog: int = -1) -> Trace:
    """In-budget tenants at a constant trickle plus one misbehaver offering
    ``hog_factor`` times the whole fleet's base load (paper Fig. 22: the
    10x-overloading VM must not hurt its neighbours)."""
    loads = np.full((n_tenants, intervals), float(base))
    loads[hog] = hog_factor * base * n_tenants
    return Trace(loads=loads)


def correlated_burst_trace(n_tenants: int, intervals: int = 60,
                           seed: int = 0, base: float = 4.0,
                           burst: float = 30.0, period: int = 12,
                           width: int = 3) -> Trace:
    """All tenants burst *together* (one customer population): the worst
    case for multiplexing economics and the stress case for fairness —
    every burst is contested."""
    rng = np.random.default_rng(seed)
    loads = rng.gamma(2.0, base / 2.0, size=(n_tenants, intervals))
    for k in range(0, intervals, period):
        loads[:, k:k + width] += burst
    return Trace(loads=loads)


def ramp_trace(n_tenants: int, intervals: int = 60,
               base: float = 6.0, peak: float = 40.0,
               ramper: int = 0) -> Trace:
    """One tenant ramps linearly from idle to ``peak`` while the rest hold
    a constant base load — exercises controller tracking (allocations must
    follow the ramp, so delta-push stays busy here)."""
    loads = np.full((n_tenants, intervals), float(base))
    loads[ramper] = np.linspace(0.0, peak, intervals)
    return Trace(loads=loads)


def idle_window_trace(n_tenants: int, intervals: int = 60,
                      base: float = 3.0, idle_level: float = 0.2,
                      idle_start: Optional[int] = None,
                      idle_end: Optional[int] = None) -> Trace:
    """Every tenant busy at ``base``, then a shared idle window at
    ``idle_level`` (a trickle, not silence — tenants stay placeable),
    then busy again. The consolidation story: during the window the whole
    fleet fits one engine, so a closed placement loop should pack tenants
    together and park the rest of the cluster (cores saved), waking it
    when load returns."""
    idle_start = intervals // 3 if idle_start is None else idle_start
    idle_end = 2 * intervals // 3 if idle_end is None else idle_end
    loads = np.full((n_tenants, intervals), float(base))
    loads[:, idle_start:idle_end] = float(idle_level)
    return Trace(loads=loads)


def hotspot_trace(n_tenants: int, intervals: int = 60,
                  base: float = 1.0, hog_factor: float = 10.0,
                  hog: int = -1, onset: Optional[int] = None) -> Trace:
    """Everyone equal until ``onset``, then one tenant turns into a
    ``hog_factor``x-the-fleet misbehaver — the hotspot *develops* mid-run
    (unlike ``adversarial_trace``, which is hot from interval 0), so a
    placement loop has to detect the heating engine and migrate the hog
    away on its own."""
    onset = intervals // 3 if onset is None else onset
    loads = np.full((n_tenants, intervals), float(base))
    loads[hog, onset:] = hog_factor * base * n_tenants
    return Trace(loads=loads)


TRACES = {
    "bursty": bursty_trace,
    "steady": steady_trace,
    "adversarial": adversarial_trace,
    "correlated": correlated_burst_trace,
    "ramp": ramp_trace,
    "idle_window": idle_window_trace,
    "hotspot": hotspot_trace,
}


def chip_accounting(trace: Trace, cap_per_chip: float,
                    engine_overhead_chips: int = 1) -> Dict:
    """Chips needed: dedicated per-tenant peaks vs one shared engine."""
    dedicated = int(sum(math.ceil(p / cap_per_chip) for p in trace.peaks))
    shared = int(math.ceil(trace.aggregate_peak / cap_per_chip)) \
        + engine_overhead_chips
    return {
        "tenants": int(trace.loads.shape[0]),
        "dedicated_chips": dedicated,
        "shared_chips": shared,
        "savings_frac": 1.0 - shared / max(dedicated, 1),
        "aggregate_peak": trace.aggregate_peak,
        "sum_of_peaks": float(trace.peaks.sum()),
    }


def paper_table2_analog(n_tenants: int = 16, seed: int = 0,
                        cap_per_chip: float = 50.0) -> Dict:
    """The fleet-level claim: >40% savings at equal served load."""
    t = bursty_trace(n_tenants, seed=seed)
    return chip_accounting(t, cap_per_chip)


# ---------------------------------------------------------------------------
# Fairness-aware replay (management-plane view of the shared engine)
# ---------------------------------------------------------------------------


def jain_index(xs: Sequence[float]) -> float:
    """Jain's fairness index: 1.0 = perfectly equal, 1/n = one hog.

    ``xs``: per-tenant rates (any shared unit — tokens/s, bytes/s).
    Degenerate idle intervals are *defined* as perfectly fair: an empty or
    all-zero vector returns 1.0, and non-finite entries (the NaN a 0/0
    rate computation produces for an idle tenant) are treated as 0.0
    instead of poisoning the index into NaN.

    >>> jain_index([2.0, 2.0, 2.0])
    1.0
    >>> jain_index([0.0, 0.0, 0.0])
    1.0
    >>> jain_index([])
    1.0
    >>> round(jain_index([float("nan"), 3.0]), 3)
    0.5
    """
    xs = [float(x) if math.isfinite(x) else 0.0 for x in xs]
    n = len(xs)
    sq = sum(x * x for x in xs)
    if n == 0 or sq <= 0:
        return 1.0
    return sum(xs) ** 2 / (n * sq)


def fair_replay(trace: Trace, capacity: float,
                weights: Optional[Dict[int, float]] = None,
                rate_caps: Optional[Dict[int, float]] = None,
                interval_s: float = 1.0) -> Dict:
    """Replay a load trace through a weighted max-min fair shared engine.

    Fluid-flow model of what the RateController enforces on a real
    deployment: per interval, each tenant demands its offered load plus any
    backlog carried from earlier intervals; the bottleneck ``capacity``
    (requests/s) is divided weighted-max-min-fair; unserved demand queues.
    ``rate_caps`` bounds individual tenants (Fig. 21 hard caps) — capacity a
    capped tenant cannot use is re-filled to the others (work conservation).
    """
    loads = trace.loads
    n, T = loads.shape
    served = np.zeros((n, T))
    backlog = np.zeros(n)
    backlogged_jain: List[float] = []
    for t in range(T):
        demand = {i: loads[i, t] * interval_s + backlog[i] for i in range(n)}
        if rate_caps:
            demand = {i: min(d, rate_caps.get(i, math.inf) * interval_s)
                      for i, d in demand.items()}
        alloc = max_min_fair(capacity * interval_s, demand, weights)
        for i in range(n):
            served[i, t] = alloc[i] / interval_s
            backlog[i] = max(backlog[i] + loads[i, t] * interval_s
                             - alloc[i], 0.0)
        contested = [i for i in range(n) if demand[i] > alloc[i] + 1e-9]
        if len(contested) >= 2:
            w = weights or {}
            backlogged_jain.append(jain_index(
                [served[i, t] / w.get(i, 1.0) for i in contested]))
    total = float(served.sum()) * interval_s
    offered = float(loads.sum()) * interval_s
    return {
        "served": served,
        "per_tenant_served": served.sum(axis=1) * interval_s,
        "utilization": total / (capacity * T * interval_s),
        "served_frac": total / max(offered, 1e-12),
        "jain_backlogged": (float(np.mean(backlogged_jain))
                            if backlogged_jain else 1.0),
        "backlog_final": backlog,
    }
