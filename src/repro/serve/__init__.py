from repro.serve.engine import ServeEngine, Slot
from repro.serve.multiplex import (
    Trace, bursty_trace, chip_accounting, fair_replay, jain_index,
    paper_table2_analog,
)
from repro.serve.scheduler import Request, TenantScheduler

__all__ = [
    "ServeEngine", "Slot", "Trace", "bursty_trace", "chip_accounting",
    "fair_replay", "jain_index", "paper_table2_analog", "Request",
    "TenantScheduler",
]
