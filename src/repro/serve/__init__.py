from repro.serve.cluster import (
    ClusterLedger, EngineCluster, MigrationRecord, SwapRecord,
)
from repro.serve.engine import ServeEngine, Slot
from repro.serve.multiplex import (
    TRACES, Trace, adversarial_trace, bursty_trace, chip_accounting,
    correlated_burst_trace, fair_replay, hotspot_trace, idle_window_trace,
    jain_index, paper_table2_analog, ramp_trace, steady_trace,
)
from repro.serve.replay import (
    CLUSTER_SCENARIOS, SCENARIOS, ReplayReport, TenantReport, TraceReplayer,
    make_replay_cluster, make_replay_engine, operator_rebalance,
    replay_scenario, scenario_spec, stack_swap_events, swap_live_stack,
)
from repro.serve.scheduler import Request, TenantScheduler

__all__ = [
    "ClusterLedger", "EngineCluster", "MigrationRecord", "SwapRecord",
    "ServeEngine", "Slot", "TRACES", "Trace", "adversarial_trace",
    "bursty_trace", "chip_accounting", "correlated_burst_trace",
    "fair_replay", "hotspot_trace", "idle_window_trace", "jain_index",
    "paper_table2_analog", "ramp_trace", "steady_trace",
    "CLUSTER_SCENARIOS", "SCENARIOS", "ReplayReport", "TenantReport",
    "TraceReplayer", "make_replay_cluster", "make_replay_engine",
    "operator_rebalance", "replay_scenario", "scenario_spec",
    "stack_swap_events", "swap_live_stack", "Request", "TenantScheduler",
]
