"""EngineCluster: one controller, N stack modules per plane, live migration.

The paper's operator owns the stack as *infrastructure*: many guests
multiplex onto shared stack modules, and the operator can rebalance that
mapping at will — including moving a tenant between modules without the
guest noticing. This module is that placement power, written against the
``StackModule`` protocol (repro.fabric) rather than any concrete engine:

  * N live ``ServeEngine``s (think: NSMs on different hosts) behind ONE
    shared ``RateController``. The controller's water-fill runs over the
    merged telemetry of every engine's scheduler — one tokens/s bottleneck
    spanning the cluster — and splits each tenant's global allocation
    across engines in proportion to where its traffic shows up.
  * a tenant -> engine ``placement`` map the operator controls. New
    tenants auto-place on the least-loaded engine; ``migrate`` moves a
    live tenant mid-replay.
  * optional extra planes: ``core_engines`` pairs each ServeEngine with a
    bytes-plane ``CoreEngine``; one migration then moves the tenant's
    serve *and* collective state through the same protocol calls.

Migration is drain-and-transfer, and conserves every plane's ledger:

  1. each plane's module exports the tenant (``StackModule.export_tenant``:
     unserved queue, WFQ weight, token-bucket *level* on the serve plane;
     bucket level + flattened counters on the bytes plane) and the
     destination module imports it (a move can never reopen a fresh burst);
  2. the source's cumulative counters fold into the plane's
     ``ConservationLedger`` carried view, so the global view never jumps
     (telemetry on the source sees a counter reset, not a negative rate);
  3. in-flight slots are NOT moved: they finish — and bill — where they
     were admitted; the tenant is ``draining`` until they run dry, then
     the residual billing folds and the migration finalizes.

Each plane's ``ConservationLedger`` pins carried + live counters against
the modules' summed billed ground truth — ONE assert implementation for
both planes, invoked on every move (no lost tokens or bytes, no
double-billing).

Two closed-loop extensions sit on top of the migration primitive:

  * **park/unpark lifecycle** — a quiesced engine can be parked: it stops
    stepping (the cluster "saves cores", the paper's multiplexing claim)
    AND its modules ``suspend()`` — the KV-cache, slot table and scratch
    are dropped, so parking saves *memory* too. ``unpark`` resumes the
    modules (cache re-init is lazy: it re-materializes on the first
    admission). ``parked_engine_steps`` and ``mem_saved_byte_steps``
    accumulate the savings; at least one engine always stays awake.
  * **autopilot** — an attached ``PlacementController``
    (repro.control.placement) is ticked every ``place_every`` steps,
    exactly how the shared RateController is ticked, and applies its
    plans through ``apply_plan`` -> ``migrate``: the placement loop runs
    closed, next to the rate loop.
  * **checkpoint / kill-and-restore failover** — ``checkpoint()``
    captures the whole fabric as one versioned ``FabricSnapshot``
    (repro.fabric.checkpoint); ``fail_engine`` simulates a crash (module
    state wiped in place, in-flight slots lost, admissions gap-buffered)
    and ``recover_engine`` re-materializes the slot from its last
    snapshot, replays the gap and re-asserts conservation on every
    plane — the work lost is bounded by one checkpoint interval.
    ``restore()`` is the full-fabric reset to a snapshot.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.control.telemetry import format_prometheus
from repro.fabric import (
    FABRIC_SNAPSHOT_VERSION, FabricSnapshot, ModuleSnapshot, PlaneSnapshot,
    StackPlane, TenantState,
)
from repro.obs import tracing
from repro.obs.hist import TenantHistograms
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Request


@dataclass
class MigrationRecord:
    """One migrate() call, for the operator's audit log."""

    tenant: int
    src: int                      # engine index the tenant left
    dst: int                      # engine index it moved to
    started_step: int             # cluster step count at the move
    queued_moved: int             # unserved requests transferred
    inflight_at_move: int         # slots left draining on the source
    bucket_tokens_moved: float    # token-bucket level transferred (tokens)
    finalized_step: int = -1      # -1 while the source is still draining

    @property
    def finalized(self) -> bool:
        return self.finalized_step >= 0


@dataclass
class SwapRecord:
    """One swap_module() call — a live stack hot-swap — for the audit log.

    The paper's flagship move (kernel TCP -> mTCP under an unmodified
    guest): the module serving one engine slot is replaced in place,
    under traffic, with every tenant transferred across the boundary and
    the plane's conservation ledger unchanged.
    """

    engine: int                   # engine slot swapped in place
    plane: str                    # plane name ("serve", "bytes", ...)
    step: int                     # cluster step count at the swap
    tenants: Tuple[int, ...]      # tenants transferred across the boundary
    inflight_at_swap: int         # slots quiesced before the transfer
    quiesce_steps: int            # extra engine steps the quiesce ran
    old_stack: str                # descriptor of the retired module
    new_stack: str                # descriptor of the replacement


@dataclass
class FailureRecord:
    """One fail_engine() crash (and its recovery), for the audit log.

    ``tokens_lost`` is the serve-plane ground truth billed between the
    restored checkpoint and the crash — the work a kill-and-restore
    failover genuinely loses, bounded by one checkpoint interval. It is
    -1.0 until ``recover_engine`` computes it against the snapshot it
    restored from.
    """

    engine: int                   # engine slot that crashed
    step: int                     # cluster step count at the crash
    inflight_lost: int            # decode slots lost with the crash
    queued_lost: int              # queued requests lost with the crash
    gt_at_crash: Dict[int, float]  # serve billed ground truth at crash
    tokens_lost: float = -1.0     # gt billed after the restored snapshot
    recovered_step: int = -1      # -1 while the slot is still dark

    @property
    def recovered(self) -> bool:
        return self.recovered_step >= 0


class ClusterLedger:
    """Duck-types the ``TenantScheduler`` ledger surface over a cluster.

    ``TraceReplayer`` (and anything else written against one scheduler's
    ledgers) reads per-tenant counters through this facade and sees the
    cluster-global view: carried (migrated-away) history plus the live
    counters of every engine, so a tenant's numbers are continuous across
    migrations.
    """

    def __init__(self, cluster: "EngineCluster"):
        self._cluster = cluster

    @property
    def queues(self) -> Dict[int, int]:
        """Known tenants (tenant -> engine index) — membership view."""
        return dict(self._cluster.placement)

    def add_tenant(self, tenant_id: int, weight: float = 1.0, **kw):
        self._cluster.add_tenant(tenant_id, weight=weight)

    def set_weight(self, tenant_id: int, weight: float):
        self._cluster.set_weight(tenant_id, weight)

    def pending(self, tenant_id: Optional[int] = None) -> int:
        return sum(e.scheduler.pending(tenant_id)
                   for e in self._cluster.engines)

    @property
    def served_tokens(self) -> Dict[int, int]:
        return self._cluster.merged_ledger("served_tokens")

    @property
    def admitted_requests(self) -> Dict[int, int]:
        return self._cluster.merged_ledger("admitted_requests")

    @property
    def deferred_polls(self) -> Dict[int, int]:
        return self._cluster.merged_ledger("deferred_polls")

    @property
    def admit_wait_sum(self) -> Dict[int, float]:
        return self._cluster.merged_ledger("admit_wait_sum")

    def ledger(self) -> Dict[int, Dict[str, float]]:
        """Cluster-global version of ``TenantScheduler.ledger``."""
        served = self.served_tokens
        admitted = self.admitted_requests
        deferred = self.deferred_polls
        waits = self.admit_wait_sum
        out: Dict[int, Dict[str, float]] = {}
        for t in set(served) | set(admitted) | set(deferred):
            adm = admitted.get(t, 0)
            out[t] = {
                "served_tokens": float(served.get(t, 0)),
                "admitted_requests": float(adm),
                "deferred_polls": float(deferred.get(t, 0)),
                "queued": float(self.pending(t)),
                "mean_admit_wait_s": (waits.get(t, 0.0) / adm
                                      if adm else 0.0),
            }
        return out


class EngineCluster:
    """N serve-plane StackModules + one shared RateController + placement.

    Exposes the same driving surface as a single ``ServeEngine`` (``B``,
    ``submit``, ``step``, ``completed``, ``decode_steps``, ``scheduler``,
    ``controller``) so ``TraceReplayer`` runs a cluster unchanged. All
    tenant movement, ledger folding, conservation checks and the park
    suspend/resume lifecycle go through the ``StackModule`` protocol —
    the cluster never names a concrete engine class.

    Args:
        engines: live serve-plane modules (``ServeEngine`` or any
            ``SchedulerServeModule``). Their own ``controller`` hooks must
            be unset — the cluster drives the shared controller itself
            (one tick for the whole cluster per control interval, not one
            per engine).
        controller: the shared ``RateController`` (capacity in tokens/s =
            the ONE bottleneck spanning all engines). Any engine scheduler
            not yet attached to it is attached here.
        control_every: controller tick period, in cluster steps.
        core_engines: optional bytes-plane ``CoreEngine`` per ServeEngine
            (same order/length): a migration then moves the tenant's
            collective-traffic state (bucket level + carried ledger) in
            the same plan, byte conservation asserted.
        place_every: autopilot tick period, in cluster steps (takes
            effect once ``attach_autopilot`` is called).
    """

    def __init__(self, engines: Sequence[ServeEngine], controller=None,
                 *, control_every: int = 4, core_engines=None,
                 place_every: int = 8):
        self.engines: List[ServeEngine] = list(engines)
        if not self.engines:
            raise ValueError("EngineCluster needs at least one engine")
        for k, e in enumerate(self.engines):
            # one trace track per engine: request lifecycle events from
            # the engine and its scheduler land on the same timeline
            e.trace_name = f"engine{k}"
            e.scheduler.trace_track = f"engine{k}"
        for e in self.engines:
            if e.controller is not None:
                raise ValueError(
                    "cluster engines must not own a controller; the "
                    "cluster ticks the shared one")
        self.controller = controller
        if controller is not None:
            attached = {id(s) for s, _ in controller._schedulers}
            for e in self.engines:
                if id(e.scheduler) not in attached:
                    controller.attach_scheduler(e.scheduler)
        self.control_every = max(int(control_every), 1)
        self.core_engines = list(core_engines) if core_engines else None
        if self.core_engines is not None and \
                len(self.core_engines) != len(self.engines):
            raise ValueError(
                f"core_engines must pair 1:1 with engines "
                f"({len(self.core_engines)} vs {len(self.engines)})")
        # every plane is modules + ONE shared ConservationLedger — the
        # serve plane always, the bytes plane when attached
        self.planes: List[StackPlane] = [
            StackPlane.build("serve", self.engines)]
        if self.core_engines is not None:
            self.planes.append(StackPlane.build("bytes", self.core_engines))
        self.autopilot = None
        self.place_every = max(int(place_every), 1)
        self.placement: Dict[int, int] = {}
        self.draining: Dict[int, int] = {}          # tenant -> src engine
        self.parked: Set[int] = set()               # engine indices asleep
        self.parked_engine_steps = 0                # the cores-saved ledger
        self.max_parked = 0                         # peak engines asleep
        # the memory-saved ledger: bytes currently freed per parked engine,
        # cumulative bytes ever freed, the per-step integral of freed
        # bytes, and the peak resident droppable-buffer footprint
        self._suspended_bytes: Dict[int, int] = {}
        self.bytes_freed_total = 0
        self.mem_saved_byte_steps = 0
        self.peak_resident_bytes = 0
        self.migration_log: List[MigrationRecord] = []
        self.migrations_started = 0
        self.migrations_completed = 0
        self.swap_log: List[SwapRecord] = []
        self.swaps_total: Dict[str, int] = {}   # plane name -> swaps done
        # kill-and-restore failover: engine slots currently dark, the
        # bounded admission gap buffered per dark slot, and the meters
        # the checkpoint/recover lifecycle exports
        self.failed: Set[int] = set()
        self._gap: Dict[int, List[Request]] = {}
        self.failure_log: List[FailureRecord] = []
        self.checkpoints_total = 0
        self.recoveries_total = 0
        self.completed: List[Request] = []
        self._seen_completed = [len(e.completed) for e in self.engines]
        # liveness ledger the watchdog's engine-dark rule reads: one
        # heartbeat per engine per cluster step it actually ran (parked
        # and failed engines do not beat — that absence IS the signal)
        self.heartbeats: Dict[int, int] = {
            k: 0 for k in range(len(self.engines))}
        self.watchdog = None
        self.watch_every = 1
        self.steps = 0
        self.scheduler = ClusterLedger(self)
        self._note_resident()

    @property
    def serve_plane(self) -> StackPlane:
        return self.planes[0]

    def attach_autopilot(self, autopilot,
                         place_every: Optional[int] = None):
        """Close the placement loop: tick ``autopilot`` (typically a
        ``repro.control.placement.PlacementController`` built over this
        cluster) every ``place_every`` cluster steps, next to the rate
        controller's own cadence. Returns the autopilot for chaining."""
        self.autopilot = autopilot
        if place_every is not None:
            self.place_every = max(int(place_every), 1)
        return autopilot

    def attach_watchdog(self, watchdog, scrape_every: int = 1):
        """Give the fabric its own pulse: tick ``watchdog`` (a
        ``repro.obs.slo.FabricWatchdog``) every ``scrape_every`` cluster
        steps, alongside the controller/autopilot cadences. The caller
        owns the watchdog's registry wiring; this cluster's ``counters``
        and ``health`` providers are what it should scrape. Returns the
        watchdog for chaining."""
        self.watchdog = watchdog
        self.watch_every = max(int(scrape_every), 1)
        return watchdog

    # -- engine-like surface ------------------------------------------------
    @property
    def B(self) -> int:
        """Total decode slots across the cluster."""
        return sum(e.B for e in self.engines)

    @property
    def decode_steps(self) -> int:
        return sum(e.decode_steps for e in self.engines)

    def submit(self, req: Request) -> int:
        """Route one request to its tenant's placed engine (auto-placing
        an unknown tenant on the least-loaded one). A request for a
        tenant placed on a FAILED engine is not dropped: it buffers in
        that slot's admission gap and ``recover_engine`` replays it in
        arrival order — the gap is bounded by the fail->recover window.
        Returns the engine index it landed on (or is buffered for)."""
        idx = self.placement.get(req.tenant_id)
        if idx is None:
            idx = self.add_tenant(req.tenant_id)
        if idx in self.failed:
            self._gap[idx].append(req)
            return idx
        self.engines[idx].submit(req)
        return idx

    def step(self, now: Optional[float] = None) -> int:
        """One cluster step: tick the shared controller (every
        ``control_every`` steps), step every awake engine once, collect
        completions, finalize any drained migrations, tick the autopilot
        (every ``place_every`` steps). Parked engines do not step — that
        skipped work *is* the cores-saved claim (``parked_engine_steps``)
        and their suspended buffers *are* the memory-saved claim
        (``mem_saved_byte_steps``). Returns the number of active slots
        cluster-wide."""
        self.steps += 1
        if self.controller is not None and \
                self.steps % self.control_every == 0:
            self.controller.tick(time.monotonic() if now is None else now)
        active = 0
        for k, e in enumerate(self.engines):
            if k in self.parked or k in self.failed:
                continue
            active += e.step(now=now)
            self.heartbeats[k] = self.heartbeats.get(k, 0) + 1
        # account the parked set that actually held during the engine loop
        # — an engine the autopilot parks below still ran this step and
        # must not be billed as a saved core until the next one
        self.parked_engine_steps += len(self.parked)
        self.mem_saved_byte_steps += sum(self._suspended_bytes.values())
        self.max_parked = max(self.max_parked, len(self.parked))
        self._note_resident()
        self._collect_completed()
        self._poll_drains(now)
        if self.autopilot is not None and \
                self.steps % self.place_every == 0:
            self.autopilot.tick(time.monotonic() if now is None else now)
        if self.watchdog is not None and \
                self.steps % self.watch_every == 0:
            self.watchdog.tick(time.monotonic() if now is None else now)
        return active

    # -- placement ----------------------------------------------------------
    def add_tenant(self, tenant_id: int, weight: float = 1.0,
                   engine: Optional[int] = None) -> int:
        """Register (or re-weight) a tenant. ``engine`` pins the placement
        of a NEW tenant; None auto-places on the least-loaded engine.
        Returns the engine index the tenant lives on. Re-placing an
        existing tenant is ``migrate``'s job — passing a different
        ``engine`` for one raises instead of silently ignoring the pin."""
        if tenant_id in self.placement:
            idx = self.placement[tenant_id]
            if engine is not None and engine != idx:
                raise ValueError(
                    f"tenant {tenant_id} is already placed on engine "
                    f"{idx}; use migrate({tenant_id}, {engine}) to move "
                    f"a live tenant")
            self.engines[idx].scheduler.set_weight(tenant_id, weight)
            return idx
        idx = engine if engine is not None else self._auto_place()
        if not 0 <= idx < len(self.engines):
            raise IndexError(f"engine {idx} not in cluster")
        if idx in self.parked:
            raise ValueError(f"engine {idx} is parked; unpark it before "
                             f"placing tenant {tenant_id} there")
        if idx in self.failed:
            raise ValueError(f"engine {idx} has failed; recover it before "
                             f"placing tenant {tenant_id} there")
        self.placement[tenant_id] = idx
        self.engines[idx].scheduler.add_tenant(tenant_id, weight=weight)
        return idx

    def set_weight(self, tenant_id: int, weight: float) -> None:
        self.add_tenant(tenant_id, weight=weight)

    def active_engines(self) -> List[int]:
        """Engine indices currently awake (neither parked nor failed)."""
        return [k for k in range(len(self.engines))
                if k not in self.parked and k not in self.failed]

    def _auto_place(self) -> int:
        def load(k: int):
            placed = sum(1 for v in self.placement.values() if v == k)
            return (self.engine_load(k), placed, k)
        return min(self.active_engines(), key=load)

    def engine_load(self, k: int) -> float:
        """Demand pressure on engine ``k``: queued + in-flight requests
        (the serve module's ``StackModule.load``)."""
        return self.engines[k].load()

    def hottest_engine(self) -> int:
        return max(self.active_engines(),
                   key=lambda k: (self.engine_load(k), -k))

    def coolest_engine(self) -> int:
        return min(self.active_engines(),
                   key=lambda k: (self.engine_load(k), k))

    # -- park/unpark lifecycle (cores- AND memory-saved claims) -------------
    def parkable(self, k: int) -> bool:
        """True iff engine ``k`` could be parked right now: awake, fully
        quiesced (no placed tenants, no draining source, no queued or
        in-flight work) and not the last awake engine."""
        if not 0 <= k < len(self.engines) or k in self.parked or \
                k in self.failed:
            return False
        if len(self.active_engines()) <= 1:
            return False
        if any(v == k for v in self.placement.values()):
            return False
        if any(src == k for src in self.draining.values()):
            return False
        return self.engines[k].load() == 0

    def _trace_ts(self, now: Optional[float]) -> float:
        """Timestamp for a control-plane trace event: the caller's clock
        when given, else the step count (wall-clock callers that never
        pass ``now`` still get a monotonic timeline)."""
        return float(self.steps) if now is None else float(now)

    def park(self, k: int, *, now: Optional[float] = None) -> None:
        """Put a quiesced engine to sleep: it stops stepping (saved cores)
        AND every plane's module at ``k`` suspends — KV-cache, slot table
        and scratch are dropped (saved memory) — until ``unpark``. Raises
        if the engine still has any work: parking must never strand a
        tenant."""
        if not 0 <= k < len(self.engines):
            raise IndexError(f"engine {k} not in cluster")
        if k in self.parked:
            raise ValueError(f"engine {k} is already parked")
        if not self.parkable(k):
            raise ValueError(
                f"engine {k} is not quiesced (tenants placed, work "
                f"in-flight, a drain in progress, or it is the last "
                f"awake engine); refuse to park")
        self.parked.add(k)
        freed = sum(plane.modules[k].suspend() for plane in self.planes)
        self._suspended_bytes[k] = freed
        self.bytes_freed_total += freed
        if tracing.TRACER.enabled:
            tracing.TRACER.instant("cluster", "park", self._trace_ts(now),
                                   engine=k, freed_bytes=freed)

    def unpark(self, k: int, *, now: Optional[float] = None) -> None:
        """Wake a parked engine: every plane's module ``resume``s (the
        KV-cache re-materializes lazily on the first admission) and it
        can step and host tenants again immediately."""
        if not 0 <= k < len(self.engines):
            raise IndexError(f"engine {k} not in cluster")
        if k not in self.parked:
            raise ValueError(f"engine {k} is not parked")
        self.parked.discard(k)
        for plane in self.planes:
            plane.modules[k].resume()
        self._suspended_bytes.pop(k, None)
        if tracing.TRACER.enabled:
            tracing.TRACER.instant("cluster", "unpark", self._trace_ts(now),
                                   engine=k)

    def cores_saved(self) -> float:
        """Average engines parked per cluster step so far — the closed-loop
        analog of the paper's Table-2 core savings (engine units; 1.0 =
        one whole engine slept through the run)."""
        return self.parked_engine_steps / max(self.steps, 1)

    def parked_bytes(self) -> int:
        """Bytes currently freed by suspended (parked) engines."""
        return sum(self._suspended_bytes.values())

    def mem_saved(self) -> float:
        """Average bytes freed per cluster step so far — the memory analog
        of ``cores_saved`` (bytes; the integral of parked buffer bytes
        over steps, normalized)."""
        return self.mem_saved_byte_steps / max(self.steps, 1)

    def resident_bytes(self) -> int:
        """Droppable buffer bytes currently resident across every plane's
        modules (suspended modules report 0)."""
        return sum(m.resident_bytes()
                   for plane in self.planes for m in plane.modules)

    def _note_resident(self) -> None:
        self.peak_resident_bytes = max(self.peak_resident_bytes,
                                       self.resident_bytes())

    # -- migration ----------------------------------------------------------
    def migrate(self, tenant: int, dst_engine: int,
                *, now: Optional[float] = None) -> Optional[MigrationRecord]:
        """Move a live tenant to ``dst_engine`` mid-run, conserving its
        ledger on every plane.

        For each plane: the source module exports the tenant (queue, WFQ
        weight, token-bucket level), the carried counters fold into the
        plane's ``ConservationLedger``, and the destination imports —
        identical protocol calls whether the plane is serve or bytes.
        In-flight slots stay draining on the source (they finish and bill
        there). Delta-push history for the tenant is invalidated so the
        controller re-pushes fresh rates to every enforcement point next
        tick. Returns the ``MigrationRecord`` (None if the tenant is
        already on ``dst_engine``).
        """
        if tenant not in self.placement:
            raise KeyError(f"tenant {tenant} is not placed on this cluster")
        if tenant in self.draining:
            raise RuntimeError(
                f"tenant {tenant} is still draining from a previous "
                f"migration; wait for it to finalize")
        src = self.placement[tenant]
        dst = int(dst_engine)
        if not 0 <= dst < len(self.engines):
            raise IndexError(f"engine {dst} not in cluster")
        if dst == src:
            return None
        if dst in self.parked:
            raise ValueError(f"engine {dst} is parked; unpark it before "
                             f"migrating tenant {tenant} onto it")
        if dst in self.failed:
            raise ValueError(f"engine {dst} has failed; recover it before "
                             f"migrating tenant {tenant} onto it")
        if src in self.failed:
            raise RuntimeError(
                f"tenant {tenant} is placed on failed engine {src}; its "
                f"live state died with the crash — recover_engine first, "
                f"then migrate")
        # validate EVERY plane's destination BEFORE the first destructive
        # export: failing after an export would lose the unserved queue
        # (or strand carried counters half-folded)
        for plane in self.planes:
            if plane.modules[dst].has_tenant(tenant):
                raise ValueError(
                    f"tenant {tenant} has live {plane.name}-plane state "
                    f"on engine {dst} (out-of-band submission or rate "
                    f"push?); migration requires a quiesced destination "
                    f"on every plane")
        totals_before = {p.name: p.ledger.total(tenant) for p in self.planes}
        inflight = self.engines[src].tenant_load(tenant).inflight
        ts = self._trace_ts(now)
        serve_state: Optional[TenantState] = None
        for plane in self.planes:
            state = plane.modules[src].export_tenant(tenant, now)
            plane.ledger.fold(tenant, plane.modules[src], state)
            plane.modules[dst].import_tenant(tenant, state, now)
            if plane is self.serve_plane:
                serve_state = state
        if tracing.TRACER.enabled:
            tracing.TRACER.span(
                "cluster", "migrate.transfer", ts, ts, tenant=tenant,
                src=src, dst=dst, queued=len(serve_state.queue),
                inflight=inflight)
            # the drain window [move, finalize] as an async pair keyed by
            # tenant — drains of different tenants overlap on this track
            tracing.TRACER.async_begin("cluster", "migrate.drain",
                                       tenant, ts, tenant=tenant, src=src,
                                       inflight=inflight)
        self.placement[tenant] = dst
        if self.controller is not None:
            self.controller.invalidate_tenant(tenant)
        rec = MigrationRecord(
            tenant=tenant, src=src, dst=dst, started_step=self.steps,
            queued_moved=len(serve_state.queue), inflight_at_move=inflight,
            bucket_tokens_moved=serve_state.bucket_tokens)
        self.migrations_started += 1
        self.migration_log.append(rec)
        # the move itself bills nothing: no plane's global ledger may jump
        for plane in self.planes:
            after = plane.ledger.total(tenant)
            if int(round(after)) != int(round(totals_before[plane.name])):
                raise AssertionError(
                    f"{plane.name}-plane migration broke tenant {tenant}'s "
                    f"ledger continuity: {totals_before[plane.name]} -> "
                    f"{after} {plane.ledger.conserved}")
        self.assert_ledger_conservation(tenant)
        if inflight:
            self.draining[tenant] = src
        else:
            self._finalize(rec, now)
        return rec

    # -- live stack hot-swap (the paper's kernel-TCP -> mTCP move) ----------
    # quiesce safety valve: a slot that never drains (a stuck decode loop)
    # must fail loudly instead of spinning the swap forever
    QUIESCE_STEP_CAP = 10_000

    @staticmethod
    def _stack_desc(module) -> str:
        """Audit-log descriptor for one stack module: the class name plus
        the knob a swap actually flips (the bytes plane swaps CoreEngine
        for CoreEngine — only ``default_nsm`` tells them apart; serve
        variants differ by scheduler policy)."""
        name = type(module).__name__
        nsm = getattr(module, "default_nsm", None)
        if nsm is not None:
            return f"{name}[{nsm}]"
        policy = getattr(getattr(module, "scheduler", None), "policy", None)
        return f"{name}[{policy}]" if policy else name

    def swap_module(self, engine_id: int, plane: str,
                    new_module_factory: Callable[[], object],
                    *, now: Optional[float] = None) -> SwapRecord:
        """Hot-swap the ``StackModule`` serving one engine slot, live.

        The NetKernel headline demo as a cluster primitive: the operator
        replaces the stack beneath unmodified tenants (native <->
        ``CompressedNsm`` on the bytes plane; an alternate scheduler
        variant on the serve plane) while traffic is running, with zero
        dropped or double-billed tokens. Three phases, one trace span
        each:

          1. **quiesce** (``swap.quiesce`` async pair): admission pauses
             (``scheduler.paused`` — queued work stays put, no
             deferred-poll noise) and the old module steps until its
             in-flight slots run dry — they finish *and bill* on the
             stack that admitted them, exactly like a migration drain.
          2. **transfer** (``swap.transfer`` span): every placed tenant
             exports via ``TenantState``, its counters fold into the
             plane's ``ConservationLedger``, the replacement is built and
             adopts the retired module's billed ground truth
             (``inherit_ground_truth`` — completed records / billed
             bytes stay attributed to this engine slot), the module list
             entry is replaced IN PLACE (the plane, the cluster and the
             ledger share the list by reference), the controller's
             enforcement point is re-wired, and every tenant re-imports.
          3. **resume** (``swap.resume`` instant): admission reopens on
             the new module; ``invalidate_tenant`` forces the delta-push
             controller to re-push fresh rates to every enforcement
             point next tick, so no stale rate survives the swap.

        Ledger continuity AND ground-truth continuity are asserted per
        tenant across the boundary, then the full conservation invariant.
        Refused while the engine is parked or is the draining source of a
        live migration (the residual billing would be stranded on the
        retired module — same contract as mid-drain re-migration).
        Returns the ``SwapRecord``.
        """
        k = int(engine_id)
        if not 0 <= k < len(self.engines):
            raise IndexError(f"engine {k} not in cluster")
        pl = next((p for p in self.planes if p.name == plane), None)
        if pl is None:
            raise KeyError(
                f"plane {plane!r} is not attached to this cluster "
                f"(have: {[p.name for p in self.planes]})")
        if k in self.parked:
            raise ValueError(
                f"engine {k} is parked; unpark it before swapping its "
                f"{plane} module")
        if k in self.failed:
            raise ValueError(
                f"engine {k} has failed; recover it before swapping its "
                f"{plane} module")
        if any(src == k for src in self.draining.values()):
            raise RuntimeError(
                f"engine {k} is the draining source of a live migration; "
                f"a swap would strand the residual billing on the retired "
                f"module — wait for the drain to finalize")
        old = pl.modules[k]
        tenants = tuple(sorted(
            t for t, e in self.placement.items()
            if e == k and old.has_tenant(t)))
        ts0 = self._trace_ts(now)
        quiesce_id = f"{pl.name}:{k}:{self.steps}"
        if tracing.TRACER.enabled:
            tracing.TRACER.async_begin("cluster", "swap.quiesce",
                                       quiesce_id, ts0, engine=k,
                                       plane=pl.name)
        # 1. quiesce: pause admission, drain in-flight slots on the old
        # module (planes without slot machinery skip straight through)
        sched = getattr(old, "scheduler", None)
        inflight_fn = getattr(old, "inflight", None)
        inflight0 = int(inflight_fn()) if callable(inflight_fn) else 0
        quiesce_steps = 0
        if sched is not None:
            sched.paused = True
        try:
            while callable(inflight_fn) and inflight_fn():
                if quiesce_steps >= self.QUIESCE_STEP_CAP:
                    raise RuntimeError(
                        f"engine {k} failed to quiesce within "
                        f"{self.QUIESCE_STEP_CAP} steps "
                        f"({inflight_fn()} slot(s) still in flight)")
                old.step(now=now)
                quiesce_steps += 1
        finally:
            if sched is not None:
                sched.paused = False
        ts1 = self._trace_ts(now)
        if tracing.TRACER.enabled:
            tracing.TRACER.async_end("cluster", "swap.quiesce",
                                     quiesce_id, ts1, engine=k,
                                     plane=pl.name)
        # 2. transfer: totals are taken AFTER the quiesce (drain billing
        # moved them) and must be unchanged by everything below
        totals_before = {t: pl.ledger.total(t) for t in tenants}
        truth_before = {t: pl.ledger.ground_truth(t) for t in tenants}
        states: Dict[int, TenantState] = {}
        for t in tenants:
            state = old.export_tenant(t, now)
            pl.ledger.fold(t, old, state)
            states[t] = state
        new = new_module_factory()
        if getattr(new, "plane", pl.name) != pl.name:
            raise ValueError(
                f"replacement module is {getattr(new, 'plane')!r}-plane; "
                f"cannot swap it into the {pl.name} plane")
        if getattr(new, "controller", None) is not None:
            raise ValueError(
                "replacement module must not own a controller; the "
                "cluster ticks the shared one")
        # the replacement takes over the slot's identity: trace track and
        # the retired module's never-migrates ground truth
        if hasattr(new, "trace_name"):
            new.trace_name = f"engine{k}"
        new_sched = getattr(new, "scheduler", None)
        if new_sched is not None:
            new_sched.trace_track = f"engine{k}"
        new.inherit_ground_truth(old)
        pl.modules[k] = new    # in place: engines/planes/ledger all see it
        if pl is self.serve_plane and self.controller is not None:
            if sched is not None:
                self.controller.detach_scheduler(sched)
            if new_sched is not None:
                self.controller.attach_scheduler(new_sched)
        for t in tenants:
            new.import_tenant(t, states[t], now)
        # 3. resume: fresh rates to every enforcement point next tick
        if self.controller is not None:
            for t in tenants:
                self.controller.invalidate_tenant(t)
        for t in tenants:
            after = pl.ledger.total(t)
            if int(round(after)) != int(round(totals_before[t])):
                raise AssertionError(
                    f"{pl.name}-plane swap broke tenant {t}'s ledger "
                    f"continuity: {totals_before[t]} -> {after} "
                    f"{pl.ledger.conserved}")
            truth_after = pl.ledger.ground_truth(t)
            if int(round(truth_after)) != int(round(truth_before[t])):
                raise AssertionError(
                    f"{pl.name}-plane swap lost tenant {t}'s billed "
                    f"ground truth across the boundary: "
                    f"{truth_before[t]} -> {truth_after}")
            self.assert_ledger_conservation(t)
        ts2 = self._trace_ts(now)
        rec = SwapRecord(
            engine=k, plane=pl.name, step=self.steps, tenants=tenants,
            inflight_at_swap=inflight0, quiesce_steps=quiesce_steps,
            old_stack=self._stack_desc(old),
            new_stack=self._stack_desc(new))
        self.swap_log.append(rec)
        self.swaps_total[pl.name] = self.swaps_total.get(pl.name, 0) + 1
        if tracing.TRACER.enabled:
            tracing.TRACER.span(
                "cluster", "swap.transfer", ts1, ts2, engine=k,
                plane=pl.name, tenants=len(tenants),
                old=rec.old_stack, new=rec.new_stack)
            tracing.TRACER.instant("cluster", "swap.resume", ts2,
                                   engine=k, plane=pl.name)
        return rec

    # -- checkpoint / kill-and-restore failover -----------------------------
    def checkpoint(self, *, now: Optional[float] = None) -> FabricSnapshot:
        """Capture the whole fabric as one ``FabricSnapshot``.

        Every plane's per-tenant state is exported non-destructively
        (``StackModule.snapshot_tenant`` — live counters included), plus
        each module's FULL billed-ground-truth map (departed tenants'
        never-migrates history included), the serve plane's engine-side
        latency tails, the per-plane carried ledgers, the placement map,
        park set, swap log and the controller's soft state.

        The capture is passive: no admission pause, no drain. In-flight
        slots are deliberately NOT captured — a crash loses them by
        definition — but their billing-so-far IS (in both the counters
        and the ground-truth map), so conservation holds exactly on any
        restore. Refused mid-drain (a draining tenant's residual billing
        lives in in-flight slots a snapshot cannot carry) and while an
        engine is failed (the admission-gap buffer is not part of the
        wire format — recover first). Emits one ``checkpoint`` span per
        engine so the trace checker can pin recover-after-checkpoint
        ordering per slot.
        """
        if self.draining:
            raise RuntimeError(
                f"cannot checkpoint mid-drain (tenants "
                f"{sorted(self.draining)} still draining): residual "
                f"billing lives in in-flight slots a snapshot cannot "
                f"carry; wait for the migration to finalize")
        if self.failed:
            raise RuntimeError(
                f"cannot checkpoint with failed engines "
                f"{sorted(self.failed)}: their buffered admission gap "
                f"is not part of the snapshot; recover them first")
        ts = self._trace_ts(now)
        planes: List[PlaneSnapshot] = []
        for plane in self.planes:
            mods: List[ModuleSnapshot] = []
            for k, m in enumerate(plane.modules):
                tenants = {
                    t: m.snapshot_tenant(t, now)
                    for t, e in self.placement.items()
                    if e == k and m.has_tenant(t)}
                latency: Dict[str, Dict[int, dict]] = {}
                if plane is self.serve_plane:
                    latency = {
                        fam: {t: h.to_payload()
                              for t, h in th.per_tenant.items()}
                        for fam, th in m.latency_hists().items()}
                mods.append(ModuleSnapshot(
                    tenants=tenants, ground_truth=m.ground_truth_map(),
                    latency=latency))
            planes.append(PlaneSnapshot(
                name=plane.name,
                carried={f: dict(d)
                         for f, d in plane.ledger.carried.items()},
                modules=mods))
        ctrl: Dict[str, object] = {}
        if self.controller is not None:
            ctrl = {"capacity": float(self.controller.capacity),
                    "ticks": int(self.controller.ticks),
                    "allocations": dict(self.controller.allocations)}
        snap = FabricSnapshot(
            step=self.steps, placement=dict(self.placement),
            draining={}, parked=sorted(self.parked), planes=planes,
            controller=ctrl,
            swap_log=[dict(vars(r), tenants=list(r.tenants))
                      for r in self.swap_log])
        self.checkpoints_total += 1
        if tracing.TRACER.enabled:
            for k in range(len(self.engines)):
                tracing.TRACER.span("cluster", "checkpoint", ts, ts,
                                    engine=k, step=self.steps)
        return snap

    def _check_snapshot(self, snapshot: FabricSnapshot) -> Dict[str, PlaneSnapshot]:
        """Shared restore-side validation: version strict-reject (a
        hand-built snapshot skips ``from_bytes``) and plane/module shape
        against this cluster. Returns the planes keyed by name."""
        if snapshot.version != FABRIC_SNAPSHOT_VERSION:
            raise ValueError(
                f"unknown FabricSnapshot version {snapshot.version!r} "
                f"(this cluster understands {FABRIC_SNAPSHOT_VERSION})")
        by_name = {p.name: p for p in snapshot.planes}
        for plane in self.planes:
            if plane.name not in by_name:
                raise ValueError(
                    f"snapshot has no {plane.name!r} plane "
                    f"(have: {sorted(by_name)})")
            n = len(by_name[plane.name].modules)
            if n != len(self.engines):
                raise ValueError(
                    f"snapshot {plane.name} plane has {n} modules; this "
                    f"cluster has {len(self.engines)} engines")
        return by_name

    def fail_engine(self, k: int, *,
                    now: Optional[float] = None) -> FailureRecord:
        """Simulated crash of one engine slot: every plane's module at
        ``k`` is wiped in place (``StackModule.crash``) — queued and
        in-flight work lost, counters and billed records gone, latency
        tails gone. The slot stops stepping and stops receiving
        dispatches; requests for its tenants buffer in a bounded
        admission gap that ``recover_engine`` replays. For tenants placed
        on the slot, live counters equal the module's billed ground truth
        at every instant, so wiping both sides together preserves
        conservation. Ground-truth history the slot holds for tenants
        placed ELSEWHERE (a drained migration leaves its completed
        records on the source forever) is finalized billing the carried
        ledger already references — it is re-seeded as a baseline, not
        lost: a crash destroys live state, not the billing record.
        Conservation is asserted for every placed tenant before
        returning.

        Refused for a parked engine (park and failure are distinct
        lifecycle states — unpark first), for the draining source of a
        live migration (the residual billing would be unrecoverable),
        and for the last live engine.
        """
        if not 0 <= k < len(self.engines):
            raise IndexError(f"engine {k} not in cluster")
        if k in self.failed:
            raise ValueError(f"engine {k} has already failed")
        if k in self.parked:
            raise ValueError(
                f"engine {k} is parked; unpark it before failing it")
        if any(src == k for src in self.draining.values()):
            raise RuntimeError(
                f"engine {k} is the draining source of a live migration; "
                f"crashing it now would lose the residual billing "
                f"forever — wait for the drain to finalize")
        if len(self.active_engines()) <= 1:
            raise ValueError(
                f"engine {k} is the last live engine; refusing to fail "
                f"the whole cluster")
        serve_mod = self.serve_plane.modules[k]
        rec = FailureRecord(
            engine=k, step=self.steps,
            inflight_lost=int(self.engines[k].inflight()),
            queued_lost=int(self.engines[k].scheduler.pending()),
            gt_at_crash=dict(serve_mod.ground_truth_map()))
        for plane in self.planes:
            mod = plane.modules[k]
            history = {t: v for t, v in mod.ground_truth_map().items()
                       if self.placement.get(t) != k}
            mod.crash()
            for t, v in history.items():
                mod.restore_ground_truth(t, v)
        self._seen_completed[k] = 0
        self.failed.add(k)
        self._gap[k] = []
        self.failure_log.append(rec)
        for t in self.placement:
            self.assert_ledger_conservation(t)
        if tracing.TRACER.enabled:
            tracing.TRACER.instant(
                "cluster", "fail", self._trace_ts(now), engine=k,
                inflight_lost=rec.inflight_lost,
                queued_lost=rec.queued_lost)
        return rec

    def recover_engine(self, k: int, snapshot: FabricSnapshot, *,
                       now: Optional[float] = None) -> FailureRecord:
        """Re-materialize a crashed engine slot from its last
        ``FabricSnapshot`` and replay the bounded admission gap.

        Per plane (matched by name): the slot's tenants restore through
        ``StackModule.restore_tenant`` (refused onto live state — the
        double-restore guard), the module's FULL billed-ground-truth map
        re-installs (SET, never added), and the serve plane's engine-side
        latency tails replace wholesale. Carried ledgers are NOT touched:
        nothing folded while the slot was dark. Tenants placed on the
        slot after the checkpoint re-register empty (their pre-crash work
        is lost with the crash, like everything billed after the
        checkpoint — ``tokens_lost`` on the returned record, bounded by
        one checkpoint interval). Buffered requests replay through
        ``submit`` in arrival order, delta-push history is invalidated so
        fresh rates reach the slot next tick, and conservation is
        asserted for every placed tenant on every plane.
        """
        if not 0 <= k < len(self.engines):
            raise IndexError(f"engine {k} not in cluster")
        if k not in self.failed:
            raise ValueError(
                f"engine {k} has not failed; recover_engine "
                f"re-materializes a crashed slot — use restore() for a "
                f"full-fabric reset")
        by_name = self._check_snapshot(snapshot)
        serve_snap = by_name[self.serve_plane.name].modules[k]
        for t in serve_snap.tenants:
            if self.placement.get(t) != k:
                raise ValueError(
                    f"tenant {t} was on engine {k} at checkpoint time "
                    f"but is placed on {self.placement.get(t)} now; "
                    f"recovery needs a checkpoint taken since the last "
                    f"move")
        restored: Set[int] = set()
        for plane in self.planes:
            snap_mod = by_name[plane.name].modules[k]
            mod = plane.modules[k]
            for t, value in snap_mod.ground_truth.items():
                mod.restore_ground_truth(t, value)
            for t, state in snap_mod.tenants.items():
                mod.restore_tenant(t, state, now)
                restored.add(t)
            if plane is self.serve_plane:
                mod.restore_latency(snap_mod.latency)
        # tenants placed here after the checkpoint: re-register empty so
        # admission works the moment the slot is live again
        for t, e in self.placement.items():
            if e == k and t not in serve_snap.tenants:
                self.engines[k].scheduler.add_tenant(t)
        self.failed.discard(k)
        gap = self._gap.pop(k, [])
        for req in gap:
            self.submit(req)
        if self.controller is not None:
            for t in restored:
                self.controller.invalidate_tenant(t)
        rec = next((r for r in reversed(self.failure_log)
                    if r.engine == k and not r.recovered), None)
        if rec is None:        # failed outside fail_engine? keep the log sane
            rec = FailureRecord(engine=k, step=self.steps,
                                inflight_lost=0, queued_lost=0,
                                gt_at_crash={})
            self.failure_log.append(rec)
        rec.recovered_step = self.steps
        rec.tokens_lost = sum(
            max(gt - float(serve_snap.ground_truth.get(t, 0.0)), 0.0)
            for t, gt in rec.gt_at_crash.items())
        self.recoveries_total += 1
        for t in self.placement:
            self.assert_ledger_conservation(t)
        if tracing.TRACER.enabled:
            ts = self._trace_ts(now)
            tracing.TRACER.span(
                "cluster", "recover", ts, ts, engine=k,
                tenants=len(restored), gap_replayed=len(gap),
                tokens_lost=rec.tokens_lost)
        return rec

    def restore(self, snapshot: FabricSnapshot, *,
                now: Optional[float] = None) -> None:
        """Full-fabric reset to a ``FabricSnapshot``: every engine slot
        on every plane crashes in place, then the snapshot's placement,
        park set, per-tenant states, ground-truth maps, latency tails,
        carried ledgers, swap log and controller soft state install.
        In-flight work at snapshot time was never captured (crash
        semantics) and anything submitted since the snapshot is gone —
        including failed slots' buffered gaps. Conservation is asserted
        for every placed tenant before returning."""
        by_name = self._check_snapshot(snapshot)
        for plane in self.planes:
            for m in plane.modules:
                m.crash()
        self.failed.clear()
        self._gap.clear()
        self.placement = dict(snapshot.placement)
        self.draining = dict(snapshot.draining)
        # crash() left every module resumed; re-park per the snapshot
        # (a freshly wiped module has no cache, so freed bytes are ~0)
        self.parked = set()
        self._suspended_bytes.clear()
        for k in snapshot.parked:
            self.parked.add(k)
            freed = sum(p.modules[k].suspend() for p in self.planes)
            self._suspended_bytes[k] = freed
        for plane in self.planes:
            sp = by_name[plane.name]
            for f in plane.ledger.fields:
                plane.ledger.carried[f] = dict(sp.carried.get(f, {}))
            for k, snap_mod in enumerate(sp.modules):
                mod = plane.modules[k]
                for t, value in snap_mod.ground_truth.items():
                    mod.restore_ground_truth(t, value)
                for t, state in snap_mod.tenants.items():
                    mod.restore_tenant(t, state, now)
                if plane is self.serve_plane:
                    mod.restore_latency(snap_mod.latency)
        self.steps = int(snapshot.step)
        self.swap_log = [
            SwapRecord(**dict(r, tenants=tuple(r.get("tenants", ()))))
            for r in snapshot.swap_log]
        self.swaps_total = {}
        for srec in self.swap_log:
            self.swaps_total[srec.plane] = \
                self.swaps_total.get(srec.plane, 0) + 1
        self._seen_completed = [len(e.completed) for e in self.engines]
        if self.controller is not None and snapshot.controller:
            self.controller.capacity = \
                float(snapshot.controller.get("capacity",
                                              self.controller.capacity))
            self.controller.ticks = int(snapshot.controller.get("ticks", 0))
            self.controller.allocations = dict(
                snapshot.controller.get("allocations", {}))
            # full re-push next tick: no stale delta-push judgment may
            # survive a fabric reset
            self.controller._last_push.clear()
        for t in self.placement:
            self.assert_ledger_conservation(t)
        if tracing.TRACER.enabled:
            tracing.TRACER.instant("cluster", "restore",
                                   self._trace_ts(now),
                                   step=int(snapshot.step))

    def rebalance(self, *, tenant: Optional[int] = None,
                  now: Optional[float] = None) -> Optional[MigrationRecord]:
        """Operator one-shot: move a tenant off the hottest engine onto the
        coolest. Default victim is the hottest engine's most-backlogged
        tenant (by queue depth — under an adversarial trace, the hog).
        No-op (returns None) if the cluster is already balanced.

        .. deprecated:: since the placement autopilot landed this is a
           thin wrapper over ``PlacementController.plan_once`` (the
           ``spread_hot`` policy, forced: no bands, no cooldown, no drain
           gate — the legacy semantics). Calling it emits a
           ``DeprecationWarning``; prefer attaching a
           ``PlacementController`` via ``attach_autopilot`` (closed loop)
           or calling ``PlacementController.plan_once(force=True)``
           directly (one-shot).
        """
        from repro.serve.replay import operator_rebalance
        warnings.warn(
            "EngineCluster.rebalance() is deprecated; use "
            "operator_rebalance / PlacementController.plan_once("
            "force=True) for the one-shot or attach_autopilot() for the "
            "closed loop", DeprecationWarning, stacklevel=2)
        if tenant is not None:
            # keep the legacy error contract migrate() provided
            if tenant not in self.placement:
                raise KeyError(
                    f"tenant {tenant} is not placed on this cluster")
            if tenant in self.draining:
                raise RuntimeError(
                    f"tenant {tenant} is still draining from a previous "
                    f"migration; wait for it to finalize")
        return operator_rebalance(self, now=now, pin_tenant=tenant)

    def apply_plan(self, plan, *,
                   now: Optional[float] = None) -> List[MigrationRecord]:
        """Apply a ``PlacementPlan``: unpark first (a move may target a
        waking engine), then every move through ``migrate``'s
        ledger-conserving drain-and-transfer, then park engines the plan
        emptied. Stale entries — a tenant that already moved or is
        mid-drain, a park target that turns out non-quiesced — are skipped
        rather than raised: plans are computed from a snapshot and the
        cluster may have moved on. Returns the records of the migrations
        that actually happened (conservation was asserted on each)."""
        records: List[MigrationRecord] = []
        for k in plan.unpark:
            if k in self.parked:
                self.unpark(k, now=now)
        for mv in plan.moves:
            if mv.tenant not in self.placement or \
                    mv.tenant in self.draining:
                continue
            if self.placement[mv.tenant] != mv.src:
                continue                           # stale: already moved
            if mv.dst in self.parked:
                continue                           # unpark was skipped
            rec = self.migrate(mv.tenant, mv.dst, now=now)
            if rec is not None:
                records.append(rec)
        for k in plan.park:
            if k not in self.parked and self.parkable(k):
                self.park(k, now=now)
        return records

    def _finalize(self, rec: MigrationRecord,
                  now: Optional[float] = None) -> None:
        rec.finalized_step = self.steps
        self.migrations_completed += 1
        self.assert_ledger_conservation(rec.tenant)
        if self.controller is not None:
            # the source no longer holds the tenant: drop its telemetry
            # EWMA/baseline state there (the destination, which does hold
            # it, is left untouched) — without this, every migration
            # leaked the tenant's control state on the source forever
            self.controller.evict_tenant(rec.tenant)
        if tracing.TRACER.enabled:
            ts = self._trace_ts(now)
            tracing.TRACER.async_end("cluster", "migrate.drain",
                                     rec.tenant, ts)
            tracing.TRACER.span(
                "cluster", "migrate.finalize", ts, ts, tenant=rec.tenant,
                src=rec.src, dst=rec.dst,
                drained_steps=rec.finalized_step - rec.started_step)

    def _poll_drains(self, now: Optional[float] = None) -> None:
        serve = self.serve_plane
        for tenant, src in list(self.draining.items()):
            if serve.modules[src].tenant_load(tenant).inflight:
                continue
            # in-flight work finished on the source: fold its residual
            # billing (decode tokens accrued since the move) and finalize
            residual = serve.modules[src].export_tenant(tenant)
            if residual.queue:
                raise AssertionError(
                    f"tenant {tenant} grew a queue on drained source "
                    f"engine {src}: routing leaked past the placement map")
            serve.ledger.fold(tenant, serve.modules[src], residual)
            del self.draining[tenant]
            rec = next(r for r in reversed(self.migration_log)
                       if r.tenant == tenant)
            self._finalize(rec, now)

    def _collect_completed(self) -> None:
        for k, e in enumerate(self.engines):
            if len(e.completed) > self._seen_completed[k]:
                self.completed.extend(e.completed[self._seen_completed[k]:])
                self._seen_completed[k] = len(e.completed)

    # -- cluster-global ledger ----------------------------------------------
    def merged_ledger(self, fld: str) -> Dict[int, float]:
        """Carried (migrated-away) history + live per-engine counters for
        one serve-plane ledger field — the continuous cluster-global
        view."""
        return self.serve_plane.ledger.merged(fld)

    def tenant_served_tokens(self, tenant: int) -> float:
        """Tokens billed to a tenant cluster-wide, continuous across
        migrations (carried + live engine counters)."""
        return self.serve_plane.ledger.total(tenant, "served_tokens")

    def tenant_core_bytes(self, tenant: int) -> float:
        """Collective bytes routed for a tenant cluster-wide, continuous
        across migrations (bytes-plane carried + live CoreEngine ledgers).
        0.0 when the cluster has no bytes plane attached."""
        for plane in self.planes:
            if plane.name == "bytes":
                return plane.ledger.total(tenant, "bytes")
        return 0.0

    def tenant_billed_ground_truth(self, tenant: int) -> int:
        """Request-level ground truth: prompt+generated tokens over the
        tenant's completed and in-flight requests, summed over every
        serve module (completed records never migrate). The billing
        scheme (admit bills prompt + first prefill token, each decode
        step bills the token it produced) makes this equal the ledger at
        all times."""
        return int(round(self.serve_plane.ledger.ground_truth(tenant)))

    def assert_ledger_conservation(self, tenant: int) -> None:
        """No lost units, no double-billing, on ANY plane: each plane's
        carried+live ledger must equal its modules' summed billed ground
        truth exactly — one shared assert implementation
        (``ConservationLedger.assert_conservation``)."""
        for plane in self.planes:
            plane.ledger.assert_conservation(tenant, plane=plane.name)

    # -- reporting ----------------------------------------------------------
    def latency(self) -> Dict[str, TenantHistograms]:
        """Cluster-global per-tenant latency families (admit wait, TTFT,
        e2e): every serve module's histograms merged. Continuous across
        migrations — the admit-wait counts travel with the tenant, the
        engine-side TTFT/e2e counts stay where they were served."""
        out: Dict[str, TenantHistograms] = {}
        for m in self.serve_plane.modules:
            for name, th in m.latency().items():
                out[name] = out[name].merged(th) if name in out \
                    else th.merged(TenantHistograms(name, th.edges))
        return out

    def health(self) -> Dict[str, float]:
        """Liveness series for the watchdog's absence rules, kept out of
        ``counters()`` so existing scrapes are unchanged: ``nk_engine_up``
        (0 only while failed — a parked engine is asleep, not dead) and
        ``nk_engine_heartbeat_total`` (steps the engine actually ran; a
        stalled heartbeat on an unparked engine means the slot is dark).
        Register alongside ``counters``:
        ``registry.register_provider(cluster.health, name="health")``."""
        out: Dict[str, float] = {}
        for k in range(len(self.engines)):
            out[f'nk_engine_up{{engine="{k}"}}'] = \
                0.0 if k in self.failed else 1.0
            out[f'nk_engine_heartbeat_total{{engine="{k}"}}'] = \
                float(self.heartbeats.get(k, 0))
        return out

    def counters(self) -> Dict[str, float]:
        """Placement/migration counters (Prometheus naming), merged with
        the shared controller's."""
        out: Dict[str, float] = {
            "nk_cluster_engines": float(len(self.engines)),
            "nk_cluster_steps_total": float(self.steps),
            "nk_migrations_started_total": float(self.migrations_started),
            "nk_migrations_completed_total":
                float(self.migrations_completed),
            "nk_migrations_draining": float(len(self.draining)),
            "nk_cluster_parked": float(len(self.parked)),
            "nk_parked_engine_steps_total":
                float(self.parked_engine_steps),
            "nk_cores_saved": self.cores_saved(),
            "nk_parked_bytes": float(self.parked_bytes()),
            "nk_bytes_freed_total": float(self.bytes_freed_total),
            "nk_mem_saved_bytes": self.mem_saved(),
            "nk_resident_cache_bytes": float(self.resident_bytes()),
            "nk_peak_resident_cache_bytes":
                float(self.peak_resident_bytes),
        }
        for t, k in sorted(self.placement.items()):
            out[f'nk_placement{{tenant="{t}"}}'] = float(k)
        for k, e in enumerate(self.engines):
            out[f'nk_engine_load{{engine="{k}"}}'] = self.engine_load(k)
            out[f'nk_engine_parked{{engine="{k}"}}'] = \
                float(k in self.parked)
            out[f'nk_engine_decode_steps_total{{engine="{k}"}}'] = \
                float(e.decode_steps)
        # recent moves as info series (value = cluster step the move
        # started at) — what nk_top's "recent autopilot moves" pane reads
        for rec in self.migration_log[-5:]:
            out[f'nk_migration_info{{seq="{rec.started_step}",'
                f'tenant="{rec.tenant}",src="{rec.src}",'
                f'dst="{rec.dst}"}}'] = float(rec.started_step)
        out["nk_checkpoints_total"] = float(self.checkpoints_total)
        out["nk_recoveries_total"] = float(self.recoveries_total)
        out["nk_engines_failed"] = float(len(self.failed))
        for plane_name, n in sorted(self.swaps_total.items()):
            out[f'nk_swaps_total{{plane="{plane_name}"}}'] = float(n)
        # recent hot-swaps as info series (value = cluster step), like
        # nk_migration_info above
        for srec in self.swap_log[-5:]:
            out[f'nk_swap_info{{seq="{srec.step}",'
                f'engine="{srec.engine}",plane="{srec.plane}",'
                f'old="{srec.old_stack}",new="{srec.new_stack}"}}'] = \
                float(srec.step)
        for th in self.latency().values():
            out.update(th.counters())
        if self.autopilot is not None and \
                hasattr(self.autopilot, "counters"):
            out.update(self.autopilot.counters())
        if self.controller is not None:
            out.update(self.controller.counters())
        return out

    def export_prometheus(self) -> str:
        return format_prometheus(self.counters())
