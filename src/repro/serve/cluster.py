"""EngineCluster: one controller, N ServeEngines, live tenant migration.

The paper's operator owns the stack as *infrastructure*: many guests
multiplex onto shared stack modules, and the operator can rebalance that
mapping at will — including moving a tenant between modules without the
guest noticing. This module is that placement power for the serving plane:

  * N live ``ServeEngine``s (think: NSMs on different hosts) behind ONE
    shared ``RateController``. The controller's water-fill runs over the
    merged telemetry of every engine's scheduler — one tokens/s bottleneck
    spanning the cluster — and splits each tenant's global allocation
    across engines in proportion to where its traffic shows up.
  * a tenant -> engine ``placement`` map the operator controls. New
    tenants auto-place on the least-loaded engine; ``migrate`` moves a
    live tenant mid-replay.

Migration is drain-and-transfer, and conserves the served-token ledger:

  1. the tenant's unserved queue, WFQ weight and token-bucket *level*
     are exported from the source scheduler and imported at the
     destination (a move can never reopen a fresh burst);
  2. the source's cumulative ledger entries fold into the cluster-level
     ``carried`` ledger, so the global view never jumps (telemetry on the
     source sees a counter reset, not a negative rate);
  3. in-flight slots are NOT moved: they finish — and bill — where they
     were admitted; the tenant is ``draining`` until they run dry, then
     the residual billing folds and the migration finalizes.

``tenant_served_tokens`` (carried + live counters) therefore equals the
request-level ground truth — sum of prompt+generated tokens over the
tenant's completed and in-flight requests — at every instant, including
across the migration window. ``assert_ledger_conservation`` checks exactly
that (no lost tokens, no double-billing) and is invoked on every move.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.control.telemetry import format_prometheus
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Request

_LEDGER_FIELDS = ("served_tokens", "admitted_requests", "deferred_polls",
                  "admit_wait_sum")


@dataclass
class MigrationRecord:
    """One migrate() call, for the operator's audit log."""

    tenant: int
    src: int                      # engine index the tenant left
    dst: int                      # engine index it moved to
    started_step: int             # cluster step count at the move
    queued_moved: int             # unserved requests transferred
    inflight_at_move: int         # slots left draining on the source
    bucket_tokens_moved: float    # token-bucket level transferred (tokens)
    finalized_step: int = -1      # -1 while the source is still draining

    @property
    def finalized(self) -> bool:
        return self.finalized_step >= 0


class ClusterLedger:
    """Duck-types the ``TenantScheduler`` ledger surface over a cluster.

    ``TraceReplayer`` (and anything else written against one scheduler's
    ledgers) reads per-tenant counters through this facade and sees the
    cluster-global view: carried (migrated-away) history plus the live
    counters of every engine, so a tenant's numbers are continuous across
    migrations.
    """

    def __init__(self, cluster: "EngineCluster"):
        self._cluster = cluster

    @property
    def queues(self) -> Dict[int, int]:
        """Known tenants (tenant -> engine index) — membership view."""
        return dict(self._cluster.placement)

    def add_tenant(self, tenant_id: int, weight: float = 1.0, **kw):
        self._cluster.add_tenant(tenant_id, weight=weight)

    def set_weight(self, tenant_id: int, weight: float):
        self._cluster.set_weight(tenant_id, weight)

    def pending(self, tenant_id: Optional[int] = None) -> int:
        return sum(e.scheduler.pending(tenant_id)
                   for e in self._cluster.engines)

    @property
    def served_tokens(self) -> Dict[int, int]:
        return self._cluster.merged_ledger("served_tokens")

    @property
    def admitted_requests(self) -> Dict[int, int]:
        return self._cluster.merged_ledger("admitted_requests")

    @property
    def deferred_polls(self) -> Dict[int, int]:
        return self._cluster.merged_ledger("deferred_polls")

    @property
    def admit_wait_sum(self) -> Dict[int, float]:
        return self._cluster.merged_ledger("admit_wait_sum")

    def ledger(self) -> Dict[int, Dict[str, float]]:
        """Cluster-global version of ``TenantScheduler.ledger``."""
        served = self.served_tokens
        admitted = self.admitted_requests
        deferred = self.deferred_polls
        waits = self.admit_wait_sum
        out: Dict[int, Dict[str, float]] = {}
        for t in set(served) | set(admitted) | set(deferred):
            adm = admitted.get(t, 0)
            out[t] = {
                "served_tokens": float(served.get(t, 0)),
                "admitted_requests": float(adm),
                "deferred_polls": float(deferred.get(t, 0)),
                "queued": float(self.pending(t)),
                "mean_admit_wait_s": (waits.get(t, 0.0) / adm
                                      if adm else 0.0),
            }
        return out


class EngineCluster:
    """N ServeEngines + one shared RateController + operator placement.

    Exposes the same driving surface as a single ``ServeEngine`` (``B``,
    ``submit``, ``step``, ``completed``, ``decode_steps``, ``scheduler``,
    ``controller``) so ``TraceReplayer`` runs a cluster unchanged.

    Args:
        engines: live ServeEngines. Their own ``controller`` hooks must be
            unset — the cluster drives the shared controller itself (one
            tick for the whole cluster per control interval, not one per
            engine).
        controller: the shared ``RateController`` (capacity in tokens/s =
            the ONE bottleneck spanning all engines). Any engine scheduler
            not yet attached to it is attached here.
        control_every: controller tick period, in cluster steps.
    """

    def __init__(self, engines: Sequence[ServeEngine], controller=None,
                 *, control_every: int = 4):
        self.engines: List[ServeEngine] = list(engines)
        if not self.engines:
            raise ValueError("EngineCluster needs at least one engine")
        for e in self.engines:
            if e.controller is not None:
                raise ValueError(
                    "cluster engines must not own a controller; the "
                    "cluster ticks the shared one")
        self.controller = controller
        if controller is not None:
            attached = {id(s) for s, _ in controller._schedulers}
            for e in self.engines:
                if id(e.scheduler) not in attached:
                    controller.attach_scheduler(e.scheduler)
        self.control_every = max(int(control_every), 1)
        self.placement: Dict[int, int] = {}
        self.draining: Dict[int, int] = {}          # tenant -> src engine
        self.migration_log: List[MigrationRecord] = []
        self.migrations_started = 0
        self.migrations_completed = 0
        self.completed: List[Request] = []
        self._seen_completed = [len(e.completed) for e in self.engines]
        self.steps = 0
        self._carried: Dict[str, Dict[int, float]] = \
            {f: {} for f in _LEDGER_FIELDS}
        self.scheduler = ClusterLedger(self)

    # -- engine-like surface ------------------------------------------------
    @property
    def B(self) -> int:
        """Total decode slots across the cluster."""
        return sum(e.B for e in self.engines)

    @property
    def decode_steps(self) -> int:
        return sum(e.decode_steps for e in self.engines)

    def submit(self, req: Request) -> int:
        """Route one request to its tenant's placed engine (auto-placing
        an unknown tenant on the least-loaded one). Returns the engine
        index it landed on."""
        idx = self.placement.get(req.tenant_id)
        if idx is None:
            idx = self.add_tenant(req.tenant_id)
        self.engines[idx].submit(req)
        return idx

    def step(self, now: Optional[float] = None) -> int:
        """One cluster step: tick the shared controller (every
        ``control_every`` steps), step every engine once, collect
        completions, finalize any drained migrations. Returns the number
        of active slots cluster-wide."""
        self.steps += 1
        if self.controller is not None and \
                self.steps % self.control_every == 0:
            self.controller.tick(time.monotonic() if now is None else now)
        active = 0
        for e in self.engines:
            active += e.step(now=now)
        self._collect_completed()
        self._poll_drains()
        return active

    # -- placement ----------------------------------------------------------
    def add_tenant(self, tenant_id: int, weight: float = 1.0,
                   engine: Optional[int] = None) -> int:
        """Register (or re-weight) a tenant. ``engine`` pins the placement
        of a NEW tenant; None auto-places on the least-loaded engine.
        Returns the engine index the tenant lives on. Re-placing an
        existing tenant is ``migrate``'s job — passing a different
        ``engine`` for one raises instead of silently ignoring the pin."""
        if tenant_id in self.placement:
            idx = self.placement[tenant_id]
            if engine is not None and engine != idx:
                raise ValueError(
                    f"tenant {tenant_id} is already placed on engine "
                    f"{idx}; use migrate({tenant_id}, {engine}) to move "
                    f"a live tenant")
            self.engines[idx].scheduler.set_weight(tenant_id, weight)
            return idx
        idx = engine if engine is not None else self._auto_place()
        if not 0 <= idx < len(self.engines):
            raise IndexError(f"engine {idx} not in cluster")
        self.placement[tenant_id] = idx
        self.engines[idx].scheduler.add_tenant(tenant_id, weight=weight)
        return idx

    def set_weight(self, tenant_id: int, weight: float) -> None:
        self.add_tenant(tenant_id, weight=weight)

    def _auto_place(self) -> int:
        def load(k: int):
            placed = sum(1 for v in self.placement.values() if v == k)
            return (self.engine_load(k), placed, k)
        return min(range(len(self.engines)), key=load)

    def engine_load(self, k: int) -> float:
        """Demand pressure on engine ``k``: queued + in-flight requests."""
        e = self.engines[k]
        return float(e.scheduler.pending() + e.inflight())

    def hottest_engine(self) -> int:
        return max(range(len(self.engines)),
                   key=lambda k: (self.engine_load(k), -k))

    def coolest_engine(self) -> int:
        return min(range(len(self.engines)),
                   key=lambda k: (self.engine_load(k), k))

    # -- migration ----------------------------------------------------------
    def migrate(self, tenant: int, dst_engine: int,
                *, now: Optional[float] = None) -> Optional[MigrationRecord]:
        """Move a live tenant to ``dst_engine`` mid-run, conserving its
        ledger.

        Transfers the unserved queue, WFQ weight and token-bucket level to
        the destination immediately; folds the source's cumulative counters
        into the cluster ledger; leaves in-flight slots draining on the
        source (they finish and bill there). Delta-push history for the
        tenant is invalidated so the controller re-pushes fresh rates to
        every enforcement point next tick. Returns the ``MigrationRecord``
        (None if the tenant is already on ``dst_engine``).
        """
        if tenant not in self.placement:
            raise KeyError(f"tenant {tenant} is not placed on this cluster")
        if tenant in self.draining:
            raise RuntimeError(
                f"tenant {tenant} is still draining from a previous "
                f"migration; wait for it to finalize")
        src = self.placement[tenant]
        dst = int(dst_engine)
        if not 0 <= dst < len(self.engines):
            raise IndexError(f"engine {dst} not in cluster")
        if dst == src:
            return None
        src_eng, dst_eng = self.engines[src], self.engines[dst]
        # validate the destination BEFORE the destructive export: failing
        # after export_tenant would lose the unserved queue it returned
        if tenant in dst_eng.scheduler.queues:
            raise ValueError(
                f"tenant {tenant} is already active on engine {dst} "
                f"(out-of-band submission?); migration requires a "
                f"quiesced destination")
        total_before = self.tenant_served_tokens(tenant)
        inflight = src_eng.inflight(tenant)
        state = src_eng.scheduler.export_tenant(tenant, now)
        self._fold(tenant, state)
        dst_eng.scheduler.import_tenant(tenant, state, now)
        self.placement[tenant] = dst
        if self.controller is not None:
            self.controller.invalidate_tenant(tenant)
        rec = MigrationRecord(
            tenant=tenant, src=src, dst=dst, started_step=self.steps,
            queued_moved=len(state["queue"]), inflight_at_move=inflight,
            bucket_tokens_moved=(state["bucket"] or {}).get("tokens", 0.0))
        self.migrations_started += 1
        self.migration_log.append(rec)
        # the move itself bills nothing: the global ledger must not jump
        total_after = self.tenant_served_tokens(tenant)
        if total_after != total_before:
            raise AssertionError(
                f"migration changed tenant {tenant}'s served-token ledger: "
                f"{total_before} -> {total_after}")
        self.assert_ledger_conservation(tenant)
        if inflight:
            self.draining[tenant] = src
        else:
            self._finalize(rec)
        return rec

    def rebalance(self, *, tenant: Optional[int] = None,
                  now: Optional[float] = None) -> Optional[MigrationRecord]:
        """Operator one-shot: move a tenant off the hottest engine onto the
        coolest. Default victim is the hottest engine's most-backlogged
        tenant (by queue depth — under an adversarial trace, the hog).
        No-op (returns None) if the cluster is already balanced."""
        hot, cool = self.hottest_engine(), self.coolest_engine()
        if hot == cool:
            return None
        if tenant is None:
            on_hot = [t for t, k in self.placement.items()
                      if k == hot and t not in self.draining]
            if not on_hot:
                return None
            sched = self.engines[hot].scheduler
            tenant = max(on_hot, key=lambda t: (sched.pending(t), -t))
        return self.migrate(tenant, cool, now=now)

    def _fold(self, tenant: int, state: Dict) -> None:
        for f in _LEDGER_FIELDS:
            c = self._carried[f]
            c[tenant] = c.get(tenant, 0) + state.get(f, 0)

    def _finalize(self, rec: MigrationRecord) -> None:
        rec.finalized_step = self.steps
        self.migrations_completed += 1
        self.assert_ledger_conservation(rec.tenant)

    def _poll_drains(self) -> None:
        for tenant, src in list(self.draining.items()):
            src_eng = self.engines[src]
            if src_eng.inflight(tenant):
                continue
            # in-flight work finished on the source: fold its residual
            # billing (decode tokens accrued since the move) and finalize
            residual = src_eng.scheduler.export_tenant(tenant)
            if residual["queue"]:
                raise AssertionError(
                    f"tenant {tenant} grew a queue on drained source "
                    f"engine {src}: routing leaked past the placement map")
            self._fold(tenant, residual)
            del self.draining[tenant]
            rec = next(r for r in reversed(self.migration_log)
                       if r.tenant == tenant)
            self._finalize(rec)

    def _collect_completed(self) -> None:
        for k, e in enumerate(self.engines):
            if len(e.completed) > self._seen_completed[k]:
                self.completed.extend(e.completed[self._seen_completed[k]:])
                self._seen_completed[k] = len(e.completed)

    # -- cluster-global ledger ----------------------------------------------
    def merged_ledger(self, fld: str) -> Dict[int, float]:
        """Carried (migrated-away) history + live per-engine counters for
        one ledger field — the continuous cluster-global view."""
        if fld not in _LEDGER_FIELDS:
            raise KeyError(f"unknown ledger field {fld!r}")
        out = dict(self._carried[fld])
        for e in self.engines:
            for t, v in getattr(e.scheduler, fld).items():
                out[t] = out.get(t, 0) + v
        return out

    def tenant_served_tokens(self, tenant: int) -> float:
        """Tokens billed to a tenant cluster-wide, continuous across
        migrations (carried + live engine counters)."""
        return self._carried["served_tokens"].get(tenant, 0) + sum(
            e.scheduler.served_tokens.get(tenant, 0) for e in self.engines)

    def tenant_billed_ground_truth(self, tenant: int) -> int:
        """Request-level ground truth: prompt+generated tokens over the
        tenant's completed and in-flight requests. The billing scheme
        (admit bills prompt + first prefill token, each decode step bills
        the token it produced) makes this equal the ledger at all times."""
        self._collect_completed()
        total = sum(len(r.prompt) + len(r.generated)
                    for r in self.completed if r.tenant_id == tenant)
        for e in self.engines:
            for s in e.slots:
                if s.active and s.req.tenant_id == tenant:
                    total += len(s.req.prompt) + len(s.req.generated)
        return total

    def assert_ledger_conservation(self, tenant: int) -> None:
        """No lost tokens, no double-billing: the cluster ledger must equal
        the request-level ground truth exactly."""
        ledger = self.tenant_served_tokens(tenant)
        truth = self.tenant_billed_ground_truth(tenant)
        if int(round(ledger)) != truth:
            raise AssertionError(
                f"tenant {tenant} ledger broke conservation: ledger says "
                f"{ledger} tokens, requests account for {truth}")

    # -- reporting ----------------------------------------------------------
    def counters(self) -> Dict[str, float]:
        """Placement/migration counters (Prometheus naming), merged with
        the shared controller's."""
        out: Dict[str, float] = {
            "nk_cluster_engines": float(len(self.engines)),
            "nk_cluster_steps_total": float(self.steps),
            "nk_migrations_started_total": float(self.migrations_started),
            "nk_migrations_completed_total":
                float(self.migrations_completed),
            "nk_migrations_draining": float(len(self.draining)),
        }
        for t, k in sorted(self.placement.items()):
            out[f'nk_placement{{tenant="{t}"}}'] = float(k)
        for k, e in enumerate(self.engines):
            out[f'nk_engine_load{{engine="{k}"}}'] = self.engine_load(k)
            out[f'nk_engine_decode_steps_total{{engine="{k}"}}'] = \
                float(e.decode_steps)
        if self.controller is not None:
            out.update(self.controller.counters())
        return out

    def export_prometheus(self) -> str:
        return format_prometheus(self.counters())
