"""EngineCluster: one controller, N stack modules per plane, live migration.

The paper's operator owns the stack as *infrastructure*: many guests
multiplex onto shared stack modules, and the operator can rebalance that
mapping at will — including moving a tenant between modules without the
guest noticing. This module is that placement power, written against the
``StackModule`` protocol (repro.fabric) rather than any concrete engine:

  * N live ``ServeEngine``s (think: NSMs on different hosts) behind ONE
    shared ``RateController``. The controller's water-fill runs over the
    merged telemetry of every engine's scheduler — one tokens/s bottleneck
    spanning the cluster — and splits each tenant's global allocation
    across engines in proportion to where its traffic shows up.
  * a tenant -> engine ``placement`` map the operator controls. New
    tenants auto-place on the least-loaded engine; ``migrate`` moves a
    live tenant mid-replay.
  * optional extra planes: ``core_engines`` pairs each ServeEngine with a
    bytes-plane ``CoreEngine``; one migration then moves the tenant's
    serve *and* collective state through the same protocol calls.

Migration is drain-and-transfer, and conserves every plane's ledger:

  1. each plane's module exports the tenant (``StackModule.export_tenant``:
     unserved queue, WFQ weight, token-bucket *level* on the serve plane;
     bucket level + flattened counters on the bytes plane) and the
     destination module imports it (a move can never reopen a fresh burst);
  2. the source's cumulative counters fold into the plane's
     ``ConservationLedger`` carried view, so the global view never jumps
     (telemetry on the source sees a counter reset, not a negative rate);
  3. in-flight slots are NOT moved: they finish — and bill — where they
     were admitted; the tenant is ``draining`` until they run dry, then
     the residual billing folds and the migration finalizes.

Each plane's ``ConservationLedger`` pins carried + live counters against
the modules' summed billed ground truth — ONE assert implementation for
both planes, invoked on every move (no lost tokens or bytes, no
double-billing).

Two closed-loop extensions sit on top of the migration primitive:

  * **park/unpark lifecycle** — a quiesced engine can be parked: it stops
    stepping (the cluster "saves cores", the paper's multiplexing claim)
    AND its modules ``suspend()`` — the KV-cache, slot table and scratch
    are dropped, so parking saves *memory* too. ``unpark`` resumes the
    modules (cache re-init is lazy: it re-materializes on the first
    admission). ``parked_engine_steps`` and ``mem_saved_byte_steps``
    accumulate the savings; at least one engine always stays awake.
  * **autopilot** — an attached ``PlacementController``
    (repro.control.placement) is ticked every ``place_every`` steps,
    exactly how the shared RateController is ticked, and applies its
    plans through ``apply_plan`` -> ``migrate``: the placement loop runs
    closed, next to the rate loop.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro.control.telemetry import format_prometheus
from repro.fabric import StackPlane, TenantState
from repro.obs import tracing
from repro.obs.hist import TenantHistograms
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Request


@dataclass
class MigrationRecord:
    """One migrate() call, for the operator's audit log."""

    tenant: int
    src: int                      # engine index the tenant left
    dst: int                      # engine index it moved to
    started_step: int             # cluster step count at the move
    queued_moved: int             # unserved requests transferred
    inflight_at_move: int         # slots left draining on the source
    bucket_tokens_moved: float    # token-bucket level transferred (tokens)
    finalized_step: int = -1      # -1 while the source is still draining

    @property
    def finalized(self) -> bool:
        return self.finalized_step >= 0


@dataclass
class SwapRecord:
    """One swap_module() call — a live stack hot-swap — for the audit log.

    The paper's flagship move (kernel TCP -> mTCP under an unmodified
    guest): the module serving one engine slot is replaced in place,
    under traffic, with every tenant transferred across the boundary and
    the plane's conservation ledger unchanged.
    """

    engine: int                   # engine slot swapped in place
    plane: str                    # plane name ("serve", "bytes", ...)
    step: int                     # cluster step count at the swap
    tenants: Tuple[int, ...]      # tenants transferred across the boundary
    inflight_at_swap: int         # slots quiesced before the transfer
    quiesce_steps: int            # extra engine steps the quiesce ran
    old_stack: str                # descriptor of the retired module
    new_stack: str                # descriptor of the replacement


class ClusterLedger:
    """Duck-types the ``TenantScheduler`` ledger surface over a cluster.

    ``TraceReplayer`` (and anything else written against one scheduler's
    ledgers) reads per-tenant counters through this facade and sees the
    cluster-global view: carried (migrated-away) history plus the live
    counters of every engine, so a tenant's numbers are continuous across
    migrations.
    """

    def __init__(self, cluster: "EngineCluster"):
        self._cluster = cluster

    @property
    def queues(self) -> Dict[int, int]:
        """Known tenants (tenant -> engine index) — membership view."""
        return dict(self._cluster.placement)

    def add_tenant(self, tenant_id: int, weight: float = 1.0, **kw):
        self._cluster.add_tenant(tenant_id, weight=weight)

    def set_weight(self, tenant_id: int, weight: float):
        self._cluster.set_weight(tenant_id, weight)

    def pending(self, tenant_id: Optional[int] = None) -> int:
        return sum(e.scheduler.pending(tenant_id)
                   for e in self._cluster.engines)

    @property
    def served_tokens(self) -> Dict[int, int]:
        return self._cluster.merged_ledger("served_tokens")

    @property
    def admitted_requests(self) -> Dict[int, int]:
        return self._cluster.merged_ledger("admitted_requests")

    @property
    def deferred_polls(self) -> Dict[int, int]:
        return self._cluster.merged_ledger("deferred_polls")

    @property
    def admit_wait_sum(self) -> Dict[int, float]:
        return self._cluster.merged_ledger("admit_wait_sum")

    def ledger(self) -> Dict[int, Dict[str, float]]:
        """Cluster-global version of ``TenantScheduler.ledger``."""
        served = self.served_tokens
        admitted = self.admitted_requests
        deferred = self.deferred_polls
        waits = self.admit_wait_sum
        out: Dict[int, Dict[str, float]] = {}
        for t in set(served) | set(admitted) | set(deferred):
            adm = admitted.get(t, 0)
            out[t] = {
                "served_tokens": float(served.get(t, 0)),
                "admitted_requests": float(adm),
                "deferred_polls": float(deferred.get(t, 0)),
                "queued": float(self.pending(t)),
                "mean_admit_wait_s": (waits.get(t, 0.0) / adm
                                      if adm else 0.0),
            }
        return out


class EngineCluster:
    """N serve-plane StackModules + one shared RateController + placement.

    Exposes the same driving surface as a single ``ServeEngine`` (``B``,
    ``submit``, ``step``, ``completed``, ``decode_steps``, ``scheduler``,
    ``controller``) so ``TraceReplayer`` runs a cluster unchanged. All
    tenant movement, ledger folding, conservation checks and the park
    suspend/resume lifecycle go through the ``StackModule`` protocol —
    the cluster never names a concrete engine class.

    Args:
        engines: live serve-plane modules (``ServeEngine`` or any
            ``SchedulerServeModule``). Their own ``controller`` hooks must
            be unset — the cluster drives the shared controller itself
            (one tick for the whole cluster per control interval, not one
            per engine).
        controller: the shared ``RateController`` (capacity in tokens/s =
            the ONE bottleneck spanning all engines). Any engine scheduler
            not yet attached to it is attached here.
        control_every: controller tick period, in cluster steps.
        core_engines: optional bytes-plane ``CoreEngine`` per ServeEngine
            (same order/length): a migration then moves the tenant's
            collective-traffic state (bucket level + carried ledger) in
            the same plan, byte conservation asserted.
        place_every: autopilot tick period, in cluster steps (takes
            effect once ``attach_autopilot`` is called).
    """

    def __init__(self, engines: Sequence[ServeEngine], controller=None,
                 *, control_every: int = 4, core_engines=None,
                 place_every: int = 8):
        self.engines: List[ServeEngine] = list(engines)
        if not self.engines:
            raise ValueError("EngineCluster needs at least one engine")
        for k, e in enumerate(self.engines):
            # one trace track per engine: request lifecycle events from
            # the engine and its scheduler land on the same timeline
            e.trace_name = f"engine{k}"
            e.scheduler.trace_track = f"engine{k}"
        for e in self.engines:
            if e.controller is not None:
                raise ValueError(
                    "cluster engines must not own a controller; the "
                    "cluster ticks the shared one")
        self.controller = controller
        if controller is not None:
            attached = {id(s) for s, _ in controller._schedulers}
            for e in self.engines:
                if id(e.scheduler) not in attached:
                    controller.attach_scheduler(e.scheduler)
        self.control_every = max(int(control_every), 1)
        self.core_engines = list(core_engines) if core_engines else None
        if self.core_engines is not None and \
                len(self.core_engines) != len(self.engines):
            raise ValueError(
                f"core_engines must pair 1:1 with engines "
                f"({len(self.core_engines)} vs {len(self.engines)})")
        # every plane is modules + ONE shared ConservationLedger — the
        # serve plane always, the bytes plane when attached
        self.planes: List[StackPlane] = [
            StackPlane.build("serve", self.engines)]
        if self.core_engines is not None:
            self.planes.append(StackPlane.build("bytes", self.core_engines))
        self.autopilot = None
        self.place_every = max(int(place_every), 1)
        self.placement: Dict[int, int] = {}
        self.draining: Dict[int, int] = {}          # tenant -> src engine
        self.parked: Set[int] = set()               # engine indices asleep
        self.parked_engine_steps = 0                # the cores-saved ledger
        self.max_parked = 0                         # peak engines asleep
        # the memory-saved ledger: bytes currently freed per parked engine,
        # cumulative bytes ever freed, the per-step integral of freed
        # bytes, and the peak resident droppable-buffer footprint
        self._suspended_bytes: Dict[int, int] = {}
        self.bytes_freed_total = 0
        self.mem_saved_byte_steps = 0
        self.peak_resident_bytes = 0
        self.migration_log: List[MigrationRecord] = []
        self.migrations_started = 0
        self.migrations_completed = 0
        self.swap_log: List[SwapRecord] = []
        self.swaps_total: Dict[str, int] = {}   # plane name -> swaps done
        self.completed: List[Request] = []
        self._seen_completed = [len(e.completed) for e in self.engines]
        self.steps = 0
        self.scheduler = ClusterLedger(self)
        self._note_resident()

    @property
    def serve_plane(self) -> StackPlane:
        return self.planes[0]

    def attach_autopilot(self, autopilot,
                         place_every: Optional[int] = None):
        """Close the placement loop: tick ``autopilot`` (typically a
        ``repro.control.placement.PlacementController`` built over this
        cluster) every ``place_every`` cluster steps, next to the rate
        controller's own cadence. Returns the autopilot for chaining."""
        self.autopilot = autopilot
        if place_every is not None:
            self.place_every = max(int(place_every), 1)
        return autopilot

    # -- engine-like surface ------------------------------------------------
    @property
    def B(self) -> int:
        """Total decode slots across the cluster."""
        return sum(e.B for e in self.engines)

    @property
    def decode_steps(self) -> int:
        return sum(e.decode_steps for e in self.engines)

    def submit(self, req: Request) -> int:
        """Route one request to its tenant's placed engine (auto-placing
        an unknown tenant on the least-loaded one). Returns the engine
        index it landed on."""
        idx = self.placement.get(req.tenant_id)
        if idx is None:
            idx = self.add_tenant(req.tenant_id)
        self.engines[idx].submit(req)
        return idx

    def step(self, now: Optional[float] = None) -> int:
        """One cluster step: tick the shared controller (every
        ``control_every`` steps), step every awake engine once, collect
        completions, finalize any drained migrations, tick the autopilot
        (every ``place_every`` steps). Parked engines do not step — that
        skipped work *is* the cores-saved claim (``parked_engine_steps``)
        and their suspended buffers *are* the memory-saved claim
        (``mem_saved_byte_steps``). Returns the number of active slots
        cluster-wide."""
        self.steps += 1
        if self.controller is not None and \
                self.steps % self.control_every == 0:
            self.controller.tick(time.monotonic() if now is None else now)
        active = 0
        for k, e in enumerate(self.engines):
            if k in self.parked:
                continue
            active += e.step(now=now)
        # account the parked set that actually held during the engine loop
        # — an engine the autopilot parks below still ran this step and
        # must not be billed as a saved core until the next one
        self.parked_engine_steps += len(self.parked)
        self.mem_saved_byte_steps += sum(self._suspended_bytes.values())
        self.max_parked = max(self.max_parked, len(self.parked))
        self._note_resident()
        self._collect_completed()
        self._poll_drains(now)
        if self.autopilot is not None and \
                self.steps % self.place_every == 0:
            self.autopilot.tick(time.monotonic() if now is None else now)
        return active

    # -- placement ----------------------------------------------------------
    def add_tenant(self, tenant_id: int, weight: float = 1.0,
                   engine: Optional[int] = None) -> int:
        """Register (or re-weight) a tenant. ``engine`` pins the placement
        of a NEW tenant; None auto-places on the least-loaded engine.
        Returns the engine index the tenant lives on. Re-placing an
        existing tenant is ``migrate``'s job — passing a different
        ``engine`` for one raises instead of silently ignoring the pin."""
        if tenant_id in self.placement:
            idx = self.placement[tenant_id]
            if engine is not None and engine != idx:
                raise ValueError(
                    f"tenant {tenant_id} is already placed on engine "
                    f"{idx}; use migrate({tenant_id}, {engine}) to move "
                    f"a live tenant")
            self.engines[idx].scheduler.set_weight(tenant_id, weight)
            return idx
        idx = engine if engine is not None else self._auto_place()
        if not 0 <= idx < len(self.engines):
            raise IndexError(f"engine {idx} not in cluster")
        if idx in self.parked:
            raise ValueError(f"engine {idx} is parked; unpark it before "
                             f"placing tenant {tenant_id} there")
        self.placement[tenant_id] = idx
        self.engines[idx].scheduler.add_tenant(tenant_id, weight=weight)
        return idx

    def set_weight(self, tenant_id: int, weight: float) -> None:
        self.add_tenant(tenant_id, weight=weight)

    def active_engines(self) -> List[int]:
        """Engine indices currently awake (not parked)."""
        return [k for k in range(len(self.engines)) if k not in self.parked]

    def _auto_place(self) -> int:
        def load(k: int):
            placed = sum(1 for v in self.placement.values() if v == k)
            return (self.engine_load(k), placed, k)
        return min(self.active_engines(), key=load)

    def engine_load(self, k: int) -> float:
        """Demand pressure on engine ``k``: queued + in-flight requests
        (the serve module's ``StackModule.load``)."""
        return self.engines[k].load()

    def hottest_engine(self) -> int:
        return max(self.active_engines(),
                   key=lambda k: (self.engine_load(k), -k))

    def coolest_engine(self) -> int:
        return min(self.active_engines(),
                   key=lambda k: (self.engine_load(k), k))

    # -- park/unpark lifecycle (cores- AND memory-saved claims) -------------
    def parkable(self, k: int) -> bool:
        """True iff engine ``k`` could be parked right now: awake, fully
        quiesced (no placed tenants, no draining source, no queued or
        in-flight work) and not the last awake engine."""
        if not 0 <= k < len(self.engines) or k in self.parked:
            return False
        if len(self.active_engines()) <= 1:
            return False
        if any(v == k for v in self.placement.values()):
            return False
        if any(src == k for src in self.draining.values()):
            return False
        return self.engines[k].load() == 0

    def _trace_ts(self, now: Optional[float]) -> float:
        """Timestamp for a control-plane trace event: the caller's clock
        when given, else the step count (wall-clock callers that never
        pass ``now`` still get a monotonic timeline)."""
        return float(self.steps) if now is None else float(now)

    def park(self, k: int, *, now: Optional[float] = None) -> None:
        """Put a quiesced engine to sleep: it stops stepping (saved cores)
        AND every plane's module at ``k`` suspends — KV-cache, slot table
        and scratch are dropped (saved memory) — until ``unpark``. Raises
        if the engine still has any work: parking must never strand a
        tenant."""
        if not 0 <= k < len(self.engines):
            raise IndexError(f"engine {k} not in cluster")
        if k in self.parked:
            raise ValueError(f"engine {k} is already parked")
        if not self.parkable(k):
            raise ValueError(
                f"engine {k} is not quiesced (tenants placed, work "
                f"in-flight, a drain in progress, or it is the last "
                f"awake engine); refuse to park")
        self.parked.add(k)
        freed = sum(plane.modules[k].suspend() for plane in self.planes)
        self._suspended_bytes[k] = freed
        self.bytes_freed_total += freed
        if tracing.TRACER.enabled:
            tracing.TRACER.instant("cluster", "park", self._trace_ts(now),
                                   engine=k, freed_bytes=freed)

    def unpark(self, k: int, *, now: Optional[float] = None) -> None:
        """Wake a parked engine: every plane's module ``resume``s (the
        KV-cache re-materializes lazily on the first admission) and it
        can step and host tenants again immediately."""
        if not 0 <= k < len(self.engines):
            raise IndexError(f"engine {k} not in cluster")
        if k not in self.parked:
            raise ValueError(f"engine {k} is not parked")
        self.parked.discard(k)
        for plane in self.planes:
            plane.modules[k].resume()
        self._suspended_bytes.pop(k, None)
        if tracing.TRACER.enabled:
            tracing.TRACER.instant("cluster", "unpark", self._trace_ts(now),
                                   engine=k)

    def cores_saved(self) -> float:
        """Average engines parked per cluster step so far — the closed-loop
        analog of the paper's Table-2 core savings (engine units; 1.0 =
        one whole engine slept through the run)."""
        return self.parked_engine_steps / max(self.steps, 1)

    def parked_bytes(self) -> int:
        """Bytes currently freed by suspended (parked) engines."""
        return sum(self._suspended_bytes.values())

    def mem_saved(self) -> float:
        """Average bytes freed per cluster step so far — the memory analog
        of ``cores_saved`` (bytes; the integral of parked buffer bytes
        over steps, normalized)."""
        return self.mem_saved_byte_steps / max(self.steps, 1)

    def resident_bytes(self) -> int:
        """Droppable buffer bytes currently resident across every plane's
        modules (suspended modules report 0)."""
        return sum(m.resident_bytes()
                   for plane in self.planes for m in plane.modules)

    def _note_resident(self) -> None:
        self.peak_resident_bytes = max(self.peak_resident_bytes,
                                       self.resident_bytes())

    # -- migration ----------------------------------------------------------
    def migrate(self, tenant: int, dst_engine: int,
                *, now: Optional[float] = None) -> Optional[MigrationRecord]:
        """Move a live tenant to ``dst_engine`` mid-run, conserving its
        ledger on every plane.

        For each plane: the source module exports the tenant (queue, WFQ
        weight, token-bucket level), the carried counters fold into the
        plane's ``ConservationLedger``, and the destination imports —
        identical protocol calls whether the plane is serve or bytes.
        In-flight slots stay draining on the source (they finish and bill
        there). Delta-push history for the tenant is invalidated so the
        controller re-pushes fresh rates to every enforcement point next
        tick. Returns the ``MigrationRecord`` (None if the tenant is
        already on ``dst_engine``).
        """
        if tenant not in self.placement:
            raise KeyError(f"tenant {tenant} is not placed on this cluster")
        if tenant in self.draining:
            raise RuntimeError(
                f"tenant {tenant} is still draining from a previous "
                f"migration; wait for it to finalize")
        src = self.placement[tenant]
        dst = int(dst_engine)
        if not 0 <= dst < len(self.engines):
            raise IndexError(f"engine {dst} not in cluster")
        if dst == src:
            return None
        if dst in self.parked:
            raise ValueError(f"engine {dst} is parked; unpark it before "
                             f"migrating tenant {tenant} onto it")
        # validate EVERY plane's destination BEFORE the first destructive
        # export: failing after an export would lose the unserved queue
        # (or strand carried counters half-folded)
        for plane in self.planes:
            if plane.modules[dst].has_tenant(tenant):
                raise ValueError(
                    f"tenant {tenant} has live {plane.name}-plane state "
                    f"on engine {dst} (out-of-band submission or rate "
                    f"push?); migration requires a quiesced destination "
                    f"on every plane")
        totals_before = {p.name: p.ledger.total(tenant) for p in self.planes}
        inflight = self.engines[src].tenant_load(tenant).inflight
        ts = self._trace_ts(now)
        serve_state: Optional[TenantState] = None
        for plane in self.planes:
            state = plane.modules[src].export_tenant(tenant, now)
            plane.ledger.fold(tenant, plane.modules[src], state)
            plane.modules[dst].import_tenant(tenant, state, now)
            if plane is self.serve_plane:
                serve_state = state
        if tracing.TRACER.enabled:
            tracing.TRACER.span(
                "cluster", "migrate.transfer", ts, ts, tenant=tenant,
                src=src, dst=dst, queued=len(serve_state.queue),
                inflight=inflight)
            # the drain window [move, finalize] as an async pair keyed by
            # tenant — drains of different tenants overlap on this track
            tracing.TRACER.async_begin("cluster", "migrate.drain",
                                       tenant, ts, tenant=tenant, src=src,
                                       inflight=inflight)
        self.placement[tenant] = dst
        if self.controller is not None:
            self.controller.invalidate_tenant(tenant)
        rec = MigrationRecord(
            tenant=tenant, src=src, dst=dst, started_step=self.steps,
            queued_moved=len(serve_state.queue), inflight_at_move=inflight,
            bucket_tokens_moved=serve_state.bucket_tokens)
        self.migrations_started += 1
        self.migration_log.append(rec)
        # the move itself bills nothing: no plane's global ledger may jump
        for plane in self.planes:
            after = plane.ledger.total(tenant)
            if int(round(after)) != int(round(totals_before[plane.name])):
                raise AssertionError(
                    f"{plane.name}-plane migration broke tenant {tenant}'s "
                    f"ledger continuity: {totals_before[plane.name]} -> "
                    f"{after} {plane.ledger.conserved}")
        self.assert_ledger_conservation(tenant)
        if inflight:
            self.draining[tenant] = src
        else:
            self._finalize(rec, now)
        return rec

    # -- live stack hot-swap (the paper's kernel-TCP -> mTCP move) ----------
    # quiesce safety valve: a slot that never drains (a stuck decode loop)
    # must fail loudly instead of spinning the swap forever
    QUIESCE_STEP_CAP = 10_000

    @staticmethod
    def _stack_desc(module) -> str:
        """Audit-log descriptor for one stack module: the class name plus
        the knob a swap actually flips (the bytes plane swaps CoreEngine
        for CoreEngine — only ``default_nsm`` tells them apart; serve
        variants differ by scheduler policy)."""
        name = type(module).__name__
        nsm = getattr(module, "default_nsm", None)
        if nsm is not None:
            return f"{name}[{nsm}]"
        policy = getattr(getattr(module, "scheduler", None), "policy", None)
        return f"{name}[{policy}]" if policy else name

    def swap_module(self, engine_id: int, plane: str,
                    new_module_factory: Callable[[], object],
                    *, now: Optional[float] = None) -> SwapRecord:
        """Hot-swap the ``StackModule`` serving one engine slot, live.

        The NetKernel headline demo as a cluster primitive: the operator
        replaces the stack beneath unmodified tenants (native <->
        ``CompressedNsm`` on the bytes plane; an alternate scheduler
        variant on the serve plane) while traffic is running, with zero
        dropped or double-billed tokens. Three phases, one trace span
        each:

          1. **quiesce** (``swap.quiesce`` async pair): admission pauses
             (``scheduler.paused`` — queued work stays put, no
             deferred-poll noise) and the old module steps until its
             in-flight slots run dry — they finish *and bill* on the
             stack that admitted them, exactly like a migration drain.
          2. **transfer** (``swap.transfer`` span): every placed tenant
             exports via ``TenantState``, its counters fold into the
             plane's ``ConservationLedger``, the replacement is built and
             adopts the retired module's billed ground truth
             (``inherit_ground_truth`` — completed records / billed
             bytes stay attributed to this engine slot), the module list
             entry is replaced IN PLACE (the plane, the cluster and the
             ledger share the list by reference), the controller's
             enforcement point is re-wired, and every tenant re-imports.
          3. **resume** (``swap.resume`` instant): admission reopens on
             the new module; ``invalidate_tenant`` forces the delta-push
             controller to re-push fresh rates to every enforcement
             point next tick, so no stale rate survives the swap.

        Ledger continuity AND ground-truth continuity are asserted per
        tenant across the boundary, then the full conservation invariant.
        Refused while the engine is parked or is the draining source of a
        live migration (the residual billing would be stranded on the
        retired module — same contract as mid-drain re-migration).
        Returns the ``SwapRecord``.
        """
        k = int(engine_id)
        if not 0 <= k < len(self.engines):
            raise IndexError(f"engine {k} not in cluster")
        pl = next((p for p in self.planes if p.name == plane), None)
        if pl is None:
            raise KeyError(
                f"plane {plane!r} is not attached to this cluster "
                f"(have: {[p.name for p in self.planes]})")
        if k in self.parked:
            raise ValueError(
                f"engine {k} is parked; unpark it before swapping its "
                f"{plane} module")
        if any(src == k for src in self.draining.values()):
            raise RuntimeError(
                f"engine {k} is the draining source of a live migration; "
                f"a swap would strand the residual billing on the retired "
                f"module — wait for the drain to finalize")
        old = pl.modules[k]
        tenants = tuple(sorted(
            t for t, e in self.placement.items()
            if e == k and old.has_tenant(t)))
        ts0 = self._trace_ts(now)
        quiesce_id = f"{pl.name}:{k}:{self.steps}"
        if tracing.TRACER.enabled:
            tracing.TRACER.async_begin("cluster", "swap.quiesce",
                                       quiesce_id, ts0, engine=k,
                                       plane=pl.name)
        # 1. quiesce: pause admission, drain in-flight slots on the old
        # module (planes without slot machinery skip straight through)
        sched = getattr(old, "scheduler", None)
        inflight_fn = getattr(old, "inflight", None)
        inflight0 = int(inflight_fn()) if callable(inflight_fn) else 0
        quiesce_steps = 0
        if sched is not None:
            sched.paused = True
        try:
            while callable(inflight_fn) and inflight_fn():
                if quiesce_steps >= self.QUIESCE_STEP_CAP:
                    raise RuntimeError(
                        f"engine {k} failed to quiesce within "
                        f"{self.QUIESCE_STEP_CAP} steps "
                        f"({inflight_fn()} slot(s) still in flight)")
                old.step(now=now)
                quiesce_steps += 1
        finally:
            if sched is not None:
                sched.paused = False
        ts1 = self._trace_ts(now)
        if tracing.TRACER.enabled:
            tracing.TRACER.async_end("cluster", "swap.quiesce",
                                     quiesce_id, ts1, engine=k,
                                     plane=pl.name)
        # 2. transfer: totals are taken AFTER the quiesce (drain billing
        # moved them) and must be unchanged by everything below
        totals_before = {t: pl.ledger.total(t) for t in tenants}
        truth_before = {t: pl.ledger.ground_truth(t) for t in tenants}
        states: Dict[int, TenantState] = {}
        for t in tenants:
            state = old.export_tenant(t, now)
            pl.ledger.fold(t, old, state)
            states[t] = state
        new = new_module_factory()
        if getattr(new, "plane", pl.name) != pl.name:
            raise ValueError(
                f"replacement module is {getattr(new, 'plane')!r}-plane; "
                f"cannot swap it into the {pl.name} plane")
        if getattr(new, "controller", None) is not None:
            raise ValueError(
                "replacement module must not own a controller; the "
                "cluster ticks the shared one")
        # the replacement takes over the slot's identity: trace track and
        # the retired module's never-migrates ground truth
        if hasattr(new, "trace_name"):
            new.trace_name = f"engine{k}"
        new_sched = getattr(new, "scheduler", None)
        if new_sched is not None:
            new_sched.trace_track = f"engine{k}"
        new.inherit_ground_truth(old)
        pl.modules[k] = new    # in place: engines/planes/ledger all see it
        if pl is self.serve_plane and self.controller is not None:
            if sched is not None:
                self.controller.detach_scheduler(sched)
            if new_sched is not None:
                self.controller.attach_scheduler(new_sched)
        for t in tenants:
            new.import_tenant(t, states[t], now)
        # 3. resume: fresh rates to every enforcement point next tick
        if self.controller is not None:
            for t in tenants:
                self.controller.invalidate_tenant(t)
        for t in tenants:
            after = pl.ledger.total(t)
            if int(round(after)) != int(round(totals_before[t])):
                raise AssertionError(
                    f"{pl.name}-plane swap broke tenant {t}'s ledger "
                    f"continuity: {totals_before[t]} -> {after} "
                    f"{pl.ledger.conserved}")
            truth_after = pl.ledger.ground_truth(t)
            if int(round(truth_after)) != int(round(truth_before[t])):
                raise AssertionError(
                    f"{pl.name}-plane swap lost tenant {t}'s billed "
                    f"ground truth across the boundary: "
                    f"{truth_before[t]} -> {truth_after}")
            self.assert_ledger_conservation(t)
        ts2 = self._trace_ts(now)
        rec = SwapRecord(
            engine=k, plane=pl.name, step=self.steps, tenants=tenants,
            inflight_at_swap=inflight0, quiesce_steps=quiesce_steps,
            old_stack=self._stack_desc(old),
            new_stack=self._stack_desc(new))
        self.swap_log.append(rec)
        self.swaps_total[pl.name] = self.swaps_total.get(pl.name, 0) + 1
        if tracing.TRACER.enabled:
            tracing.TRACER.span(
                "cluster", "swap.transfer", ts1, ts2, engine=k,
                plane=pl.name, tenants=len(tenants),
                old=rec.old_stack, new=rec.new_stack)
            tracing.TRACER.instant("cluster", "swap.resume", ts2,
                                   engine=k, plane=pl.name)
        return rec

    def rebalance(self, *, tenant: Optional[int] = None,
                  now: Optional[float] = None) -> Optional[MigrationRecord]:
        """Operator one-shot: move a tenant off the hottest engine onto the
        coolest. Default victim is the hottest engine's most-backlogged
        tenant (by queue depth — under an adversarial trace, the hog).
        No-op (returns None) if the cluster is already balanced.

        .. deprecated:: since the placement autopilot landed this is a
           thin wrapper over ``PlacementController.plan_once`` (the
           ``spread_hot`` policy, forced: no bands, no cooldown, no drain
           gate — the legacy semantics). Calling it emits a
           ``DeprecationWarning``; prefer attaching a
           ``PlacementController`` via ``attach_autopilot`` (closed loop)
           or calling ``PlacementController.plan_once(force=True)``
           directly (one-shot).
        """
        from repro.serve.replay import operator_rebalance
        warnings.warn(
            "EngineCluster.rebalance() is deprecated; use "
            "operator_rebalance / PlacementController.plan_once("
            "force=True) for the one-shot or attach_autopilot() for the "
            "closed loop", DeprecationWarning, stacklevel=2)
        if tenant is not None:
            # keep the legacy error contract migrate() provided
            if tenant not in self.placement:
                raise KeyError(
                    f"tenant {tenant} is not placed on this cluster")
            if tenant in self.draining:
                raise RuntimeError(
                    f"tenant {tenant} is still draining from a previous "
                    f"migration; wait for it to finalize")
        return operator_rebalance(self, now=now, pin_tenant=tenant)

    def apply_plan(self, plan, *,
                   now: Optional[float] = None) -> List[MigrationRecord]:
        """Apply a ``PlacementPlan``: unpark first (a move may target a
        waking engine), then every move through ``migrate``'s
        ledger-conserving drain-and-transfer, then park engines the plan
        emptied. Stale entries — a tenant that already moved or is
        mid-drain, a park target that turns out non-quiesced — are skipped
        rather than raised: plans are computed from a snapshot and the
        cluster may have moved on. Returns the records of the migrations
        that actually happened (conservation was asserted on each)."""
        records: List[MigrationRecord] = []
        for k in plan.unpark:
            if k in self.parked:
                self.unpark(k, now=now)
        for mv in plan.moves:
            if mv.tenant not in self.placement or \
                    mv.tenant in self.draining:
                continue
            if self.placement[mv.tenant] != mv.src:
                continue                           # stale: already moved
            if mv.dst in self.parked:
                continue                           # unpark was skipped
            rec = self.migrate(mv.tenant, mv.dst, now=now)
            if rec is not None:
                records.append(rec)
        for k in plan.park:
            if k not in self.parked and self.parkable(k):
                self.park(k, now=now)
        return records

    def _finalize(self, rec: MigrationRecord,
                  now: Optional[float] = None) -> None:
        rec.finalized_step = self.steps
        self.migrations_completed += 1
        self.assert_ledger_conservation(rec.tenant)
        if tracing.TRACER.enabled:
            ts = self._trace_ts(now)
            tracing.TRACER.async_end("cluster", "migrate.drain",
                                     rec.tenant, ts)
            tracing.TRACER.span(
                "cluster", "migrate.finalize", ts, ts, tenant=rec.tenant,
                src=rec.src, dst=rec.dst,
                drained_steps=rec.finalized_step - rec.started_step)

    def _poll_drains(self, now: Optional[float] = None) -> None:
        serve = self.serve_plane
        for tenant, src in list(self.draining.items()):
            if serve.modules[src].tenant_load(tenant).inflight:
                continue
            # in-flight work finished on the source: fold its residual
            # billing (decode tokens accrued since the move) and finalize
            residual = serve.modules[src].export_tenant(tenant)
            if residual.queue:
                raise AssertionError(
                    f"tenant {tenant} grew a queue on drained source "
                    f"engine {src}: routing leaked past the placement map")
            serve.ledger.fold(tenant, serve.modules[src], residual)
            del self.draining[tenant]
            rec = next(r for r in reversed(self.migration_log)
                       if r.tenant == tenant)
            self._finalize(rec, now)

    def _collect_completed(self) -> None:
        for k, e in enumerate(self.engines):
            if len(e.completed) > self._seen_completed[k]:
                self.completed.extend(e.completed[self._seen_completed[k]:])
                self._seen_completed[k] = len(e.completed)

    # -- cluster-global ledger ----------------------------------------------
    def merged_ledger(self, fld: str) -> Dict[int, float]:
        """Carried (migrated-away) history + live per-engine counters for
        one serve-plane ledger field — the continuous cluster-global
        view."""
        return self.serve_plane.ledger.merged(fld)

    def tenant_served_tokens(self, tenant: int) -> float:
        """Tokens billed to a tenant cluster-wide, continuous across
        migrations (carried + live engine counters)."""
        return self.serve_plane.ledger.total(tenant, "served_tokens")

    def tenant_core_bytes(self, tenant: int) -> float:
        """Collective bytes routed for a tenant cluster-wide, continuous
        across migrations (bytes-plane carried + live CoreEngine ledgers).
        0.0 when the cluster has no bytes plane attached."""
        for plane in self.planes:
            if plane.name == "bytes":
                return plane.ledger.total(tenant, "bytes")
        return 0.0

    def tenant_billed_ground_truth(self, tenant: int) -> int:
        """Request-level ground truth: prompt+generated tokens over the
        tenant's completed and in-flight requests, summed over every
        serve module (completed records never migrate). The billing
        scheme (admit bills prompt + first prefill token, each decode
        step bills the token it produced) makes this equal the ledger at
        all times."""
        return int(round(self.serve_plane.ledger.ground_truth(tenant)))

    def assert_ledger_conservation(self, tenant: int) -> None:
        """No lost units, no double-billing, on ANY plane: each plane's
        carried+live ledger must equal its modules' summed billed ground
        truth exactly — one shared assert implementation
        (``ConservationLedger.assert_conservation``)."""
        for plane in self.planes:
            plane.ledger.assert_conservation(tenant, plane=plane.name)

    # -- reporting ----------------------------------------------------------
    def latency(self) -> Dict[str, TenantHistograms]:
        """Cluster-global per-tenant latency families (admit wait, TTFT,
        e2e): every serve module's histograms merged. Continuous across
        migrations — the admit-wait counts travel with the tenant, the
        engine-side TTFT/e2e counts stay where they were served."""
        out: Dict[str, TenantHistograms] = {}
        for m in self.serve_plane.modules:
            for name, th in m.latency().items():
                out[name] = out[name].merged(th) if name in out \
                    else th.merged(TenantHistograms(name, th.edges))
        return out

    def counters(self) -> Dict[str, float]:
        """Placement/migration counters (Prometheus naming), merged with
        the shared controller's."""
        out: Dict[str, float] = {
            "nk_cluster_engines": float(len(self.engines)),
            "nk_cluster_steps_total": float(self.steps),
            "nk_migrations_started_total": float(self.migrations_started),
            "nk_migrations_completed_total":
                float(self.migrations_completed),
            "nk_migrations_draining": float(len(self.draining)),
            "nk_cluster_parked": float(len(self.parked)),
            "nk_parked_engine_steps_total":
                float(self.parked_engine_steps),
            "nk_cores_saved": self.cores_saved(),
            "nk_parked_bytes": float(self.parked_bytes()),
            "nk_bytes_freed_total": float(self.bytes_freed_total),
            "nk_mem_saved_bytes": self.mem_saved(),
            "nk_resident_cache_bytes": float(self.resident_bytes()),
            "nk_peak_resident_cache_bytes":
                float(self.peak_resident_bytes),
        }
        for t, k in sorted(self.placement.items()):
            out[f'nk_placement{{tenant="{t}"}}'] = float(k)
        for k, e in enumerate(self.engines):
            out[f'nk_engine_load{{engine="{k}"}}'] = self.engine_load(k)
            out[f'nk_engine_parked{{engine="{k}"}}'] = \
                float(k in self.parked)
            out[f'nk_engine_decode_steps_total{{engine="{k}"}}'] = \
                float(e.decode_steps)
        # recent moves as info series (value = cluster step the move
        # started at) — what nk_top's "recent autopilot moves" pane reads
        for rec in self.migration_log[-5:]:
            out[f'nk_migration_info{{seq="{rec.started_step}",'
                f'tenant="{rec.tenant}",src="{rec.src}",'
                f'dst="{rec.dst}"}}'] = float(rec.started_step)
        for plane_name, n in sorted(self.swaps_total.items()):
            out[f'nk_swaps_total{{plane="{plane_name}"}}'] = float(n)
        # recent hot-swaps as info series (value = cluster step), like
        # nk_migration_info above
        for srec in self.swap_log[-5:]:
            out[f'nk_swap_info{{seq="{srec.step}",'
                f'engine="{srec.engine}",plane="{srec.plane}",'
                f'old="{srec.old_stack}",new="{srec.new_stack}"}}'] = \
                float(srec.step)
        for th in self.latency().values():
            out.update(th.counters())
        if self.autopilot is not None and \
                hasattr(self.autopilot, "counters"):
            out.update(self.autopilot.counters())
        if self.controller is not None:
            out.update(self.controller.counters())
        return out

    def export_prometheus(self) -> str:
        return format_prometheus(self.counters())
