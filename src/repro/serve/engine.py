"""Multi-tenant serving engine: continuous batching over shared decode steps.

The NetKernel multiplexing story (use case 1) in serving terms: one engine
("NSM") serves requests from many tenants ("VMs"). Decode slots are the
shared resource; the TenantScheduler (CoreEngine control plane) decides
admission with fairness/rate policies; weights are shared by all tenants of
the same model (the shared-memory use case — tenants never hold their own
copy). Model code is untouched: prefill/decode are the same pure functions
the dry-run lowers for 256-chip meshes.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.distribution.sharding import ShardingCtx, init_params
from repro.fabric import SchedulerServeModule
from repro.models.model import (
    cache_schema, forward_decode, forward_prefill, model_schema,
)
from repro.serve.scheduler import Request, TenantScheduler


@dataclass
class Slot:
    active: bool = False
    req: Optional[Request] = None
    pos: int = 0           # next write position (== tokens so far - 1)
    remaining: int = 0


class ServeEngine(SchedulerServeModule):
    """Slot-based continuous batching engine (greedy decoding).

    Implements the serve-plane ``StackModule`` protocol (repro.fabric)
    via ``SchedulerServeModule``: tenant export/import delegate to the
    scheduler, ``billed_ground_truth`` reads completed requests + live
    slots, and ``suspend``/``resume`` make parking a real memory saving —
    suspend drops the KV-cache, slot table and step scratch; resume
    re-materializes the cache lazily from the shared ``cache_schema`` on
    the first admission after unpark.
    """

    def __init__(self, cfg: ModelConfig, rcfg: RunConfig, mesh, params=None,
                 *, batch_slots: int = 8, max_seq: int = 256,
                 scheduler: Optional[TenantScheduler] = None, key=None,
                 controller=None, control_every: int = 4):
        """``batch_slots``: concurrent decode slots (the shared resource);
        ``max_seq``: KV-cache length in tokens; ``params``: share another
        engine's weights (the shared-memory story — cluster replicas pass
        the first engine's) or None to init fresh; ``controller``:
        optional management-plane hook ticked every ``control_every``
        steps (must be None when the engine joins an EngineCluster, which
        ticks the shared controller itself)."""
        self.cfg, self.rcfg, self.mesh = cfg, rcfg, mesh
        self.B, self.max_seq = batch_slots, max_seq
        self.shd = ShardingCtx(mesh)
        self.scheduler = scheduler or TenantScheduler()
        # management plane: anything with tick(now) — typically a
        # repro.control.RateController attached to self.scheduler. Rates it
        # pushes take effect on the very next admission decision.
        self.controller = controller
        self.control_every = max(int(control_every), 1)
        self.params = params if params is not None else init_params(
            model_schema(cfg, mesh), key or jax.random.PRNGKey(0))
        self.slots = self._make_slots()
        self.caches = None
        self._cache_nbytes = 0
        self._init_caches()
        self.steps = 0
        self.decode_steps = 0
        self.completed: List[Request] = []
        self.step_times: List[float] = []

        cfg_, rcfg_, shd_ = cfg, rcfg, self.shd

        def _prefill(params, tokens):
            return forward_prefill(params, tokens, cfg_, shd_, rcfg_,
                                   max_seq=max_seq)

        def _decode(params, caches, tokens, pos):
            logits, caches = forward_decode(params, caches, tokens, pos,
                                            cfg_, shd_, rcfg_)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt, caches

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode, donate_argnums=(1,))

    # -- StackModule buffer hooks (the suspend/resume memory story) --------
    def _make_slots(self):
        return [Slot() for _ in range(self.B)]

    def _init_caches(self) -> None:
        """(Re-)materialize the KV-cache from the shared ``cache_schema``
        — at construction, and lazily on the first admission after a
        ``resume`` (an unparked engine with no traffic stays cache-free).
        Slot caches are fully overwritten by prefill on admission, so a
        re-init is bit-identical to never having suspended."""
        self.caches = init_params(
            cache_schema(self.cfg, self.B, self.max_seq),
            jax.random.PRNGKey(1))
        self._cache_nbytes = sum(
            int(x.size) * x.dtype.itemsize
            for x in jax.tree.leaves(self.caches))

    def _cache_bytes(self) -> int:
        return 0 if self.caches is None else self._cache_nbytes

    def _release_buffers(self) -> None:
        self.caches = None
        self.step_times = []

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        """Queue one request for admission (delegates to the scheduler)."""
        self.scheduler.submit(req)

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if not s.active:
                return i
        return None

    def _admit(self, now=None):
        while True:
            i = self._free_slot()
            if i is None:
                return
            req = self.scheduler.next_request(now)
            if req is None:
                return
            if self.caches is None:
                # lazy resume: the KV-cache dropped at park re-materializes
                # only when a request actually lands here
                self._init_caches()
            prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
            last_logits, caches1 = self._prefill(self.params, prompt)
            # install the single-sequence cache into slot i
            self.caches = jax.tree.map(
                lambda big, one: big.at[:, i].set(one[:, 0].astype(big.dtype)),
                self.caches, caches1)
            first = int(jnp.argmax(last_logits[0]))
            req.generated.append(first)
            req.admit_time = time.monotonic() if now is None else now
            self.observe_admitted(req)
            # prompt tokens + the first generated token: prefill produced
            # both, so the ledger must bill them here — decode steps only
            # account the tokens they themselves produce (leaving the
            # prefill token out undercounts every request by one and caps
            # measured throughput below the enforced allocation)
            self.scheduler.account(req.tenant_id, len(req.prompt) + 1)
            if req.max_new_tokens <= 1:
                # prefill already produced the only requested token; a slot
                # would run one decode step anyway and over-generate (and
                # over-bill) past the bucket's prompt+max_new price
                req.finish_time = req.admit_time
                self.completed.append(req)
                self.observe_finished(req)
                continue
            self.slots[i] = Slot(active=True, req=req,
                                 pos=len(req.prompt),
                                 remaining=req.max_new_tokens - 1)

    def step(self, now=None) -> int:
        """Admit + one decode step for all active slots. Returns #active."""
        if self.suspended:
            raise RuntimeError(
                "engine is suspended (parked); resume() before stepping")
        t0 = time.monotonic()
        self.steps += 1
        # tick before admission (and before the no-work early return): a
        # fully-throttled engine must still get rate updates or it livelocks
        if self.controller is not None and self.steps % self.control_every == 0:
            self.controller.tick(time.monotonic() if now is None else now)
        self._admit(now)
        active = [i for i, s in enumerate(self.slots) if s.active]
        if not active:
            return 0
        tokens = np.zeros((self.B, 1), np.int32)
        pos = np.zeros((self.B,), np.int32)
        for i, s in enumerate(self.slots):
            if s.active:
                tokens[i, 0] = s.req.generated[-1]
                pos[i] = s.pos
        nxt, self.caches = self._decode(self.params, self.caches,
                                        jnp.asarray(tokens), jnp.asarray(pos))
        nxt = np.asarray(nxt)
        for i in active:
            s = self.slots[i]
            s.req.generated.append(int(nxt[i]))
            s.pos += 1
            s.remaining -= 1
            self.scheduler.account(s.req.tenant_id, 1)
            if s.remaining <= 0 or s.pos >= self.max_seq - 1:
                s.req.finish_time = time.monotonic() if now is None else now
                self.completed.append(s.req)
                self.observe_finished(s.req)
                self.slots[i] = Slot()
        self.decode_steps += 1
        self.step_times.append(time.monotonic() - t0)
        return len(active)

    def run_until_drained(self, max_steps: int = 10000) -> Dict:
        n = 0
        while (self.scheduler.pending() or
               any(s.active for s in self.slots)) and n < max_steps:
            self.step()
            n += 1
        return {"decode_steps": self.decode_steps,
                "completed": len(self.completed),
                "shares": self.scheduler.shares()}

    # -- utilization metrics ------------------------------------------------
    def slot_utilization(self) -> float:
        """Fraction of slot-steps that produced a token (1.0 = no idle
        slots across the run)."""
        if not self.decode_steps:
            return 0.0
        served = sum(len(r.generated) for r in self.completed)
        return served / max(self.decode_steps * self.B, 1)
