from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import adamw_update, cosine_schedule, init_opt_state
from repro.train.runner import FailurePlan, Runner, StragglerWatchdog
from repro.train.train_loop import (
    batch_shardings, loss_fn, make_train_state, make_train_step,
    state_shardings,
)

__all__ = [
    "CheckpointManager", "adamw_update", "cosine_schedule", "init_opt_state",
    "FailurePlan", "Runner", "StragglerWatchdog", "batch_shardings",
    "loss_fn", "make_train_state", "make_train_step", "state_shardings",
]
