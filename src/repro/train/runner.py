"""Fault-tolerant training runner: restart-on-failure, stragglers, elastic.

The runner owns the step loop the way NetKernel's operator owns the stack:
the model/application never sees failures, checkpoints or topology changes.

 * **checkpoint/restart**: periodic (async) checkpoints; on any step failure
   the runner restores the last checkpoint and replays. The data pipeline is
   a pure function of (seed, step), so recovery is bit-exact (tested).
 * **failure injection**: ``FailurePlan`` raises at chosen steps to exercise
   the recovery path deterministically.
 * **straggler watchdog**: per-step wall times vs a rolling median; steps
   slower than ``straggler_factor``x are logged and counted (the per-host
   heartbeat analog for a 1000-node deployment).
 * **elastic re-mesh**: ``Runner.remesh(new_mesh)`` re-lowers the step and
   reshards the restored state onto the new topology mid-run (tested 4->8
   devices).
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax

from repro.configs.base import ModelConfig, RunConfig
from repro.train import checkpoint as ckpt_mod
from repro.train.train_loop import (
    batch_shardings, make_train_state, make_train_step, state_shardings,
)


@dataclass
class FailurePlan:
    """Deterministic fault injection: raise at given global steps (once)."""

    fail_at: List[int] = field(default_factory=list)
    exception: type = RuntimeError
    _fired: set = field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise self.exception(f"injected node failure at step {step}")


@dataclass
class StragglerWatchdog:
    factor: float = 3.0
    window: int = 20
    times: List[float] = field(default_factory=list)
    straggler_steps: List[int] = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        hist = self.times[-self.window:]
        if len(hist) >= 5:
            med = statistics.median(hist)
            if dt > self.factor * med:
                self.straggler_steps.append(step)
                return True
        return False


class Runner:
    def __init__(self, cfg: ModelConfig, rcfg: RunConfig, mesh, pipeline,
                 ckpt_dir: str, engine=None,
                 failure_plan: Optional[FailurePlan] = None,
                 delay_injector: Optional[Callable[[int], float]] = None):
        self.cfg, self.rcfg, self.mesh = cfg, rcfg, mesh
        self.pipeline = pipeline
        self.engine = engine
        self.ckpt = ckpt_mod.CheckpointManager(ckpt_dir, keep=rcfg.keep_checkpoints)
        self.failure_plan = failure_plan or FailurePlan()
        self.watchdog = StragglerWatchdog(factor=rcfg.straggler_factor)
        self.delay_injector = delay_injector
        self.recoveries = 0
        self.metrics_log: List[Dict] = []
        self._build()

    # ------------------------------------------------------------------
    def _build(self):
        self.step_fn = jax.jit(
            make_train_step(self.cfg, self.rcfg, self.mesh, self.engine),
            donate_argnums=(0,))
        self.state_sh = state_shardings(self.cfg, self.rcfg, self.mesh)
        self.batch_sh = batch_shardings(
            self.cfg, self.mesh, rcfg=self.rcfg,
            global_batch=self.pipeline.dcfg.global_batch)
        self.pipeline.shardings = self.batch_sh
        self.pipeline.mesh = self.mesh

    def init_state(self, key=None):
        self.state = make_train_state(self.cfg, self.rcfg, self.mesh, key)
        self.state = jax.device_put(self.state, self.state_sh)
        self.step = 0

    # ------------------------------------------------------------------
    def restore_latest(self) -> bool:
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        template = make_train_state(self.cfg, self.rcfg, self.mesh,
                                    abstract=True)
        self.state, _ = self.ckpt.restore(template, latest, self.state_sh)
        self.step = latest
        return True

    def remesh(self, new_mesh):
        """Elastic topology change: re-lower, reshard state from checkpoint."""
        self.ckpt.wait()
        self.mesh = new_mesh
        self._build()
        if not self.restore_latest():
            raise RuntimeError("elastic remesh requires a checkpoint")

    # ------------------------------------------------------------------
    def run(self, num_steps: int) -> Dict:
        assert hasattr(self, "state"), "call init_state() or restore_latest()"
        target = self.step + num_steps
        while self.step < target:
            try:
                self._one_step()
            except Exception as e:   # node failure: restore & replay
                if not self._recover(e):
                    raise
        self.ckpt.wait()
        return {"final_step": self.step, "recoveries": self.recoveries,
                "stragglers": list(self.watchdog.straggler_steps)}

    def _one_step(self):
        t0 = time.monotonic()
        self.failure_plan.maybe_fail(self.step)
        batch = self.pipeline.batch_at(self.step)
        self.state, metrics = self.step_fn(self.state, batch)
        jax.block_until_ready(metrics["loss"])
        if self.delay_injector is not None:
            time.sleep(self.delay_injector(self.step))
        dt = time.monotonic() - t0
        self.watchdog.observe(self.step, dt)
        self.metrics_log.append(
            {"step": self.step, "dt": dt,
             **{k: float(v) for k, v in metrics.items()}})
        self.step += 1
        if self.step % self.rcfg.checkpoint_every == 0:
            self.ckpt.save(self.step, self.state,
                           blocking=not self.rcfg.async_checkpoint)

    def _recover(self, err: Exception) -> bool:
        self.ckpt.wait()
        template = make_train_state(self.cfg, self.rcfg, self.mesh,
                                    abstract=True)
        latest = self.ckpt.latest_step()
        if latest is None:
            if self.step == 0:
                return False
            # no checkpoint yet: restart from init (deterministic data replay)
            self.init_state()
            self.recoveries += 1
            return True
        self.state, _ = self.ckpt.restore(template, latest, self.state_sh)
        self.step = latest
        self.recoveries += 1
        return True
