"""Training step factory: loss, grad accumulation, NSM-routed pod sync.

Two stacks for the same model code (the paper's use case 3, applied to
training):

  * **gspmd** (paper-faithful baseline, "kernel stack"): one pjit'd step,
    every collective chosen and scheduled by XLA.
  * **netkernel pod sync** (`RunConfig.explicit_pod_sync`): the step runs
    inside a shard_map that is *manual over the pod axis only* (data/model
    stay GSPMD-auto). Per-pod gradients are synchronized through the
    CoreEngine (`nk_grad_sync`), so the operator's routing table decides the
    cross-pod transport (hierarchical / int8-compressed / ring) — without
    touching model or loss code.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig, RunConfig
from repro.core.collectives import nk_grad_sync, use_engine
from repro.core.engine import CoreEngine
from repro.distribution.sharding import (
    ParamDesc, ShardingCtx, abstract_params, make_rules, param_shardings,
    sharding_for, strip_axes_from_rules,
)
from repro.launch.mesh import data_axes
from repro.models.model import forward_train, model_schema
from repro.train.optimizer import adamw_update, init_opt_state


def loss_fn(params, batch: Dict, cfg: ModelConfig, shd: ShardingCtx,
            rcfg: RunConfig) -> Tuple[jax.Array, Dict]:
    logits, aux = forward_train(params, batch, cfg, shd, rcfg)
    logits = logits.astype(jnp.float32)
    labels = batch["labels"]
    # CE via select-reduce, never a gather over the (model-sharded) vocab
    # dim: a vocab gather makes the SPMD partitioner replicate the logits.
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    picked = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0),
                     axis=-1)
    ce = lse - picked
    loss = jnp.mean(ce)
    metrics = {"ce_loss": loss}
    if rcfg.z_loss:
        zl = rcfg.z_loss * jnp.mean(
            jnp.square(jax.nn.logsumexp(logits, axis=-1)))
        loss = loss + zl
        metrics["z_loss"] = zl
    if "moe_lb_loss" in aux:
        moe_l = 1e-2 * aux["moe_lb_loss"] + 1e-3 * aux["moe_z_loss"]
        loss = loss + moe_l
        metrics.update({k: v for k, v in aux.items()})
    metrics["loss"] = loss
    return loss, metrics


def _grads(params, batch, cfg, shd, rcfg, grad_shardings=None):
    if rcfg.grad_accum <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg, shd, rcfg)
        return grads, metrics
    # microbatch accumulation: scan over grad_accum slices of the batch
    a = rcfg.grad_accum
    mb = jax.tree.map(lambda x: x.reshape((a, x.shape[0] // a) + x.shape[1:]),
                      batch)

    def _pin(tree):
        # the zero-init accumulator carries no sharding; without pinning it
        # to the parameter shardings the partitioner materializes grads
        # nearly replicated (measured: 61.7 GB/chip on nemotron-340b)
        if grad_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            grad_shardings)

    def step(carry, mbatch):
        acc, _ = carry
        (loss, metrics), g = jax.value_and_grad(
            loss_fn, has_aux=True)(params, mbatch, cfg, shd, rcfg)
        acc = _pin(jax.tree.map(lambda A, G: A + G.astype(A.dtype), acc, g))
        return (acc, metrics), None

    adt = jnp.dtype(rcfg.grad_accum_dtype)
    zero = _pin(jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params))
    (gacc, metrics), _ = jax.lax.scan(step, (zero, _zero_metrics(cfg, rcfg)), mb)
    grads = jax.tree.map(lambda g: (g / a).astype(jnp.bfloat16), gacc)
    return grads, metrics


def ef_residual_metrics(grads) -> Dict:
    """Measured int8 error-feedback residual of a gradient tree.

    ``ef_residual_max`` is the largest absolute one-step quantization
    error any gradient element would incur on the int8 wire — the
    residual EF-SGD carries, and the quantity an error-feedback-aware
    numerics bound is derived from (``RunConfig.track_ef_residual``
    exposes it as a per-step training metric; the NSM conformance suite
    derives the compressed stack's tolerance from the same measurement
    instead of a hand-tuned constant).
    """
    from repro.core.compression import int8_roundtrip_residual
    leaves = [jnp.max(jnp.abs(int8_roundtrip_residual(g)))
              for g in jax.tree.leaves(grads)]
    return {"ef_residual_max": jnp.max(jnp.stack(leaves))}


def _zero_metrics(cfg, rcfg):
    m = {"ce_loss": jnp.zeros((), jnp.float32), "loss": jnp.zeros((), jnp.float32)}
    if rcfg.z_loss:
        m["z_loss"] = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        m.update({k: jnp.zeros((), jnp.float32) for k in
                  ("moe_lb_loss", "moe_z_loss", "moe_max_frac",
                   "moe_drop_frac")})
    return m


def make_train_step(cfg: ModelConfig, rcfg: RunConfig, mesh,
                    engine: Optional[CoreEngine] = None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""
    shd = ShardingCtx(mesh, rules=make_rules(rcfg.rules_variant),
                      seq_parallel=rcfg.seq_parallel_activations)
    multi_pod = "pod" in mesh.axis_names
    gshard = param_shardings(model_schema(cfg, mesh),
                             mesh, make_rules(rcfg.rules_variant))

    def plain_step(state, batch):
        grads, metrics = _grads(state["params"], batch, cfg, shd, rcfg,
                                grad_shardings=gshard)
        if rcfg.track_ef_residual:
            metrics.update(ef_residual_metrics(grads))
        new_p, new_o, om = adamw_update(state["params"], grads,
                                        state["opt"], rcfg)
        metrics.update(om)
        return {"params": new_p, "opt": new_o,
                "step": state["step"] + 1}, metrics

    if not (rcfg.explicit_pod_sync and multi_pod):
        return plain_step

    # --- NetKernel-owned cross-pod gradient sync ---
    # Per-pod gradients are computed as independent vmap lanes (plain GSPMD
    # over data/model; the lane dim is sharded over 'pod'), then synchronized
    # in a tiny shard_map that is manual over 'pod' ONLY and contains nothing
    # but the engine-routed psum. Keeping model code out of the partial-
    # manual region sidesteps an XLA CPU partitioner bug and — more to the
    # point — makes the cross-pod transport a swappable NSM concern.
    shd_in = ShardingCtx(None, seq_parallel=rcfg.seq_parallel_activations)
    pods = dict(zip(mesh.axis_names, mesh.devices.shape))["pod"]

    def pod_step(state, batch):
        mb = jax.tree.map(
            lambda x: jax.lax.with_sharding_constraint(
                x.reshape((pods, x.shape[0] // pods) + x.shape[1:]),
                NamedSharding(mesh, P("pod", "data"))), batch)

        def gfn(b):
            return _grads(state["params"], b, cfg, shd_in, rcfg)

        grads_pp, metrics_pp = jax.vmap(gfn)(mb)     # leading dim = pods

        def sync(g):
            # local view: leading dim 1 (this pod's grads)
            with use_engine(engine):
                g = nk_grad_sync(g, ("pod",))
            return jax.tree.map(lambda a: a[0] / pods, g)

        gspecs = jax.tree.map(lambda _: P("pod"), grads_pp)
        ospecs = jax.tree.map(lambda _: P(), grads_pp)
        grads = shard_map(sync, mesh=mesh, in_specs=(gspecs,),
                          out_specs=ospecs, axis_names={"pod"},
                          check_vma=False)(grads_pp)
        metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), metrics_pp)
        if rcfg.track_ef_residual:
            # the residual of the *synced* gradients: what the int8 wire
            # would have cost this step had the compressed stack carried it
            metrics.update(ef_residual_metrics(grads))
        new_p, new_o, om = adamw_update(state["params"], grads,
                                        state["opt"], rcfg)
        metrics.update(om)
        return {"params": new_p, "opt": new_o,
                "step": state["step"] + 1}, metrics

    return pod_step


def make_train_state(cfg: ModelConfig, rcfg: RunConfig, mesh, key=None,
                     abstract: bool = False) -> Dict:
    from repro.train.optimizer import _nu_shapes
    schema = model_schema(cfg, mesh)
    mdt = jnp.dtype(rcfg.moment_dtype)
    if abstract:
        params = abstract_params(schema)

        def nu_leaf(s):
            return {k: jax.ShapeDtypeStruct(
                        shp, jnp.float32 if rcfg.factored_nu and k != "full"
                        else mdt)
                    for k, shp in _nu_shapes(s.shape, rcfg.factored_nu).items()}

        opt = {"mu": jax.tree.map(
                   lambda s: jax.ShapeDtypeStruct(s.shape, mdt), params),
               "nu": jax.tree.map(nu_leaf, params),
               "count": jax.ShapeDtypeStruct((), jnp.int32)}
        return {"params": params, "opt": opt,
                "step": jax.ShapeDtypeStruct((), jnp.int32)}
    from repro.distribution.sharding import init_params
    params = init_params(schema, key if key is not None else jax.random.PRNGKey(0))
    return {"params": params, "opt": init_opt_state(params, rcfg),
            "step": jnp.zeros((), jnp.int32)}


def state_shardings(cfg: ModelConfig, rcfg: RunConfig, mesh):
    import dataclasses as _dc
    schema = model_schema(cfg, mesh)
    rules = make_rules(rcfg.rules_variant)
    pshard = param_shardings(schema, mesh, rules)
    rep = NamedSharding(mesh, P())

    def nu_shard(desc):
        if not rcfg.factored_nu or len(desc.shape) < 2:
            return {"full": sharding_for(desc.shape, desc.dims, mesh, rules)}
        return {"vr": sharding_for(desc.shape[:-1], desc.dims[:-1], mesh, rules),
                "vc": sharding_for(desc.shape[:-2] + desc.shape[-1:],
                                   desc.dims[:-2] + desc.dims[-1:],
                                   mesh, rules)}

    nshard = jax.tree.map(nu_shard, schema,
                          is_leaf=lambda x: isinstance(x, ParamDesc))
    return {"params": pshard,
            "opt": {"mu": pshard, "nu": nshard, "count": rep},
            "step": rep}


def batch_shardings(cfg: ModelConfig, mesh, with_labels=True,
                    rcfg: Optional[RunConfig] = None,
                    global_batch: Optional[int] = None):
    rules = make_rules(rcfg.rules_variant) if rcfg is not None else None
    from repro.distribution.sharding import spec_for
    gb = global_batch or (1 << 30)   # sentinel: divisible by any mesh axis
    spec = spec_for((gb, 1), ("batch", None), mesh, rules)
    tok = NamedSharding(mesh, spec)
    out = {"tokens": tok}
    if with_labels:
        out["labels"] = tok
    if cfg.encoder_layers:
        out["frames"] = tok
    return out
