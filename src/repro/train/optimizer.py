"""AdamW with configurable moment dtype + cosine schedule + global clipping.

Self-contained (no optax in this environment). Moments can be kept in
bfloat16 for >=100B-parameter models (nemotron-4-340b at 256 chips needs it;
see DESIGN.md §4); bias correction runs in f32 regardless.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig


def cosine_schedule(rcfg: RunConfig):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = rcfg.learning_rate * (step + 1) / max(rcfg.warmup_steps, 1)
        t = jnp.clip((step - rcfg.warmup_steps)
                     / max(rcfg.total_steps - rcfg.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * rcfg.learning_rate * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < rcfg.warmup_steps, warm, cos)
    return lr


def _decay_mask(params):
    """No weight decay for 1-D params (norm scales, biases, A_log, ...)."""
    return jax.tree.map(lambda p: jnp.asarray(p.ndim >= 2, jnp.float32), params)


def _nu_shapes(p_shape, factored: bool):
    """Second-moment leaf layout: full, or Adafactor row/col factors over
    the last two dims (stacked layer dims are kept)."""
    if not factored or len(p_shape) < 2:
        return {"full": p_shape}
    return {"vr": p_shape[:-1], "vc": p_shape[:-2] + p_shape[-1:]}


def init_opt_state(params, rcfg: RunConfig) -> Dict:
    mdt = jnp.dtype(rcfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)

    def nu_leaf(p):
        # row/col factors stay f32: they're tiny and precision matters
        return {k: jnp.zeros(s, jnp.float32 if rcfg.factored_nu and k != "full"
                             else mdt)
                for k, s in _nu_shapes(p.shape, rcfg.factored_nu).items()}

    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(nu_leaf, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(params, grads, opt_state, rcfg: RunConfig
                 ) -> Tuple[Dict, Dict, Dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    lr = cosine_schedule(rcfg)(opt_state["count"])
    b1, b2 = rcfg.beta1, rcfg.beta2
    eps = 1e-8
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, rcfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if rcfg.grad_clip > 0 else jnp.float32(1.0)
    count = opt_state["count"] + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)
    mask = _decay_mask(params)

    # moment math dtype: f32 normally; bf16 when moments are stored bf16
    # (>=100B models) — halves the optimizer's elementwise-chain temporaries
    # per chip; bias correction and the factored-nu reconstruction stay f32.
    cdt = jnp.bfloat16 if rcfg.moment_dtype == "bfloat16" else jnp.float32

    def nu_update(nu, g2):
        if "full" in nu:
            nu_f = nu["full"].astype(cdt) * b2 + jnp.asarray(1 - b2, cdt) * g2
            return {"full": nu_f.astype(nu["full"].dtype)}, nu_f
        g2f = g2.astype(jnp.float32)
        vr = nu["vr"] * b2 + (1 - b2) * jnp.mean(g2f, axis=-1)
        vc = nu["vc"] * b2 + (1 - b2) * jnp.mean(g2f, axis=-2)
        denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
        nu_f = (vr[..., None] * vc[..., None, :] / denom[..., None]).astype(cdt)
        return {"vr": vr, "vc": vc}, nu_f

    def upd_one(p, g, mu, nu, m):
        g = g.astype(cdt) * jnp.asarray(scale, cdt)
        mu_f = mu.astype(cdt) * jnp.asarray(b1, cdt) + jnp.asarray(1 - b1, cdt) * g
        new_nu, nu_f = nu_update(nu, (g * g).astype(cdt))
        step = (mu_f.astype(jnp.float32) / c1) / \
            (jnp.sqrt(nu_f.astype(jnp.float32) / c2) + eps)
        step = step + rcfg.weight_decay * m * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, mu_f.astype(mu.dtype), new_nu

    # NOTE: a lax.map-chunked update over stacked-layer leaves was tried to
    # shrink f32 temporaries and REGRESSED (+7 GB/chip: scan double-buffers
    # the full xs/ys) — recorded in EXPERIMENTS.md §Perf. Whole-leaf updates
    # fuse well under donation.
    upd = upd_one

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(opt_state["mu"])
    flat_nu = [jax.tree.map(lambda x: x, n) for n in
               jax.tree.flatten(opt_state["nu"],
                                is_leaf=lambda x: isinstance(x, dict)
                                and ("full" in x or "vr" in x))[0]]
    flat_m = jax.tree.leaves(mask)
    out = [upd(*t) for t in zip(flat_p, flat_g, flat_mu, flat_nu, flat_m)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"mu": new_mu, "nu": new_nu, "count": count}, metrics
