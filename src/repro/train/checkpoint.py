"""Sharded checkpointing: atomic, async, topology-independent restore.

Layout:  <dir>/step_<N>/
           manifest.json        tree structure, shapes, dtypes, step, extras
           leaf_<i>.npy         one blob per pytree leaf (host-gathered)

Guarantees:
  * **atomic**: written to ``step_<N>.tmp`` then ``os.replace``d — a crash
    mid-save never corrupts the latest checkpoint (restore scans for the
    newest complete manifest).
  * **async**: ``save(..., blocking=False)`` snapshots to host (device_get)
    synchronously, then writes on a background thread — the step loop
    resumes immediately (paper-grade "operator owns the substrate" behavior:
    the application never sees the storage path).
  * **elastic**: blobs are *global* (unsharded) arrays; ``restore`` places
    them into any target shardings via ``jax.make_array_from_callback``, so
    a 4-chip checkpoint restores onto 8 chips (tested).
  * keep-last-k GC.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import ml_dtypes
import numpy as np

import jax
import jax.numpy as jnp

# numpy can't roundtrip ml_dtypes (bfloat16 etc.) through .npy; store such
# leaves as same-width unsigned views and restore via the manifest dtype.
_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
           "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
           "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8)}


def _tree_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(kp), v) for kp, v in flat]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def save(self, step: int, state, blocking: bool = True,
             extras: Optional[Dict] = None) -> None:
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        if blocking:
            self._write(step, host, extras or {})
        else:
            self._thread = threading.Thread(
                target=self._write_guard, args=(step, host, extras or {}),
                daemon=True)
            self._thread.start()

    def _write_guard(self, step, host, extras):
        try:
            self._write(step, host, extras)
        except BaseException as e:   # surfaced on next wait()
            self._error = e

    def _write(self, step: int, host, extras: Dict) -> None:
        final = os.path.join(self.dir, f"step_{step:09d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves, treedef = jax.tree.flatten(host)
        manifest = {
            "step": step,
            "treedef": jax.tree_util.tree_structure(host).serialize_using_proto().hex(),
            "leaves": [{"file": f"leaf_{i}.npy", "shape": list(x.shape),
                        "dtype": str(x.dtype)} for i, x in enumerate(leaves)],
            "extras": extras,
        }
        for i, x in enumerate(leaves):
            name = str(x.dtype)
            if name in _EXOTIC:
                x = x.view(_EXOTIC[name][1])
            np.save(os.path.join(tmp, f"leaf_{i}.npy"), x, allow_pickle=False)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    # ------------------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, state_like, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, Dict]:
        """``state_like``: pytree of arrays or ShapeDtypeStructs (the
        template). ``shardings``: matching tree of NamedShardings (optional:
        restore resharded onto any mesh)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_t, treedef = jax.tree.flatten(state_like)
        assert len(leaves_t) == len(manifest["leaves"]), \
            f"tree mismatch: {len(leaves_t)} vs {len(manifest['leaves'])}"
        shard_leaves = (jax.tree.leaves(shardings)
                        if shardings is not None else [None] * len(leaves_t))
        out = []
        for i, (tpl, meta, sh) in enumerate(
                zip(leaves_t, manifest["leaves"], shard_leaves)):
            arr = np.load(os.path.join(path, meta["file"]))
            if meta["dtype"] in _EXOTIC:
                arr = arr.view(_EXOTIC[meta["dtype"]][0])
            assert tuple(arr.shape) == tuple(tpl.shape), (arr.shape, tpl.shape)
            if sh is None:
                out.append(jnp.asarray(arr))
            else:
                out.append(jax.make_array_from_callback(
                    arr.shape, sh, lambda idx, _a=arr: _a[idx]))
        return jax.tree.unflatten(treedef, out), manifest["extras"]

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)
