"""Live tenant migration on a multi-engine fabric — operator placement.

    PYTHONPATH=src python examples/cluster_migration.py

Three smoke-scale ServeEngines behind ONE RateController serve four
tenants; tenant 3 misbehaves (10x the bottleneck) and heats its engine.
Mid-replay the operator rebalances: the hog is migrated *live* to the
coolest engine — unserved queue, token-bucket level and ledger continuity
move with it, in-flight slots drain (and bill) on the source — while the
fairness/isolation bounds hold and the served-token ledger is conserved.
The guest never notices: it keeps submitting, the placement map routes.
"""
from repro.serve.replay import (
    TraceReplayer, make_replay_cluster, operator_rebalance, scenario_spec,
)

trace, cap = scenario_spec("migration", n_tenants=4, intervals=12)
cluster = make_replay_cluster(capacity=cap, engines=3)

log = []


def rebalance(cl, now):
    # the one-shot operator move: PlacementController.plan_once(force=True)
    # under the hood (EngineCluster.rebalance() is deprecated)
    log.append(operator_rebalance(cl, now=now))


print(f"cluster: 3 engines, one shared {cap:.0f} tok/s bottleneck; "
      f"adversarial 10x hog\n")
rep = TraceReplayer(cluster, capacity=cap).run(trace,
                                               events=[(6, rebalance)])
rec = log[0]
print(f"migration @ step {rec.started_step}: tenant {rec.tenant} "
      f"engine {rec.src} -> {rec.dst}; {rec.queued_moved} queued requests "
      f"and {rec.bucket_tokens_moved:.1f} bucket tokens moved, "
      f"{rec.inflight_at_move} in-flight slots drained on the source")
cluster.assert_ledger_conservation(rec.tenant)
print(f"ledger conserved: {cluster.tenant_served_tokens(rec.tenant):.0f} "
      f"tokens == request-level ground truth "
      f"{cluster.tenant_billed_ground_truth(rec.tenant)}\n")
print("tenant  demand(tok/s)  achieved  engine")
for t, r in sorted(rep.per_tenant.items()):
    tag = "  <- migrated hog" if t == rec.tenant else ""
    print(f"  {t}    {r.demand_rate:10.1f} {r.achieved_rate:9.1f}"
          f"      e{rep.placement[t]}{tag}")
print(f"\nJain {rep.jain():.3f} across the migration window; "
      f"{rep.migrations} live migration(s)")
print("\nplacement/migration counters (excerpt):")
for line in cluster.export_prometheus().splitlines():
    if "migra" in line or "placement" in line:
        print("  " + line)
