"""End-to-end training driver: train a small LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py --preset 20m --steps 200
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

Demonstrates the full substrate on CPU: deterministic data pipeline,
AdamW + cosine schedule, per-layer remat, async checkpoints, straggler
watchdog, and (with --pods 2) the NetKernel compressed cross-pod stack.
The loss on the synthetic copy-structured corpus drops well below the
unigram entropy — the model learns the copy rule.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import argparse
import dataclasses
import tempfile
import time

import jax

from repro.configs import RunConfig, ShapeConfig, get_smoke_config
from repro.configs.base import ModelConfig
from repro.core import make_engine
from repro.data import for_model
from repro.launch.mesh import make_host_mesh
from repro.train import Runner

PRESETS = {
    # ~20M params: fast on CPU
    "20m": dict(num_layers=4, d_model=256, num_heads=8, num_kv_heads=4,
                d_ff=1024, vocab_size=8192, head_dim=32),
    # ~100M params: the "train a ~100M model" example (slower)
    "100m": dict(num_layers=8, d_model=640, num_heads=10, num_kv_heads=5,
                 d_ff=2560, vocab_size=32000, head_dim=64),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="20m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--pods", type=int, default=0)
    ap.add_argument("--nsm", default="xla",
                    choices=["xla", "compressed", "hierarchical"])
    args = ap.parse_args()

    cfg = dataclasses.replace(get_smoke_config("llama3.2-3b"),
                              name=f"lm-{args.preset}", **PRESETS[args.preset])
    n = cfg.num_params()
    shape = ShapeConfig("train", args.seq, args.batch, "train")
    mesh = (make_host_mesh(2, 2, pod=args.pods) if args.pods
            else make_host_mesh(2, 4))
    rcfg = RunConfig(attn_q_block=64, attn_kv_block=64,
                     learning_rate=3e-3, warmup_steps=20,
                     total_steps=args.steps, checkpoint_every=50,
                     explicit_pod_sync=bool(args.pods) and args.nsm != "xla",
                     nsm_policy=args.nsm)
    engine = make_engine(mesh, args.nsm) if args.nsm != "xla" else None
    print(f"model {cfg.name}: {n/1e6:.1f}M params on mesh "
          f"{dict(zip(mesh.axis_names, mesh.devices.shape))}, NSM={args.nsm}")

    with tempfile.TemporaryDirectory() as d:
        r = Runner(cfg, rcfg, mesh, for_model(cfg, shape), d, engine=engine)
        r.init_state(jax.random.PRNGKey(0))
        t0 = time.time()
        out = r.run(args.steps)
        dt = time.time() - t0
        losses = [m["ce_loss"] for m in r.metrics_log]
        print(f"steps={out['final_step']} wall={dt:.1f}s "
              f"({dt / args.steps * 1e3:.0f} ms/step)")
        for i in range(0, len(losses), max(1, len(losses) // 10)):
            print(f"  step {i:4d}  ce_loss {losses[i]:.4f}")
        print(f"  final ce_loss {losses[-1]:.4f} "
              f"(started {losses[0]:.4f})")
        assert losses[-1] < losses[0]
    if engine is not None:
        print("CoreEngine ledger:", engine.ledger_table()[:2])


if __name__ == "__main__":
    main()
