"""Quickstart: the NetKernel-JAX public API in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. pick an assigned architecture (reduced for CPU),
2. train a few steps with the fault-tolerant runner,
3. swap the cross-pod gradient stack (xla -> compressed) with ZERO model
   changes — the paper's thesis as a config flip,
4. serve two tenants from one engine with fair scheduling.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import tempfile

import jax

from repro.configs import RunConfig, ShapeConfig, get_smoke_config
from repro.core import make_engine
from repro.data import for_model
from repro.launch.mesh import make_host_mesh
from repro.serve import Request, ServeEngine, TenantScheduler
from repro.train import Runner

cfg = get_smoke_config("llama3.2-3b")          # any of the 10 archs works
shape = ShapeConfig("tiny", 32, 8, "train")
mesh = make_host_mesh(2, 2, pod=2)             # mini 2-pod mesh

# --- 1+2: train with checkpoints/fault tolerance ---------------------------
rcfg = RunConfig(attn_q_block=16, attn_kv_block=16, checkpoint_every=5,
                 learning_rate=1e-2, warmup_steps=5, total_steps=40)
with tempfile.TemporaryDirectory() as ckpt_dir:
    runner = Runner(cfg, rcfg, mesh, for_model(cfg, shape), ckpt_dir)
    runner.init_state(jax.random.PRNGKey(0))
    runner.run(8)
    print(f"[train/xla-stack]  loss {runner.metrics_log[0]['ce_loss']:.3f} "
          f"-> {runner.metrics_log[-1]['ce_loss']:.3f}")

    # --- 3: operator swaps the cross-pod stack; model code untouched -------
    rcfg2 = RunConfig(attn_q_block=16, attn_kv_block=16, checkpoint_every=5,
                      learning_rate=1e-2, warmup_steps=5, total_steps=40,
                      explicit_pod_sync=True, nsm_policy="compressed")
    engine = make_engine(mesh, "compressed")   # int8 on the pod axis
    runner2 = Runner(cfg, rcfg2, mesh, for_model(cfg, shape),
                     ckpt_dir + "/b", engine=engine)
    runner2.init_state(jax.random.PRNGKey(0))
    runner2.run(8)
    print(f"[train/compressed] loss {runner2.metrics_log[0]['ce_loss']:.3f} "
          f"-> {runner2.metrics_log[-1]['ce_loss']:.3f}")
    print(f"[train/compressed] CoreEngine ledger: "
          f"{engine.ledger_table()[:1]} ...")

# --- 4: multi-tenant serving (multiplexing + fairness) ----------------------
sched = TenantScheduler(policy="wfq")
sched.add_tenant(0, weight=1.0)
sched.add_tenant(1, weight=1.0, rate_tokens_per_s=100.0)
serve = ServeEngine(cfg, RunConfig(attn_q_block=16, attn_kv_block=16),
                    make_host_mesh(1, 1), batch_slots=4, max_seq=64,
                    scheduler=sched)
for i in range(4):
    serve.submit(Request(tenant_id=0, prompt=[1, 2, 3], max_new_tokens=8))
    serve.submit(Request(tenant_id=1, prompt=[4, 5], max_new_tokens=8))
out = serve.run_until_drained()
print(f"[serve] {out['completed']} requests from 2 tenants on one engine; "
      f"shares={ {k: round(v, 2) for k, v in out['shares'].items()} }")
print("quickstart OK")
