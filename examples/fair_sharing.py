"""Distributed congestion control & fair bandwidth sharing — use case 2.

    PYTHONPATH=src python examples/fair_sharing.py

The management plane closing the loop (paper Figs. 21-22): two CoreEngines
(think: two hosts) share one 1 MB/s cross-pod fabric. Four tenants offer
very different loads — one tiny, two greedy, one outright misbehaving (10x
the fabric). A RateController watches the engines' ledgers, runs weighted
max-min fair water-filling every interval, and pushes per-tenant rates back
into the engines' token buckets, which dispatch() now actually enforces.

No model code anywhere: tenants are CommOp streams, enforcement is
engine-side — exactly the "stack as infrastructure" pitch.
"""
from repro.control import RateController, SharedBottleneckSim, SimTenant
from repro.serve import bursty_trace, fair_replay

MB = 1_000_000.0
CAPACITY = 1.0 * MB

tenants = [
    SimTenant(1, demand=0.10 * CAPACITY, weight=1.0),   # small, satisfied
    SimTenant(2, demand=0.80 * CAPACITY, weight=1.0),   # greedy
    SimTenant(3, demand=0.80 * CAPACITY, weight=2.0),   # greedy, 2x weight
    SimTenant(9, demand=10.0 * CAPACITY, weight=1.0),   # misbehaving
]
sim = SharedBottleneckSim(tenants, CAPACITY, n_engines=2, dt=0.05)
res = sim.run(12.0)
ref = sim.fair_reference()

print(f"shared fabric: {CAPACITY/MB:.1f} MB/s across 2 engines\n")
print("tenant  weight  offered(MB/s)  served(MB/s)  max-min fair")
for t in sorted(ref):
    tn = next(x for x in tenants if x.tenant_id == t)
    print(f"  {t}     {tn.weight:4.1f}    {tn.offered_at(12.0)/MB:10.2f}"
          f"    {res.served_rate(t)/MB:10.2f}    {ref[t]/MB:9.2f}")
print(f"\nfabric utilization: {res.total_served_rate()/CAPACITY:.0%}; "
      f"the 10x hog was held to {res.served_rate(9)/CAPACITY:.0%} "
      f"of capacity, tenant 1's trickle untouched")

ctrl: RateController = sim.controller
print(f"controller: {ctrl.ticks} ticks; pushed rates land in live "
      f"token buckets (balances preserved across updates)")
print("\nexported counters (excerpt):")
for line in ctrl.export_prometheus().splitlines():
    if "allocated" in line:
        print("  " + line)

# the same allocator, replayed over the bursty fleet trace of use case 1:
t = bursty_trace(8, seed=1)
out = fair_replay(t, capacity=float(t.loads.sum(axis=0).mean()) * 0.7)
print(f"\nfair replay over 8 bursty tenants at 70% of mean aggregate load:"
      f"\n  served {out['served_frac']:.0%} of offered demand,"
      f" Jain index among backlogged tenants "
      f"{out['jain_backlogged']:.3f} (1.0 = perfectly fair)")

# ...and the same claim end-to-end: real Requests through a real ServeEngine
# (jitted prefill/decode, WFQ admission, controller-enforced buckets), every
# number read from engine ledgers. Delta push keeps the control plane quiet.
from repro.serve import replay_scenario  # noqa: E402

rep = replay_scenario("adversarial", n_tenants=4, intervals=10,
                      push_mode="delta")
hog = max(rep.per_tenant, key=lambda t: rep.per_tenant[t].demand_rate)
print("\nend-to-end (real ServeEngine, adversarial 10x misbehaver):")
print("tenant  demand(tok/s)  achieved  admit-wait(s)")
for t, r in sorted(rep.per_tenant.items()):
    tag = "  <- hog" if t == hog else ""
    print(f"  {t}    {r.demand_rate:10.1f} {r.achieved_rate:9.1f}"
          f" {r.mean_admit_wait_s:10.2f}{tag}")
print(f"Jain {rep.jain():.3f}; hog held to "
      f"{rep.per_tenant[hog].achieved_rate / rep.capacity:.0%} of the "
      f"{rep.capacity:.0f} tok/s bottleneck; controller issued "
      f"{rep.set_rate_calls} set_rate calls ({rep.push_skipped} skipped "
      f"as unchanged)")
