"""The placement autopilot flying a cluster — nobody at the wheel.

    PYTHONPATH=src python examples/placement_autopilot.py

Three smoke-scale ServeEngines behind one RateController serve four
tenants through a busy -> idle -> busy window. A PlacementController
(`consolidate` policy) runs on a cadence next to the rate loop: when the
fleet goes idle it packs every tenant onto one engine and PARKS the other
two — the paper's multiplexing claim ("save cores by sharing stack
modules"), closed-loop — then wakes them when load returns. Parking is a
real suspend: the parked engines drop their KV-caches and slot buffers
(memory saved, not just cores), lazily re-initialized on unpark. Every
move runs through migrate()'s ledger-conserving drain-and-transfer; no
tenant ever moves twice within the hysteresis window.
"""
from repro.serve.replay import TraceReplayer, make_replay_cluster, \
    scenario_spec

INTERVALS = 12
trace, cap = scenario_spec("consolidation", n_tenants=4,
                           intervals=INTERVALS)
cluster = make_replay_cluster(capacity=cap, engines=3,
                              autopilot="consolidate")

timeline = []


def snap(cl, now):
    timeline.append((now, dict(cl.placement), sorted(cl.parked)))


print(f"cluster: 3 engines, one shared {cap:.0f} tok/s bottleneck; "
      f"4 tenants, idle window mid-run; autopilot: consolidate\n")
rep = TraceReplayer(cluster, capacity=cap).run(
    trace, events=[(i, snap) for i in range(INTERVALS)])

print("t(s)  placement (tenant->engine)        parked")
for now, placement, parked in timeline:
    pl = " ".join(f"{t}->e{k}" for t, k in sorted(placement.items()))
    print(f"{now:5.1f}  {pl:32s}  {parked or '-'}")

pilot = cluster.autopilot
print(f"\nautopilot: {pilot.moves_applied} moves applied, "
      f"{pilot.moves_skipped_cooldown} gated by the hysteresis cooldown, "
      f"{pilot.moves_skipped_drain} by the drain-cost model")
for when, mv in pilot.move_log:
    print(f"  t={when:5.1f}s  tenant {mv.tenant}: e{mv.src} -> e{mv.dst} "
          f"({mv.reason}, gain {mv.expected_gain:.0f} tok, "
          f"drain {mv.drain_cost:.0f} tok)")
pilot.assert_no_ping_pong()
print(f"\ncores saved: {rep.cores_saved:.2f} engines/step on average "
      f"(peak {rep.max_parked} parked); Jain {rep.jain():.3f}")
print(f"mem saved:   {rep.mem_saved_bytes / 1024:.1f} KiB/step on average "
      f"(peak {rep.max_parked_bytes / 1024:.1f} KiB freed while parked, "
      f"of {rep.peak_resident_cache_bytes / 1024:.1f} KiB peak resident "
      f"KV-cache)")
for t in sorted(rep.per_tenant):
    cluster.assert_ledger_conservation(t)
print("served-token ledger conserved for every tenant across "
      f"{rep.migrations} live migration(s)")
print("\nplacement counters (excerpt):")
for line in cluster.export_prometheus().splitlines():
    if any(k in line for k in ("placement", "parked", "cores", "mem_",
                               "bytes_freed", "resident")):
        print("  " + line)
