"""Multi-tenant serving: multiplexing, fairness, isolation — use cases 1+2.

    PYTHONPATH=src python examples/serve_multitenant.py

Three tenants share one engine (the paper's "one NSM serves many VMs"):
  tenant 0: normal load
  tenant 1: selfish (8x the requests)        -> WFQ keeps shares equal
  tenant 2: rate-capped by token bucket      -> hard isolation
Then the fleet-level economics: chips for dedicated-per-tenant peaks vs one
shared engine on bursty traces (the >40% saving of Table 2).
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=1")

from repro.configs import RunConfig, get_smoke_config
from repro.launch.mesh import make_single_device_mesh
from repro.serve import (
    Request, ServeEngine, TenantScheduler, bursty_trace, chip_accounting,
)

cfg = get_smoke_config("internlm2-1.8b")
rcfg = RunConfig(attn_q_block=16, attn_kv_block=16)

sched = TenantScheduler(policy="wfq")
sched.add_tenant(0, weight=1.0)
sched.add_tenant(1, weight=1.0)
sched.add_tenant(2, weight=1.0, rate_tokens_per_s=2.0, burst=16.0)

eng = ServeEngine(cfg, rcfg, make_single_device_mesh(), batch_slots=4,
                  max_seq=64, scheduler=sched)

for i in range(4):
    eng.submit(Request(tenant_id=0, prompt=[1, 2, 3], max_new_tokens=12))
for i in range(32):
    eng.submit(Request(tenant_id=1, prompt=[7, 8], max_new_tokens=12))
for i in range(6):
    eng.submit(Request(tenant_id=2, prompt=[11], max_new_tokens=12))

# run under contention and report shares while everyone is backlogged
for step in range(30):
    eng.step(now=step * 0.05)
print("shares under contention (tenant 1 is 8x selfish):",
      {k: round(v, 2) for k, v in sched.shares().items()})

out = eng.run_until_drained()
done = {t: sum(1 for r in eng.completed if r.tenant_id == t)
        for t in (0, 1, 2)}
print(f"completed per tenant: {done} "
      f"(tenant 2 capped at 2 tok/s: only {done[2]} of 6 admitted)")

acc = chip_accounting(bursty_trace(16, seed=0), cap_per_chip=50.0)
print(f"fleet economics (16 bursty tenants): dedicated "
      f"{acc['dedicated_chips']} chips vs shared {acc['shared_chips']} "
      f"-> {acc['savings_frac']:.0%} saved (paper claims >40%)")
