"""Use case 3: deploy a different stack beneath unmodified code.

    PYTHONPATH=src python examples/stack_swap.py

The paper deploys mTCP under unmodified nginx. Here:
  (a) the same attention call runs on the naive / blockwise / Pallas stacks,
  (b) the same training step runs with its cross-pod gradient transport on
      xla / hierarchical / compressed(int8) stacks,
  (c) a live `EngineCluster` hot-swaps an engine's bytes-plane stack
      (xla -> compressed) *between ops*, with billed ground truth carried
      across the swap — the cluster analog of restarting nothing,
and in every case the "application" (model / loss / op stream) is
byte-identical — only the operator's routing table changes.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs import RunConfig, ShapeConfig, get_smoke_config
from repro.core import make_engine
from repro.data import for_model
from repro.kernels import ops
from repro.launch.mesh import make_host_mesh
from repro.train import Runner

# --- (a) attention stacks ---------------------------------------------------
b, h, s, d = 1, 8, 512, 64
q = jax.random.normal(jax.random.PRNGKey(0), (b, h, s, d), jnp.float32)
k = jax.random.normal(jax.random.PRNGKey(1), (b, h, s, d), jnp.float32)
v = jax.random.normal(jax.random.PRNGKey(2), (b, h, s, d), jnp.float32)
for impl in ("ref", "pallas"):
    f = lambda: jax.block_until_ready(
        ops.mha_forward(q, k, v, impl=impl, q_block=128, kv_block=128))
    f()
    t0 = time.perf_counter()
    for _ in range(3):
        f()
    dt = (time.perf_counter() - t0) / 3
    print(f"[attention stack={impl:7s}] {dt * 1e3:7.1f} ms/call "
          f"(same call site, swapped implementation)")

# --- (b) gradient-transport stacks ------------------------------------------
cfg = get_smoke_config("granite-8b")
shape = ShapeConfig("tiny", 32, 8, "train")
mesh = make_host_mesh(2, 2, pod=2)
for policy in ("xla", "hierarchical", "compressed"):
    rcfg = RunConfig(attn_q_block=16, attn_kv_block=16, learning_rate=1e-2,
                     warmup_steps=2, total_steps=20,
                     explicit_pod_sync=(policy != "xla"), nsm_policy=policy)
    engine = make_engine(mesh, policy)
    with tempfile.TemporaryDirectory() as dd:
        r = Runner(cfg, rcfg, mesh, for_model(cfg, shape), dd, engine=engine)
        r.init_state(jax.random.PRNGKey(0))
        r.run(5)
        losses = [m["ce_loss"] for m in r.metrics_log]
        wire = engine.total_bytes()
        print(f"[grad stack={policy:12s}] loss {losses[0]:.3f}->{losses[-1]:.3f}"
              f"  routed-bytes={wire / 1e6:.1f} MB "
              f"({'int8 wire' if policy == 'compressed' else 'bf16/f32 wire'})")

# --- (c) live hot-swap on a running cluster ---------------------------------
# (a) and (b) pick a stack per run; the paper's real move swaps it under a
# LIVE guest. One engine slot, bytes plane: bill ops on the native stack,
# swap xla -> compressed mid-stream, keep billing — ground truth carries.
from repro.core.nqe import CommOp
from repro.serve import swap_live_stack
from repro.serve.replay import make_replay_cluster

cl = make_replay_cluster(capacity=64.0, engines=1, core_plane=True)
cl.add_tenant(0, engine=0)

def pump(n, size=4096, now=0.0):
    core = cl.core_engines[0]
    for _ in range(n):
        op = CommOp(verb="psum", axes=("pod",), tenant_id=0,
                    size_bytes=size)
        core.admit(op, now)
        core.route(op)

pump(3)
pre = cl.core_engines[0].billed_ground_truth(0)
rec = swap_live_stack(cl, "bytes", now=0.5)     # xla -> compressed, live
pump(3, now=1.0)
post = cl.core_engines[0].billed_ground_truth(0)
assert post == pre * 2 and cl.tenant_core_bytes(0) == post
print(f"[live swap] {rec.old_stack} -> {rec.new_stack}: "
      f"{pre} bytes billed pre-swap carried, {post} total, conserved")
print("stack_swap OK — zero model-code changes across all six stacks, "
      "one of them swapped in live")
