#!/usr/bin/env python3
"""Offline fabric watchdog: replay a recorded scrape sequence to alerts.

    PYTHONPATH=src python tools/nk_watch.py SCRAPES.txt
    PYTHONPATH=src:. python tools/nk_watch.py --demo

Reads the artifact ``FabricWatchdog.write_scrapes`` dumps (each scrape
prefixed ``# SCRAPE ts=<t>``, terminated ``# EOF``), feeds the scrapes
through a fresh ``SeriesStore`` + ``AlertEngine`` in timestamp order,
and renders what an on-call wants from an incident bundle:

  * the alert timeline — every fire/resolve with rule, severity, labels
    and the violating value, in the order the watchdog saw them;
  * the alerts still active at the end of the recording;
  * the final burn rates for every burn-rate rule (fast and slow
    window), so "how close were the quiet tenants to paging" is visible
    next to the one that did.

The rule windows are sized from the recording itself (median scrape
spacing) unless ``--interval`` pins them, so an artifact recorded at
any cadence replays with the same windows-per-scrape geometry the live
watchdog used. Same contract as ``tools/nk_top.py``: everything is
derived from the artifact text, no handle on a live fabric. ``--demo``
replays the adversarial scenario with a recording watchdog attached and
renders the resulting artifact — a self-contained smoke test of the
whole record -> replay -> alert path.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))


def _table(rows, headers):
    rows = [headers] + [[str(c) for c in r] for r in rows]
    widths = [max(len(r[i]) for r in rows) for i in range(len(headers))]
    out = []
    for j, r in enumerate(rows):
        out.append("  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip())
        if j == 0:
            out.append("  ".join("-" * w for w in widths))
    return "\n".join(out)


def _labels(labels) -> str:
    return ",".join(f"{k}={v}" for k, v in labels) or "-"


def infer_interval(times) -> float:
    """Median spacing between scrapes; 1.0 when undeterminable."""
    gaps = sorted(b - a for a, b in zip(times, times[1:]) if b > a)
    return gaps[len(gaps) // 2] if gaps else 1.0


def replay_alerts(scrapes, rules=None, interval_s=None):
    """Feed ``[(ts, text), ...]`` through a fresh store + alert engine.

    Returns ``(store, engine, events)`` where ``events`` is the flat
    ``[(ts, "fire"|"resolve", Alert), ...]`` timeline."""
    from repro.obs.slo import AlertEngine, default_rules
    from repro.obs.timeseries import SeriesStore

    if interval_s is None:
        interval_s = infer_interval([ts for ts, _ in scrapes])
    store = SeriesStore()
    engine = AlertEngine(default_rules(interval_s)
                         if rules is None else rules)
    events = []
    for ts, text in sorted(scrapes):
        store.ingest(text, ts)
        for kind, alert in engine.evaluate(store, ts):
            events.append((ts, kind, alert))
    return store, engine, events


def render(store, engine, events, interval_s) -> str:
    from repro.obs.slo import BurnRateRule

    times = store.times()
    span = (times[-1] - times[0]) if len(times) > 1 else 0.0
    lines = [f"nk_watch — {store.scrapes} scrapes over {span:.3g}s, "
             f"{len(engine.rules)} rules (interval {interval_s:.3g}s)",
             ""]

    if events:
        rows = [[f"{ts:.2f}", kind.upper(), a.rule, a.severity,
                 _labels(a.labels),
                 f"{a.value:.3f}" if kind == "fire" else ""]
                for ts, kind, a in events]
        lines.append(_table(rows, ["time", "event", "rule", "sev",
                                   "labels", "value"]))
    else:
        lines.append("no alerts fired — the fabric held its SLOs")
    lines.append("")

    if engine.active:
        rows = [[a.rule, a.severity, _labels(a.labels),
                 f"{a.fired_at:.2f}", f"{a.value:.3f}"]
                for _, a in sorted(engine.active.items())]
        lines.append("still active at end of recording:")
        lines.append(_table(rows, ["rule", "sev", "labels", "since",
                                   "value"]))
        lines.append("")

    now = times[-1] if times else 0.0
    for rule in engine.rules:
        if not isinstance(rule, BurnRateRule):
            continue
        burns = rule.burn_rates(store, now)
        if not burns:
            continue
        lines.append(
            f"{rule.name} @ t={now:.2f} (objective "
            f"{rule.spec.objective:g}, fires past {rule.burn_threshold:g}"
            f" on both windows):")
        rows = [[k, f"{bf:.2f}", f"{bs:.2f}",
                 "FIRING" if (rule.name, ((rule.key, k),)) in engine.active
                 else ""]
                for k, (bf, bs) in sorted(burns.items(),
                                          key=lambda i: (len(i[0]), i[0]))]
        lines.append(_table(rows, [rule.key, "burn_fast", "burn_slow",
                                   "state"]))
        lines.append("")
    return "\n".join(lines).rstrip() + "\n"


def demo_sequence() -> str:
    """Replay the adversarial scenario with a recording watchdog and
    return its scrape-sequence artifact."""
    from repro.control.controller import RateController
    from repro.serve.replay import replay_scenario, scenario_spec
    from tests.test_placement import ControlledFakeEngine

    _, cap = scenario_spec("adversarial", n_tenants=4, intervals=12)
    eng = ControlledFakeEngine()
    ctrl = RateController(cap, alpha=0.6, push_mode="full")
    ctrl.attach_scheduler(eng.scheduler)
    eng.controller = ctrl
    rep = replay_scenario("adversarial", n_tenants=4, intervals=12,
                          engine=eng, watch="record")
    return rep.watchdog.scrape_sequence()


def main(argv=None) -> int:
    from repro.obs.slo import read_scrape_sequence

    ap = argparse.ArgumentParser(
        description="replay a recorded scrape sequence into an alert "
                    "timeline and burn rates")
    ap.add_argument("scrapes", nargs="?", type=pathlib.Path,
                    help="scrape-sequence artifact "
                         "(FabricWatchdog.write_scrapes output)")
    ap.add_argument("--interval", type=float, default=None,
                    help="rule-window scrape interval in seconds "
                         "(default: median spacing in the recording)")
    ap.add_argument("--demo", action="store_true",
                    help="record the adversarial replay scenario and "
                         "render its artifact")
    args = ap.parse_args(argv)
    if args.demo:
        text = demo_sequence()
    elif args.scrapes is not None:
        try:
            text = args.scrapes.read_text()
        except OSError as e:
            print(f"unreadable artifact: {e}")
            return 1
    else:
        ap.error("need a SCRAPES file or --demo")
    try:
        scrapes = read_scrape_sequence(text)
    except ValueError as e:
        print(f"artifact does not parse: {e}")
        return 1
    if not scrapes:
        print("artifact holds no scrapes")
        return 1
    interval = args.interval if args.interval is not None \
        else infer_interval([ts for ts, _ in scrapes])
    store, engine, events = replay_alerts(scrapes, interval_s=interval)
    sys.stdout.write(render(store, engine, events, interval))
    return 0


if __name__ == "__main__":
    sys.exit(main())
