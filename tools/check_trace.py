#!/usr/bin/env python3
"""Validate a fabric flight-recorder trace (Chrome trace-event JSON).

    python tools/check_trace.py TRACE.json [--scenario migration]

Checks, in order:
  * the file is well-formed JSON with a ``traceEvents`` list;
  * every event has the required fields for its phase (``ph``), with
    numeric ``ts`` and known phases only;
  * per (pid, tid) track, non-async event timestamps are monotonically
    non-decreasing (Perfetto renders out-of-order slices as garbage);
  * async begin/end ("b"/"e") events pair up per (cat, id, name);
  * any hot-swap events are well-formed: per (engine, plane) the
    swap.quiesce begin/end, swap.transfer span, and swap.resume instant
    counts balance, and no ``request.dispatch`` lands on an engine's
    track while that engine's quiesce window is open (the replay clock
    is virtual, so the window is judged by event order, not ts);
  * any failover events are well-formed: a ``recover`` span is preceded
    by a ``fail`` instant AND a ``checkpoint`` span for that engine, no
    engine fails twice without recovering in between, no
    ``request.dispatch`` lands on an engine's track between its ``fail``
    and its ``recover`` (event order, not ts — the replay clock is
    virtual), and no engine is left failed at the end of the trace;
  * with ``--scenario migration``: the trace contains the full
    stack-module lifecycle — migrate.transfer and migrate.finalize
    spans, a migrate.drain begin/end pair, and park/unpark instants;
  * with ``--scenario stack_swap``: at least one complete hot-swap on
    *each* plane (serve and bytes);
  * with ``--scenario failover``: at least one ``checkpoint`` span, one
    ``fail`` instant and one ``recover`` span;
  * any watchdog alert instants are well-formed: per (rule, labels) an
    ``alert.resolve`` must be preceded by a matching ``alert.fire``,
    and an active alert never fires twice without resolving in between
    (alerts still active at the end of the trace are legal — a
    recording can stop mid-incident).

Stdlib only (runs in CI before any pip install). Exit 1 with a listing
on any violation.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

KNOWN_PHASES = {"X", "B", "E", "i", "I", "b", "e", "n", "M", "C"}

# the lifecycle the migration scenario's trace must show: event name ->
# set of phases at least one event must carry
MIGRATION_LIFECYCLE = {
    "migrate.transfer": {"X"},
    "migrate.drain": {"b"},
    "migrate.drain/end": {"e"},          # pseudo-key: see _lifecycle_key
    "migrate.finalize": {"X"},
    "park": {"i", "I"},
    "unpark": {"i", "I"},
}


# the swap lifecycle: each live hot-swap must show its quiesce window
# (async b/e), one transfer span, and one resume instant, per
# (engine, plane) — phase -> counter name used in the balance check
_SWAP_PHASES = {"b": "quiesce-begin", "e": "quiesce-end",
                "X": "transfer", "i": "resume", "I": "resume"}


def _lifecycle_key(name: str, ph: str) -> str:
    return f"{name}/end" if (name, ph) == ("migrate.drain", "e") else name


def _alert_key(args: dict):
    """Identity of one alert: its rule plus the label args — everything
    the watchdog attaches except severity and the violating value."""
    return (args.get("rule"),
            tuple(sorted((k, str(v)) for k, v in args.items()
                         if k not in ("rule", "severity", "value"))))


def check_trace(doc, scenario=None) -> list:
    problems = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["no traceEvents list"]
    last_ts = {}
    async_open = {}
    seen = {}
    thread_names = {}     # (pid, tid) -> track name, from "M" metadata
    swap_counts = {}      # (engine, plane) -> {counter name: count}
    open_quiesce = {}     # engine -> index of the opening swap.quiesce
    swap_planes = set()   # planes with at least one swap.transfer
    checkpointed = set()  # engines with at least one checkpoint span
    open_failed = {}      # engine -> index of the opening fail instant
    open_alerts = {}      # (rule, labels) -> index of the firing instant
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in KNOWN_PHASES:
            problems.append(f"event {i}: unknown phase {ph!r}")
            continue
        if ph == "M":
            if ev.get("name") == "thread_name":
                thread_names[(ev.get("pid"), ev.get("tid"))] = \
                    (ev.get("args") or {}).get("name")
            continue
        name, ts = ev.get("name"), ev.get("ts")
        if not isinstance(name, str) or not name:
            problems.append(f"event {i}: missing name")
        if not isinstance(ts, (int, float)):
            problems.append(f"event {i}: non-numeric ts {ts!r}")
            continue
        key = _lifecycle_key(name, ph)
        seen.setdefault(key, set()).add(ph)
        # -- hot-swap lifecycle: the replay clock is virtual (a whole
        # quiesce can be zero-width in ts), so the no-dispatch-while-
        # quiesced rule goes by event ORDER, not timestamps
        args = ev.get("args") or {}
        if isinstance(name, str) and name.startswith("swap.") \
                and ph in _SWAP_PHASES:
            eng, plane = args.get("engine"), args.get("plane")
            cnt = swap_counts.setdefault(
                (eng, plane), {"quiesce-begin": 0, "quiesce-end": 0,
                               "transfer": 0, "resume": 0})
            cnt[_SWAP_PHASES[ph]] += 1
            if name == "swap.quiesce" and ph == "b":
                if eng in open_quiesce:
                    problems.append(
                        f"event {i}: nested swap.quiesce for engine "
                        f"{eng} (window from event "
                        f"{open_quiesce[eng]} still open)")
                open_quiesce[eng] = i
            elif name == "swap.quiesce" and ph == "e":
                if eng not in open_quiesce:
                    problems.append(
                        f"event {i}: swap.quiesce end without begin "
                        f"for engine {eng}")
                else:
                    del open_quiesce[eng]
            elif name == "swap.transfer":
                swap_planes.add(plane)
        # -- failover lifecycle: checkpoint-before-recover and the
        # no-dispatch-while-dark window are judged by event order too
        elif name == "checkpoint" and ph == "X":
            checkpointed.add(args.get("engine"))
        elif name == "fail" and ph in ("i", "I"):
            eng = args.get("engine")
            if eng in open_failed:
                problems.append(
                    f"event {i}: engine {eng} failed twice without a "
                    f"recover in between (first fail at event "
                    f"{open_failed[eng]})")
            open_failed[eng] = i
        elif name == "recover" and ph == "X":
            eng = args.get("engine")
            if eng not in open_failed:
                problems.append(
                    f"event {i}: recover for engine {eng} without a "
                    f"preceding fail")
            else:
                del open_failed[eng]
            if eng not in checkpointed:
                problems.append(
                    f"event {i}: recover for engine {eng} with no "
                    f"preceding checkpoint span for that engine")
        # -- watchdog alert lifecycle: resolve needs a prior fire, and
        # an alert the engine already holds active cannot fire again
        elif name == "alert.fire" and ph in ("i", "I"):
            k = _alert_key(args)
            if k in open_alerts:
                problems.append(
                    f"event {i}: alert {k[0]!r} {dict(k[1])} fired "
                    f"twice without a resolve in between (first fire "
                    f"at event {open_alerts[k]})")
            open_alerts[k] = i
        elif name == "alert.resolve" and ph in ("i", "I"):
            k = _alert_key(args)
            if k not in open_alerts:
                problems.append(
                    f"event {i}: alert.resolve for {k[0]!r} "
                    f"{dict(k[1])} without a preceding alert.fire")
            else:
                del open_alerts[k]
        elif name == "request.dispatch" and (open_quiesce or open_failed):
            tname = thread_names.get((ev.get("pid"), ev.get("tid")))
            for eng in open_quiesce:
                if tname == f"engine{eng}":
                    problems.append(
                        f"event {i}: request.dispatch on track "
                        f"{tname!r} inside engine {eng}'s "
                        f"swap.quiesce window")
            for eng in open_failed:
                if tname == f"engine{eng}":
                    problems.append(
                        f"event {i}: request.dispatch on track "
                        f"{tname!r} while engine {eng} is failed "
                        f"(fail at event {open_failed[eng]}, no "
                        f"recover yet)")
        if ph in ("b", "e"):
            # async events live on their (cat, id) timeline, not the
            # track's — don't hold them to per-track monotonicity
            aid = (ev.get("cat"), ev.get("id"), name)
            if ev.get("id") is None:
                problems.append(f"event {i}: async {ph!r} without id")
            if ph == "b":
                async_open[aid] = async_open.get(aid, 0) + 1
            else:
                if async_open.get(aid, 0) <= 0:
                    problems.append(
                        f"event {i}: async end without begin for {aid}")
                else:
                    async_open[aid] -= 1
            continue
        track = (ev.get("pid"), ev.get("tid"))
        if ts < last_ts.get(track, float("-inf")):
            problems.append(
                f"event {i} ({name}): ts {ts} goes backwards on track "
                f"{track} (last {last_ts[track]})")
        # an X span occupies [ts, ts+dur]; later events must not start
        # before it ended on the same track or the slices overlap
        end = ts + ev.get("dur", 0) if ph == "X" else ts
        last_ts[track] = max(last_ts.get(track, float("-inf")), end)
    for aid, n in async_open.items():
        if n > 0:
            problems.append(f"async begin without end for {aid}")
    for eng, idx in sorted(open_failed.items(), key=str):
        problems.append(
            f"engine {eng} failed at event {idx} and never recovered")
    for (eng, plane), cnt in sorted(swap_counts.items(), key=str):
        counts = [cnt["quiesce-begin"], cnt["quiesce-end"],
                  cnt["transfer"], cnt["resume"]]
        if not (counts[0] == counts[1] == counts[2] == counts[3] >= 1):
            problems.append(
                f"swap lifecycle unbalanced for engine {eng} plane "
                f"{plane!r}: " + ", ".join(
                    f"{k}={v}" for k, v in sorted(cnt.items())))
    if scenario == "stack_swap":
        for plane in ("serve", "bytes"):
            if plane not in swap_planes:
                problems.append(
                    f"stack_swap lifecycle incomplete: no "
                    f"swap.transfer on plane {plane!r}")
    if scenario == "migration":
        for key, phases in MIGRATION_LIFECYCLE.items():
            name = key.split("/", 1)[0]
            if not (seen.get(key, set()) & phases):
                problems.append(
                    f"migration lifecycle incomplete: no "
                    f"{sorted(phases)} event named {name!r}")
    if scenario == "failover":
        for name, phases in (("checkpoint", {"X"}), ("fail", {"i", "I"}),
                             ("recover", {"X"})):
            if not (seen.get(name, set()) & phases):
                problems.append(
                    f"failover lifecycle incomplete: no "
                    f"{sorted(phases)} event named {name!r}")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="validate a Chrome trace-event JSON trace")
    ap.add_argument("trace", type=pathlib.Path)
    ap.add_argument("--scenario", default=None,
                    help="also require this scenario's lifecycle events "
                         "(supported: migration, stack_swap, failover)")
    args = ap.parse_args(argv)
    try:
        doc = json.loads(args.trace.read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"unreadable trace: {e}")
        return 1
    problems = check_trace(doc, scenario=args.scenario)
    if problems:
        print(f"{args.trace}: trace invalid:")
        for p in problems:
            print(f"  {p}")
        return 1
    n = sum(1 for e in doc["traceEvents"]
            if isinstance(e, dict) and e.get("ph") != "M")
    print(f"{args.trace}: ok ({n} events"
          + (f", {args.scenario} lifecycle complete" if args.scenario
             else "") + ")")
    return 0


if __name__ == "__main__":
    sys.exit(main())
