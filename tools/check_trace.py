#!/usr/bin/env python3
"""Validate a fabric flight-recorder trace (Chrome trace-event JSON).

    python tools/check_trace.py TRACE.json [--scenario migration]

Checks, in order:
  * the file is well-formed JSON with a ``traceEvents`` list;
  * every event has the required fields for its phase (``ph``), with
    numeric ``ts`` and known phases only;
  * per (pid, tid) track, non-async event timestamps are monotonically
    non-decreasing (Perfetto renders out-of-order slices as garbage);
  * async begin/end ("b"/"e") events pair up per (cat, id, name);
  * with ``--scenario migration``: the trace contains the full
    stack-module lifecycle — migrate.transfer and migrate.finalize
    spans, a migrate.drain begin/end pair, and park/unpark instants.

Stdlib only (runs in CI before any pip install). Exit 1 with a listing
on any violation.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

KNOWN_PHASES = {"X", "B", "E", "i", "I", "b", "e", "n", "M", "C"}

# the lifecycle the migration scenario's trace must show: event name ->
# set of phases at least one event must carry
MIGRATION_LIFECYCLE = {
    "migrate.transfer": {"X"},
    "migrate.drain": {"b"},
    "migrate.drain/end": {"e"},          # pseudo-key: see _lifecycle_key
    "migrate.finalize": {"X"},
    "park": {"i", "I"},
    "unpark": {"i", "I"},
}


def _lifecycle_key(name: str, ph: str) -> str:
    return f"{name}/end" if (name, ph) == ("migrate.drain", "e") else name


def check_trace(doc, scenario=None) -> list:
    problems = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["no traceEvents list"]
    last_ts = {}
    async_open = {}
    seen = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in KNOWN_PHASES:
            problems.append(f"event {i}: unknown phase {ph!r}")
            continue
        if ph == "M":
            continue
        name, ts = ev.get("name"), ev.get("ts")
        if not isinstance(name, str) or not name:
            problems.append(f"event {i}: missing name")
        if not isinstance(ts, (int, float)):
            problems.append(f"event {i}: non-numeric ts {ts!r}")
            continue
        key = _lifecycle_key(name, ph)
        seen.setdefault(key, set()).add(ph)
        if ph in ("b", "e"):
            # async events live on their (cat, id) timeline, not the
            # track's — don't hold them to per-track monotonicity
            aid = (ev.get("cat"), ev.get("id"), name)
            if ev.get("id") is None:
                problems.append(f"event {i}: async {ph!r} without id")
            if ph == "b":
                async_open[aid] = async_open.get(aid, 0) + 1
            else:
                if async_open.get(aid, 0) <= 0:
                    problems.append(
                        f"event {i}: async end without begin for {aid}")
                else:
                    async_open[aid] -= 1
            continue
        track = (ev.get("pid"), ev.get("tid"))
        if ts < last_ts.get(track, float("-inf")):
            problems.append(
                f"event {i} ({name}): ts {ts} goes backwards on track "
                f"{track} (last {last_ts[track]})")
        # an X span occupies [ts, ts+dur]; later events must not start
        # before it ended on the same track or the slices overlap
        end = ts + ev.get("dur", 0) if ph == "X" else ts
        last_ts[track] = max(last_ts.get(track, float("-inf")), end)
    for aid, n in async_open.items():
        if n > 0:
            problems.append(f"async begin without end for {aid}")
    if scenario == "migration":
        for key, phases in MIGRATION_LIFECYCLE.items():
            name = key.split("/", 1)[0]
            if not (seen.get(key, set()) & phases):
                problems.append(
                    f"migration lifecycle incomplete: no "
                    f"{sorted(phases)} event named {name!r}")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="validate a Chrome trace-event JSON trace")
    ap.add_argument("trace", type=pathlib.Path)
    ap.add_argument("--scenario", default=None,
                    help="also require this scenario's lifecycle events "
                         "(supported: migration)")
    args = ap.parse_args(argv)
    try:
        doc = json.loads(args.trace.read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"unreadable trace: {e}")
        return 1
    problems = check_trace(doc, scenario=args.scenario)
    if problems:
        print(f"{args.trace}: trace invalid:")
        for p in problems:
            print(f"  {p}")
        return 1
    n = sum(1 for e in doc["traceEvents"]
            if isinstance(e, dict) and e.get("ph") != "M")
    print(f"{args.trace}: ok ({n} events"
          + (f", {args.scenario} lifecycle complete" if args.scenario
             else "") + ")")
    return 0


if __name__ == "__main__":
    sys.exit(main())
