#!/usr/bin/env python3
"""Link-check the docs tree: every relative markdown link in README.md and
docs/*.md must resolve to an existing file. Stdlib only (CI's docs job
runs this before pip has installed anything heavy).

Exit status 1 with a listing if any link is broken.
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
# inline markdown links [text](target); images ![alt](target) match too
LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
SKIP_SCHEMES = ("http://", "https://", "mailto:")


def files_to_check():
    docs = ROOT / "docs"
    out = [ROOT / "README.md"]
    if docs.is_dir():
        out += sorted(docs.glob("*.md"))
    return out


def broken_links(md: pathlib.Path):
    for target in LINK.findall(md.read_text()):
        if target.startswith(SKIP_SCHEMES):
            continue
        path = target.split("#", 1)[0]
        if not path:                     # pure intra-document anchor
            continue
        if not (md.parent / path).resolve().exists():
            yield target


def main() -> int:
    checked, broken = 0, []
    for md in files_to_check():
        checked += 1
        broken += [f"{md.relative_to(ROOT)}: {t}" for t in broken_links(md)]
    if broken:
        print("broken links:")
        for b in broken:
            print(f"  {b}")
        return 1
    print(f"checked {checked} markdown files; all relative links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
