#!/usr/bin/env python3
"""One-screen fabric snapshot rendered from a Prometheus scrape alone.

    PYTHONPATH=src python tools/nk_top.py SCRAPE.txt
    PYTHONPATH=src python tools/nk_top.py OLD.txt NEW.txt   # diff mode
    PYTHONPATH=src:. python tools/nk_top.py --demo

Reads one text-format export (the output of any ``export_prometheus()``
or a ``MetricsRegistry`` collecting several), parses it with the strict
scrape-side parser, and renders what an operator wants at a glance:

  * the fabric summary — engines up/parked, steps, migrations in flight
    and completed, average cores saved by the autopilot;
  * a per-engine table — load, decode steps, parked state;
  * a per-tenant table — current engine, admit-wait p50/p99 estimated
    from the exported histogram buckets (same upper-edge rule as
    ``repro.obs.hist.Histogram.quantile``);
  * the recent live migrations from ``nk_migration_info`` series.

With TWO scrape files the tool switches to diff mode: both are loaded
into a ``repro.obs.timeseries.SeriesStore`` and rendered as *true
rates* — tokens/s and bytes/s per tenant, migrations and checkpoints
per minute — using the store's counter-reset-aware ``rate()``, so a
restarted engine between the two scrapes reads as a reset, never as a
negative rate. Scrape timestamps come from a leading ``# SCRAPE ts=``
header (what ``FabricWatchdog.write_scrapes`` emits) when present,
else from ``--dt``.

Everything is derived from the scrape text: no handle on the live
cluster, no side channel. ``--demo`` builds the test suite's jit-free
fake cluster, drives a migration, exports through a MetricsRegistry
twice, and renders the second snapshot plus the diff between them — a
self-contained smoke test of both paths.
"""
from __future__ import annotations

import argparse
import math
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))


def _fmt(v, unit=""):
    if v is None:
        return "-"
    if math.isnan(v):
        # "no data" (an empty latency window) must render as absence,
        # not as a number an operator could mistake for a measurement
        return "-"
    if unit == "s":
        return f"{v * 1e3:.1f}ms" if v < 1.0 else f"{v:.2f}s"
    if abs(v) >= 1e9:
        return f"{v / 1e9:.2f}G{unit}"
    if abs(v) >= 1e6:
        return f"{v / 1e6:.2f}M{unit}"
    if v == int(v):
        return f"{int(v)}{unit}"
    return f"{v:.3g}{unit}"


class Scrape:
    """Indexed view over parsed (name, labels) -> value series."""

    def __init__(self, series):
        self.series = series

    def value(self, name, **labels):
        want = tuple(sorted((k, str(v)) for k, v in labels.items()))
        for (n, lbl), v in self.series.items():
            if n == name and tuple(sorted(lbl)) == want:
                return v
        return None

    def by_label(self, name, label):
        """All series of ``name`` keyed by one label's value."""
        out = {}
        for (n, lbl), v in self.series.items():
            d = dict(lbl)
            if n == name and label in d:
                out[d[label]] = v
        return out

    def label_values(self, name, label):
        return sorted(self.by_label(name, label),
                      key=lambda s: (len(s), s))

    def hist_quantile(self, family, q, **labels):
        """Quantile from cumulative ``_bucket`` series (upper edge)."""
        want = tuple(sorted((k, str(v)) for k, v in labels.items()))
        buckets = []
        for (n, lbl), v in self.series.items():
            if n != family + "_bucket":
                continue
            d = dict(lbl)
            le = d.pop("le", None)
            if le is None or tuple(sorted(d.items())) != want:
                continue
            edge = float("inf") if le == "+Inf" else float(le)
            buckets.append((edge, v))
        if not buckets:
            return None
        buckets.sort()
        total = buckets[-1][1]
        if total <= 0:
            return None
        rank = max(1, math.ceil(q * total))
        for edge, cum in buckets:
            if cum >= rank:
                return edge
        return buckets[-1][0]


def _table(rows, headers):
    rows = [headers] + [[str(c) for c in r] for r in rows]
    widths = [max(len(r[i]) for r in rows) for i in range(len(headers))]
    out = []
    for j, r in enumerate(rows):
        out.append("  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip())
        if j == 0:
            out.append("  ".join("-" * w for w in widths))
    return "\n".join(out)


def render(scrape: Scrape) -> str:
    s = scrape
    lines = []

    engines = s.value("nk_cluster_engines")
    parked = s.value("nk_cluster_parked")
    steps = s.value("nk_cluster_steps_total")
    draining = s.value("nk_migrations_draining")
    done = s.value("nk_migrations_completed_total")
    saved = s.value("nk_cores_saved")
    head = ["nk_top — fabric snapshot"]
    if engines is not None:
        head.append(f"engines {_fmt(engines)} ({_fmt(parked or 0)} parked)")
    if steps is not None:
        head.append(f"steps {_fmt(steps)}")
    if done is not None or draining is not None:
        head.append(f"migrations {_fmt(done or 0)} done"
                    f" / {_fmt(draining or 0)} draining")
    if saved is not None:
        head.append(f"cores saved {saved:.2f}")
    lines.append("  |  ".join(head))

    # control-plane tick cost, straight from the counters: mean µs per
    # controller tick and the tenant population the last tick covered
    ticks = s.value("nk_control_ticks_total")
    secs = s.value("nk_control_tick_seconds_total")
    tenants_per_tick = s.value("nk_control_tenants")
    if ticks:
        ctrl = [f"control {_fmt(ticks)} ticks"]
        if secs is not None:
            ctrl.append(f"{secs / ticks * 1e6:.0f}us/tick")
        if tenants_per_tick is not None:
            ctrl.append(f"{_fmt(tenants_per_tick)} tenants/tick")
        lines.append("  |  ".join(ctrl))
    lines.append("")

    loads = s.by_label("nk_engine_load", "engine")
    if loads:
        parked_by = s.by_label("nk_engine_parked", "engine")
        steps_by = s.by_label("nk_engine_decode_steps_total", "engine")
        rows = [[k, _fmt(loads.get(k)), _fmt(steps_by.get(k)),
                 "parked" if parked_by.get(k) else "up"]
                for k in s.label_values("nk_engine_load", "engine")]
        lines.append(_table(rows, ["engine", "load", "decode_steps",
                                   "state"]))
        lines.append("")

    placement = s.by_label("nk_placement", "tenant")
    wait_tenants = s.label_values("nk_admit_wait_seconds_count", "tenant")
    tenants = sorted(set(placement) | set(wait_tenants),
                     key=lambda t: (len(t), t))
    if tenants:
        rows = []
        for t in tenants:
            eng = placement.get(t)
            n = s.value("nk_admit_wait_seconds_count", tenant=t)
            rows.append([
                t,
                _fmt(eng) if eng is not None else "-",
                _fmt(n or 0),
                _fmt(s.hist_quantile("nk_admit_wait_seconds", 0.50,
                                     tenant=t), "s"),
                _fmt(s.hist_quantile("nk_admit_wait_seconds", 0.99,
                                     tenant=t), "s"),
                _fmt(s.hist_quantile("nk_ttft_seconds", 0.99,
                                     tenant=t), "s"),
                _fmt(s.hist_quantile("nk_e2e_seconds", 0.99,
                                     tenant=t), "s"),
            ])
        lines.append(_table(rows, ["tenant", "engine", "admits",
                                   "wait_p50", "wait_p99", "ttft_p99",
                                   "e2e_p99"]))
        lines.append("")

    moves = []
    for (n, lbl), v in s.series.items():
        if n == "nk_migration_info":
            d = dict(lbl)
            moves.append((float(d.get("seq", v)), d))
    if moves:
        moves.sort(key=lambda m: m[0])
        rows = [[_fmt(seq), d.get("tenant", "?"),
                 f"{d.get('src', '?')} -> {d.get('dst', '?')}"]
                for seq, d in moves]
        lines.append(_table(rows, ["step", "tenant", "move"]))
        lines.append("")

    if len(lines) <= 2:
        lines.append("(no fabric series in scrape — is this a cluster "
                     "export?)")
    return "\n".join(lines).rstrip() + "\n"


def _scrape_ts(text: str):
    """Timestamp from a leading ``# SCRAPE ts=`` header, else None."""
    from repro.obs.slo import SCRAPE_HEADER

    for line in text.splitlines():
        if line.startswith(SCRAPE_HEADER):
            try:
                return float(line[len(SCRAPE_HEADER):].strip())
            except ValueError:
                return None
        if line and not line.startswith("#"):
            break
    return None


def render_diff(old_text: str, new_text: str, dt: float = 1.0) -> str:
    """True rates between two scrapes via reset-aware ``rate()``.

    ``dt`` is the spacing used when the scrapes carry no ``# SCRAPE ts=``
    headers. An engine restart between the scrapes rebaselines (the
    decrease contributes zero) instead of printing a negative rate."""
    from repro.obs.timeseries import SeriesStore, series_key

    t0, t1 = _scrape_ts(old_text), _scrape_ts(new_text)
    if t0 is None or t1 is None or t1 <= t0:
        t0, t1 = 0.0, float(dt)
    store = SeriesStore()
    store.ingest(old_text, ts=t0)
    store.ingest(new_text, ts=t1)
    span = t1 - t0

    def rate(name, **labels):
        key = series_key(name, **labels)
        return store.rate(key) if store.latest(key) is not None else None

    lines = [f"nk_top — diff over {span:.3g}s (reset-aware rates)", ""]

    fleet = []
    for label, name, scale, unit in (
            ("steps/s", "nk_cluster_steps_total", 1.0, "/s"),
            ("decode steps/s", "nk_cluster_decode_steps_total", 1.0, "/s"),
            ("migrations/min", "nk_migrations_completed_total", 60.0,
             "/min"),
            ("checkpoints/min", "nk_checkpoints_total", 60.0, "/min"),
            ("recoveries/min", "nk_recoveries_total", 60.0, "/min"),
            ("bytes freed/s", "nk_bytes_freed_total", 1.0, "B/s")):
        r = rate(name)
        if r is not None:
            fleet.append([label, _fmt(r * scale, unit)])
    if fleet:
        lines.append(_table(fleet, ["fleet", "rate"]))
        lines.append("")

    tenants = sorted(
        {v for name in ("nk_served_tokens_total", "nk_offered_bytes_total",
                        "nk_deferred_polls_total")
         for v in store.label_values(name, "tenant")},
        key=lambda s: (len(s), s))
    if tenants:
        rows = []
        for t in tenants:
            rows.append([
                t,
                _fmt(rate("nk_served_tokens_total", tenant=t), "tok/s"),
                _fmt(rate("nk_offered_bytes_total", tenant=t), "B/s"),
                _fmt(rate("nk_deferred_polls_total", tenant=t), "/s"),
            ])
        lines.append(_table(rows, ["tenant", "served", "offered",
                                   "deferred"]))
        lines.append("")

    if len(lines) <= 2:
        lines.append("(no counter series shared by both scrapes)")
    return "\n".join(lines).rstrip() + "\n"


def demo_scrapes():
    """Drive the jit-free fake cluster; export twice via one registry.

    Returns ``(old_text, new_text)`` — snapshots a migration apart, so
    the diff path renders non-trivial rates."""
    from repro.control.controller import RateController
    from repro.control.placement import PlacementController
    from repro.obs.metrics import MetricsRegistry
    from repro.serve.scheduler import Request
    from tests.test_placement import make_fake_cluster

    cluster = make_fake_cluster(3, controller=RateController(512.0,
                                                             alpha=0.6))
    for t in range(4):
        cluster.add_tenant(t)
        for r in range(3):
            cluster.submit(Request(t, [1, 2], 4, req_id=10 * t + r,
                                   arrival=0.1 * r))
    for i in range(8):
        cluster.step(now=0.1 * (i + 1))

    reg = MetricsRegistry()
    # the cluster folds its attached autopilot's and controller's
    # counters into its own export, so one provider covers the fabric
    reg.register_provider(cluster, name="cluster")
    old = f"# SCRAPE ts=0.8\n{reg.export_prometheus()}"

    # a second wave of traffic between the snapshots, so the diff
    # renders non-zero per-tenant served rates
    for t in range(4):
        for r in range(3):
            cluster.submit(Request(t, [1, 2], 4, req_id=100 + 10 * t + r,
                                   arrival=1.0 + 0.1 * r))
    cluster.migrate(0, (cluster.placement[0] + 1) % 3, now=1.0)
    for i in range(8):
        cluster.step(now=1.0 + 0.1 * (i + 1))
    pilot = PlacementController(cluster, policy="spread_hot")
    cluster.attach_autopilot(pilot)
    pilot.tick(now=3.0)
    new = f"# SCRAPE ts=1.8\n{reg.export_prometheus()}"
    return old, new


def demo_scrape() -> str:
    """The second demo snapshot (single-scrape rendering path)."""
    return demo_scrapes()[1]


def main(argv=None) -> int:
    from repro.obs.metrics import parse_prometheus_text

    ap = argparse.ArgumentParser(
        description="render a fabric snapshot from a Prometheus scrape, "
                    "or true rates from two")
    ap.add_argument("scrape", nargs="?", type=pathlib.Path,
                    help="text-format export to render")
    ap.add_argument("scrape2", nargs="?", type=pathlib.Path,
                    help="second (newer) scrape: render the diff as rates")
    ap.add_argument("--demo", action="store_true",
                    help="drive the fake cluster and render its export "
                         "(snapshot + diff)")
    ap.add_argument("--dt", type=float, default=1.0,
                    help="seconds between the two scrapes when they carry "
                         "no '# SCRAPE ts=' headers (default 1.0)")
    args = ap.parse_args(argv)
    if args.demo:
        old_text, text = demo_scrapes()
    elif args.scrape is not None:
        try:
            text = args.scrape.read_text()
            old_text = None
            if args.scrape2 is not None:
                old_text, text = text, args.scrape2.read_text()
        except OSError as e:
            print(f"unreadable scrape: {e}")
            return 1
    else:
        ap.error("need a SCRAPE file or --demo")
    try:
        series = parse_prometheus_text(text)
    except ValueError as e:
        print(f"scrape does not parse: {e}")
        return 1
    if old_text is not None:
        try:
            sys.stdout.write(render_diff(old_text, text, dt=args.dt))
        except ValueError as e:
            print(f"old scrape does not parse: {e}")
            return 1
        if not args.demo:
            return 0
        sys.stdout.write("\n")
    sys.stdout.write(render(Scrape(series)))
    return 0


if __name__ == "__main__":
    sys.exit(main())
