#!/usr/bin/env python3
"""One-screen fabric snapshot rendered from a Prometheus scrape alone.

    PYTHONPATH=src python tools/nk_top.py SCRAPE.txt
    PYTHONPATH=src:. python tools/nk_top.py --demo

Reads one text-format export (the output of any ``export_prometheus()``
or a ``MetricsRegistry`` collecting several), parses it with the strict
scrape-side parser, and renders what an operator wants at a glance:

  * the fabric summary — engines up/parked, steps, migrations in flight
    and completed, average cores saved by the autopilot;
  * a per-engine table — load, decode steps, parked state;
  * a per-tenant table — current engine, admit-wait p50/p99 estimated
    from the exported histogram buckets (same upper-edge rule as
    ``repro.obs.hist.Histogram.quantile``);
  * the recent live migrations from ``nk_migration_info`` series.

Everything is derived from the scrape text: no handle on the live
cluster, no side channel. ``--demo`` builds the test suite's jit-free
fake cluster, drives a migration, exports through a MetricsRegistry,
and renders that — a self-contained smoke test of the whole path.
"""
from __future__ import annotations

import argparse
import math
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))


def _fmt(v, unit=""):
    if v is None:
        return "-"
    if math.isnan(v):
        return "NaN"
    if unit == "s":
        return f"{v * 1e3:.1f}ms" if v < 1.0 else f"{v:.2f}s"
    if abs(v) >= 1e9:
        return f"{v / 1e9:.2f}G{unit}"
    if abs(v) >= 1e6:
        return f"{v / 1e6:.2f}M{unit}"
    if v == int(v):
        return f"{int(v)}{unit}"
    return f"{v:.3g}{unit}"


class Scrape:
    """Indexed view over parsed (name, labels) -> value series."""

    def __init__(self, series):
        self.series = series

    def value(self, name, **labels):
        want = tuple(sorted((k, str(v)) for k, v in labels.items()))
        for (n, lbl), v in self.series.items():
            if n == name and tuple(sorted(lbl)) == want:
                return v
        return None

    def by_label(self, name, label):
        """All series of ``name`` keyed by one label's value."""
        out = {}
        for (n, lbl), v in self.series.items():
            d = dict(lbl)
            if n == name and label in d:
                out[d[label]] = v
        return out

    def label_values(self, name, label):
        return sorted(self.by_label(name, label),
                      key=lambda s: (len(s), s))

    def hist_quantile(self, family, q, **labels):
        """Quantile from cumulative ``_bucket`` series (upper edge)."""
        want = tuple(sorted((k, str(v)) for k, v in labels.items()))
        buckets = []
        for (n, lbl), v in self.series.items():
            if n != family + "_bucket":
                continue
            d = dict(lbl)
            le = d.pop("le", None)
            if le is None or tuple(sorted(d.items())) != want:
                continue
            edge = float("inf") if le == "+Inf" else float(le)
            buckets.append((edge, v))
        if not buckets:
            return None
        buckets.sort()
        total = buckets[-1][1]
        if total <= 0:
            return None
        rank = max(1, math.ceil(q * total))
        for edge, cum in buckets:
            if cum >= rank:
                return edge
        return buckets[-1][0]


def _table(rows, headers):
    rows = [headers] + [[str(c) for c in r] for r in rows]
    widths = [max(len(r[i]) for r in rows) for i in range(len(headers))]
    out = []
    for j, r in enumerate(rows):
        out.append("  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip())
        if j == 0:
            out.append("  ".join("-" * w for w in widths))
    return "\n".join(out)


def render(scrape: Scrape) -> str:
    s = scrape
    lines = []

    engines = s.value("nk_cluster_engines")
    parked = s.value("nk_cluster_parked")
    steps = s.value("nk_cluster_steps_total")
    draining = s.value("nk_migrations_draining")
    done = s.value("nk_migrations_completed_total")
    saved = s.value("nk_cores_saved")
    head = ["nk_top — fabric snapshot"]
    if engines is not None:
        head.append(f"engines {_fmt(engines)} ({_fmt(parked or 0)} parked)")
    if steps is not None:
        head.append(f"steps {_fmt(steps)}")
    if done is not None or draining is not None:
        head.append(f"migrations {_fmt(done or 0)} done"
                    f" / {_fmt(draining or 0)} draining")
    if saved is not None:
        head.append(f"cores saved {saved:.2f}")
    lines.append("  |  ".join(head))
    lines.append("")

    loads = s.by_label("nk_engine_load", "engine")
    if loads:
        parked_by = s.by_label("nk_engine_parked", "engine")
        steps_by = s.by_label("nk_engine_decode_steps_total", "engine")
        rows = [[k, _fmt(loads.get(k)), _fmt(steps_by.get(k)),
                 "parked" if parked_by.get(k) else "up"]
                for k in s.label_values("nk_engine_load", "engine")]
        lines.append(_table(rows, ["engine", "load", "decode_steps",
                                   "state"]))
        lines.append("")

    placement = s.by_label("nk_placement", "tenant")
    wait_tenants = s.label_values("nk_admit_wait_seconds_count", "tenant")
    tenants = sorted(set(placement) | set(wait_tenants),
                     key=lambda t: (len(t), t))
    if tenants:
        rows = []
        for t in tenants:
            eng = placement.get(t)
            n = s.value("nk_admit_wait_seconds_count", tenant=t)
            rows.append([
                t,
                _fmt(eng) if eng is not None else "-",
                _fmt(n or 0),
                _fmt(s.hist_quantile("nk_admit_wait_seconds", 0.50,
                                     tenant=t), "s"),
                _fmt(s.hist_quantile("nk_admit_wait_seconds", 0.99,
                                     tenant=t), "s"),
                _fmt(s.hist_quantile("nk_ttft_seconds", 0.99,
                                     tenant=t), "s"),
                _fmt(s.hist_quantile("nk_e2e_seconds", 0.99,
                                     tenant=t), "s"),
            ])
        lines.append(_table(rows, ["tenant", "engine", "admits",
                                   "wait_p50", "wait_p99", "ttft_p99",
                                   "e2e_p99"]))
        lines.append("")

    moves = []
    for (n, lbl), v in s.series.items():
        if n == "nk_migration_info":
            d = dict(lbl)
            moves.append((float(d.get("seq", v)), d))
    if moves:
        moves.sort(key=lambda m: m[0])
        rows = [[_fmt(seq), d.get("tenant", "?"),
                 f"{d.get('src', '?')} -> {d.get('dst', '?')}"]
                for seq, d in moves]
        lines.append(_table(rows, ["step", "tenant", "move"]))
        lines.append("")

    if len(lines) <= 2:
        lines.append("(no fabric series in scrape — is this a cluster "
                     "export?)")
    return "\n".join(lines).rstrip() + "\n"


def demo_scrape() -> str:
    """Drive the jit-free fake cluster and export via a registry."""
    from repro.control.placement import PlacementController
    from repro.obs.metrics import MetricsRegistry
    from repro.serve.scheduler import Request
    from tests.test_placement import make_fake_cluster

    cluster = make_fake_cluster(3)
    for t in range(4):
        cluster.add_tenant(t)
        for r in range(3):
            cluster.submit(Request(t, [1, 2], 4, req_id=10 * t + r,
                                   arrival=0.1 * r))
    for i in range(8):
        cluster.step(now=0.1 * (i + 1))
    cluster.migrate(0, (cluster.placement[0] + 1) % 3, now=1.0)
    for i in range(8):
        cluster.step(now=1.0 + 0.1 * (i + 1))
    pilot = PlacementController(cluster, policy="spread_hot")
    cluster.attach_autopilot(pilot)
    pilot.tick(now=3.0)

    reg = MetricsRegistry()
    # the cluster folds its attached autopilot's counters into its own
    # export, so one provider covers the whole fabric
    reg.register_provider(cluster, name="cluster")
    return reg.export_prometheus()


def main(argv=None) -> int:
    from repro.obs.metrics import parse_prometheus_text

    ap = argparse.ArgumentParser(
        description="render a fabric snapshot from a Prometheus scrape")
    ap.add_argument("scrape", nargs="?", type=pathlib.Path,
                    help="text-format export to render")
    ap.add_argument("--demo", action="store_true",
                    help="drive the fake cluster and render its export")
    args = ap.parse_args(argv)
    if args.demo:
        text = demo_scrape()
    elif args.scrape is not None:
        try:
            text = args.scrape.read_text()
        except OSError as e:
            print(f"unreadable scrape: {e}")
            return 1
    else:
        ap.error("need a SCRAPE file or --demo")
    try:
        series = parse_prometheus_text(text)
    except ValueError as e:
        print(f"scrape does not parse: {e}")
        return 1
    sys.stdout.write(render(Scrape(series)))
    return 0


if __name__ == "__main__":
    sys.exit(main())
