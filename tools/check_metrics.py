#!/usr/bin/env python3
"""Validate every live exporter's ``export_prometheus()`` output against
the text-format grammar.

    PYTHONPATH=src python tools/check_metrics.py

Builds one jit-free module per exporter family (the same fakes the test
suite drives clusters with), exercises enough traffic that every metric
family appears, then parses each export with the strict scrape-side
parser (``repro.obs.metrics.parse_prometheus_text``): HELP/TYPE lines,
label escaping, value rendering, and no duplicate series. This is the CI
gate that keeps ``format_prometheus`` honest — a new counter with an
unescaped label or a colliding name fails the docs job, not a user's
scrape.

Runs against the same jit-free fakes the test suite drives clusters
with, so it needs the dev environment (pytest importable) but finishes
in well under a second.
"""
from __future__ import annotations

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))


class _Payload:
    """Duck-typed array descriptor CoreEngine.dispatch sizes bytes from."""

    dtype = None

    def __init__(self, n):
        import numpy as np
        self.dtype = np.uint8
        self.shape = (int(n),)


def build_exporters():
    """Live exporter instances with enough traffic to emit every family."""
    from repro.control.controller import RateController
    from repro.control.placement import PlacementController
    from repro.control.telemetry import EngineTelemetry, SchedulerTelemetry
    from repro.core.engine import CoreEngine
    from repro.serve.scheduler import Request, TenantScheduler
    from tests.test_placement import make_fake_cluster

    # serve plane: scheduler + telemetry + controller
    sched = TenantScheduler(charge_prompt=True)
    for t in (0, 1):
        sched.add_tenant(t, rate_tokens_per_s=8.0)
        sched.submit(Request(t, [1, 2], 4, req_id=t + 1, arrival=0.0))
    r = sched.next_request(now=0.5)
    if r is not None:
        sched.account(r.tenant_id, 6)
    stel = SchedulerTelemetry(sched)
    stel.update(0.0)
    stel.update(1.0)

    # bytes plane: CoreEngine + telemetry
    core = CoreEngine(enforcement="account")
    core.set_tenant_rate(0, 1e6, burst=1e6)
    core.dispatch("shm_move", _Payload(4096), ("pod",), tenant_id=0,
                  now=0.5)
    etel = EngineTelemetry(core)
    etel.update(0.0)
    etel.update(1.0)

    ctrl = RateController(64.0).attach_scheduler(sched)
    ctrl.tick(2.0)
    ctrl.tick(3.0)

    # cluster + autopilot over the test suite's jit-free fakes, driven
    # through a migration so placement/migration/latency series exist
    cluster = make_fake_cluster(3)
    for t in range(3):
        cluster.add_tenant(t)
        cluster.submit(Request(t, [1, 2], 4, req_id=10 + t, arrival=0.0))
    for i in range(6):
        cluster.step(now=0.1 * (i + 1))
    cluster.migrate(0, (cluster.placement[0] + 1) % 3, now=1.0)
    for i in range(6):
        cluster.step(now=1.0 + 0.1 * (i + 1))
    pilot = PlacementController(cluster, policy="spread_hot")
    pilot.tick(now=3.0)
    cluster.attach_autopilot(pilot)

    return {
        "SchedulerTelemetry": stel,
        "EngineTelemetry": etel,
        "RateController": ctrl,
        "PlacementController": pilot,
        "EngineCluster": cluster,
    }


def main() -> int:
    from repro.obs.metrics import parse_prometheus_text

    failures = []
    total = 0
    for name, exporter in build_exporters().items():
        text = exporter.export_prometheus() if hasattr(
            exporter, "export_prometheus") else None
        if text is None:
            from repro.control.telemetry import format_prometheus
            text = format_prometheus(exporter.counters())
        try:
            series = parse_prometheus_text(text)
        except ValueError as e:
            failures.append(f"{name}: {e}")
            continue
        if not series:
            failures.append(f"{name}: export is empty")
            continue
        total += len(series)
        print(f"{name}: {len(series)} series ok")
    if failures:
        print("invalid prometheus exports:")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"all exports parse under the text-format grammar "
          f"({total} series)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
