#!/usr/bin/env python3
"""Gate a bench_fairness --json result against committed thresholds.

    python tools/check_bench.py RESULTS.json benchmarks/bench_thresholds.json

Thresholds map metric names (the bench's "section,metric" row names) to
{"min": x} / {"max": x} bounds (inclusive). A metric missing from the
results is a failure too — a silently dropped bench must not pass the
gate. Keys starting with "_" are comments. Stdlib only, exit 1 with a
listing on any violation.
"""
from __future__ import annotations

import json
import pathlib
import sys


def check(results: dict, thresholds: dict) -> list:
    metrics = results.get("metrics", {})
    problems = []
    for name, bound in thresholds.items():
        if name.startswith("_"):
            continue
        if name not in metrics:
            problems.append(f"{name}: missing from results")
            continue
        v = metrics[name]
        if "min" in bound and v < bound["min"]:
            problems.append(f"{name}: {v:.4f} < min {bound['min']}")
        if "max" in bound and v > bound["max"]:
            problems.append(f"{name}: {v:.4f} > max {bound['max']}")
    if not results.get("ok", False):
        problems.append("bench reported ok=false (a claim failed)")
    return problems


def main(argv) -> int:
    if len(argv) != 2:
        print(__doc__.strip())
        return 2
    results = json.loads(pathlib.Path(argv[0]).read_text())
    thresholds = json.loads(pathlib.Path(argv[1]).read_text())
    problems = check(results, thresholds)
    if problems:
        print("bench regression vs committed thresholds:")
        for p in problems:
            print(f"  {p}")
        return 1
    n = sum(1 for k in thresholds if not k.startswith("_"))
    print(f"all {n} thresholds hold")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
