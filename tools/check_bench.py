#!/usr/bin/env python3
"""Gate bench --json results against committed thresholds.

    python tools/check_bench.py RESULTS.json [MORE.json ...] \\
        benchmarks/bench_thresholds.json

The LAST argument is the thresholds file; every earlier argument is a
results document (bench_fairness, bench_control_scale, ...). Several
results files are merged — metric maps unioned (a duplicate metric name
across files is an error: two benches must not claim the same row), and
the overall ``ok`` flag is the AND across files — so one shared
thresholds file gates the whole suite.

Thresholds map metric names (the bench's "section,metric" row names) to
{"min": x} / {"max": x} bounds (inclusive). A metric missing from the
results is a failure too — a silently dropped bench must not pass the
gate. Keys starting with "_" are comments. Stdlib only, exit 1 with a
listing on any violation.
"""
from __future__ import annotations

import json
import pathlib
import sys


def merge_results(docs: list) -> dict:
    """Union several bench --json docs into one checkable document."""
    merged = {"ok": True, "metrics": {}}
    for doc in docs:
        merged["ok"] = merged["ok"] and bool(doc.get("ok", False))
        for name, v in doc.get("metrics", {}).items():
            if name in merged["metrics"]:
                raise ValueError(f"metric {name!r} appears in more than "
                                 f"one results file")
            merged["metrics"][name] = v
    return merged


def check(results: dict, thresholds: dict) -> list:
    metrics = results.get("metrics", {})
    problems = []
    for name, bound in thresholds.items():
        if name.startswith("_"):
            continue
        if name not in metrics:
            problems.append(f"{name}: missing from results")
            continue
        v = metrics[name]
        if "min" in bound and v < bound["min"]:
            problems.append(f"{name}: {v:.4f} < min {bound['min']}")
        if "max" in bound and v > bound["max"]:
            problems.append(f"{name}: {v:.4f} > max {bound['max']}")
    if not results.get("ok", False):
        problems.append("bench reported ok=false (a claim failed)")
    return problems


def main(argv) -> int:
    if len(argv) < 2:
        print(__doc__.strip())
        return 2
    docs = [json.loads(pathlib.Path(p).read_text()) for p in argv[:-1]]
    thresholds = json.loads(pathlib.Path(argv[-1]).read_text())
    try:
        results = merge_results(docs)
    except ValueError as e:
        print(f"bad results set: {e}")
        return 2
    problems = check(results, thresholds)
    if problems:
        print("bench regression vs committed thresholds:")
        for p in problems:
            print(f"  {p}")
        return 1
    n = sum(1 for k in thresholds if not k.startswith("_"))
    print(f"all {n} thresholds hold")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
