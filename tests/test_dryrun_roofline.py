"""Dry-run machinery at test scale + roofline HLO parsers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.launch import roofline as rl
from repro.launch.mesh import make_host_mesh


def _compiled_with_scan(mesh, n_iters=7):
    def f(x):
        def body(c, _):
            # keep the carry varying over 'model' so VMA types match
            return c * 0.5 + jax.lax.psum(c, "model") * 0.25, None
        c, _ = jax.lax.scan(body, x, None, length=n_iters)
        return c
    g = shard_map(f, mesh=mesh, in_specs=P("data", "model"),
                  out_specs=P("data", "model"))
    return jax.jit(g).lower(jnp.ones((8, 64), jnp.float32)).compile()


def test_collective_bytes_multiplies_trip_count(mesh8):
    comp = _compiled_with_scan(mesh8, n_iters=7)
    total, kinds = rl.collective_bytes(comp.as_text())
    # per-device psum payload: (4, 16) f32 = 256B, once per loop iter
    assert "all-reduce" in kinds
    assert kinds["all-reduce"] == 7 * 4 * 16 * 4, kinds


def test_hlo_traffic_nonzero_and_bounded(mesh8):
    comp = _compiled_with_scan(mesh8, n_iters=3)
    traffic = rl.hlo_traffic_bytes(comp.as_text())
    assert traffic > 0
    assert traffic < 10e6   # tiny program


def test_roofline_cell_terms():
    cell = rl.RooflineCell(
        arch="x", shape="train_4k", mesh="16x16", chips=256,
        flops_per_chip=197e12, hbm_bytes_per_chip=819e9,
        coll_bytes_per_chip=50e9, coll_by_kind={}, model_flops_global=197e12 * 256,
        memory_per_chip_gb=10.0, compile_seconds=1.0,
        ideal_bytes_global=819e9 * 256)
    assert cell.t_compute == pytest.approx(1.0)
    assert cell.t_memory == pytest.approx(1.0)
    assert cell.t_collective == pytest.approx(1.0)
    assert cell.roofline_fraction == pytest.approx(1.0)
    assert cell.useful_ratio == pytest.approx(1.0)


def test_model_flops_and_ideal_bytes():
    from repro.configs import get_config, get_shape
    cfg = get_config("llama3.2-3b")
    tr = get_shape("train_4k")
    de = get_shape("decode_32k")
    n = cfg.num_active_params()
    assert rl.model_flops(cfg, tr) == pytest.approx(6 * n * 256 * 4096)
    assert rl.model_flops(cfg, de) == pytest.approx(2 * n * 128)
    assert rl.cache_bytes_global(cfg, de) == pytest.approx(
        2 * 128 * 32768 * 8 * 128 * 2 * 28)
    assert rl.ideal_bytes(cfg, de) > rl.cache_bytes_global(cfg, de)


def test_small_scale_cell_lowers(mesh8, rcfg_small):
    """The dry-run path end-to-end on a host mesh with a smoke config."""
    import dataclasses
    from repro.configs import ShapeConfig, get_smoke_config
    from repro.distribution.sharding import (
        ShardingCtx, abstract_params, param_shardings)
    from repro.models.model import cache_schema, forward_decode, model_schema
    cfg = get_smoke_config("llama3.2-3b")
    shape = ShapeConfig("t", 64, 8, "decode")
    shd = ShardingCtx(mesh8)
    params = abstract_params(model_schema(cfg, mesh8))
    psh = param_shardings(model_schema(cfg, mesh8), mesh8)
    caches = abstract_params(cache_schema(cfg, 8, 64))
    csh = param_shardings(cache_schema(cfg, 8, 64), mesh8)

    def serve_step(p, c, t, pos):
        return forward_decode(p, c, t, pos, cfg, shd, rcfg_small)

    lowered = jax.jit(serve_step, in_shardings=(psh, csh, None, None),
                      donate_argnums=(1,)).lower(
        params, caches, jax.ShapeDtypeStruct((8, 1), jnp.int32),
        jax.ShapeDtypeStruct((8,), jnp.int32))
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    assert ma.temp_size_in_bytes >= 0
    total, kinds = rl.collective_bytes(compiled.as_text())
    assert total >= 0


def test_data_pipeline_deterministic_and_sharded(mesh8):
    from repro.configs import ShapeConfig, get_smoke_config
    from repro.data import for_model
    cfg = get_smoke_config("granite-8b")
    pipe = for_model(cfg, ShapeConfig("t", 16, 8, "train"))
    b1 = pipe.batch_at(5)
    b2 = pipe.batch_at(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = pipe.batch_at(6)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(b1["labels"][:, :-1]),
                                  np.asarray(b1["tokens"][:, 1:]))
