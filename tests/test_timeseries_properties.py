"""Property tests for the watchdog's time-series math.

Three invariants the alert rules lean on, exercised over generated
sample sequences (real hypothesis when installed, the seeded fallback
batch from ``_hyp`` otherwise):

  * ``increase`` is non-negative for ANY sample sequence — arbitrary
    counter resets (migrations, hot-swaps, restarts) rebaseline instead
    of going negative, so no rate a rule or ``nk_top --diff`` computes
    can ever be below zero;
  * ``increase`` is additive over a window split at a sample boundary:
    the adjacent-delta pairs partition, so burn-rate shares computed on
    different windows are consistent with each other;
  * ``quantile_over_time`` over exported ``_bucket`` series lands inside
    ``Histogram.quantile_bounds`` for the samples observed inside the
    window — the windowed p99 the admit-wait rule alerts on is a true
    bucket-resolution quantile, not an artifact of cumulative counts.
"""
import math

from _hyp import given, settings, st

from repro.obs import Histogram, SeriesStore, series_key

# generated counter samples: non-negative, ordinary magnitudes. Lists
# long enough to contain several resets when values are drawn freely.
_VALUES = st.lists(st.floats(min_value=0.0, max_value=1e6),
                   min_size=0, max_size=24)

# histogram observations inside the finite bucket range (DEFAULT_BUCKETS
# spans 1ms..100s; staying inside keeps quantile_bounds' upper edge
# finite so the bracket assertion is meaningful either way)
_OBS = st.lists(st.floats(min_value=0.001, max_value=99.0),
                min_size=0, max_size=32)


def _store_of(values):
    st_ = SeriesStore()
    for i, v in enumerate(values):
        st_.ingest({"nk_c_total": v}, ts=float(i))
    return st_


@settings(max_examples=120, deadline=None)
@given(values=_VALUES)
def test_increase_never_negative_under_resets(values):
    store = _store_of(values)
    k = series_key("nk_c_total")
    assert store.increase(k) >= 0.0
    assert store.rate(k) >= 0.0
    # and on every sub-window anchored at every sample
    for now in range(len(values)):
        for w in (1.0, 3.0, 8.0):
            assert store.increase(k, window_s=w, now=float(now)) >= 0.0
            assert store.rate(k, window_s=w, now=float(now)) >= 0.0


@settings(max_examples=120, deadline=None)
@given(values=st.lists(st.floats(min_value=0.0, max_value=1e6),
                       min_size=2, max_size=24),
       cut=st.floats(min_value=0.0, max_value=1.0))
def test_increase_is_additive_over_a_split_window(values, cut):
    store = _store_of(values)
    k = series_key("nk_c_total")
    last = float(len(values) - 1)
    split = float(int(last * cut))           # a sample boundary
    total = store.increase(k)
    # both halves include the boundary sample; each adjacent-delta pair
    # lands in exactly one half, so the windowed sums partition the total
    left = store.increase(k, window_s=split - 0.0, now=split)
    right = store.increase(k, window_s=last - split, now=last)
    assert math.isclose(left + right, total, rel_tol=1e-9, abs_tol=1e-9)


@settings(max_examples=80, deadline=None)
@given(first=_OBS, second=_OBS,
       q=st.sampled_from([0.0, 0.25, 0.5, 0.9, 0.99, 1.0]))
def test_quantile_over_time_is_bracketed_by_histogram_bounds(
        first, second, q):
    h = Histogram()
    store = SeriesStore()
    # increases need a baseline pair: scrape the empty histogram first,
    # exactly like the watchdog's pre-traffic baseline tick
    store.ingest(h.counters("nk_lat_seconds", tenant="0"), ts=0.0)
    for v in first:
        h.observe(v)
    store.ingest(h.counters("nk_lat_seconds", tenant="0"), ts=1.0)
    for v in second:
        h.observe(v)
    store.ingest(h.counters("nk_lat_seconds", tenant="0"), ts=2.0)

    # full window = all samples: must agree with Histogram.quantile and
    # sit inside quantile_bounds
    qt = store.quantile_over_time("nk_lat_seconds", q, tenant="0")
    if not first and not second:
        assert qt is None
        return
    # the bucket edge round-trips through the exposition text's %g
    # rendering of the `le` label, so compare at that precision
    assert math.isclose(qt, h.quantile(q), rel_tol=1e-5)
    lo, hi = h.quantile_bounds(q)
    assert lo * (1 - 1e-5) <= qt <= hi * (1 + 1e-5)

    # the [t1, t2] sub-window sees only the second batch: compare
    # against a histogram holding exactly those samples
    h2 = Histogram()
    for v in second:
        h2.observe(v)
    qt2 = store.quantile_over_time("nk_lat_seconds", q, window_s=1.0,
                                   now=2.0, tenant="0")
    if not second:
        assert qt2 is None
    else:
        assert math.isclose(qt2, h2.quantile(q), rel_tol=1e-5)
        lo2, hi2 = h2.quantile_bounds(q)
        assert lo2 * (1 - 1e-5) <= qt2 <= hi2 * (1 + 1e-5)
