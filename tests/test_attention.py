"""Blockwise attention vs the naive oracle: causal, windows, padding,
GQA maps, inert padded heads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    blockwise_attention, decode_attention, head_mask, naive_attention,
    q_to_kv_map,
)

KS = jax.random.split(jax.random.PRNGKey(1), 4)


@pytest.mark.parametrize("s,t,qb,kvb,causal,window", [
    (128, 128, 32, 32, True, 0),
    (100, 100, 32, 32, True, 0),       # padding path
    (128, 128, 32, 32, True, 48),      # window
    (64, 192, 32, 32, False, 0),       # cross-attention shape
    (96, 96, 128, 128, True, 33),      # blocks larger than seq
])
def test_blockwise_matches_naive(s, t, qb, kvb, causal, window):
    b, h, kv, d = 2, 6, 3, 32
    q = jax.random.normal(KS[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(KS[1], (b, t, kv, d), jnp.float32)
    v = jax.random.normal(KS[2], (b, t, kv, d), jnp.float32)
    kv_map = q_to_kv_map(h, h, kv)
    o1 = blockwise_attention(q, k, v, kv_map=kv_map, causal=causal,
                             window=window, q_block=qb, kv_block=kvb)
    o2 = naive_attention(q, k, v, kv_map=kv_map, causal=causal, window=window)
    np.testing.assert_allclose(o1, o2, rtol=2e-4, atol=2e-4)


def test_padded_heads_are_inert():
    """Masked padded heads contribute 0 to outputs AND receive 0 grads."""
    b, s, h, hp, kv, d = 1, 32, 3, 4, 1, 16
    q = jax.random.normal(KS[0], (b, s, hp, d), jnp.float32)
    k = jax.random.normal(KS[1], (b, s, kv, d), jnp.float32)
    v = jax.random.normal(KS[2], (b, s, kv, d), jnp.float32)
    wo = jax.random.normal(KS[3], (hp, d, 8), jnp.float32)
    mask = head_mask(h, hp, jnp.float32)

    def out(q, wo):
        o = blockwise_attention(q, k, v, kv_map=q_to_kv_map(h, hp, kv),
                                q_block=16, kv_block=16)
        o = o * mask[None, None, :, None]
        return jnp.einsum("bshk,hkd->bsd", o, wo)

    y = out(q, wo)
    # changing padded-head inputs/weights must not change the output
    q2 = q.at[:, :, h:].set(123.0)
    wo2 = wo.at[h:].set(-7.0)
    np.testing.assert_allclose(y, out(q2, wo), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(y, out(q, wo2), rtol=1e-6, atol=1e-6)
    # gradients into padded slices are exactly zero
    gq, gwo = jax.grad(lambda q_, w_: jnp.sum(out(q_, w_) ** 2),
                       argnums=(0, 1))(q, wo)
    assert float(jnp.abs(gq[:, :, h:]).max()) == 0.0
    assert float(jnp.abs(gwo[h:]).max()) == 0.0


def test_ring_buffer_decode_window():
    """Window-cache ring layout attends exactly the last `window` tokens."""
    b, kv, d, w = 1, 2, 16, 8
    hq = 2
    kv_map = q_to_kv_map(hq, hq, kv)
    q = jax.random.normal(KS[0], (b, 1, hq, d), jnp.float32)
    # linear cache of 32 tokens, pos = 20
    t = 32
    k = jax.random.normal(KS[1], (b, t, hq, d), jnp.float32)
    v = jax.random.normal(KS[2], (b, t, hq, d), jnp.float32)
    pos = jnp.array([20])
    o_lin = decode_attention(q, k, v, pos, kv_map=kv_map, window=w)
    # ring layout: slot j holds token pos - ((pos - j) % w)
    slots = (20 - ((20 - jnp.arange(w)) % w))
    kr = k[:, slots]
    vr = v[:, slots]
    kv_pos = jnp.broadcast_to(slots[None], (b, w))
    o_ring = decode_attention(q, kr, vr, pos, kv_map=kv_map, window=w,
                              kv_pos=kv_pos)
    np.testing.assert_allclose(o_ring, o_lin, rtol=1e-5, atol=1e-5)
