"""Management plane: telemetry, congestion control, closed-loop enforcement.

Everything here runs on simulated clocks — no jax, no sleeping — except the
one ServeEngine integration check at the bottom.
"""
import math

import numpy as np
import pytest

from repro.control import (
    Aimd, Dctcp, RateController, SharedBottleneckSim, SimTenant, WaterFill,
    max_min_fair,
)
from repro.control.telemetry import EngineTelemetry, TenantObs
from repro.core.engine import CoreEngine, TokenBucket
from repro.serve.multiplex import bursty_trace, fair_replay, jain_index
from repro.serve.scheduler import Request, TenantScheduler


class _Payload:
    dtype = np.uint8

    def __init__(self, n):
        self.shape = (int(n),)


# --- token bucket ------------------------------------------------------------


def test_wait_time_zero_rate_returns_inf():
    """Regression: a hard-blocked (rate=0) tenant used to ZeroDivisionError."""
    b = TokenBucket(rate=0.0, capacity=10.0)
    assert b.consume(10, now=0.0)
    assert b.wait_time(1, now=0.0) == math.inf
    assert not b.consume(1, now=1e9)


def test_bucket_burst_then_backfill():
    b = TokenBucket(rate=100.0, capacity=300.0)
    now = 0.0
    assert b.consume(300, now)            # full burst available immediately
    assert not b.consume(1, now)
    assert not b.consume(150, now + 1.0)  # only 100 refilled
    assert b.consume(150, now + 1.5)      # 150 after 1.5s
    assert b.wait_time(300, now + 1.5) == pytest.approx(3.0)


def test_bucket_set_rate_preserves_tokens():
    b = TokenBucket(rate=100.0, capacity=100.0)
    assert b.consume(80, now=0.0)          # 20 left
    b.set_rate(10.0, now=0.5)              # settles +50 at the old rate first
    assert b.tokens == pytest.approx(70.0)
    assert b.rate == 10.0
    # new rate prices the future, not the past
    assert b.wait_time(100, now=0.5) == pytest.approx(3.0)


def test_bucket_drain_is_partial_and_never_negative():
    b = TokenBucket(rate=10.0, capacity=50.0)
    assert b.drain(30, now=0.0) == pytest.approx(30.0)
    assert b.drain(100, now=0.0) == pytest.approx(20.0)   # only what's left
    assert b.drain(5, now=0.0) == 0.0
    assert b.tokens == pytest.approx(0.0)


# --- max-min fair allocator ---------------------------------------------------


def test_max_min_fair_textbook():
    # capacity 10, demands 2/4/10 -> 2/4/4 (the classic example)
    assert max_min_fair(10, {1: 2, 2: 4, 3: 10}) == \
        pytest.approx({1: 2.0, 2: 4.0, 3: 4.0})


def test_max_min_fair_weighted_and_greedy():
    alloc = max_min_fair(90, {1: math.inf, 2: math.inf, 3: 10},
                         weights={1: 2.0, 2: 1.0, 3: 1.0})
    # tenant 3 takes 10; the 80 residual splits 2:1
    assert alloc[3] == pytest.approx(10.0)
    assert alloc[1] == pytest.approx(2 * alloc[2])
    assert sum(alloc.values()) == pytest.approx(90.0)


def test_max_min_fair_work_conserving_and_bounded():
    alloc = max_min_fair(100, {1: 20, 2: 30})
    assert alloc == pytest.approx({1: 20.0, 2: 30.0})   # under-demand: no pad
    alloc = max_min_fair(100, {1: math.inf, 2: math.inf, 3: math.inf})
    assert sum(alloc.values()) == pytest.approx(100.0)
    assert max_min_fair(0.0, {1: 5}) == {1: 0.0}
    assert max_min_fair(10.0, {}) == {}


# --- dispatch enforcement -----------------------------------------------------


def test_dispatch_consumes_buckets_and_meters_shortfall():
    eng = CoreEngine(enforcement="account")
    eng.set_tenant_rate(1, bytes_per_s=100.0, burst=100.0)
    eng.set_tenant_rate(2, bytes_per_s=1000.0, burst=1000.0)
    for k in range(5):
        now = float(k)
        eng.dispatch("shm_move", _Payload(200), ("pod",), tenant_id=1, now=now)
        eng.dispatch("shm_move", _Payload(200), ("pod",), tenant_id=2, now=now)
    # tenant 1 offered 1000B at 100B/s: ~half deferred; tenant 2 untouched
    assert eng.total_bytes(1) == 1000
    assert eng.deferred_bytes(1) >= 400
    assert eng.deferred_bytes(2) == 0
    assert any(t == 1 for t, _, _ in eng.throttle_log)
    assert not any(t == 2 for t, _, _ in eng.throttle_log)


def test_dispatch_enforcement_off_by_default():
    eng = CoreEngine()
    eng.set_tenant_rate(1, bytes_per_s=1.0, burst=1.0)
    for _ in range(10):
        eng.dispatch("shm_move", _Payload(1000), ("pod",), tenant_id=1,
                     now=0.0)
    assert eng.deferred_bytes(1) == 0      # advisory buckets: seed behaviour


def test_engine_admit_ledger_tracks_admitted_and_wait():
    """CoreEngine-side admission ledger: in-rate ops/bytes per tenant plus
    the cumulative shaping delay enforcement charged."""
    eng = CoreEngine(enforcement="account")
    eng.set_tenant_rate(1, bytes_per_s=100.0, burst=100.0)
    eng.buckets[1].updated = 0.0
    eng.dispatch("shm_move", _Payload(60), ("pod",), tenant_id=1, now=0.0)
    eng.dispatch("shm_move", _Payload(60), ("pod",), tenant_id=1, now=0.0)
    eng.dispatch("shm_move", _Payload(10), ("pod",), tenant_id=2, now=0.0)
    snap = eng.admit_snapshot()
    ops1, bytes1, wait1 = snap[1]
    assert ops1 == 1                       # first op fully in-rate
    assert bytes1 == 100                   # 60 + the 40 the bucket covered
    assert wait1 == pytest.approx(20 / 100.0)   # shortfall / rate
    assert snap[2] == (1, 10, 0.0)         # uncapped tenant: no wait
    eng.reset_ledger()
    assert eng.admit_snapshot() == {}


def test_update_tenant_rate_keeps_balance():
    eng = CoreEngine(enforcement="account")
    eng.set_tenant_rate(1, 100.0, burst=100.0)
    eng.buckets[1].updated = 0.0
    eng.dispatch("shm_move", _Payload(70), ("pod",), tenant_id=1, now=0.0)
    eng.update_tenant_rate(1, 10.0, now=0.0)
    assert eng.buckets[1].tokens == pytest.approx(30.0)
    assert eng.buckets[1].rate == 10.0


# --- telemetry ----------------------------------------------------------------


def test_engine_telemetry_rates_and_counters():
    eng = CoreEngine(enforcement="account")
    tel = EngineTelemetry(eng, alpha=1.0, axes_filter=("pod",))
    tel.update(now=0.0)                                   # baseline
    eng.dispatch("shm_move", _Payload(500), ("pod",), tenant_id=3, now=0.5)
    obs = tel.update(now=1.0)
    assert obs[3].rate == pytest.approx(500.0)
    assert not obs[3].backlogged
    c = tel.counters()
    assert c['nk_offered_bytes_total{tenant="3",axes="pod"}'] == 500
    assert 'nk_served_bytes_per_s{tenant="3"}' in tel.export_prometheus()


def test_engine_telemetry_axes_filter_excludes_other_traffic():
    eng = CoreEngine(enforcement="account")
    tel = EngineTelemetry(eng, alpha=1.0, axes_filter=("pod",))
    tel.update(now=0.0)
    eng.dispatch("shm_move", _Payload(500), ("model",), tenant_id=3, now=0.5)
    obs = tel.update(now=1.0)
    assert obs.get(3, TenantObs()).offered == 0.0


def test_telemetry_deferred_marks_backlogged():
    eng = CoreEngine(enforcement="account")
    eng.set_tenant_rate(7, 100.0, burst=100.0)
    eng.buckets[7].updated = 0.0
    tel = EngineTelemetry(eng, alpha=1.0)
    tel.update(now=0.0)
    eng.dispatch("shm_move", _Payload(500), ("pod",), tenant_id=7, now=1.0)
    obs = tel.update(now=1.0 + 1e-3)
    assert obs[7].backlogged
    assert obs[7].rate < obs[7].offered


# --- congestion-control algorithms -------------------------------------------


def _obs(rate, deferred=0.0, queue=0.0):
    return TenantObs(rate=rate, offered=rate + deferred, deferred=deferred,
                     queue=queue)


def test_aimd_backs_off_under_congestion_and_recovers():
    algo = Aimd(increase=10.0, decrease=0.5, min_rate=1.0)
    congested = {1: _obs(600.0), 2: _obs(600.0)}      # offered 1200 > 1000
    r1 = algo.allocate(congested, capacity=1000.0)
    r2 = algo.allocate(congested, capacity=1000.0)
    assert r2[1] == pytest.approx(r1[1] * 0.5)
    calm = {1: _obs(100.0), 2: _obs(100.0)}
    r3 = algo.allocate(calm, capacity=1000.0)
    assert r3[1] == pytest.approx(r2[1] + 10.0)


def test_dctcp_backoff_scales_with_marking_fraction():
    heavy, light = Dctcp(increase=5.0, g=1.0), Dctcp(increase=5.0, g=1.0)
    start = {1: _obs(500.0)}
    h0 = heavy.allocate(start, 1000.0)[1]
    # 50% of traffic deferred vs 5%: proportionally larger cut
    h1 = heavy.allocate({1: _obs(250.0, deferred=250.0)}, 1000.0)[1]
    l1 = light.allocate({1: _obs(475.0, deferred=25.0)}, 1000.0)[1]
    assert h1 == pytest.approx(h0 * (1 - 0.5 / 2))
    assert l1 == pytest.approx(h0 * (1 - 0.05 / 2))
    assert h1 < l1


def test_waterfill_satisfied_get_headroom_backlogged_split_residual():
    algo = WaterFill(headroom=1.2)
    obs = {1: _obs(100.0), 2: _obs(400.0, deferred=50.0),
           3: _obs(400.0, deferred=50.0)}
    alloc = algo.allocate(obs, capacity=1000.0)
    assert alloc[1] == pytest.approx(120.0)           # demand * headroom
    assert alloc[2] == pytest.approx(440.0)           # (1000-120)/2
    assert alloc[3] == pytest.approx(440.0)


# --- closed loop --------------------------------------------------------------


def test_controller_converges_to_max_min_fair():
    tenants = [SimTenant(1, 200.0), SimTenant(2, 900.0),
               SimTenant(3, 2000.0)]
    sim = SharedBottleneckSim(tenants, capacity=1000.0, dt=0.05)
    res = sim.run(10.0)
    ref = sim.fair_reference()
    assert ref == pytest.approx({1: 200.0, 2: 400.0, 3: 400.0})
    for t, want in ref.items():
        assert res.served_rate(t) == pytest.approx(want, rel=0.10)


def test_controller_distributed_engines_share_one_bottleneck():
    """Two engines, same fabric: per-tenant rate sums respect the global
    allocation and the split follows where the traffic is."""
    tenants = [SimTenant(1, 2000.0, engine_split=(0.75, 0.25)),
               SimTenant(2, 2000.0, engine_split=(0.25, 0.75))]
    sim = SharedBottleneckSim(tenants, capacity=1000.0, n_engines=2, dt=0.05)
    res = sim.run(10.0)
    for t in (1, 2):
        assert res.served_rate(t) == pytest.approx(500.0, rel=0.10)
    b0, b1 = sim.engines[0].buckets, sim.engines[1].buckets
    assert b0[1].rate > b1[1].rate        # tenant 1 mostly on engine 0
    assert b1[2].rate > b0[2].rate
    assert b0[1].rate + b1[1].rate == pytest.approx(500.0, rel=0.15)


def test_controller_weighted_shares():
    tenants = [SimTenant(1, 5000.0, weight=3.0),
               SimTenant(2, 5000.0, weight=1.0)]
    sim = SharedBottleneckSim(tenants, capacity=1000.0, dt=0.05)
    res = sim.run(10.0)
    assert res.served_rate(1) / res.served_rate(2) == pytest.approx(3.0,
                                                                    rel=0.15)


def test_controller_work_conserving_backfill():
    """When a tenant goes idle its share is re-absorbed; when it returns it
    gets its fair share back."""
    def on_off(t):
        return 900.0 if t < 5.0 or t >= 10.0 else 0.0
    tenants = [SimTenant(1, on_off), SimTenant(2, 2000.0)]
    sim = SharedBottleneckSim(tenants, capacity=1000.0, dt=0.05)
    sim.run(5.0)
    mid = sim.run(5.0)        # tenant 1 idle: tenant 2 absorbs the capacity
    assert mid.served_rate(2, 0.4, 1.0) == pytest.approx(1000.0, rel=0.10)
    back = sim.run(5.0)       # tenant 1 returns: back to 500/500
    assert back.served_rate(1, 0.5, 1.0) == pytest.approx(500.0, rel=0.15)
    assert back.served_rate(2, 0.5, 1.0) == pytest.approx(500.0, rel=0.15)


def test_controller_prometheus_export():
    tenants = [SimTenant(1, 500.0)]
    sim = SharedBottleneckSim(tenants, capacity=1000.0)
    sim.run(2.0)
    text = sim.controller.export_prometheus()
    assert "controller_ticks_total" in text
    assert 'nk_allocated_rate{tenant="1"}' in text


# --- scheduler-side fairness --------------------------------------------------


def _drain_synthetic(sched, steps, tokens_per_req=10, dt=0.01):
    """Serve loop stand-in: admit one request per step, account its cost."""
    served = {t: 0 for t in sched.queues}
    now = 0.0
    for _ in range(steps):
        now += dt
        req = sched.next_request(now)
        if req is None:
            continue
        sched.account(req.tenant_id, tokens_per_req)
        served[req.tenant_id] += tokens_per_req
    return served


def test_wfq_share_convergence_unequal_weights():
    sched = TenantScheduler(policy="wfq")
    sched.add_tenant(1, weight=3.0)
    sched.add_tenant(2, weight=1.0)
    for i in range(400):
        sched.submit(Request(tenant_id=1 + i % 2, prompt=[1],
                             max_new_tokens=10))
    served = _drain_synthetic(sched, steps=200)
    assert served[1] / served[2] == pytest.approx(3.0, rel=0.10)


def test_scheduler_set_rate_midrun_takes_effect_and_keeps_balance():
    sched = TenantScheduler(policy="wfq")
    sched.add_tenant(1, rate_tokens_per_s=1000.0, burst=1000.0)
    sched.buckets[1].updated = 0.0
    sched.submit(Request(tenant_id=1, prompt=[1], max_new_tokens=400))
    sched.submit(Request(tenant_id=1, prompt=[1], max_new_tokens=400))
    assert sched.next_request(now=0.0) is not None     # 600 tokens left
    sched.set_rate(1, 1.0, now=0.0)                    # throttle hard...
    assert sched.buckets[1].tokens == pytest.approx(600.0)   # ...balance kept
    assert sched.next_request(now=0.0) is not None     # balance still covers
    sched.submit(Request(tenant_id=1, prompt=[1], max_new_tokens=400))
    assert sched.next_request(now=0.0) is None         # 200 left: blocked
    sched.set_rate(1, None)                            # lift the cap
    assert sched.next_request(now=0.0) is not None


def test_set_rate_on_unknown_tenant_creates_no_ghost_queue():
    """Regression: a controller probing every enforcement point used to
    register full queue state for tenants that never submitted here — ghost
    tenants WFQ/RR scanned forever, each holding a stale rate entry."""
    sched = TenantScheduler()
    sched.add_tenant(1)
    sched.set_rate(5, 100.0, now=0.0)          # tenant 5 never submitted
    assert 5 not in sched.queues
    assert 5 not in sched._rr_order
    assert sched.pending() == 0
    assert 5 in sched.buckets                  # the rate itself does apply...
    sched.submit(Request(tenant_id=5, prompt=[1], max_new_tokens=40))
    assert 5 in sched.queues                   # ...once the tenant shows up
    assert sched.next_request(now=0.0) is not None   # burst covers 40
    sched.submit(Request(tenant_id=5, prompt=[1], max_new_tokens=400))
    assert sched.next_request(now=0.0) is None       # and then rate-bound


def test_drop_tenant_clears_stale_rate_entry():
    """Regression: zero-queue tenants kept their last pushed rate forever;
    a tenant returning after drop_tenant starts uncapped, not throttled."""
    sched = TenantScheduler()
    sched.add_tenant(1)
    sched.set_rate(1, 1e-6, now=0.0)           # throttled hard, then departs
    assert sched.pending(1) == 0
    sched.drop_tenant(1)
    assert 1 not in sched.buckets and 1 not in sched.queues
    assert 1 not in sched._rr_order
    sched.submit(Request(tenant_id=1, prompt=[1], max_new_tokens=400))
    assert sched.next_request(now=1.0) is not None   # no stale 1e-6 cap


def test_scheduler_admission_ledger():
    """admit/defer/latency counters the replay harness reads."""
    sched = TenantScheduler()
    sched.add_tenant(1, rate_tokens_per_s=10.0, burst=10.0)
    sched.buckets[1].updated = 0.0
    sched.submit(Request(tenant_id=1, prompt=[1], max_new_tokens=10,
                         arrival=0.0))    # t=0 arrival must count (regression)
    sched.submit(Request(tenant_id=1, prompt=[1], max_new_tokens=10,
                         arrival=0.0))
    assert sched.next_request(now=1.0) is not None   # burst covers one
    assert sched.next_request(now=1.0) is None       # second: deferred
    led = sched.ledger()[1]
    assert led["admitted_requests"] == 1
    assert led["deferred_polls"] >= 1
    assert led["mean_admit_wait_s"] == pytest.approx(1.0)


def test_delta_push_cuts_chatter_in_fluid_sim():
    """Same closed loop, push_mode=delta: far fewer set_rate calls on a
    stable workload, same converged allocation."""
    tenants = [SimTenant(1, 200.0), SimTenant(2, 900.0), SimTenant(3, 2000.0)]
    full = SharedBottleneckSim(tenants, capacity=1000.0, dt=0.05,
                               push_mode="full")
    delta = SharedBottleneckSim(
        [SimTenant(1, 200.0), SimTenant(2, 900.0), SimTenant(3, 2000.0)],
        capacity=1000.0, dt=0.05, push_mode="delta")
    rf, rd = full.run(10.0), delta.run(10.0)
    assert delta.controller.push_calls <= 0.25 * full.controller.push_calls
    assert delta.controller.push_skipped > 0
    for t, want in full.fair_reference().items():
        assert rd.served_rate(t) == pytest.approx(want, rel=0.12)
    c = delta.controller.counters()
    assert c["controller_push_calls_total"] == delta.controller.push_calls
    assert c["controller_push_skipped_total"] > 0


def test_delta_push_refresh_recovers_external_reset():
    """Soft-state refresh: if an enforcement point is reset behind the
    controller's back (drop_tenant), delta mode re-pushes within
    refresh_every ticks instead of skipping forever."""
    sched = TenantScheduler()
    sched.add_tenant(1)
    ctrl = RateController(capacity=100.0, push_mode="delta",
                          refresh_every=5).attach_scheduler(sched)
    now = 0.0
    for _ in range(20):
        now += 0.05
        sched.submit(Request(tenant_id=1, prompt=[1], max_new_tokens=5))
        req = sched.next_request(now)
        if req is not None:
            sched.account(1, 5)
        ctrl.tick(now)
    assert 1 in sched.buckets
    rate_before = sched.buckets[1].rate
    sched.drop_tenant(1)                       # external reset
    assert 1 not in sched.buckets
    for _ in range(2 * 5):                     # at most one refresh period...
        now += 0.05
        sched.submit(Request(tenant_id=1, prompt=[1], max_new_tokens=5))
        req = sched.next_request(now)
        if req is not None:
            sched.account(1, 5)
        ctrl.tick(now)
    assert 1 in sched.buckets                  # ...and the cap is back
    assert sched.buckets[1].rate == pytest.approx(rate_before, rel=0.5)


def test_controller_drives_scheduler_buckets():
    """Serving-side loop: queue-backlogged tenants end up at equal token
    rates without any engine involved."""
    sched = TenantScheduler(policy="wfq")
    sched.add_tenant(1)
    sched.add_tenant(2)
    ctrl = RateController(capacity=100.0).attach_scheduler(sched)
    for i in range(100):
        sched.submit(Request(tenant_id=1 + i % 2, prompt=[1],
                             max_new_tokens=5))
    now = 0.0
    for _ in range(200):
        now += 0.05
        req = sched.next_request(now)
        if req is not None:
            sched.account(req.tenant_id, 5)
        ctrl.tick(now)
    assert set(ctrl.allocations) == {1, 2}
    assert ctrl.allocations[1] == pytest.approx(ctrl.allocations[2],
                                                rel=0.25)
    assert sched.buckets[1].rate == pytest.approx(ctrl.allocations[1])
    # pushed rates must not shrink bucket capacity below a request's cost
    # (requests admit whole: a tiny burst would head-of-line-block forever)
    assert sched.buckets[1].capacity >= 5


def test_controller_recovers_hard_blocked_scheduler_tenant():
    """A tenant starting at rate=0/burst=0 must become servable once the
    controller raises its rate (capacity grows to >= 1s of the new rate)."""
    sched = TenantScheduler()
    sched.add_tenant(1, rate_tokens_per_s=0.0, burst=0.0)
    ctrl = RateController(capacity=50.0).attach_scheduler(sched)
    for _ in range(10):
        sched.submit(Request(tenant_id=1, prompt=[1], max_new_tokens=5))
    now, served = 0.0, 0
    for _ in range(100):
        now += 0.1
        req = sched.next_request(now)
        if req is not None:
            sched.account(1, 5)
            served += 1
        ctrl.tick(now)
    assert served == 10
    assert sched.buckets[1].capacity >= sched.buckets[1].rate


def test_controller_splits_allocation_across_schedulers():
    """Two serving hosts, one token bottleneck: per-tenant rates are split,
    not granted in full at each host (which would over-admit 2x)."""
    s1, s2 = TenantScheduler(), TenantScheduler()
    ctrl = RateController(capacity=100.0)
    ctrl.attach_scheduler(s1).attach_scheduler(s2)
    now = 0.0
    for k in range(40):
        now += 0.05
        for sched in (s1, s2):
            sched.submit(Request(tenant_id=1, prompt=[1], max_new_tokens=5))
            req = sched.next_request(now)
            if req is not None:
                sched.account(req.tenant_id, 5)
        ctrl.tick(now)
    total_rate = s1.buckets[1].rate + s2.buckets[1].rate
    assert total_rate == pytest.approx(ctrl.allocations[1], rel=1e-6)
    assert total_rate <= 100.0 * (1 + 1e-6)


# --- fair replay --------------------------------------------------------------


def test_fair_replay_work_conserving_and_fair():
    t = bursty_trace(6, seed=3)
    cap = float(t.loads.sum(axis=0).mean()) * 0.6      # force contention
    out = fair_replay(t, cap)
    assert out["jain_backlogged"] > 0.99    # contested capacity split evenly
    served_rates = out["served"].sum(axis=0)
    assert float(served_rates.max()) <= cap * (1 + 1e-6)
    # work conservation: when demand exceeds cap, serve exactly cap
    demand = t.loads.sum(axis=0)
    congested = demand > cap * 1.01
    assert congested.any()
    np.testing.assert_allclose(served_rates[congested], cap, rtol=1e-6)


def test_fair_replay_rate_caps_leave_capacity_to_others():
    t = bursty_trace(3, seed=0)
    cap = float(t.loads.sum(axis=0).max())             # ample capacity
    out = fair_replay(t, cap, rate_caps={0: 1.0})
    assert float(out["served"][0].max()) <= 1.0 + 1e-6
    # the capped tenant's unused share went to the others, not to waste
    others_served = out["served"][1:].sum()
    others_offered = t.loads[1:].sum()
    assert others_served == pytest.approx(others_offered, rel=1e-6)


def test_jain_index():
    assert jain_index([5, 5, 5, 5]) == pytest.approx(1.0)
    assert jain_index([1, 0, 0, 0]) == pytest.approx(0.25)
    assert jain_index([]) == 1.0


# --- ServeEngine integration --------------------------------------------------


def test_serve_engine_ticks_controller(mesh1, rcfg_small):
    from repro.configs import get_smoke_config
    from repro.serve import Request as SReq, ServeEngine

    class TickCounter:
        def __init__(self):
            self.ticks = []

        def tick(self, now=None):
            self.ticks.append(now)

    ctrl = TickCounter()
    eng = ServeEngine(get_smoke_config("llama3.2-3b"), rcfg_small, mesh1,
                      batch_slots=2, max_seq=32, controller=ctrl,
                      control_every=2)
    for i in range(3):
        eng.submit(SReq(tenant_id=i % 2, prompt=[1, 2], max_new_tokens=6,
                        req_id=i))
    eng.run_until_drained()
    # ticks follow step() calls (not just decode steps): a fully-throttled
    # engine with zero active slots must still reach the controller
    assert len(ctrl.ticks) == eng.steps // 2
    assert eng.steps >= eng.decode_steps
