"""CommOp (NQE) wire format: 32-byte invariant, roundtrip properties,
semantic-checksum (shape_crc) verification, corrupt-record rejection."""
import pytest
from _hyp import given, settings, st

from repro.core.nqe import AXIS_BITS, _AXIS_MASK, CommOp, NQE_SIZE, VERBS


def test_nqe_is_32_bytes():
    op = CommOp(verb="psum", axes=("pod",))
    assert NQE_SIZE == 32
    assert len(op.pack()) == 32


axes_st = st.lists(st.sampled_from(sorted(AXIS_BITS)), unique=True,
                   max_size=len(AXIS_BITS)).map(tuple)


@given(verb=st.sampled_from(VERBS), axes=axes_st,
       tenant=st.integers(0, 255), tag=st.integers(0, 2**32 - 1),
       op_data=st.integers(0, 2**64 - 1), size=st.integers(0, 2**64 - 1),
       flags=st.integers(0, 255))
@settings(max_examples=200, deadline=None)
def test_nqe_roundtrip(verb, axes, tenant, tag, op_data, size, flags):
    op = CommOp(verb=verb, axes=axes, tenant_id=tenant, tag=tag,
                op_data=op_data, size_bytes=size, flags=flags,
                shape_desc="bf16[3,4]")
    back = CommOp.unpack(op.pack())
    assert back.verb == verb
    assert set(back.axes) == set(axes)
    assert back.tenant_id == tenant
    assert back.tag == tag
    assert back.op_data == op_data
    assert back.size_bytes == size
    assert back.flags == flags
    assert back.matches(op)


@given(verb=st.sampled_from(VERBS), axes=axes_st,
       shape=st.sampled_from(["bf16[3,4]", "f32[256,4096]", "i8[1]", ""]))
@settings(max_examples=100, deadline=None)
def test_nqe_crc_roundtrip_with_expected_shape(verb, axes, shape):
    """unpack(expect_shape=) verifies the semantic checksum and restores
    the descriptor string the crc was computed from."""
    op = CommOp(verb=verb, axes=axes, shape_desc=shape)
    back = CommOp.unpack(op.pack(), expect_shape=shape)
    assert back.shape_desc == shape
    assert back.pack() == op.pack()          # full 32-byte identity


@given(verb=st.sampled_from(VERBS), axes=axes_st)
@settings(max_examples=50, deadline=None)
def test_nqe_crc_mismatch_detected(verb, axes):
    op = CommOp(verb=verb, axes=axes, shape_desc="bf16[256,4096]")
    with pytest.raises(ValueError, match="shape_crc mismatch"):
        CommOp.unpack(op.pack(), expect_shape="bf16[256,4097]")


def test_nqe_invalid_verb_code_rejected():
    raw = bytearray(CommOp(verb="psum", axes=("pod",)).pack())
    raw[0] = len(VERBS)                       # first out-of-range code
    with pytest.raises(ValueError, match="invalid verb code"):
        CommOp.unpack(bytes(raw))
    raw[0] = 0xFF
    with pytest.raises(ValueError, match="invalid verb code"):
        CommOp.unpack(bytes(raw))


@given(bits=st.integers(1, 255))
@settings(max_examples=60, deadline=None)
def test_nqe_unknown_axis_bits_rejected(bits):
    raw = bytearray(CommOp(verb="psum", axes=()).pack())
    raw[2] = bits
    if bits & ~_AXIS_MASK:
        with pytest.raises(ValueError, match="unknown axis bits"):
            CommOp.unpack(bytes(raw))
    else:
        assert set(CommOp.unpack(bytes(raw)).axes) == \
            {a for a, b in AXIS_BITS.items() if bits & b}


def test_nqe_forwarder_roundtrip_preserves_crc():
    """A node that decodes an NQE without knowing the shape and re-encodes
    it to forward must keep the original semantic checksum intact."""
    op = CommOp(verb="psum", axes=("pod",), shape_desc="bf16[256,4096]")
    forwarded = CommOp.unpack(op.pack()).pack()     # decode blind, re-encode
    assert forwarded == op.pack()                   # byte-identical
    # the final receiver can still verify against the true shape
    back = CommOp.unpack(forwarded, expect_shape="bf16[256,4096]")
    assert back.shape_desc == "bf16[256,4096]"


def test_nqe_wrong_length_rejected():
    op = CommOp(verb="psum", axes=("pod",))
    with pytest.raises(ValueError, match="32 bytes"):
        CommOp.unpack(op.pack()[:31])
    with pytest.raises(ValueError, match="32 bytes"):
        CommOp.unpack(op.pack() + b"\x00")


def test_matches_ignores_crc_but_not_header():
    a = CommOp(verb="psum", axes=("pod",), shape_desc="bf16[3,4]")
    b = CommOp(verb="psum", axes=("pod",), shape_desc="f32[9,9]")
    assert a.matches(b)                       # crc excluded from matches()
    assert a.pack() != b.pack()               # ...but present on the wire
    c = CommOp(verb="all_gather", axes=("pod",), shape_desc="bf16[3,4]")
    assert not a.matches(c)


def test_bad_verb_rejected():
    with pytest.raises(ValueError):
        CommOp(verb="sendfile", axes=())


def test_bad_tenant_rejected():
    with pytest.raises(ValueError):
        CommOp(verb="psum", axes=(), tenant_id=256)
