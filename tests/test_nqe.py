"""CommOp (NQE) wire format: 32-byte invariant + roundtrip properties."""
import pytest
from _hyp import given, settings, st

from repro.core.nqe import AXIS_BITS, CommOp, NQE_SIZE, VERBS


def test_nqe_is_32_bytes():
    op = CommOp(verb="psum", axes=("pod",))
    assert NQE_SIZE == 32
    assert len(op.pack()) == 32


axes_st = st.lists(st.sampled_from(sorted(AXIS_BITS)), unique=True,
                   max_size=len(AXIS_BITS)).map(tuple)


@given(verb=st.sampled_from(VERBS), axes=axes_st,
       tenant=st.integers(0, 255), tag=st.integers(0, 2**32 - 1),
       op_data=st.integers(0, 2**64 - 1), size=st.integers(0, 2**64 - 1),
       flags=st.integers(0, 255))
@settings(max_examples=200, deadline=None)
def test_nqe_roundtrip(verb, axes, tenant, tag, op_data, size, flags):
    op = CommOp(verb=verb, axes=axes, tenant_id=tenant, tag=tag,
                op_data=op_data, size_bytes=size, flags=flags,
                shape_desc="bf16[3,4]")
    back = CommOp.unpack(op.pack())
    assert back.verb == verb
    assert set(back.axes) == set(axes)
    assert back.tenant_id == tenant
    assert back.tag == tag
    assert back.op_data == op_data
    assert back.size_bytes == size
    assert back.flags == flags
    assert back.matches(op)


def test_bad_verb_rejected():
    with pytest.raises(ValueError):
        CommOp(verb="sendfile", axes=())


def test_bad_tenant_rejected():
    with pytest.raises(ValueError):
        CommOp(verb="psum", axes=(), tenant_id=256)
