"""SSD correctness: chunked scan vs naive recurrence; streaming decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import causal_conv, conv_step, ssd_chunked, ssd_decode_step

KS = jax.random.split(jax.random.PRNGKey(2), 6)


def _naive_ssd(xdt, dA, B, C):
    """Token-by-token recurrence oracle: h_t = exp(dA_t) h_{t-1} + B_t xdt_t."""
    b, l, h, p = xdt.shape
    n = B.shape[-1]
    state = np.zeros((b, h, p, n), np.float64)
    ys = []
    for t in range(l):
        decay = np.exp(np.asarray(dA[:, t], np.float64))[:, :, None, None]
        upd = np.einsum("bhp,bn->bhpn", np.asarray(xdt[:, t], np.float64),
                        np.asarray(B[:, t], np.float64))
        state = state * decay + upd
        ys.append(np.einsum("bhpn,bn->bhp", state,
                            np.asarray(C[:, t], np.float64)))
    return np.stack(ys, axis=1), state


@pytest.mark.parametrize("l,chunk", [(64, 16), (60, 16), (32, 32), (48, 64)])
def test_ssd_chunked_matches_recurrence(l, chunk):
    b, h, p, n = 2, 4, 8, 16
    xdt = jax.random.normal(KS[0], (b, l, h, p), jnp.float32) * 0.2
    dA = -jnp.abs(jax.random.normal(KS[1], (b, l, h), jnp.float32)) * 0.2
    B = jax.random.normal(KS[2], (b, l, n), jnp.float32) * 0.4
    C = jax.random.normal(KS[3], (b, l, n), jnp.float32) * 0.4
    y, st = ssd_chunked(xdt, dA, B, C, chunk)
    y_ref, st_ref = _naive_ssd(xdt, dA, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st), st_ref, rtol=2e-3, atol=2e-3)


def test_decode_step_continues_the_scan():
    """Prefill state + single decode steps == full-sequence scan."""
    b, l, h, p, n = 1, 24, 2, 4, 8
    xdt = jax.random.normal(KS[0], (b, l + 4, h, p), jnp.float32) * 0.2
    dA = -jnp.abs(jax.random.normal(KS[1], (b, l + 4, h), jnp.float32)) * 0.2
    B = jax.random.normal(KS[2], (b, l + 4, n), jnp.float32) * 0.4
    C = jax.random.normal(KS[3], (b, l + 4, n), jnp.float32) * 0.4
    y_full, st_full = ssd_chunked(xdt, dA, B, C, 8)
    y_pre, st = ssd_chunked(xdt[:, :l], dA[:, :l], B[:, :l], C[:, :l], 8)
    for t in range(l, l + 4):
        dt_like = jnp.ones((b, h))    # dA already folded into dA[:, t]
        # reconstruct (x*dt) and dt*A from the prepared tensors
        y_t, st = ssd_decode_step(
            xdt[:, t], dt_like, dA[:, t] / 1.0, B[:, t], C[:, t], st)
        # ssd_decode_step computes exp(dt*A) with dt=1 -> exp(dA)
        np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_full[:, t]),
                                   rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_full),
                               rtol=2e-3, atol=2e-3)


def test_causal_conv_streaming_equivalence():
    b, l, c, w = 2, 10, 6, 4
    u = jax.random.normal(KS[4], (b, l, c), jnp.float32)
    wgt = jax.random.normal(KS[5], (w, c), jnp.float32)
    y_full = causal_conv(u, wgt)
    state = jnp.zeros((b, w - 1, c))
    outs = []
    for t in range(l):
        y_t, state = conv_step(u[:, t], state, wgt)
        outs.append(y_t)
    y_stream = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_stream), np.asarray(y_full),
                               rtol=1e-5, atol=1e-5)
