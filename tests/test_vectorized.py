"""Vectorized control plane: equivalence properties and eviction guards.

The array backend is only allowed to exist because it is *indistinguishable*
from the object control plane at every contract point; this suite pins that
as properties (hypothesis when installed, the deterministic ``tests/_hyp``
fallback otherwise):

  * the jitted array water-fill (both the exact sort-based ``ref`` impl and
    the fixed-iteration bisection ``pallas`` kernel) matches the scalar
    ``max_min_fair`` within 1e-6 x capacity on arbitrary demand vectors —
    including ``inf`` (backlogged) demands, zero demands and zero weights —
    never over-fills capacity, and hands satisfied tenants their demand
    exactly;
  * a ``StoreBucket`` (one row of the flat ``BucketStore``) is operation-
    for-operation *bit-identical* to a ``TokenBucket`` over arbitrary
    consume/drain/wait_time/set_rate sequences on the virtual clock,
    including snapshot/restore round trips in both directions — so
    migration TenantState payloads cross backends losslessly;
  * ``TenantIndex`` keeps the tenant<->slot map dense and stable under
    arbitrary add/drop/compact churn;
  * a ``VectorizedControlPlane`` driven by the same counter trace as a real
    TenantScheduler + RateController produces the same allocations;
  * telemetry eviction: a departed tenant's EWMA/baseline state leaves the
    telemetry maps (the PR 10 leak regression) — on explicit
    ``evict_tenant`` and on the cluster's migration-finalize path.
"""
import math

import numpy as np
import pytest

from repro.control.congestion import INF, WaterFill, max_min_fair
from repro.control.controller import RateController
from repro.control.telemetry import SchedulerTelemetry
from repro.control.vectorized import (
    BucketStore, TenantIndex, VectorizedControlPlane, check_backend,
    waterfill_allocate,
)
from repro.core.engine import TokenBucket
from repro.serve.scheduler import TenantScheduler

from _hyp import given, settings, st

CAP = 1000.0


def test_check_backend():
    assert check_backend("object") == "object"
    assert check_backend("vectorized") == "vectorized"
    with pytest.raises(ValueError):
        check_backend("simd")


# ---------------------------------------------------------------------------
# water-fill equivalence
# ---------------------------------------------------------------------------

_DEMAND = st.tuples(
    st.sampled_from(["zero", "small", "big", "inf"]),
    st.floats(min_value=0.01, max_value=1.0),
    st.sampled_from([0.0, 0.5, 1.0, 2.0, 4.0]),
)


def _build(entries):
    demands, weights = {}, {}
    for t, (kind, frac, w) in enumerate(entries):
        demands[t] = {"zero": 0.0, "small": frac * CAP / len(entries),
                      "big": frac * 2.0 * CAP, "inf": INF}[kind]
        weights[t] = w
    return demands, weights


def _check_against_mmf(demands, weights, vec, exact):
    mmf = max_min_fair(CAP, demands, weights)
    assert set(vec) == set(mmf)
    total = sum(vec.values())
    assert total <= CAP * (1 + 1e-9) + 1e-6
    # sums to capacity exactly when demand is sufficient
    want = sum(min(d, CAP) if math.isfinite(d) else CAP
               for t, d in demands.items() if weights[t] > 0)
    if want >= CAP:
        assert total == pytest.approx(CAP, abs=1e-6 * CAP)
    for t in mmf:
        assert vec[t] == pytest.approx(mmf[t], abs=1e-6 * CAP)
        if exact and math.isfinite(demands[t]) and mmf[t] == demands[t]:
            assert vec[t] == demands[t]      # satisfied => demand, exactly


@settings(max_examples=25, deadline=None)
@given(entries=st.lists(_DEMAND, min_size=1, max_size=12))
def test_waterfill_ref_matches_max_min_fair(entries):
    demands, weights = _build(entries)
    vec = waterfill_allocate(demands, CAP, weights, impl="ref")
    _check_against_mmf(demands, weights, vec, exact=True)


@settings(max_examples=5, deadline=None)
@given(entries=st.lists(_DEMAND, min_size=1, max_size=8))
def test_waterfill_pallas_matches_max_min_fair(entries):
    demands, weights = _build(entries)
    vec = waterfill_allocate(demands, CAP, weights, impl="pallas")
    _check_against_mmf(demands, weights, vec, exact=False)


def test_waterfill_facade_dispatch():
    """WaterFill(backend="vectorized").allocate == object backend."""
    from repro.control.telemetry import TenantObs

    obs = {0: TenantObs(rate=100.0, offered=100.0),
           1: TenantObs(rate=50.0, offered=50.0, deferred=30.0),
           2: TenantObs(rate=0.0, offered=0.0, queue=4.0)}
    weights = {0: 1.0, 1: 2.0, 2: 1.0}
    a_obj = WaterFill(weights, backend="object").allocate(obs, CAP)
    a_vec = WaterFill(weights, backend="vectorized").allocate(obs, CAP)
    assert set(a_obj) == set(a_vec)
    for t in a_obj:
        assert a_vec[t] == pytest.approx(a_obj[t], abs=1e-6 * CAP)


# ---------------------------------------------------------------------------
# bucket equivalence
# ---------------------------------------------------------------------------

_OPS = st.lists(
    st.tuples(st.sampled_from(["consume", "drain", "wait", "set_rate",
                               "set_rate_burst", "snapshot_roundtrip"]),
              st.floats(min_value=0.0, max_value=2.0),
              st.floats(min_value=0.01, max_value=1.0)),
    min_size=1, max_size=40)


def _apply(bucket, ops, rate, cap):
    """Drive one bucket through an op sequence; return observed outputs."""
    out, now = [], 0.0
    for op, x, dt in ops:
        now += dt
        if op == "consume":
            out.append(bucket.consume(x * cap, now=now))
        elif op == "drain":
            out.append(bucket.drain(x * cap, now=now))
        elif op == "wait":
            out.append(bucket.wait_time(x * cap, now=now))
        elif op == "set_rate":
            bucket.set_rate(rate * (0.5 + x), burst=None, now=now)
        elif op == "set_rate_burst":
            bucket.set_rate(rate * (0.5 + x), burst=cap * (0.5 + x), now=now)
        else:
            snap = bucket.snapshot(now=now)
            out.append(tuple(sorted(snap.items())))
        out.append((bucket.rate, bucket.capacity, bucket.tokens,
                    bucket.updated))
    return out


@settings(max_examples=30, deadline=None)
@given(rate=st.floats(min_value=0.5, max_value=500.0),
       cap=st.floats(min_value=1.0, max_value=1000.0), ops=_OPS)
def test_store_bucket_bit_identical_to_token_bucket(rate, cap, ops):
    ref = TokenBucket(rate, cap)
    store = BucketStore()
    vec = store.add(7, rate, cap)
    assert _apply(ref, ops, rate, cap) == _apply(vec, ops, rate, cap)


@settings(max_examples=20, deadline=None)
@given(rate=st.floats(min_value=0.5, max_value=500.0),
       cap=st.floats(min_value=1.0, max_value=1000.0), ops=_OPS,
       t0=st.floats(min_value=0.0, max_value=50.0))
def test_bucket_snapshots_cross_backends(rate, cap, ops, t0):
    """snapshot() from either backend restores into the other exactly."""
    ref = TokenBucket(rate, cap)
    store = BucketStore()
    vec = store.add(1, rate, cap)
    _apply(ref, ops, rate, cap)
    _apply(vec, ops, rate, cap)
    assert ref.snapshot(now=t0 + 100.0) == vec.snapshot(now=t0 + 100.0)
    # object -> array
    s2 = BucketStore()
    back = s2.restore(2, ref.snapshot(now=t0 + 100.0), now=t0 + 100.0)
    # array -> object
    forth = TokenBucket.restore(vec.snapshot(now=t0 + 100.0),
                                now=t0 + 100.0)
    for dt in (0.0, 3.7):
        want = ref.wait_time(cap, now=t0 + 100.0 + dt)
        assert back.wait_time(cap, now=t0 + 100.0 + dt) == want
        assert forth.wait_time(cap, now=t0 + 100.0 + dt) == want


# ---------------------------------------------------------------------------
# tenant index
# ---------------------------------------------------------------------------

_CHURN = st.lists(st.tuples(st.sampled_from(["add", "drop", "compact"]),
                            st.integers(min_value=0, max_value=30)),
                  min_size=1, max_size=60)


@settings(max_examples=30, deadline=None)
@given(churn=_CHURN)
def test_tenant_index_dense_and_stable(churn):
    idx = TenantIndex()
    shadow = {}                       # tenant -> the slot we last saw
    for op, t in churn:
        if op == "add":
            slot = idx.add(t)
            shadow[t] = slot
        elif op == "drop" and t in shadow:
            idx.drop(t)
            del shadow[t]
        elif op == "compact":
            remap = idx.compact()
            for tenant in shadow:
                s = shadow[tenant]
                shadow[tenant] = remap.get(s, s)
        # invariants after every operation
        assert len(idx) == len(shadow)
        assert idx.size >= len(idx)
        for tenant, slot in shadow.items():
            assert idx.slot(tenant) == slot
            assert idx.tenant_at(slot) == tenant
    remap = idx.compact()
    assert idx.size == len(idx)       # compact => dense
    seen = sorted(s for _, s in idx.items())
    assert seen == list(range(len(idx)))


def test_tenant_index_add_is_idempotent_and_reuses_slots():
    idx = TenantIndex()
    a = idx.add(10)
    assert idx.add(10) == a
    b = idx.add(11)
    idx.drop(10)
    assert idx.add(12) == a           # freed slot reused, size stays put
    assert idx.size == 2 and b == 1 - a or idx.size == 2


# ---------------------------------------------------------------------------
# fused tick vs the object pipeline
# ---------------------------------------------------------------------------

def _drive_both(n=40, ticks=4, seed=3):
    rng = np.random.default_rng(seed)
    weights = rng.choice([1.0, 2.0, 4.0], size=n)
    steps = np.maximum(np.round(rng.uniform(0.2, 2.0, size=n)
                                * (CAP / n)), 1.0)
    backlogged = rng.random(n) < 0.25

    sched = TenantScheduler(policy="wfq", charge_prompt=True)
    ctrl = RateController(CAP, weights={t: float(weights[t])
                                        for t in range(n)}, alpha=0.5)
    ctrl.attach_scheduler(sched)
    plane = VectorizedControlPlane(CAP, alpha=0.5, headroom=1.25)
    for t in range(n):
        sched.add_tenant(t, weight=float(weights[t]))
        plane.add_tenant(t, weight=float(weights[t]))
        if backlogged[t]:
            sched.queues[t].append(None)        # pending() counts length
    queue = np.where(backlogged, 1.0, 0.0)
    served = np.zeros(n)
    now = 0.0
    for _ in range(ticks):
        served += steps
        for t in range(n):
            sched.served_tokens[t] = int(served[t])
        ctrl.tick(now)
        plane.tick(served, queue=queue, now=now)
        now += 1.0
    trace = {"served": served, "steps": steps, "queue": queue, "now": now}
    return ctrl, plane, trace


@pytest.mark.slow
def test_vectorized_plane_matches_object_controller():
    ctrl, plane, _ = _drive_both()
    vec = plane.allocations()
    assert set(ctrl.allocations) == set(vec)
    for t, r in ctrl.allocations.items():
        assert vec[t] == pytest.approx(r, abs=1e-6 * CAP)
    # counters export the tick cost series nk_top renders
    c = plane.counters()
    assert c["nk_control_ticks_total"] >= 4
    assert c["nk_control_tick_seconds_total"] > 0
    assert c["nk_control_tenants"] == 40


@pytest.mark.slow
def test_plane_tenantstate_roundtrip_mid_flight():
    """export_tenant at an arbitrary tick point restores losslessly."""
    _, plane, trace = _drive_both(n=12, ticks=3)
    before = plane.allocations()
    snap = plane.export_tenant(5)
    assert 5 not in plane.index
    # restore at the export instant: the bucket re-anchors to ``now``, so
    # same-time restore must reproduce the snapshot bit-for-bit
    plane.restore_tenant(5, snap, now=snap["bucket"]["updated"])
    again = plane.snapshot_tenant(5)
    assert again["weight"] == snap["weight"]
    assert again["bucket"] == pytest.approx(snap["bucket"])
    assert again["ewma_offered"] == pytest.approx(snap["ewma_offered"])
    # the allocation itself re-forms on the next tick (a drop clears it,
    # exactly like the object controller re-pushing after a migration)
    served = trace["served"] + trace["steps"]
    plane.tick(served, queue=trace["queue"], now=trace["now"])
    assert plane.allocations()[5] == pytest.approx(before[5],
                                                   rel=0.35, abs=1.0)


def test_scheduler_bucket_backend_migration_roundtrip():
    """TenantState crosses object<->vectorized schedulers unchanged."""
    now = 1.0
    src = TenantScheduler(bucket_backend="vectorized")
    dst = TenantScheduler(bucket_backend="object")
    src.add_tenant(1, weight=2.0, rate_tokens_per_s=100.0, burst=50.0)
    src.buckets[1].consume(20.0, now=now)
    state = src.export_tenant(1, now=now)
    dst.import_tenant(1, state, now=now)
    assert dst.buckets[1].snapshot(now=now) == \
        pytest.approx({"rate": 100.0, "capacity": 50.0, "tokens": 30.0,
                       "updated": now})
    # and back again, via the checkpoint (full-state) path
    back = TenantScheduler(bucket_backend="vectorized")
    back.restore_tenant(1, dst.snapshot_tenant(1, now=now), now=now)
    assert back.buckets[1].snapshot(now=now) == \
        dst.buckets[1].snapshot(now=now)


# ---------------------------------------------------------------------------
# telemetry eviction (the leak regression)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["object", "vectorized"])
def test_scheduler_telemetry_eviction(backend):
    sched = TenantScheduler()
    tel = SchedulerTelemetry(sched, alpha=0.5, backend=backend)
    for t in (1, 2):
        sched.add_tenant(t)
        sched.served_tokens[t] = 10
    tel.update(now=0.0)
    sched.served_tokens[1] = 30
    sched.served_tokens[2] = 40
    tel.update(now=1.0)
    assert tel.tracked_tenants() >= {1, 2}
    sched.drop_tenant(1)
    tel.evict_tenant(1)
    assert 1 not in tel.tracked_tenants()
    assert 2 in tel.tracked_tenants()
    # the survivor's EWMA is untouched by the eviction
    obs = tel.update(now=2.0)
    assert 1 not in obs and 2 in obs


@pytest.mark.parametrize("backend", ["object", "vectorized"])
def test_controller_evict_tenant(backend):
    sched = TenantScheduler()
    ctrl = RateController(CAP, alpha=0.5, backend=backend)
    ctrl.attach_scheduler(sched)
    for t in (1, 2):
        sched.add_tenant(t)
        sched.served_tokens[t] = 5
    ctrl.tick(0.0)
    sched.served_tokens[1] = 25
    sched.served_tokens[2] = 25
    ctrl.tick(1.0)
    assert 1 in ctrl.allocations
    sched.drop_tenant(1)
    ctrl.evict_tenant(1)
    tel = ctrl._schedulers[0][1]
    assert 1 not in tel.tracked_tenants()
    assert 1 not in ctrl.allocations
    # a tenant the scheduler still holds is NOT evicted (migration source
    # that only moved one plane keeps live telemetry)
    ctrl.evict_tenant(2)
    assert 2 in tel.tracked_tenants()


def test_migration_finalize_evicts_source_telemetry():
    from repro.serve.scheduler import Request
    from test_placement import make_fake_cluster

    cluster = make_fake_cluster(2, controller=RateController(
        512.0, alpha=0.6))
    for t in range(2):
        cluster.add_tenant(t)
        for r in range(3):
            cluster.submit(Request(t, [1, 2], 4, req_id=10 * t + r,
                                   arrival=0.0))
    for i in range(8):
        cluster.step(now=0.1 * (i + 1))
    src = cluster.placement[0]
    tel_by_sched = {id(s): tel
                    for s, tel in cluster.controller._schedulers}
    src_tel = tel_by_sched[id(cluster.engines[src].scheduler)]
    assert 0 in src_tel.tracked_tenants()
    cluster.migrate(0, 1 - src, now=1.0)
    for i in range(12):
        cluster.step(now=1.0 + 0.1 * (i + 1))
    assert cluster.placement[0] == 1 - src
    assert 0 not in src_tel.tracked_tenants()      # the leak, plugged
    dst_tel = tel_by_sched[id(cluster.engines[1 - src].scheduler)]
    assert 0 in dst_tel.tracked_tenants()
