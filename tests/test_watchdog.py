"""The fabric watchdog: time-series store, SLO rules, alert lifecycle.

Five claims under test:

  * ``SeriesStore`` is a faithful retention layer: bounded scrape
    history, counter-reset-aware ``increase``/``rate`` (a decreased
    sample rebaselines and contributes zero — the live-migration /
    hot-swap reset semantics), windowed histogram quantiles by the
    ``Histogram`` upper-edge rule, and identical results whichever
    scrape form it ingests (exposition text, ``collect()`` dict, flat
    ``counters()`` dict);
  * the exposition text round-trips: render -> parse -> render is
    idempotent, and the parser tolerates blank lines, trailing
    whitespace and ``# EOF`` — so a recorded watchdog scrape replays;
  * each stock rule fires exactly on its invariant's violation and
    stays quiet on startup transients (window maturity), and the
    ``AlertEngine`` runs the fire-once / stay-active / resolve-once
    lifecycle with traced instants and exported counters;
  * the replay scenarios double as alert-precision fixtures on the
    jit-free fakes: steady fires ZERO alerts, adversarial pages the
    hog and nobody else, failover fires AND resolves engine-dark,
    stack_swap raises nothing fleet-level — and the recorded scrape
    sequence replays OFFLINE (``tools/nk_watch.py``) to the same
    alerts the live watchdog raised;
  * an empty latency window reports NaN, never a fake "perfect" 0.0,
    and every renderer shows it as ``-`` (the nk_top regression).
"""
import importlib.util
import json
import math
import pathlib

import pytest

from test_placement import ControlledFakeEngine, make_fake_cluster

from repro.control.controller import RateController
from repro.obs import (
    AbsenceRule, Alert, AlertEngine, BurnRateRule, ConservationDriftRule,
    FabricWatchdog, Histogram, JainFloorRule, MetricsRegistry,
    ParkedLeakRule, SeriesStore, SloSpec, ThresholdRule, default_rules,
    parse_prometheus_text, read_scrape_sequence, render_prometheus,
    render_series, series_key, window_mature,
)
from repro.obs.tracing import trace_to
from repro.serve.replay import make_watchdog, replay_scenario, scenario_spec

_TOOLS = pathlib.Path(__file__).resolve().parents[1] / "tools"


def _load_tool(name):
    spec = importlib.util.spec_from_file_location(name, _TOOLS / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


nk_top = _load_tool("nk_top")
nk_watch = _load_tool("nk_watch")
check_trace_mod = _load_tool("check_trace")


# ---------------------------------------------------------------------------
# SeriesStore
# ---------------------------------------------------------------------------


def test_store_retention_and_lookups():
    st = SeriesStore(retention=3)
    for i in range(5):
        st.ingest({"nk_x_total": float(i),
                   'nk_y{tenant="0"}': float(2 * i)}, ts=float(i))
    assert st.times() == (2.0, 3.0, 4.0)
    assert st.scrapes == 5
    assert st.names() == ["nk_x_total", "nk_y"]
    assert st.series("nk_y") == [("nk_y", (("tenant", "0"),))]
    assert st.label_values("nk_y", "tenant") == ["0"]
    assert st.latest(series_key("nk_x_total")) == 4.0
    # points older than the retained window are gone
    assert st.window(series_key("nk_x_total"))[0][0] == 2.0


def test_store_retention_drops_vanished_series():
    st = SeriesStore(retention=2)
    st.ingest({"nk_gone": 1.0}, ts=0.0)
    st.ingest({"nk_stays": 1.0}, ts=1.0)
    st.ingest({"nk_stays": 2.0}, ts=2.0)
    assert st.names() == ["nk_stays"]
    assert st.latest(series_key("nk_gone")) is None


def test_store_rejects_non_monotonic_scrapes():
    st = SeriesStore()
    st.ingest({"nk_x": 1.0}, ts=1.0)
    with pytest.raises(ValueError):
        st.ingest({"nk_x": 2.0}, ts=1.0)


def test_store_ingests_all_three_scrape_forms_identically():
    counters = {"nk_x_total": 3.0, 'nk_y{tenant="a b"}': 1.5}
    text = render_prometheus(counters)
    parsed = parse_prometheus_text(text)
    stores = [SeriesStore() for _ in range(3)]
    stores[0].ingest(text, ts=1.0)
    stores[1].ingest(parsed, ts=1.0)             # Series-keyed dict
    stores[2].ingest(counters, ts=1.0)           # flat counters() dict
    want = stores[0].series()
    for st in stores[1:]:
        assert st.series() == want
        for s in want:
            assert st.latest(s) == stores[0].latest(s)


def test_increase_is_reset_aware():
    st = SeriesStore()
    # 0 -> 5 (+5), 5 -> 2 (reset: +0), 2 -> 6 (+4)  => 9, never negative
    for ts, v in [(0, 0.0), (1, 5.0), (2, 2.0), (3, 6.0)]:
        st.ingest({"nk_c_total": v}, ts=float(ts))
    k = series_key("nk_c_total")
    assert st.increase(k) == 9.0
    assert st.rate(k) == pytest.approx(3.0)      # 9 over 3s
    # windowed: only the reset pair -> increase 0, rate 0
    assert st.increase(k, window_s=1.0, now=2.0) == 0.0
    assert st.rate(k, window_s=1.0, now=2.0) == 0.0


def test_rate_needs_two_samples():
    st = SeriesStore()
    st.ingest({"nk_c_total": 5.0}, ts=0.0)
    assert st.rate(series_key("nk_c_total")) == 0.0
    assert st.increase(series_key("nk_c_total")) == 0.0


def test_window_is_inclusive_both_ends():
    st = SeriesStore()
    for ts in range(5):
        st.ingest({"nk_c": float(ts)}, ts=float(ts))
    k = series_key("nk_c")
    pts = st.window(k, window_s=2.0, now=3.0)
    assert [t for t, _ in pts] == [1.0, 2.0, 3.0]


def test_quantile_over_time_upper_edge_rule():
    h = Histogram()
    st = SeriesStore()
    st.ingest(h.counters("nk_lat_seconds", tenant="0"), ts=0.0)
    for v in (0.002, 0.002, 0.002, 5.0):
        h.observe(v)
    st.ingest(h.counters("nk_lat_seconds", tenant="0"), ts=1.0)
    q50 = st.quantile_over_time("nk_lat_seconds", 0.50, tenant="0")
    q99 = st.quantile_over_time("nk_lat_seconds", 0.99, tenant="0")
    lo50, hi50 = h.quantile_bounds(0.50)
    lo99, hi99 = h.quantile_bounds(0.99)
    assert lo50 <= q50 <= hi50
    assert lo99 <= q99 <= hi99
    assert q99 >= 5.0                            # the slow sample's bucket
    # exact label match: no series for this tenant -> None
    assert st.quantile_over_time("nk_lat_seconds", 0.5, tenant="9") is None
    # empty window -> None (no samples observed inside it)
    assert st.quantile_over_time("nk_lat_seconds", 0.5, window_s=0.25,
                                 now=0.25, tenant="0") is None


# ---------------------------------------------------------------------------
# exposition round trip (the parser-tolerance satellite)
# ---------------------------------------------------------------------------


def test_parser_tolerates_blank_lines_trailing_ws_and_eof():
    text = ('# HELP nk_x Things.\n'
            '# TYPE nk_x gauge  \n'
            '\n'
            'nk_x{tenant="0"} 1  \n'
            '   \n'
            'nk_x{tenant="1"} 2\r\n'
            '# EOF\n')
    got = parse_prometheus_text(text)
    assert got[("nk_x", (("tenant", "0"),))] == 1.0
    assert got[("nk_x", (("tenant", "1"),))] == 2.0


def test_render_parse_render_is_idempotent():
    counters = {"nk_a_total": 7.0,
                'nk_b{le="+Inf",tenant="x\\"y"}': 3.0,
                "nk_gauge": 0.25}
    text1 = render_prometheus(counters)
    d1 = parse_prometheus_text(text1)
    text2 = render_prometheus(
        {render_series(n, lbl): v for (n, lbl), v in d1.items()})
    assert parse_prometheus_text(text2) == d1
    assert text1 == text2


def test_recorded_scrape_sequence_round_trips():
    reg = MetricsRegistry()
    state = {"n": 0.0}
    reg.register_provider(lambda: {"nk_ticks_total": state["n"]},
                          name="fake")
    wd = FabricWatchdog(reg, default_rules(), record=True)
    for i in range(3):
        state["n"] += 2.0
        wd.tick(float(i))
    seq = read_scrape_sequence(wd.scrape_sequence())
    assert [ts for ts, _ in seq] == [0.0, 1.0, 2.0]
    for i, (_, text) in enumerate(seq):
        got = parse_prometheus_text(text)
        assert got[("nk_ticks_total", ())] == 2.0 * (i + 1)


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------


def _polls_store(shares, *, interval=1.0, scrapes=12, per_scrape=40.0):
    """A store where tenant t accrues ``shares[t]`` of ``per_scrape``
    fleet deferred polls per scrape."""
    st = SeriesStore()
    tot = {t: 0.0 for t in shares}
    for i in range(scrapes):
        scrape = {}
        for t, sh in shares.items():
            tot[t] += sh * per_scrape
            scrape[f'nk_deferred_polls_total{{tenant="{t}"}}'] = tot[t]
        st.ingest(scrape, ts=i * interval)
    return st


def _fairness_rule(**kw):
    return BurnRateRule(
        "fairness_burn", SloSpec("share", 0.5, "max deferral share"),
        "nk_deferred_polls_total", fast_window_s=3.0, slow_window_s=8.0,
        **kw)


def test_burn_rate_fires_on_the_hog_only():
    st = _polls_store({"0": 0.05, "1": 0.05, "2": 0.9})
    rule = _fairness_rule()
    viol = rule.evaluate(st, 11.0)
    assert viol == {(("tenant", "2"),): pytest.approx(1.8)}
    burns = rule.burn_rates(st, 11.0)
    assert burns["2"][0] == pytest.approx(1.8)   # fast burn = share/obj
    assert burns["0"][1] == pytest.approx(0.1)


def test_burn_rate_requires_both_windows_burning():
    # the hog stops cold: slow window still burns, fast goes quiet
    st = SeriesStore()
    tot = {"0": 0.0, "1": 0.0}
    for i in range(12):
        hog_share = 0.9 if i < 8 else 0.0
        tot["0"] += (1.0 - hog_share) * 40.0
        tot["1"] += hog_share * 40.0
        st.ingest({f'nk_deferred_polls_total{{tenant="{t}"}}': v
                   for t, v in tot.items()}, ts=float(i))
    rule = _fairness_rule()
    assert (("tenant", "1"),) not in rule.evaluate(st, 11.0)


def test_burn_rate_min_events_floor_suppresses_trickles():
    # 90% share of a 2-events-per-scrape trickle must not page
    st = _polls_store({"0": 0.1, "1": 0.9}, per_scrape=2.0)
    assert _fairness_rule(min_events=30.0).evaluate(st, 11.0) == {}
    assert _fairness_rule(min_events=1.0).evaluate(st, 11.0) != {}


def test_threshold_rule_on_latest_value():
    st = SeriesStore()
    st.ingest({"nk_depth": 5.0}, ts=0.0)
    rule = ThresholdRule("deep", series_key("nk_depth"), bound=4.0)
    assert rule.evaluate(st, 0.0) == {(): 5.0}
    st.ingest({"nk_depth": 3.0}, ts=1.0)
    assert rule.evaluate(st, 1.0) == {}


def test_absence_rule_fires_on_frozen_counter_and_parked_gate():
    rule = AbsenceRule("engine_dark", "nk_engine_heartbeat_total",
                       key="engine", gate_family="nk_engine_parked",
                       window_s=2.0, min_scrapes=3)
    st = SeriesStore()
    for i in range(6):
        beat0 = float(min(i, 2))                 # engine 0 freezes at t=2
        st.ingest({'nk_engine_heartbeat_total{engine="0"}': beat0,
                   'nk_engine_heartbeat_total{engine="1"}': float(i),
                   'nk_engine_parked{engine="0"}': 0.0,
                   'nk_engine_parked{engine="1"}': 0.0}, ts=float(i))
    viol = rule.evaluate(st, 5.0)
    assert viol == {(("engine", "0"),): 0.0}
    # a PARKED engine's silent heartbeat is intentional, not dark
    st2 = SeriesStore()
    for i in range(6):
        st2.ingest({'nk_engine_heartbeat_total{engine="0"}': 2.0,
                    'nk_engine_parked{engine="0"}': 1.0}, ts=float(i))
    assert rule.evaluate(st2, 5.0) == {}


def test_window_mature_guards_startup():
    st = SeriesStore()
    st.ingest({"nk_x": 1.0}, ts=0.0)
    st.ingest({"nk_x": 1.0}, ts=1.0)
    assert not window_mature(st, 1.0, 8.0)       # 1s of an 8s window
    for i in range(2, 9):
        st.ingest({"nk_x": 1.0}, ts=float(i))
    assert window_mature(st, 8.0, 8.0)


def test_conservation_rule_fires_past_tolerance_not_on_startup():
    rule = ConservationDriftRule(window_s=3.0, tol=0.5)
    st = SeriesStore()
    served = 0.0
    for i in range(8):
        served += 200.0                          # 2x a 100/s capacity
        st.ingest({"controller_capacity": 100.0,
                   f'nk_served_tokens_total{{tenant="0"}}': served},
                  ts=float(i))
        viol = rule.evaluate(st, float(i))
        if i < 2:
            assert viol == {}, "immature window must not page"
    assert rule.evaluate(st, 7.0) == {(): pytest.approx(2.0)}


def _skewed_jain_store(failed_at=None):
    # three tenants (two-tenant Jain is bounded below by 0.5): one serves
    # 100x what the other two do
    st = SeriesStore()
    tot = {"0": 0.0, "1": 0.0, "2": 0.0}
    for i in range(10):
        tot["0"] += 100.0
        tot["1"] += 1.0
        tot["2"] += 1.0
        scrape = {f'nk_served_tokens_total{{tenant="{t}"}}': v
                  for t, v in tot.items()}
        scrape["nk_engines_failed"] = 1.0 if i == failed_at else 0.0
        st.ingest(scrape, ts=float(i))
    return st


def test_jain_rule_fires_on_skew_and_skips_failed_windows():
    rule = JainFloorRule(window_s=8.0, floor=0.5)
    viol = rule.evaluate(_skewed_jain_store(), 9.0)
    assert viol and next(iter(viol.values())) < 0.5
    # same skew during an engine failure window: engine-dark's problem
    assert rule.evaluate(_skewed_jain_store(failed_at=5), 9.0) == {}


def test_parked_leak_rule_needs_both_parked_and_backlog():
    rule = ParkedLeakRule(window_s=8.0, queue_floor=16.0)

    def store(parked, depth):
        st = SeriesStore()
        for i in range(10):
            st.ingest({"nk_cluster_parked": parked,
                       'nk_queue_depth{tenant="0"}': depth,
                       'nk_queue_depth{tenant="1"}': depth}, ts=float(i))
        return st

    # parked + deep fleet backlog (2 tenants x 10 >= 16) -> leak
    assert rule.evaluate(store(1.0, 10.0), 9.0) == {(): 20.0}
    # awake fleet, or parked over a shallow queue: no alert
    assert rule.evaluate(store(0.0, 10.0), 9.0) == {}
    assert rule.evaluate(store(1.0, 2.0), 9.0) == {}


def test_alert_engine_lifecycle_and_counters():
    rule = ThresholdRule("deep", series_key("nk_depth"), bound=4.0,
                         severity="ticket")
    eng = AlertEngine([rule])
    st = SeriesStore()
    with trace_to() as tr:
        st.ingest({"nk_depth": 5.0}, ts=0.0)
        assert [k for k, _ in eng.evaluate(st, 0.0)] == ["fire"]
        st.ingest({"nk_depth": 6.0}, ts=1.0)
        assert eng.evaluate(st, 1.0) == []       # still firing: no re-fire
        st.ingest({"nk_depth": 1.0}, ts=2.0)
        events = eng.evaluate(st, 2.0)
    assert [k for k, _ in events] == ["resolve"]
    a = events[0][1]
    assert isinstance(a, Alert) and a.resolved_at == 2.0 and not a.active
    assert a.value == 6.0                        # updated while active
    assert eng.counters() == {
        "nk_alerts_active": 0.0,
        'nk_alerts_total{rule="deep",severity="ticket"}': 1.0}
    names = [e["name"] for e in tr.chrome_trace()["traceEvents"]
             if e["ph"] in ("i", "I")]
    assert names.count("alert.fire") == 1
    assert names.count("alert.resolve") == 1


def test_default_rules_are_uniquely_named():
    rules = default_rules(1.0)
    names = [r.name for r in rules]
    assert len(set(names)) == len(names) == 7
    with pytest.raises(ValueError):
        AlertEngine(rules + [ThresholdRule(names[0],
                                           series_key("nk_x"), bound=1)])


def test_watchdog_is_a_metrics_provider():
    reg = MetricsRegistry()
    reg.register_provider(lambda: {"nk_x": 1.0}, name="fake")
    wd = FabricWatchdog(reg, default_rules())
    wd.tick(0.0)
    wd.tick(1.0)
    c = wd.counters()
    assert c["nk_watchdog_scrapes_total"] == 2.0
    assert c["nk_watchdog_rules"] == 7.0
    assert c["nk_alerts_active"] == 0.0
    with pytest.raises(ValueError):
        wd.scrape_sequence()                     # not recording


# ---------------------------------------------------------------------------
# scenario precision on the jit-free fakes
# ---------------------------------------------------------------------------

N_TENANTS = 4
INTERVALS = 12
HOG = str(N_TENANTS - 1)


def _watched_single(name):
    _, cap = scenario_spec(name, n_tenants=N_TENANTS, intervals=INTERVALS)
    eng = ControlledFakeEngine()
    ctrl = RateController(cap, alpha=0.6, push_mode="full")
    ctrl.attach_scheduler(eng.scheduler)
    eng.controller = ctrl
    return replay_scenario(name, n_tenants=N_TENANTS, intervals=INTERVALS,
                           engine=eng, watch=True)


def _watched_cluster(name, watch=True):
    _, cap = scenario_spec(name, n_tenants=N_TENANTS, intervals=INTERVALS)
    cl = make_fake_cluster(3, core_plane=True,
                           controller=RateController(cap, alpha=0.6))
    return replay_scenario(name, n_tenants=N_TENANTS, intervals=INTERVALS,
                           engine=cl, watch=watch)


def test_steady_scenario_fires_zero_alerts():
    rep = _watched_single("steady")
    assert rep.alerts_fired == 0, rep.alerts_by_rule()
    assert rep.alerts_active == 0
    assert rep.watchdog.ticks == INTERVALS + 1


def test_adversarial_scenario_pages_the_hog_and_nobody_else():
    rep = _watched_single("adversarial")
    by_rule = rep.alerts_by_rule()
    assert by_rule.get("fairness_burn", 0) >= 1
    for a in rep.alerts:
        lbl = dict(a.labels)
        if "tenant" in lbl:
            assert lbl["tenant"] == HOG, (a.rule, lbl)
    hog_fairness = [a for a in rep.alerts if a.rule == "fairness_burn"]
    assert all(dict(a.labels)["tenant"] == HOG for a in hog_fairness)


def test_failover_scenario_fires_and_resolves_engine_dark():
    rep = _watched_cluster("failover")
    dark = [a for a in rep.alerts if a.rule == "engine_dark"]
    assert len(dark) == 1
    assert dark[0].resolved_at is not None       # recovery resolves it
    assert dark[0].fired_at < dark[0].resolved_at
    for a in rep.alerts:                         # nothing blames a victim
        lbl = dict(a.labels)
        if "tenant" in lbl:
            assert lbl["tenant"] == HOG, (a.rule, lbl)


def test_stack_swap_scenario_stays_quiet_outside_the_quiesce():
    rep = _watched_cluster("stack_swap")
    offscript = [a for a in rep.alerts
                 if a.rule in ("engine_dark", "telemetry_stalled",
                               "conservation_drift", "jain_floor",
                               "parked_engine_leak")
                 or dict(a.labels).get("tenant") not in (HOG, None)]
    assert offscript == [], [(a.rule, dict(a.labels)) for a in offscript]


def test_recorded_run_replays_offline_to_the_same_alerts():
    _, cap = scenario_spec("adversarial", n_tenants=N_TENANTS,
                           intervals=INTERVALS)
    eng = ControlledFakeEngine()
    ctrl = RateController(cap, alpha=0.6, push_mode="full")
    ctrl.attach_scheduler(eng.scheduler)
    eng.controller = ctrl
    rep = replay_scenario("adversarial", n_tenants=N_TENANTS,
                          intervals=INTERVALS, engine=eng, watch="record")
    live = sorted((a.rule, tuple(a.labels), round(a.fired_at, 6))
                  for a in rep.alerts)
    scrapes = read_scrape_sequence(rep.watchdog.scrape_sequence())
    assert len(scrapes) == INTERVALS + 1
    interval = nk_watch.infer_interval([ts for ts, _ in scrapes])
    _, engine, events = nk_watch.replay_alerts(scrapes,
                                               interval_s=interval)
    offline = sorted((a.rule, tuple(a.labels), round(ts, 6))
                     for ts, kind, a in events if kind == "fire")
    assert offline == live


def test_alert_counters_reach_the_replay_report():
    rep = _watched_single("adversarial")
    assert rep.alerts_fired == len(rep.alerts)
    assert rep.alerts_active == sum(1 for a in rep.alerts if a.active)
    by_rule = rep.alerts_by_rule()
    assert sum(by_rule.values()) == rep.alerts_fired
    c = rep.watchdog.counters()
    assert c["nk_alerts_active"] == float(rep.alerts_active)


# ---------------------------------------------------------------------------
# check_trace: the alert-lifecycle rule
# ---------------------------------------------------------------------------


def _instant(name, ts, **args):
    return {"name": name, "ph": "i", "ts": ts, "pid": 1, "tid": 1,
            "args": args}


def test_check_trace_accepts_balanced_alert_lifecycle():
    doc = {"traceEvents": [
        _instant("alert.fire", 1, rule="deep", severity="page", tenant="3",
                 value=2.0),
        _instant("alert.resolve", 2, rule="deep", severity="page",
                 tenant="3"),
    ]}
    assert check_trace_mod.check_trace(doc) == []


def test_check_trace_flags_resolve_without_fire_and_double_fire():
    orphan = {"traceEvents": [
        _instant("alert.resolve", 1, rule="deep", severity="page",
                 tenant="3")]}
    probs = check_trace_mod.check_trace(orphan)
    assert any("alert.resolve" in p and "without" in p for p in probs)
    doubled = {"traceEvents": [
        _instant("alert.fire", 1, rule="deep", severity="page", tenant="3"),
        _instant("alert.fire", 2, rule="deep", severity="page", tenant="3"),
    ]}
    probs = check_trace_mod.check_trace(doubled)
    assert any("fired" in p and "twice" in p for p in probs)
    # still-active at end is legal: a recording can stop mid-incident
    active = {"traceEvents": [
        _instant("alert.fire", 1, rule="deep", severity="page", tenant="3")]}
    assert check_trace_mod.check_trace(active) == []


def test_watched_failover_trace_passes_the_validator():
    _, cap = scenario_spec("failover", n_tenants=N_TENANTS,
                           intervals=INTERVALS)
    cl = make_fake_cluster(3, core_plane=True,
                           controller=RateController(cap, alpha=0.6))
    with trace_to() as tr:
        rep = replay_scenario("failover", n_tenants=N_TENANTS,
                              intervals=INTERVALS, engine=cl, watch=True)
    assert rep.alerts_fired >= 1
    doc = json.loads(tr.to_json())
    assert check_trace_mod.check_trace(doc, scenario="failover") == []
    names = [e["name"] for e in doc["traceEvents"]]
    assert "alert.fire" in names and "alert.resolve" in names


def test_steady_trace_contains_no_alert_instants():
    _, cap = scenario_spec("steady", n_tenants=N_TENANTS,
                           intervals=INTERVALS)
    eng = ControlledFakeEngine()
    ctrl = RateController(cap, alpha=0.6, push_mode="full")
    ctrl.attach_scheduler(eng.scheduler)
    eng.controller = ctrl
    with trace_to() as tr:
        replay_scenario("steady", n_tenants=N_TENANTS, intervals=INTERVALS,
                        engine=eng, watch=True)
    names = {e["name"] for e in tr.chrome_trace()["traceEvents"]}
    assert not {n for n in names if n.startswith("alert.")}


# ---------------------------------------------------------------------------
# the NaN -> "-" regression (empty latency window is absence, not zero)
# ---------------------------------------------------------------------------


def test_silent_tenant_latency_is_nan_not_zero():
    trace, cap = scenario_spec("steady", n_tenants=N_TENANTS,
                               intervals=INTERVALS)
    trace.loads[0, :] = 0.0                      # tenant 0 never arrives
    from repro.serve.replay import TraceReplayer
    eng = ControlledFakeEngine()
    ctrl = RateController(cap, alpha=0.6, push_mode="full")
    ctrl.attach_scheduler(eng.scheduler)
    eng.controller = ctrl
    rep = TraceReplayer(eng, capacity=cap).run(trace)
    silent = rep.per_tenant[0]
    assert math.isnan(silent.p50_admit_wait_s)
    assert math.isnan(silent.p99_admit_wait_s)
    busy = rep.per_tenant[1]
    assert not math.isnan(busy.p99_admit_wait_s)


def test_fmt_renders_nan_and_none_as_absence():
    assert nk_top._fmt(float("nan")) == "-"
    assert nk_top._fmt(None) == "-"
    assert nk_top._fmt(0.0, "s") == "0.0ms"      # a real zero still renders


# ---------------------------------------------------------------------------
# the offline tools end to end
# ---------------------------------------------------------------------------


def test_nk_top_diff_renders_reset_aware_rates():
    old, new = nk_top.demo_scrapes()
    out = nk_top.render_diff(old, new)
    assert "reset-aware" in out
    assert "tok/s" in out
    assert "migrations/min" in out
    assert "-60" not in out and " -1" not in out  # never a negative rate
    # headers carry the timestamps: 1.0s apart
    assert "diff over 1s" in out


def test_nk_top_demo_snapshot_still_renders():
    text = nk_top.demo_scrape()
    out = nk_top.render(nk_top.Scrape(parse_prometheus_text(text)))
    assert "fabric snapshot" in out
    assert "engine" in out


def test_nk_watch_renders_the_timeline(capsys):
    _, cap = scenario_spec("adversarial", n_tenants=N_TENANTS,
                           intervals=INTERVALS)
    eng = ControlledFakeEngine()
    ctrl = RateController(cap, alpha=0.6, push_mode="full")
    ctrl.attach_scheduler(eng.scheduler)
    eng.controller = ctrl
    rep = replay_scenario("adversarial", n_tenants=N_TENANTS,
                          intervals=INTERVALS, engine=eng, watch="record")
    scrapes = read_scrape_sequence(rep.watchdog.scrape_sequence())
    store, engine, events = nk_watch.replay_alerts(scrapes)
    out = nk_watch.render(store, engine, events,
                          nk_watch.infer_interval([t for t, _ in scrapes]))
    assert "fairness_burn" in out
    assert "FIRING" in out
    assert f"tenant={HOG}" in out


def test_make_watchdog_requires_a_scrapable_engine():
    eng = ControlledFakeEngine()                 # no controller attached
    with pytest.raises(ValueError):
        make_watchdog(eng)
