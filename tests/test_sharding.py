"""Logical-axis resolution: divisibility fallback, variants, stripping."""
import pytest
from jax.sharding import PartitionSpec as P

from repro.distribution.sharding import (
    FSDP_RULES, LOGICAL_RULES, make_rules, padded_heads, resolve_dim,
    spec_for, strip_axes_from_rules,
)
from repro.launch.mesh import make_host_mesh, make_production_mesh


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh(2, 4)


def test_divisible_dims_shard(mesh):
    assert spec_for((8, 16), ("batch", "ffn"), mesh) == P("data", "model")


def test_indivisible_dims_replicate(mesh):
    # 6 % 4 != 0 -> ffn falls back to replicated; 3 % 2 != 0 -> batch too
    assert spec_for((3, 6), ("batch", "ffn"), mesh) == P()


def test_axis_used_at_most_once(mesh):
    # both dims want 'model'; second falls back
    spec = spec_for((8, 8), ("ffn", "vocab"), mesh)
    assert spec == P("model")


def test_trailing_nones_trimmed(mesh):
    assert spec_for((8, 16, 32), ("batch", None, None), mesh) == P("data")


def test_multi_axis_candidates():
    mesh = make_host_mesh(2, 2, pod=2)
    assert spec_for((8, 4), ("batch", None), mesh) == P(("pod", "data"))
    # batch=6 not divisible by pod*data=4 -> falls to data alone
    assert spec_for((6, 4), ("batch", None), mesh) == P("data")


def test_fsdp_variant_uses_whole_mesh(mesh):
    rules = make_rules("fsdp")
    assert spec_for((16, 4), ("batch", None), mesh, rules) == \
        P(("data", "model"))
    assert spec_for((16, 8), ("vocab", "embed"), mesh, rules) == \
        P(None, ("data", "model"))


def test_strip_axes():
    stripped = strip_axes_from_rules(("pod",))
    assert "pod" not in str(stripped["batch"])
    assert stripped["stage"] == ()


class _FakeMesh:
    """Only axis sizes matter for the pure sharding math (tests run with 8
    host devices; the production 16x16 mesh exists only in the dry-run)."""

    def __init__(self, **axes):
        import numpy as np
        self.axis_names = tuple(axes)
        self.devices = np.zeros(tuple(axes.values()))


def test_padded_heads():
    mesh = _FakeMesh(data=16, model=16)
    assert padded_heads(24, mesh) == 32     # llama3.2-3b
    assert padded_heads(25, mesh) == 32     # hymba
    assert padded_heads(12, mesh) == 16     # whisper
    assert padded_heads(56, mesh) == 64     # arctic
    assert padded_heads(96, mesh) == 96     # nemotron divides


def test_production_spec_resolution():
    """The production-mesh sharding decisions, via the pure spec math."""
    mesh = _FakeMesh(data=16, model=16)
    # whisper's 51865 vocab is not 16-divisible -> replicated; d=768 shards
    assert spec_for((51865, 768), ("vocab", "embed"), mesh) == P(None, "data")
    # nemotron: everything divides
    assert spec_for((256000, 18432), ("vocab", "embed"), mesh) == \
        P("model", "data")
    # deepseek experts 160 over model
    assert spec_for((160, 5120, 1536), ("experts", "embed", None), mesh) == \
        P("model", "data")
