"""Placement autopilot: the closed loop from load to *where* tenants run.

Tier-1 exercises the whole loop — policies, gates, park/unpark lifecycle,
plan application through the real ``EngineCluster``/``migrate`` machinery,
and the bytes-plane CoreEngine migration — on a jit-free ``FakeEngine``
that mirrors ServeEngine's slot/billing semantics exactly (admit bills
prompt + first token, each decode step bills one token), so ledger
conservation is asserted for real without a single compile. The jitted
end-to-end scenarios live in tests/test_replay.py under `slow`.
"""
import pytest

from repro.control.placement import (
    ClusterView, Consolidate, PlacementController, PlacementPlan,
    PlannedMove, SpreadHot, make_policy,
)
from repro.core.engine import CoreEngine
from repro.core.nqe import CommOp
from repro.fabric import SchedulerServeModule
from repro.serve.cluster import EngineCluster
from repro.serve.scheduler import Request, TenantScheduler


# ---------------------------------------------------------------------------
# FakeEngine: ServeEngine's driving surface + billing, no jax compiles
# ---------------------------------------------------------------------------


class _Slot:
    def __init__(self, req=None, remaining=0):
        self.active = req is not None
        self.req = req
        self.remaining = remaining


class FakeEngine(SchedulerServeModule):
    """Slot-for-slot mirror of ServeEngine's admission/billing contract.

    Inherits the whole serve-plane ``StackModule`` protocol (export /
    import / conservation / tenant_load / suspend / resume) from the SAME
    mixin the real engine uses, so the protocol cannot drift between the
    jitted engine and this jit-free double. Its fake "KV-cache" is
    ``FAKE_CACHE_BYTES``, dropped on suspend like the real one."""

    FAKE_CACHE_BYTES = 4096

    def __init__(self, batch_slots=4):
        self.B = batch_slots
        self.scheduler = TenantScheduler(policy="wfq", charge_prompt=True)
        self.controller = None
        self.slots = self._make_slots()
        self.completed = []
        self.decode_steps = 0

    def _make_slots(self):
        return [_Slot() for _ in range(self.B)]

    def _cache_bytes(self):
        return self.FAKE_CACHE_BYTES

    def submit(self, req):
        self.scheduler.submit(req)

    def step(self, now=None):
        for i, s in enumerate(self.slots):
            if s.active:
                continue
            req = self.scheduler.next_request(now)
            if req is None:
                break
            req.generated.append(1)          # prefill's first token
            self.scheduler.account(req.tenant_id, len(req.prompt) + 1)
            if req.max_new_tokens <= 1:
                self.completed.append(req)
                continue
            self.slots[i] = _Slot(req, req.max_new_tokens - 1)
        active = [s for s in self.slots if s.active]
        for i, s in enumerate(self.slots):
            if not s.active:
                continue
            s.req.generated.append(1)
            s.remaining -= 1
            self.scheduler.account(s.req.tenant_id, 1)
            if s.remaining <= 0:
                self.completed.append(s.req)
                self.slots[i] = _Slot()
        if active:
            self.decode_steps += 1
        return len(active)


class ControlledFakeEngine(FakeEngine):
    """FakeEngine that drives its own attached ``RateController`` —
    ``step()`` ticks the controller every ``control_every`` steps, the
    way ``ServeEngine`` does on the real path. Single-engine replay and
    watchdog tests need this: a controller nobody ticks never pushes
    rates, so telemetry/deferral counters stay flat."""

    def __init__(self, batch_slots=4, control_every=4):
        super().__init__(batch_slots)
        self.control_every = control_every
        self._ctl_steps = 0

    def step(self, now=None):
        self._ctl_steps += 1
        if self.controller is not None and \
                self._ctl_steps % self.control_every == 0:
            self.controller.tick(now)
        return super().step(now)


def make_fake_cluster(n_engines=3, *, core_plane=False, **kw):
    cores = [CoreEngine(enforcement="account") for _ in range(n_engines)] \
        if core_plane else None
    return EngineCluster([FakeEngine() for _ in range(n_engines)],
                         core_engines=cores, **kw)


def _req(tenant, k=0, tokens=6, now=0.0):
    return Request(tenant_id=tenant, prompt=[1, 2], max_new_tokens=tokens,
                   req_id=k, arrival=now)


def _view(**kw):
    base = dict(n_engines=3, parked=frozenset(), placement={},
                draining=frozenset(), engine_load=(0.0, 0.0, 0.0),
                demand={}, pending={}, queued_cost={},
                inflight_remaining={})
    base.update(kw)
    return ClusterView(**base)


# ---------------------------------------------------------------------------
# park/unpark lifecycle
# ---------------------------------------------------------------------------


def test_park_requires_quiesced_engine_and_never_last():
    cl = make_fake_cluster(2)
    cl.add_tenant(0, engine=0)
    with pytest.raises(ValueError):
        cl.park(0)                 # hosts a tenant
    cl.park(1)
    assert cl.parked == {1}
    with pytest.raises(ValueError):
        cl.park(1)                 # already parked
    with pytest.raises(ValueError):
        cl.park(0)                 # would be the last awake engine
    # parked engines are invisible to auto-placement and refuse placement
    assert cl.add_tenant(5) == 0
    with pytest.raises(ValueError):
        cl.add_tenant(6, engine=1)
    # ...and refuse migrations onto them
    with pytest.raises(ValueError):
        cl.migrate(0, 1)
    cl.unpark(1)
    with pytest.raises(ValueError):
        cl.unpark(1)               # not parked anymore
    assert cl.migrate(0, 1) is not None


def test_parked_engines_do_not_step_and_cores_saved_accumulates():
    cl = make_fake_cluster(3)
    cl.add_tenant(0, engine=0)
    cl.park(1)
    cl.park(2)
    cl.submit(_req(0))
    for _ in range(4):
        cl.step(now=0.1)
    assert cl.engines[1].decode_steps == 0
    assert cl.engines[2].decode_steps == 0
    assert cl.parked_engine_steps == 8          # 2 engines x 4 steps
    assert cl.cores_saved() == pytest.approx(2.0)
    assert cl.max_parked == 2
    counters = cl.counters()
    assert counters["nk_cluster_parked"] == 2.0
    assert counters["nk_cores_saved"] == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# consolidate policy
# ---------------------------------------------------------------------------


def test_consolidate_packs_idle_fleet_and_parks_the_rest():
    v = _view(placement={0: 0, 1: 1, 2: 2},
              demand={0: 1.0, 1: 1.0, 2: 1.0},
              queued_cost={0: 0.0, 1: 0.0, 2: 0.0})
    plan = Consolidate(ceiling=10.0).plan(v, 0.0)
    assert {(m.tenant, m.src, m.dst) for m in plan.moves} == \
        {(1, 1, 0), (2, 2, 0)}
    assert plan.park == [1, 2] and plan.unpark == []


def test_consolidate_is_sticky_in_steady_state():
    """A fleet already packed under the ceiling plans zero moves."""
    v = _view(placement={0: 0, 1: 0, 2: 1},
              demand={0: 4.0, 1: 4.0, 2: 4.0}, parked=frozenset({2}))
    plan = Consolidate(ceiling=10.0).plan(v, 0.0)
    assert plan.moves == []
    assert plan.park == [] and plan.unpark == []


def test_consolidate_unparks_when_load_returns():
    """Demand above one engine's ceiling wakes parked engines."""
    v = _view(placement={0: 0, 1: 0, 2: 0}, parked=frozenset({1, 2}),
              demand={0: 8.0, 1: 8.0, 2: 8.0})
    plan = Consolidate(ceiling=10.0).plan(v, 0.0)
    assert plan.unpark == [1, 2]
    dsts = {m.tenant: m.dst for m in plan.moves}
    assert dsts == {1: 1, 2: 2}                 # spread off the full engine
    assert plan.park == []


def test_consolidate_overload_spills_instead_of_refusing():
    """Demand no engine set can fit still places every tenant."""
    v = _view(placement={0: 0, 1: 1, 2: 2, 3: 0},
              demand={0: 9.0, 1: 9.0, 2: 9.0, 3: 9.0})
    plan = Consolidate(ceiling=10.0).plan(v, 0.0)
    # nobody fits anywhere twice: the fourth tenant spills, none park
    assert plan.park == []
    assert len(plan.moves) <= 1                 # t3 may spill elsewhere
    with pytest.raises(ValueError):
        Consolidate(ceiling=0.0)


def test_consolidate_never_moves_a_draining_tenant():
    v = _view(placement={0: 0, 1: 1}, draining=frozenset({1}),
              demand={0: 1.0, 1: 1.0})
    plan = Consolidate(ceiling=10.0).plan(v, 0.0)
    assert all(m.tenant != 1 for m in plan.moves)
    assert 1 not in plan.park                   # its engine stays open


# ---------------------------------------------------------------------------
# spread_hot policy: bands, arming, usefulness
# ---------------------------------------------------------------------------


def test_spread_hot_moves_most_backlogged_off_hot_engine():
    v = _view(placement={0: 0, 1: 0, 2: 1},
              engine_load=(20.0, 1.0, 0.0),
              pending={0: 15, 1: 3, 2: 1},
              queued_cost={0: 120.0, 1: 24.0, 2: 8.0})
    plan = SpreadHot().plan(v, 0.0)
    assert [(m.tenant, m.src, m.dst) for m in plan.moves] == [(0, 0, 2)]


def test_spread_hot_bands_ignore_small_or_balanced_loads():
    p = SpreadHot(min_hot_load=8.0, enter_ratio=2.0)
    # below the absolute floor: jitter, not a hotspot
    v = _view(placement={0: 0, 1: 1}, engine_load=(5.0, 1.0, 0.0),
              pending={0: 5, 1: 1})
    assert p.plan(v, 0.0).empty
    # above the floor but inside the ratio band: balanced enough
    v = _view(placement={0: 0, 1: 1}, engine_load=(12.0, 8.0, 9.0),
              pending={0: 12, 1: 8})
    assert p.plan(v, 0.0).empty


def test_spread_hot_disarms_moved_tenant_until_engine_cools():
    """The hysteresis band: a hog whose queue keeps every engine it
    touches hot migrates exactly once — no ping-pong, ever."""
    p = SpreadHot(min_hot_load=8.0)
    hot = _view(placement={0: 0, 1: 0, 2: 1, 3: 2},
                engine_load=(50.0, 1.0, 1.0),
                pending={0: 48, 1: 1, 2: 1, 3: 1})
    plan = p.plan(hot, 0.0)
    assert plan.moves[0].tenant == 0
    p.notify_moved(0)
    # the hog landed alone on engine 2 and heats it just the same: it is
    # disarmed, so nothing moves, however long the hotspot persists
    after = _view(placement={0: 2, 1: 0, 2: 1, 3: 1},
                  engine_load=(1.0, 2.0, 50.0),
                  pending={0: 48, 1: 1, 2: 1, 3: 1})
    assert p.plan(after, 1.0).empty              # disarmed: no bounce
    assert p.plan(after, 5.0).empty              # time alone never re-arms
    # only a cooled engine re-arms the tenant
    cooled = _view(placement={0: 2, 1: 0, 2: 0, 3: 1},
                   engine_load=(30.0, 1.0, 2.0),
                   pending={0: 1, 1: 28, 2: 1, 3: 1})
    plan = p.plan(cooled, 6.0)
    assert 0 not in p._disarmed
    assert plan.moves and plan.moves[0].tenant == 1


def test_spread_hot_refuses_useless_move_of_a_lone_hog():
    """A hog alone on its engine has no co-tenant to relieve and moving
    it cannot improve the balance: the plan must be empty."""
    v = _view(placement={0: 0, 1: 1, 2: 2},
              engine_load=(50.0, 2.0, 1.0),
              pending={0: 48, 1: 2, 2: 1})
    assert SpreadHot().plan(v, 0.0).empty


def test_make_policy_registry():
    assert isinstance(make_policy("spread_hot"), SpreadHot)
    assert isinstance(make_policy("consolidate", ceiling=5.0), Consolidate)
    with pytest.raises(KeyError):
        make_policy("nope")
    p = SpreadHot()
    assert make_policy(p) is p
    with pytest.raises(ValueError):
        make_policy(p, ceiling=5.0)


# ---------------------------------------------------------------------------
# controller gates: cooldown + drain cost
# ---------------------------------------------------------------------------


class _OneMovePolicy:
    name = "test"

    def __init__(self, moves=(), park=(), unpark=()):
        self.next_plan = PlacementPlan(moves=list(moves), park=list(park),
                                       unpark=list(unpark))

    def plan(self, view, now):
        return PlacementPlan(moves=list(self.next_plan.moves),
                             park=list(self.next_plan.park),
                             unpark=list(self.next_plan.unpark))


def test_cooldown_blocks_second_move_within_hysteresis_window():
    cl = make_fake_cluster(3)
    cl.add_tenant(0, engine=0)
    pc = PlacementController(
        cl, policy=_OneMovePolicy([PlannedMove(0, 0, 1, "test")]),
        cooldown_s=3.0, drain_cost_factor=None)
    pc.tick(now=0.0)
    assert cl.placement[0] == 1
    # the tenant wants to move again immediately: gated
    pc.policy.next_plan = PlacementPlan(
        moves=[PlannedMove(0, 1, 2, "test")])
    pc.tick(now=1.0)
    assert cl.placement[0] == 1
    assert pc.moves_skipped_cooldown == 1
    pc.tick(now=3.5)                             # window expired
    assert cl.placement[0] == 2
    pc.assert_no_ping_pong()
    # and the invariant checker actually bites on a violating log
    pc.move_log.append((3.6, PlannedMove(0, 2, 0, "test")))
    with pytest.raises(AssertionError):
        pc.assert_no_ping_pong()


def test_drain_cost_gate_skips_expensive_moves():
    cl = make_fake_cluster(2)
    cl.add_tenant(0, engine=0)
    mv = PlannedMove(0, 0, 1, "test", expected_gain=10.0, drain_cost=25.0)
    pc = PlacementController(cl, policy=_OneMovePolicy([mv]),
                             cooldown_s=0.0, drain_cost_factor=1.0)
    pc.tick(now=0.0)
    assert cl.placement[0] == 0
    assert pc.moves_skipped_drain == 1
    # disabling the gate lets the same move through
    pc2 = PlacementController(cl, policy=_OneMovePolicy([mv]),
                              cooldown_s=0.0, drain_cost_factor=None)
    pc2.tick(now=0.0)
    assert cl.placement[0] == 1


def test_gated_move_cancels_dependent_park_and_unpark():
    cl = make_fake_cluster(3)
    cl.add_tenant(0, engine=0)
    cl.park(2)
    mv = PlannedMove(0, 0, 2, "test", expected_gain=0.0, drain_cost=9.0)
    pol = _OneMovePolicy([mv], park=[0], unpark=[2])
    pc = PlacementController(cl, policy=pol, cooldown_s=0.0,
                             drain_cost_factor=1.0)
    pc.tick(now=0.0)
    # the move was drain-gated, so engine 0 still hosts the tenant (no
    # park) and waking engine 2 would have served nobody (no unpark)
    assert cl.placement[0] == 0
    assert cl.parked == {2}


# ---------------------------------------------------------------------------
# the closed loop on a live (fake) cluster
# ---------------------------------------------------------------------------


def _pump(cl, loads, vt, seconds, dt=0.25):
    """Submit per-tenant request loads (req/s) and step the cluster."""
    import itertools
    frac = {t: 0.0 for t in loads}
    ids = itertools.count(1000)
    end = vt + seconds
    while vt < end - 1e-9:
        for t, rps in loads.items():
            frac[t] += rps * dt
            while frac[t] >= 1.0:
                frac[t] -= 1.0
                cl.submit(_req(t, k=next(ids), now=vt))
        cl.step(now=vt)
        vt += dt
    return vt


def test_closed_loop_consolidation_parks_and_unparks():
    """Busy -> idle -> busy on a fake 3-engine cluster: the autopilot
    packs the idle fleet, parks engines (cores saved), and wakes them
    when load returns — zero ping-pong throughout."""
    cl = make_fake_cluster(3, place_every=4)
    pc = PlacementController(cl, policy="consolidate", ceiling=30.0,
                             cooldown_s=2.0, alpha=1.0)
    cl.attach_autopilot(pc)
    for t in range(3):
        cl.add_tenant(t, engine=t)
    busy = {t: 3.0 for t in range(3)}           # 3 req/s x 8 tok = 24 tok/s
    idle = {t: 0.25 for t in range(3)}
    vt = _pump(cl, busy, 0.0, 4.0)
    assert cl.parked == set()                    # busy fleet needs everyone
    vt = _pump(cl, idle, vt, 6.0)
    assert len(cl.parked) >= 1                   # the cores-saved window
    assert cl.cores_saved() > 0
    packed = set(cl.placement.values())
    assert len(packed) == 1                      # fleet fits one engine
    saved_at_idle = cl.parked_engine_steps
    vt = _pump(cl, busy, vt, 6.0)
    assert cl.parked == set()                    # load returned: all awake
    assert len(set(cl.placement.values())) == 3  # spread again
    assert cl.parked_engine_steps >= saved_at_idle
    pc.assert_no_ping_pong()
    for t in range(3):
        cl.assert_ledger_conservation(t)


def test_closed_loop_hotspot_migrates_hog_once():
    """A mid-run hog heats its engine; spread_hot moves it (and only it,
    and only once) to the coolest engine."""
    cl = make_fake_cluster(3, place_every=4)
    pc = PlacementController(cl, policy="spread_hot", min_hot_load=6.0,
                             cooldown_s=2.0, alpha=1.0)
    cl.attach_autopilot(pc)
    cl.add_tenant(0, engine=0)
    cl.add_tenant(1, engine=1)
    cl.add_tenant(2, engine=2)
    cl.add_tenant(3, engine=0)                   # future hog, shares e0
    calm = {0: 1.0, 1: 1.0, 2: 1.0, 3: 1.0}
    vt = _pump(cl, calm, 0.0, 3.0)
    assert cl.migrations_started == 0
    hot = {0: 1.0, 1: 1.0, 2: 1.0, 3: 30.0}     # way past 4 slots/engine
    vt = _pump(cl, hot, vt, 8.0)
    moved = [mv.tenant for _, mv in pc.move_log]
    # the hog moved away from its engine exactly once; its new neighbour
    # may evacuate once (de-colocation), but nobody moves twice
    assert moved.count(3) == 1
    assert cl.placement[3] != 0                  # the hog left its engine
    assert len(moved) == len(set(moved))
    # the loop went quiet: more hot time adds no migrations
    before = len(pc.move_log)
    vt = _pump(cl, hot, vt, 6.0)
    assert len(pc.move_log) == before
    pc.assert_no_ping_pong()
    for t in range(4):
        cl.assert_ledger_conservation(t)


# ---------------------------------------------------------------------------
# apply_plan: stale entries, conservation, record plumbing
# ---------------------------------------------------------------------------


def test_apply_plan_skips_stale_moves_and_parks_only_quiesced():
    cl = make_fake_cluster(3)
    cl.add_tenant(0, engine=0)
    cl.add_tenant(1, engine=1)
    plan = PlacementPlan(moves=[
        PlannedMove(0, 0, 1, "test"),
        PlannedMove(7, 0, 1, "test"),            # unknown tenant: stale
        PlannedMove(1, 0, 2, "test"),            # wrong src: stale
    ], park=[0, 1])
    recs = cl.apply_plan(plan, now=0.0)
    assert [r.tenant for r in recs] == [0]
    assert cl.placement == {0: 1, 1: 1}
    assert cl.parked == {0}                      # engine 1 is not quiesced


def test_rebalance_is_a_thin_wrapper_with_legacy_semantics():
    """The deprecated one-shot keeps its contract: hottest -> coolest,
    most-backlogged victim, None when balanced, KeyError/RuntimeError on
    bad pins — but the selection logic now lives in the policy."""
    cl = make_fake_cluster(3)
    cl.add_tenant(0, engine=0)
    cl.add_tenant(1, engine=0)
    cl.add_tenant(2, engine=1)
    for k in range(6):
        cl.submit(_req(0, k=k))
    for k in range(2):
        cl.submit(_req(1, k=10 + k))
    cl.submit(_req(2, k=20))
    with pytest.warns(DeprecationWarning):
        rec = cl.rebalance(now=0.0)
    assert rec is not None
    assert rec.tenant == 0 and rec.src == 0 and rec.dst == 2
    # balanced cluster (same loads everywhere): no-op
    cl2 = make_fake_cluster(2)
    cl2.add_tenant(0, engine=0)
    cl2.add_tenant(1, engine=1)
    with pytest.warns(DeprecationWarning):
        assert cl2.rebalance() is None
    # bad pins keep migrate()'s error contract
    with pytest.warns(DeprecationWarning), pytest.raises(KeyError):
        cl.rebalance(tenant=99)
    # pinned tenant moves from wherever it is
    with pytest.warns(DeprecationWarning):
        rec = cl.rebalance(tenant=1, now=0.0)
    assert rec is not None and rec.tenant == 1


def test_rebalance_emits_deprecation_warning():
    """Satellite: the PR-4 deprecation is now enforced — every
    ``rebalance()`` call warns, and ``operator_rebalance`` (the
    ``plan_once(force=True)`` spelling) does the same move silently."""
    import warnings

    from repro.serve.replay import operator_rebalance

    def hot_cluster():
        cl = make_fake_cluster(2)
        cl.add_tenant(0, engine=0)
        cl.add_tenant(1, engine=1)
        for k in range(6):
            cl.submit(_req(0, k=k))
        return cl

    with pytest.warns(DeprecationWarning, match="plan_once"):
        legacy = hot_cluster().rebalance(now=0.0)
    cl = hot_cluster()
    with warnings.catch_warnings():
        warnings.simplefilter("error")        # any warning would fail
        modern = operator_rebalance(cl, now=0.0)
    # same selection semantics, no deprecated path involved
    assert (modern.tenant, modern.src, modern.dst) == \
        (legacy.tenant, legacy.src, legacy.dst)


# ---------------------------------------------------------------------------
# bytes plane: CoreEngine migration rides the same plan
# ---------------------------------------------------------------------------


def _op(tenant, nbytes=1000):
    return CommOp(verb="psum", axes=("pod",), tenant_id=tenant,
                  size_bytes=nbytes)


def test_core_engine_export_import_moves_bucket_and_folds_ledger():
    src, dst = CoreEngine(enforcement="account"), \
        CoreEngine(enforcement="account")
    src.set_tenant_rate(1, 10000.0, burst=5000.0)
    for _ in range(3):
        op = _op(1)
        src.admit(op, now=0.0)
        src.route(op)
    level = src.buckets[1].tokens
    assert level == pytest.approx(2000.0)        # 5000 burst - 3x1000
    assert src.total_bytes(1) == 3000
    state = src.export_tenant(1, now=0.0)
    # the source forgot everything (but keeps the billed ground truth)
    assert src.total_bytes(1) == 0 and 1 not in src.buckets
    assert 1 not in src.admitted
    assert src.billed_ground_truth(1) == 3000
    # exported counters are the carried ledger (flattened + detail)
    assert state.plane == "bytes"
    assert state.carried["bytes"] == 3000
    assert sum(b for _, b in state.payload["ledger"].values()) == 3000
    assert state.payload["admitted"][1] == 3000  # all in-rate
    dst.import_tenant(1, state, now=0.0)
    # the bucket level travelled; the counters did NOT replay
    assert dst.buckets[1].tokens == pytest.approx(level)
    assert dst.total_bytes(1) == 0
    with pytest.raises(ValueError):
        dst.import_tenant(1, state)              # non-quiesced destination


def test_cluster_migration_carries_bytes_plane_conserved():
    """One plan moves both planes: serve-side ledger conservation AND
    bytes-plane continuity are asserted on the same migrate()."""
    cl = make_fake_cluster(2, core_plane=True)
    cl.add_tenant(0, engine=0)
    # zero-rate bucket: the level can only burn down, so the transferred
    # balance is deterministic (no refill between admit and migrate)
    cl.core_engines[0].set_tenant_rate(0, 0.0, burst=20000.0)
    for _ in range(5):
        op = _op(0, 2048)
        cl.core_engines[0].admit(op, now=0.0)
        cl.core_engines[0].route(op)
    cl.submit(_req(0))
    cl.step(now=0.1)
    total_before = cl.tenant_core_bytes(0)
    assert total_before == 5 * 2048
    level = cl.core_engines[0].buckets[0].tokens
    rec = cl.migrate(0, 1, now=0.2)
    assert rec is not None
    # bytes continuity across the move, and the bucket level travelled
    assert cl.tenant_core_bytes(0) == total_before
    assert cl.core_engines[1].buckets[0].tokens == pytest.approx(level)
    assert cl.core_engines[0].total_bytes(0) == 0
    # new traffic accrues on the destination, continuity holds
    op = _op(0, 1024)
    cl.core_engines[1].admit(op, now=0.3)
    cl.core_engines[1].route(op)
    assert cl.tenant_core_bytes(0) == total_before + 1024
    cl.assert_ledger_conservation(0)


def test_core_engines_must_pair_with_engines():
    with pytest.raises(ValueError):
        EngineCluster([FakeEngine()], core_engines=[CoreEngine(),
                                                    CoreEngine()])
