"""CoreEngine: routing table, ledger accounting, token buckets."""
import pytest
from _hyp import given, settings, st

from repro.core.engine import CoreEngine, TokenBucket, make_engine
from repro.core.nqe import CommOp


def _op(verb="psum", axes=("pod",), size=1 << 20, flags=0, tenant=0):
    return CommOp(verb=verb, axes=axes, size_bytes=size, flags=flags,
                  tenant_id=tenant)


def test_default_routes_to_xla():
    eng = CoreEngine()
    assert eng.route(_op()).name == "xla"


def test_rule_order_first_match_wins():
    eng = CoreEngine()
    eng.add_rule("a", lambda op: op.size_bytes > 100, "ring")
    eng.add_rule("b", lambda op: True, "hierarchical")
    assert eng.route(_op(size=1000)).name == "ring"
    assert eng.route(_op(size=10)).name == "hierarchical"


def test_unknown_nsm_rejected_eagerly():
    eng = CoreEngine()
    with pytest.raises(KeyError):
        eng.add_rule("bad", lambda op: True, "does-not-exist")


def test_ledger_accounting():
    eng = CoreEngine()
    for i in range(5):
        eng.route(_op(size=100, tenant=1))
    eng.route(_op(size=7, tenant=2))
    table = eng.ledger_table()
    assert (1, "psum", ("pod",), 5, 500) in table
    assert eng.total_bytes(tenant_id=1) == 500
    assert eng.total_bytes() == 507
    eng.reset_ledger()
    assert eng.total_bytes() == 0


def test_stock_policies_route_as_documented():
    eng = make_engine(None, "compressed")
    assert eng.route(_op(flags=1, axes=("pod",))).name == "compressed"
    assert eng.route(_op(flags=0, axes=("pod", "data"))).name == "hierarchical"
    assert eng.route(_op(flags=0, axes=("model",))).name == "xla"
    eng = make_engine(None, "ring")
    assert eng.route(_op(size=2 << 20)).name == "ring2"
    assert eng.route(_op(size=100)).name == "xla"


def test_route_log_packs_nqes():
    eng = CoreEngine()
    eng.route(_op())
    raw, choice = eng.route_log[0]
    assert len(raw) == 32
    assert CommOp.unpack(raw).verb == "psum"


# --- token bucket -----------------------------------------------------------


def test_token_bucket_caps_rate():
    b = TokenBucket(rate=100.0, capacity=100.0)
    now = 1000.0
    assert b.consume(100, now)
    assert not b.consume(1, now)          # empty
    assert b.consume(50, now + 0.5)       # refilled 50
    assert b.wait_time(100, now + 0.5) == pytest.approx(1.0)


@given(rate=st.floats(1, 1e6), cap=st.floats(1, 1e6),
       draws=st.lists(st.floats(0, 1e5), max_size=30))
@settings(max_examples=100, deadline=None)
def test_token_bucket_never_negative_never_over_capacity(rate, cap, draws):
    b = TokenBucket(rate, cap)
    now = 0.0
    for d in draws:
        now += 0.01
        b.consume(d, now)
        assert -1e-6 <= b.tokens <= cap + 1e-6
