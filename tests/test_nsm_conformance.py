"""NSM conformance: every stack must match the native (XLA) stack's numerics
for every verb it overrides — the paper's contract that stacks are swappable
behind one API, pinned as a parametrized suite.

The case matrix is discovered from the registry: for each registered NSM we
find the verbs its class (or any ancestor below ``Nsm``) overrides and run
them against ``XlaNsm`` across axis combinations and dtypes. Tolerances are
tiered: exact-ish for explicit-schedule stacks (reordered float adds); the
int8-on-the-wire compressed stack's bound is *derived per case* from the
measured error-feedback residual (``int8_roundtrip_residual`` — the same
quantity ``train_loop`` tracks under ``RunConfig.track_ef_residual``)
instead of a hand-tuned constant: the test mirrors the wire protocol
(inner sum over uncompressed axes, then one int8 round trip per
compressed-axis shard at the globally agreed scale) and sums the shards'
measured residuals, so the bound tightens automatically with the payload.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.nqe import CommOp
from repro.core.nsm import Nsm, available_nsms, get_nsm

# (relative) tolerance tiers per stack, scaled up under bf16. The
# compressed stack is NOT here: its bound is derived from the measured
# error-feedback residual per case (see _compressed_atol); only its
# uncompressed-axes cases (pure inner-stack passthrough) use the exact tier.
_TOL = {"ring": 1e-5, "ring2": 1e-5, "hierarchical": 1e-5,
        "compressed": 1e-5, "shm": 1e-6}
_BF16_FACTOR = {}
# safety on the summed measured residuals: covers bf16 carrier effects in
# the inner sum the host-side mirror computes in f32
_EF_SAFETY = 1.5

_VERBS_UNDER_TEST = ("psum", "all_gather", "reduce_scatter")

_PSUM_AXES = [("model",), ("data",), ("pod", "data")]
_ONE_AXES = [("model",), ("data",)]
_DTYPES = [jnp.float32, jnp.bfloat16]


def _overridden(name: str):
    cls = type(get_nsm(name))
    out = []
    for verb in _VERBS_UNDER_TEST:
        for klass in cls.mro():
            if klass in (Nsm, object):
                break
            if verb in klass.__dict__:
                out.append(verb)
                break
    return out


CASES = []
for _name in available_nsms():
    if _name == "xla":
        continue
    for _verb in _overridden(_name):
        axes_list = _PSUM_AXES if _verb == "psum" else _ONE_AXES
        for _axes in axes_list:
            for _dt in _DTYPES:
                CASES.append((_name, _verb, _axes, _dt))


def _tol(name: str, dtype) -> float:
    tol = _TOL[name]
    if dtype == jnp.bfloat16:
        tol = max(tol * _BF16_FACTOR.get(name, 1.0), 2e-2)
    return tol


def _compressed_atol(mesh, verb, axes, dtype, x, ref):
    """Error-feedback-derived absolute bound for one compressed-psum case
    (None when the case never touches the int8 wire).

    Mirrors ``CompressedNsm.psum`` host-side: the inner stack sums the
    uncompressed axes first, then each compressed-axis shard takes one
    int8 round trip at the globally agreed (pmax) scale. The wire error
    of the final sum is at most the sum of the shards' measured
    round-trip residuals — no hand-tuned constant anywhere.
    """
    from repro.core.compression import int8_roundtrip_residual
    from repro.core.nsm import get_nsm as _g

    comp = tuple(a for a in axes if a in _g("compressed").compress_axes)
    if verb != "psum" or not comp:
        return None                       # pure inner-stack passthrough
    if axes[:len(comp)] != comp:
        # the mirror below assumes compressed axes shard outermost (the
        # only layout the case matrix produces); stay conservative if a
        # future case reorders them
        comp = axes
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_all = int(np.prod([sizes[a] for a in axes]))
    n_comp = int(np.prod([sizes[a] for a in comp]))
    xf = np.asarray(jnp.asarray(x).astype(jnp.float32))
    # compressed cases always shard rows (P(axes, None)): the column-
    # sharded ("model",) spec never reaches here (comp would be empty)
    blocks = xf.reshape(n_all, -1, xf.shape[-1])
    # inner (uncompressed-axes) sum -> one partial per compressed shard
    partials = blocks.reshape((n_comp, n_all // n_comp) + blocks.shape[1:]) \
        .sum(axis=1)
    scale = jnp.asarray(max(np.abs(partials).max(), 1e-30) / 127.0)
    resid = sum(
        float(jnp.max(jnp.abs(int8_roundtrip_residual(
            jnp.asarray(p), scale)))) for p in partials)
    atol = _EF_SAFETY * resid
    if dtype == jnp.bfloat16:
        atol += float(np.abs(ref).max()) / 128.0   # bf16 carrier rounding
    return atol


@pytest.fixture(scope="module")
def mesh():
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh(2, 2, pod=2)


def _x(dtype):
    x = jax.random.normal(jax.random.PRNGKey(7), (16, 32), jnp.float32)
    return x.astype(dtype)


def _specs(verb, axes):
    """(in_spec, out_spec, check_vma, kwargs) for one verb invocation."""
    if verb == "psum":
        spec = P(None, "model") if axes == ("model",) else P(axes, None)
        return spec, spec, None, {}
    if verb == "reduce_scatter":
        return P(None, None), P(axes[0], None), None, {"axis": 0}
    if verb == "all_gather":
        return P(axes[0], None), P(None, None), False, {"axis": 0}
    raise AssertionError(verb)


def _run(mesh, nsm, verb, axes, x, *, op=None, **kw):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    in_spec, out_spec, check_vma, extra = _specs(verb, axes)
    extra.update(kw)

    def f(v):
        return getattr(nsm, verb)(v, axes, axis_sizes=sizes, op=op, **extra)

    return np.asarray(jax.jit(shard_map(
        f, mesh=mesh, in_specs=in_spec, out_specs=out_spec,
        check_vma=check_vma))(x), np.float32)


_REF_MEMO = {}


def _ref(mesh, verb, axes, dtype, x):
    key = (verb, axes, jnp.dtype(dtype).name)
    if key not in _REF_MEMO:
        _REF_MEMO[key] = _run(mesh, get_nsm("xla"), verb, axes, x)
    return _REF_MEMO[key]


@pytest.mark.parametrize(
    "name,verb,axes,dtype", CASES,
    ids=[f"{n}-{v}-{'+'.join(a)}-{jnp.dtype(d).name}"
         for n, v, a, d in CASES])
def test_nsm_matches_xla(mesh, name, verb, axes, dtype):
    x = _x(dtype)
    out = _run(mesh, get_nsm(name), verb, axes, x)
    ref = _ref(mesh, verb, axes, dtype, x)
    if name == "compressed":
        atol = _compressed_atol(mesh, verb, axes, dtype, x, ref)
        if atol is not None:
            np.testing.assert_allclose(out, ref, rtol=0.0, atol=atol)
            return
    tol = _tol(name, dtype)
    np.testing.assert_allclose(out, ref, rtol=tol,
                               atol=tol * float(np.abs(ref).max()))


def test_registry_covers_expected_stacks():
    """The suite above is only exhaustive if the registry is: pin the stock
    stacks so a new NSM must register (and thereby enter the matrix)."""
    have = set(available_nsms())
    assert {"xla", "ring", "ring2", "hierarchical", "compressed",
            "shm"} <= have


def test_compressed_integer_passthrough_is_exact(mesh):
    """Integer payloads must bypass the int8 wire entirely (exact sum)."""
    x = jnp.arange(16 * 32, dtype=jnp.int32).reshape(16, 32)
    out = _run(mesh, get_nsm("compressed"), "psum", ("pod", "data"), x)
    ref = _run(mesh, get_nsm("xla"), "psum", ("pod", "data"), x)
    np.testing.assert_array_equal(out, ref)


def test_shm_elision_contract(mesh):
    """ShmNsm's one divergence from XLA numerics is the documented one:
    op_data bit0 (engine-proven sharding compatibility) elides the op."""
    x = _x(jnp.float32)
    op = CommOp(verb="psum", axes=("model",), op_data=1)
    out = _run(mesh, get_nsm("shm"), "psum", ("model",), x, op=op)
    np.testing.assert_allclose(out, np.asarray(x))   # identity, no reduce
    # without the bit it must agree with the native stack
    op0 = CommOp(verb="psum", axes=("model",))
    out0 = _run(mesh, get_nsm("shm"), "psum", ("model",), x, op=op0)
    ref = _run(mesh, get_nsm("xla"), "psum", ("model",), x)
    np.testing.assert_allclose(out0, ref, rtol=1e-6, atol=1e-6)
