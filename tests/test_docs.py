"""The docs tree is executable: the scenario catalog's code blocks are
doctests and every relative link must resolve — tier-1 versions of what
CI's docs job enforces, so rot is caught before push."""
import doctest
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]


def test_replay_md_code_blocks_are_true():
    results = doctest.testfile(str(ROOT / "docs" / "replay.md"),
                               module_relative=False)
    assert results.attempted > 0          # the catalog really has examples
    assert results.failed == 0


def test_observability_md_code_blocks_are_true():
    results = doctest.testfile(str(ROOT / "docs" / "observability.md"),
                               module_relative=False)
    assert results.attempted > 0
    assert results.failed == 0


def test_docs_and_readme_links_resolve():
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_docs.py")],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
