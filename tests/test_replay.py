"""End-to-end replay scenarios: the paper's fairness claims measured on a
real ServeEngine (jitted prefill/decode, WFQ admission, controller-enforced
token buckets), not on the fluid model.

The smoke test stays in tier-1 so every push exercises the harness; the
full scenarios are `slow` (CI runs them in a dedicated job, locally via
`pytest -m slow`).
"""
import numpy as np
import pytest

from repro.serve.multiplex import Trace
from repro.serve.replay import (
    TOKENS_PER_REQUEST, TraceReplayer, adversarial_baseline,
    make_replay_cluster, make_replay_engine, replay_scenario, scenario_spec,
)


def _report(trace, *, capacity, push_mode="full", weights=None,
            unit="requests"):
    eng = make_replay_engine(capacity=capacity, push_mode=push_mode,
                             weights=weights)
    rep = TraceReplayer(eng, capacity=capacity, weights=weights)
    return rep.run(trace, unit=unit)


def test_replay_smoke_reports_from_real_ledgers():
    """Tier-1: the harness drives a real engine and measures per-tenant
    rates, admission latency and fairness from scheduler ledgers."""
    trace, cap = scenario_spec("steady", n_tenants=2, intervals=6)
    rep = _report(trace, capacity=cap)
    assert rep.decode_steps > 0
    assert set(rep.per_tenant) == {0, 1}
    for r in rep.per_tenant.values():
        assert r.achieved_rate > 0
        assert r.admitted_requests > 0
        assert r.completed_requests > 0
        assert r.served_tokens == pytest.approx(
            r.achieved_rate * rep.duration_s)
    # contention means both tenants were bucket-deferred at some point
    assert sum(r.deferred_polls for r in rep.per_tenant.values()) > 0
    assert rep.jain() > 0.95
    # work conservation under contention: the bottleneck is busy
    assert rep.total_rate() > 0.8 * cap


def test_suspend_resume_serves_bit_identical():
    """Tier-1 tentpole guard: suspend() drops the KV-cache and slot
    buffers (bytes freed > 0); resume() lazily re-materializes them on
    the next admission; and serving after the cycle is bit-identical to
    the never-parked behavior (same generated tokens, same ledger
    arithmetic) — parking is a real memory saving with no serving cost."""
    from repro.serve.scheduler import Request

    eng = make_replay_engine(capacity=1e6, batch_slots=2)

    def serve(req_id):
        eng.submit(Request(tenant_id=0, prompt=[1, 2], max_new_tokens=4,
                           req_id=req_id, arrival=0.0))
        for k in range(12):
            eng.step(now=0.1 * (k + 1))
        return eng.completed[-1]

    before = serve(0)                      # the never-parked reference
    resident = eng.resident_bytes()
    assert resident > 0
    freed = eng.suspend()
    assert freed == resident
    assert eng.resident_bytes() == 0 and eng.caches is None
    assert eng.slots == []                 # slot buffers dropped too
    with pytest.raises(RuntimeError):      # a parked engine never steps
        eng.step(now=9.9)
    eng.resume()
    assert eng.caches is None              # lazy: nothing resident yet...
    after = serve(1)
    assert eng.resident_bytes() == resident    # ...until a request lands
    # bit-identical serving: same tokens, same billing as never-parked
    assert after.generated == before.generated
    assert eng.scheduler.served_tokens[0] == sum(
        len(r.prompt) + len(r.generated) for r in eng.completed)


def test_single_token_request_billing_matches_bucket_price():
    """Regression: max_new_tokens=1 used to occupy a decode slot anyway,
    generating (and billing) a 2nd token past the bucket's price."""
    from repro.serve.scheduler import Request

    eng = make_replay_engine(capacity=100.0, batch_slots=2)
    eng.submit(Request(tenant_id=0, prompt=[1, 2], max_new_tokens=1,
                       arrival=0.0))
    for k in range(4):
        eng.step(now=0.1 * (k + 1))
    assert len(eng.completed) == 1
    req = eng.completed[0]
    assert len(req.generated) == 1                # exactly what was asked
    # ledger bills prompt + the one prefill token = the bucket's price
    assert eng.scheduler.served_tokens[0] == len(req.prompt) + 1


@pytest.mark.slow
def test_replay_convergence_jain_and_max_min():
    """Fig. 21 end-to-end: contended steady state converges to max-min fair
    within 10%, Jain >= 0.95, measured from engine ledgers."""
    trace, cap = scenario_spec("steady", n_tenants=4, intervals=18)
    rep = _report(trace, capacity=cap)
    assert rep.jain() >= 0.95
    assert rep.max_min_deviation() < 0.10


@pytest.mark.slow
def test_replay_misbehaver_isolation():
    """Fig. 22 end-to-end: a 10x-overloading tenant degrades in-budget
    tenants' served rate by < 5% vs their hog-free baseline."""
    n, intervals = 4, 16
    hog_trace, cap = scenario_spec("adversarial", n_tenants=n,
                                   intervals=intervals)
    base_trace = adversarial_baseline(hog_trace)
    baseline = _report(base_trace, capacity=cap)
    shared = _report(hog_trace, capacity=cap)
    for t in range(n - 1):                        # the in-budget tenants
        degr = 1.0 - (shared.per_tenant[t].achieved_rate
                      / baseline.per_tenant[t].achieved_rate)
        assert degr < 0.05, f"tenant {t} degraded {degr:.1%}"
    # and the hog is contained, not starved: it gets the leftover capacity
    hog = shared.per_tenant[n - 1]
    assert hog.achieved_rate < 0.75 * cap
    assert hog.achieved_rate > 0.25 * cap
    # the hog pays the queueing price, not its neighbours
    in_budget_wait = max(shared.per_tenant[t].mean_admit_wait_s
                         for t in range(n - 1))
    assert hog.mean_admit_wait_s > 4 * max(in_budget_wait, 1e-3)
    # tail latency (histogram estimates, see repro.obs.hist): the
    # victims' p99 admit wait stays bounded — under a second even with
    # the hog offering 10x — their median stays at the no-contention
    # floor, and the hog's own p99 sits an order of magnitude above its
    # victims': the tail price lands on the tenant that caused it
    victim_p99 = max(shared.per_tenant[t].p99_admit_wait_s
                     for t in range(n - 1))
    assert 0.0 < victim_p99 < 1.0
    assert max(shared.per_tenant[t].p50_admit_wait_s
               for t in range(n - 1)) <= 0.01
    assert hog.p99_admit_wait_s > 10 * victim_p99


@pytest.mark.slow
def test_replay_work_conserving_backfill():
    """A tenant going idle mid-trace frees capacity that the backlogged
    tenant absorbs (measured on the engine, interval by interval)."""
    intervals = 18
    third = intervals // 3
    loads = np.zeros((2, intervals))
    loads[0, :] = 4.0
    loads[0, third:2 * third] = 0.0               # tenant 0 idle mid-run
    loads[1, :] = 12.0                            # always backlogged
    cap = 8.0 * TOKENS_PER_REQUEST                # 8 req/s of bottleneck
    eng = make_replay_engine(capacity=cap, control_every=4)
    rep = TraceReplayer(eng, capacity=cap)
    reports = [rep.run(Trace(loads=loads[:, lo:hi]))
               for lo, hi in ((0, third), (third, 2 * third),
                              (2 * third, intervals))]
    on1, off, on2 = ({t: r.per_tenant[t].achieved_rate for t in (0, 1)}
                     for r in reports)
    # ledger windowing regression: tenant 0 admits nothing while idle, so
    # its *windowed* admission stats must be 0, not phase-1 leakage
    assert reports[1].per_tenant[0].admitted_requests == 0
    assert reports[1].per_tenant[0].mean_admit_wait_s == 0.0
    # idle phase: the survivor absorbs (nearly) the whole bottleneck
    assert off[1] > 0.85 * cap
    assert off[1] > 1.25 * on1[1]
    # return phase: tenant 0 is served again at (near) its demand
    assert on2[0] > 0.8 * (4.0 * TOKENS_PER_REQUEST)


@pytest.mark.slow
def test_replay_migration_scenario_bounds_hold_across_move():
    """The multi-engine scenario: 3 engines, one controller, the 10x hog
    heats its engine and a mid-window rebalance migrates it live. Jain and
    in-budget evenness must hold across the migration window."""
    rep = replay_scenario("migration", n_tenants=4, intervals=16, engines=3)
    assert rep.engines == 3
    assert rep.migrations >= 1
    assert rep.placement is not None and rep.placement[3] != 0
    assert rep.jain() >= 0.95
    # in-budget tenants (equal demand) stay even despite hog + migration
    rates = [rep.per_tenant[t].achieved_rate for t in range(3)]
    assert max(rates) / min(rates) < 1.05
    # the migration scenario refuses to run without a cluster
    with pytest.raises(ValueError):
        replay_scenario("migration", n_tenants=4, intervals=4, engines=1)


@pytest.mark.slow
def test_replay_migrate_hog_mid_burst_conserves_ledger():
    """Satellite edge case: migrating the hog itself mid-burst — a huge
    unserved queue plus live in-flight slots — must conserve its
    served-token ledger exactly (no loss, no double-billing)."""
    trace, cap = scenario_spec("migration", n_tenants=4, intervals=14)
    cl = make_replay_cluster(capacity=cap, engines=3)
    recs = []

    def ev(cluster, now):
        recs.append(cluster.migrate(3, cluster.coolest_engine(), now=now))

    rep = TraceReplayer(cl, capacity=cap).run(trace, events=[(7, ev)])
    rec = recs[0]
    assert rec is not None
    assert rec.inflight_at_move > 0           # genuinely mid-burst
    assert rec.queued_moved > 0               # the backlog travelled
    assert rep.migrations == 1 and not cl.draining
    cl.assert_ledger_conservation(3)
    assert cl.tenant_served_tokens(3) == cl.tenant_billed_ground_truth(3)
    # neighbours stayed isolated across the move
    assert rep.jain() >= 0.95


@pytest.mark.slow
def test_replay_consolidation_scenario_parks_and_recovers():
    """The closed placement loop on real engines: busy -> idle -> busy.
    The autopilot packs the idle fleet, parks >= 1 engine (cores AND
    memory saved — parked engines suspend their KV-caches), and wakes
    the cluster when load returns — fairness intact and serving
    bit-identical after resume."""
    trace, cap = scenario_spec("consolidation", n_tenants=4, intervals=12)
    cl = make_replay_cluster(capacity=cap, engines=3,
                             autopilot="consolidate")
    rep = TraceReplayer(cl, capacity=cap).run(trace)
    assert rep.engines == 3
    assert rep.max_parked >= 1                    # idle window parked
    assert rep.cores_saved > 0
    # the memory-saved claim: bytes were freed while parked
    assert rep.max_parked_bytes > 0
    assert rep.mem_saved_bytes > 0
    assert rep.peak_resident_cache_bytes > rep.max_parked_bytes
    assert rep.autopilot_moves >= 1               # the loop found the pack
    assert rep.jain() >= 0.95
    # load returned: every tenant is placed and served
    assert all(r.achieved_rate > 0 for r in rep.per_tenant.values())
    # bit-identical serving across suspend/resume: every request in this
    # scenario has the same prompt, so a resumed engine whose re-init
    # cache changed anything would show up as a divergent generation
    seqs = {tuple(r.generated) for r in cl.completed}
    assert len(seqs) == 1
    with pytest.raises(ValueError):
        replay_scenario("consolidation", n_tenants=4, intervals=4,
                        engines=1)


@pytest.mark.slow
def test_replay_hotspot_autopilot_migrates_hog_both_planes():
    """The developing hog is auto-migrated by the closed loop — no
    operator event anywhere — with ledger conservation on the serve AND
    bytes planes and zero ping-pong under hysteresis."""
    from repro.core.nqe import CommOp
    from repro.serve.replay import make_replay_cluster

    n, intervals = 4, 14
    trace, cap = scenario_spec("hotspot", n_tenants=n, intervals=intervals)
    cl = make_replay_cluster(capacity=cap, engines=3,
                             autopilot="spread_hot", core_plane=True)
    pumped = {}

    def pump(cluster, now):
        for t, k in sorted(cluster.placement.items()):
            op = CommOp(verb="psum", axes=("pod",), tenant_id=t,
                        size_bytes=2048)
            cluster.core_engines[k].admit(op, now)
            cluster.core_engines[k].route(op)
            pumped[t] = pumped.get(t, 0) + 2048

    rep = TraceReplayer(cl, capacity=cap).run(
        trace, events=[(i, pump) for i in range(intervals)])
    hog = n - 1
    moved = [mv.tenant for _, mv in cl.autopilot.move_log]
    assert moved.count(hog) == 1                  # auto-migrated, once
    assert len(moved) == len(set(moved))          # nobody moved twice
    cl.autopilot.assert_no_ping_pong()
    assert rep.autopilot_moves == len(moved)
    for t in range(n):
        cl.assert_ledger_conservation(t)          # serve plane
        assert cl.tenant_core_bytes(t) == pumped[t]   # bytes plane
    assert rep.jain() >= 0.95


@pytest.mark.slow
def test_replay_stack_swap_scenario_swaps_both_planes_live():
    """The paper's hot-swap headline on real jitted engines: mid-burst,
    one serve engine's module is swapped for the alternate scheduler
    variant (reusing the retired stack's weights and compiled
    prefill/decode) and one CoreEngine flips native -> compressed
    transport — under traffic, with zero dropped or double-billed
    tokens on either plane and fairness intact."""
    from repro.serve.replay import stack_swap_events

    n, intervals = 4, 12
    trace, cap = scenario_spec("stack_swap", n_tenants=n,
                               intervals=intervals)
    cl = make_replay_cluster(capacity=cap, engines=3, core_plane=True)
    rep = TraceReplayer(cl, capacity=cap).run(
        trace, events=stack_swap_events(intervals))
    assert rep.swaps == 2
    assert {r.plane for r in cl.swap_log} == {"serve", "bytes"}
    serve_rec = next(r for r in cl.swap_log if r.plane == "serve")
    bytes_rec = next(r for r in cl.swap_log if r.plane == "bytes")
    # the serve swap flipped the scheduler policy on the swapped slot...
    assert cl.engines[serve_rec.engine].scheduler.policy == "rr"
    # ...and the bytes swap flipped the transport beneath the same fleet
    assert cl.core_engines[bytes_rec.engine].default_nsm == "compressed"
    assert serve_rec.old_stack != serve_rec.new_stack
    assert bytes_rec.old_stack != bytes_rec.new_stack
    # conservation, exactly, on both planes, for every tenant: the swap
    # dropped nothing and double-billed nothing
    for t in range(n):
        cl.assert_ledger_conservation(t)
        assert cl.tenant_served_tokens(t) == \
            cl.tenant_billed_ground_truth(t)
        assert cl.tenant_core_bytes(t) == intervals * 4096
    assert rep.jain() >= 0.95
    counters = cl.counters()
    assert counters['nk_swaps_total{plane="serve"}'] == 1.0
    assert counters['nk_swaps_total{plane="bytes"}'] == 1.0
    # replay_scenario wires the same thing end to end
    rep2 = replay_scenario("stack_swap", n_tenants=n, intervals=intervals)
    assert rep2.swaps == 2


@pytest.mark.slow
def test_replay_delta_push_is_quiet_on_stable_trace():
    """Delta-based push: on a steady trace the controller issues a small
    fraction of full-push set_rate calls — O(changed), not O(tenants)."""
    trace, cap = scenario_spec("steady", n_tenants=4, intervals=14)
    full = _report(trace, capacity=cap, push_mode="full")
    delta = _report(trace, capacity=cap, push_mode="delta")
    assert full.set_rate_calls > 0
    assert delta.set_rate_calls <= 0.25 * full.set_rate_calls
    # and enforcement quality did not regress
    assert delta.jain() >= 0.95
    assert delta.max_min_deviation() < 0.12
    # the skipped pushes are accounted, proving the gate actually ran
    assert delta.push_skipped > delta.set_rate_calls


@pytest.mark.slow
def test_replay_vectorized_backend_matches_object_end_to_end():
    """The array control plane is a drop-in: the same steady scenario run
    with ``backend="vectorized"`` (flat-array telemetry banks, jitted
    water-fill, BucketStore admission buckets) meets the same fairness
    claims AND lands within a few percent of the object backend's
    per-tenant served rates — the e2e parity gate for the fused tick."""
    obj = replay_scenario("steady", n_tenants=4, intervals=16,
                          backend="object")
    vec = replay_scenario("steady", n_tenants=4, intervals=16,
                          backend="vectorized")
    assert vec.jain() >= 0.95
    assert vec.max_min_deviation() < 0.10
    for t in range(4):
        a, b = obj.per_tenant[t].achieved_rate, vec.per_tenant[t].achieved_rate
        assert b == pytest.approx(a, rel=0.02), f"tenant {t}: {a} vs {b}"
