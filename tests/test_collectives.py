"""Every NSM must implement identical collective semantics (the paper's
contract: stacks are swappable behind the same API)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import make_engine, nk_psum, use_engine, get_nsm
from repro.core.overlap import all_gather_matmul, matmul_reduce_scatter
from repro.launch.mesh import make_host_mesh


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh(2, 2, pod=2)


X = jax.random.normal(jax.random.PRNGKey(0), (16, 32), jnp.float32)


def _ref_psum(mesh, axes, spec):
    f = lambda v: jax.lax.psum(v, axes if isinstance(axes, str) else tuple(axes))
    return jax.jit(shard_map(f, mesh=mesh, in_specs=spec, out_specs=spec))(X)


@pytest.mark.parametrize("policy,axes,tol", [
    ("xla", "model", 1e-6),
    ("ring", ("pod", "data"), 1e-5),
    ("hierarchical", ("pod", "data"), 1e-5),
    ("compressed", ("pod", "data"), 2e-2),
])
def test_policy_psum_matches_native(mesh, policy, axes, tol):
    spec = P(None, "model") if axes == "model" else P(("pod", "data"), None)
    eng = make_engine(mesh, policy)
    if policy == "ring":   # force even 8MB+ threshold off: add explicit rule
        eng.clear_rules()
        eng.add_rule("all-ring", lambda op: op.verb == "psum", "ring2")

    def f(v):
        with use_engine(eng):
            return nk_psum(v, axes, gradient=True)
    out = jax.jit(shard_map(f, mesh=mesh, in_specs=spec, out_specs=spec))(X)
    ref = _ref_psum(mesh, axes, spec)
    np.testing.assert_allclose(out, ref, rtol=tol,
                               atol=tol * float(np.abs(ref).max()))
    assert eng.total_bytes() > 0   # ledger recorded the intent


@pytest.mark.parametrize("name", ["ring", "ring2"])
def test_ring_psum(mesh, name):
    nsm = get_nsm(name)
    f = lambda v: nsm.psum(v, ("model",), axis_sizes={"model": 2})
    out = jax.jit(shard_map(f, mesh=mesh, in_specs=P(None, "model"),
                            out_specs=P(None, "model")))(X)
    ref = _ref_psum(mesh, "model", P(None, "model"))
    np.testing.assert_allclose(out, ref, rtol=1e-6, atol=1e-6)


def test_ring_reduce_scatter(mesh):
    nsm = get_nsm("ring")
    f = lambda v: nsm.reduce_scatter(v, ("model",), axis_sizes={"model": 2},
                                     axis=0)
    out = jax.jit(shard_map(f, mesh=mesh, in_specs=P(None, None),
                            out_specs=P("model", None)))(X)
    ref = jax.jit(shard_map(
        lambda v: jax.lax.psum_scatter(v, "model", scatter_dimension=0,
                                       tiled=True),
        mesh=mesh, in_specs=P(None, None), out_specs=P("model", None)))(X)
    np.testing.assert_allclose(out, ref, rtol=1e-6, atol=1e-6)


def test_ring_all_gather(mesh):
    nsm = get_nsm("ring")
    f = lambda v: nsm.all_gather(v, ("model",), axis_sizes={"model": 2}, axis=0)
    out = jax.jit(shard_map(f, mesh=mesh, in_specs=P("model", None),
                            out_specs=P(None, None), check_vma=False))(X)
    np.testing.assert_allclose(out, X, rtol=1e-6, atol=1e-6)


def test_overlapped_all_gather_matmul(mesh):
    K, N, M = 32, 24, 16
    xa = jax.random.normal(jax.random.PRNGKey(2), (M, K), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(3), (K, N), jnp.float32)
    f = lambda xl, wl: all_gather_matmul(xl, wl, "model", 2)
    out = jax.jit(shard_map(f, mesh=mesh,
                            in_specs=(P(None, None), P("model", None)),
                            out_specs=P(None, None), check_vma=False))(xa, w)
    np.testing.assert_allclose(out, xa @ w, rtol=1e-4, atol=1e-4)


def test_overlapped_matmul_reduce_scatter(mesh):
    K, N, M = 32, 24, 16
    xa = jax.random.normal(jax.random.PRNGKey(2), (M, K), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(3), (K, N), jnp.float32)
    f = lambda xl, wl: matmul_reduce_scatter(xl, wl, "model", 2)
    out = jax.jit(shard_map(f, mesh=mesh,
                            in_specs=(P(None, "model"), P("model", None)),
                            out_specs=P("model", None)))(xa, w)
    np.testing.assert_allclose(out, xa @ w, rtol=1e-4, atol=1e-4)


def test_shm_nsm_elision(mesh):
    """ShmNsm skips the wire when the engine proves compatibility."""
    from repro.core.nqe import CommOp
    nsm = get_nsm("shm")
    op = CommOp(verb="psum", axes=("model",), op_data=1)   # bit0: pre-reduced

    def f(v):
        return nsm.psum(v, ("model",), axis_sizes={"model": 2}, op=op)
    out = jax.jit(shard_map(f, mesh=mesh, in_specs=P(None, None),
                            out_specs=P(None, None), check_vma=False))(X)
    np.testing.assert_allclose(out, X)   # identity move, no reduction
