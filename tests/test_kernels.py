"""Per-kernel validation vs the pure-jnp oracles (interpret=True on CPU),
with shape/dtype sweeps and hypothesis property checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels import ops, ref

KS = jax.random.split(jax.random.PRNGKey(0), 8)


@pytest.mark.parametrize("shape,causal,window,dtype", [
    ((2, 4, 256, 64), True, 0, jnp.float32),
    ((1, 2, 200, 128), True, 64, jnp.float32),
    ((2, 2, 128, 64), False, 0, jnp.float32),
    ((1, 3, 160, 64), True, 32, jnp.bfloat16),
])
def test_flash_attention_vs_ref(shape, causal, window, dtype):
    b, h, s, d = shape
    q = jax.random.normal(KS[0], shape, dtype)
    k = jax.random.normal(KS[1], shape, dtype)
    v = jax.random.normal(KS[2], shape, dtype)
    o1 = ops.mha_forward(q, k, v, causal=causal, window=window,
                         impl="pallas", q_block=64, kv_block=64)
    o2 = ops.mha_forward(q, k, v, causal=causal, window=window, impl="ref")
    tol = 2e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("t,kv_block", [(300, 128), (512, 512), (64, 32)])
def test_decode_attention_vs_ref(t, kv_block):
    b, h, d = 3, 8, 64
    q = jax.random.normal(KS[3], (b, h, d), jnp.float32)
    k = jax.random.normal(KS[4], (b, t, h, d), jnp.float32)
    v = jax.random.normal(KS[5], (b, t, h, d), jnp.float32)
    pos = jnp.array([0, t // 2, t - 1])
    o1, m1, l1 = ops.decode_step_attention(q, k, v, pos, impl="pallas",
                                           kv_block=kv_block)
    o2, m2, l2 = ref.decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(o1, o2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(m1, m2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(l1, l2, rtol=1e-4, atol=1e-4)


def test_decode_lse_combine_across_shards():
    """Sharded-cache partials combine to the unsharded result (the
    context-parallel decode contract)."""
    b, h, t, d = 2, 4, 256, 32
    q = jax.random.normal(KS[0], (b, h, d), jnp.float32)
    k = jax.random.normal(KS[1], (b, t, h, d), jnp.float32)
    v = jax.random.normal(KS[2], (b, t, h, d), jnp.float32)
    pos = jnp.array([200, 255])
    o_full, _, _ = ref.decode_attention_ref(q, k, v, pos)
    # two shards of the cache, each with local positions
    half = t // 2
    o0, m0, l0 = ref.decode_attention_ref(q, k[:, :half], v[:, :half], pos)
    o1, m1, l1 = ref.decode_attention_ref(
        q, k[:, half:], v[:, half:], pos - half)
    m = jnp.maximum(m0, m1)
    w0 = jnp.exp(m0 - m) * l0
    w1 = jnp.exp(m1 - m) * l1
    o = (o0 * w0[..., None] + o1 * w1[..., None]) / (w0 + w1)[..., None]
    np.testing.assert_allclose(o, o_full, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("H,head_block", [(16, 8), (8, 8), (32, 16)])
def test_ssd_chunk_vs_ref(H, head_block):
    nb, nc, Q, P, N = 2, 3, 64, 32, 64
    xdt = jax.random.normal(KS[6], (nb, nc, Q, H, P), jnp.float32) * 0.1
    dA = -jnp.abs(jax.random.normal(KS[7], (nb, nc, Q, H), jnp.float32)) * 0.1
    B = jax.random.normal(KS[0], (nb, nc, Q, N), jnp.float32) * 0.3
    C = jax.random.normal(KS[1], (nb, nc, Q, N), jnp.float32) * 0.3
    y1, st1, dec1 = ops.ssd_intra_chunk(xdt, dA, B, C, impl="pallas",
                                        head_block=head_block)
    y2, st2, dec2 = ops.ssd_intra_chunk(xdt, dA, B, C, impl="ref")
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st1, st2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(dec1, dec2, rtol=1e-5, atol=1e-5)


@given(r=st.integers(1, 64), cb=st.integers(1, 8),
       scale=st.floats(0.01, 100.0))
@settings(max_examples=25, deadline=None)
def test_quantization_error_bound(r, cb, scale):
    """Property: blockwise int8 error <= scale/2 elementwise (no clipping
    can occur since scale = absmax/127)."""
    c = cb * 128
    x = jax.random.normal(jax.random.PRNGKey(r), (r, c), jnp.float32) * scale
    q8, s = ops.quantize(x, block=128, impl="pallas")
    xr = ops.dequantize(q8, s, block=128)
    err = np.abs(np.asarray(xr) - np.asarray(x))
    bound = np.repeat(np.asarray(s), 128, axis=1) * 0.5 + 1e-6
    assert (err <= bound).all()


def test_quantize_pallas_matches_ref():
    x = jax.random.normal(KS[2], (100, 512), jnp.float32) * 3
    q8, s = ops.quantize(x, block=128, impl="pallas")
    q8r, sr = ops.quantize(x, block=128, impl="ref")
    np.testing.assert_array_equal(np.asarray(q8), np.asarray(q8r))
    np.testing.assert_allclose(s, sr, rtol=1e-6)
