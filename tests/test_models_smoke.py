"""Per-arch smoke tests: REDUCED family-preserving configs, one forward +
one train step on CPU, asserting shapes and no NaNs; plus prefill/decode
parity against the train-mode forward (teacher forcing)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, RunConfig, get_smoke_config
from repro.distribution.sharding import ShardingCtx
from repro.models import (
    build_params, forward_decode, forward_prefill, forward_train,
)
from repro.train.train_loop import loss_fn

B, S = 2, 64


def _cfg(name):
    cfg = get_smoke_config(name)
    if cfg.moe is not None:   # capacity drops are path-dependent: disable
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0))
    return cfg


def _batch(cfg):
    batch = {"tokens": jnp.arange(B * S).reshape(B, S) % cfg.vocab_size,
             "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.encoder_layers:
        batch["frames"] = jnp.ones(
            (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16) * 0.1
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_and_train_step(name, mesh1, rcfg_small):
    cfg = _cfg(name)
    shd = ShardingCtx(mesh1)
    params = build_params(cfg, mesh1, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = jax.jit(
        lambda p, b: forward_train(p, b, cfg, shd, rcfg_small))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    # one gradient step must produce finite grads for every leaf
    g = jax.jit(jax.grad(
        lambda p: loss_fn(p, batch, cfg, shd, rcfg_small)[0]))(params)
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_prefill_decode_parity(name, mesh1, rcfg_small):
    cfg = _cfg(name)
    shd = ShardingCtx(mesh1)
    params = build_params(cfg, mesh1, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, _ = jax.jit(
        lambda p, b: forward_train(p, b, cfg, shd, rcfg_small))(params, batch)
    last, caches = jax.jit(
        lambda p, t: forward_prefill(p, t, cfg, shd, rcfg_small,
                                     max_seq=S + 8,
                                     frames=batch.get("frames")))(
        params, batch["tokens"])
    np.testing.assert_allclose(np.asarray(last, np.float32),
                               np.asarray(logits[:, -1], np.float32),
                               rtol=2e-2, atol=2e-2)
    nxt = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
    dec, caches = jax.jit(
        lambda p, c, t, pos: forward_decode(p, c, t, pos, cfg, shd,
                                            rcfg_small))(
        params, caches, nxt, jnp.full((B,), S, jnp.int32))
    ext = dict(batch, tokens=jnp.concatenate([batch["tokens"], nxt], 1))
    ref, _ = jax.jit(
        lambda p, b: forward_train(p, b, cfg, shd, rcfg_small))(params, ext)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(ref[:, -1], np.float32),
                               rtol=1e-1, atol=1e-1)


def test_param_counts_match_analytic():
    """Analytic num_params (used by the roofline) vs materialized params."""
    for name in ("llama3.2-3b", "internlm2-1.8b", "mamba2-370m"):
        cfg = get_smoke_config(name)
        from repro.launch.mesh import make_single_device_mesh
        mesh = make_single_device_mesh()
        params = build_params(cfg, mesh, jax.random.PRNGKey(0))
        n = sum(x.size for x in jax.tree.leaves(params))
        analytic = cfg.num_params()
        # padding of heads makes materialized >= analytic; within 25%
        assert analytic <= n * 1.05
        assert n <= analytic * 1.3, (name, n, analytic)
