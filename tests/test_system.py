"""End-to-end system behaviour: training convergence, fault tolerance,
elastic re-mesh, NetKernel pod-sync stacks, serving fairness/multiplexing."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, ShapeConfig, get_smoke_config
from repro.core import make_engine
from repro.data import for_model
from repro.launch.mesh import make_host_mesh
from repro.serve import (
    Request, ServeEngine, TenantScheduler, bursty_trace, chip_accounting,
)
from repro.train import FailurePlan, Runner

CFG = get_smoke_config("llama3.2-3b")
SHAPE = ShapeConfig("tiny", 32, 8, "train")


def _rcfg(**kw):
    base = dict(attn_q_block=16, attn_kv_block=16, checkpoint_every=5,
                total_steps=40, warmup_steps=5, learning_rate=1e-2)
    base.update(kw)
    return RunConfig(**base)


@pytest.fixture(scope="module")
def pod_mesh():
    return make_host_mesh(2, 2, pod=2)


def test_loss_decreases(pod_mesh):
    with tempfile.TemporaryDirectory() as d:
        r = Runner(CFG, _rcfg(), pod_mesh, for_model(CFG, SHAPE), d)
        r.init_state(jax.random.PRNGKey(1))
        r.run(10)
        losses = [m["ce_loss"] for m in r.metrics_log]
        assert losses[-1] < losses[0]


def test_failure_recovery_bit_exact(pod_mesh):
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        r1 = Runner(CFG, _rcfg(), pod_mesh, for_model(CFG, SHAPE), d1)
        r1.init_state(jax.random.PRNGKey(1))
        r1.run(12)
        r2 = Runner(CFG, _rcfg(), pod_mesh, for_model(CFG, SHAPE), d2,
                    failure_plan=FailurePlan(fail_at=[8]))
        r2.init_state(jax.random.PRNGKey(1))
        out = r2.run(12)
        assert out["recoveries"] == 1
        for a, b in zip(jax.tree.leaves(r1.state["params"]),
                        jax.tree.leaves(r2.state["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_remesh(pod_mesh):
    with tempfile.TemporaryDirectory() as d:
        r = Runner(CFG, _rcfg(), pod_mesh, for_model(CFG, SHAPE), d)
        r.init_state(jax.random.PRNGKey(1))
        r.run(6)
        r.ckpt.save(r.step, r.state, blocking=True)
        r.remesh(make_host_mesh(4, 2))          # 2x2x2 -> 4x2 topology
        out = r.run(3)
        assert out["final_step"] == 9


def test_straggler_watchdog(pod_mesh):
    with tempfile.TemporaryDirectory() as d:
        # 2s >> 3x any plausible CPU step time: a 0.5s delay was flaky on a
        # loaded machine, where ordinary steps approach 0.25s and the
        # watchdog's 3x-median bar catches up with the injection
        delays = lambda step: 2.0 if step == 7 else 0.0
        r = Runner(CFG, _rcfg(straggler_factor=3.0), pod_mesh,
                   for_model(CFG, SHAPE), d, delay_injector=delays)
        r.init_state(jax.random.PRNGKey(1))
        out = r.run(10)
        assert 7 in out["stragglers"]


def test_explicit_pod_sync_compressed_nsm(pod_mesh):
    """Same model code, cross-pod transport swapped to int8 (use case 3)."""
    with tempfile.TemporaryDirectory() as d:
        eng = make_engine(pod_mesh, "compressed")
        rcfg = _rcfg(explicit_pod_sync=True, nsm_policy="compressed")
        r = Runner(CFG, rcfg, pod_mesh, for_model(CFG, SHAPE), d, engine=eng)
        r.init_state(jax.random.PRNGKey(1))
        r.run(4)
        losses = [m["ce_loss"] for m in r.metrics_log]
        assert losses[-1] < losses[0] + 0.05
        # ledger shows gradient-flagged pod-axis psums were routed
        table = eng.ledger_table()
        assert any(axes == ("pod",) and verb == "psum"
                   for (_, verb, axes, _, _) in table)


# --- serving ---------------------------------------------------------------


def test_serve_engine_drains(mesh1, rcfg_small):
    eng = ServeEngine(CFG, rcfg_small, mesh1, batch_slots=4, max_seq=64)
    for i in range(6):
        eng.submit(Request(tenant_id=i % 2, prompt=[1 + i, 2, 3],
                           max_new_tokens=8, req_id=i))
    out = eng.run_until_drained()
    assert out["completed"] == 6
    for r in eng.completed:
        assert len(r.generated) == 8


def test_wfq_fairness_under_contention(mesh1, rcfg_small):
    """Selfish tenant (16 requests) vs normal (4): equal shares while both
    are backlogged (paper Fig. 9 at the request level)."""
    sched = TenantScheduler(policy="wfq")
    sched.add_tenant(0)
    sched.add_tenant(1)
    eng = ServeEngine(CFG, rcfg_small, mesh1, batch_slots=2, max_seq=64,
                      scheduler=sched)
    for i in range(4):
        eng.submit(Request(tenant_id=0, prompt=[1, 2], max_new_tokens=12))
    for i in range(16):
        eng.submit(Request(tenant_id=1, prompt=[3, 4], max_new_tokens=12))
    # run while both tenants still have work: shares should stay ~equal
    for _ in range(25):
        eng.step()
        if sched.pending(0) == 0:
            break
    s = sched.shares()
    assert abs(s[0] - s[1]) < 0.34, s


def test_token_bucket_isolation(mesh1, rcfg_small):
    """Rate-capped tenant cannot exceed its budget; others take the rest."""
    sched = TenantScheduler(policy="wfq")
    sched.add_tenant(0, rate_tokens_per_s=1.0, burst=14.0)   # hard-capped
    sched.add_tenant(1)
    eng = ServeEngine(CFG, rcfg_small, mesh1, batch_slots=2, max_seq=64,
                      scheduler=sched)
    for i in range(8):
        eng.submit(Request(tenant_id=0, prompt=[1], max_new_tokens=12))
        eng.submit(Request(tenant_id=1, prompt=[2], max_new_tokens=12))
    for _ in range(120):
        eng.step(now=0.0)   # frozen clock: bucket never refills
        if (not any(s.active for s in eng.slots)
                and sched.pending(1) == 0):
            break
    t0 = [r for r in eng.completed if r.tenant_id == 0]
    t1 = [r for r in eng.completed if r.tenant_id == 1]
    # tenant 0 admitted exactly one request (burst 14 >= 12 tokens, once)
    assert len(t0) == 1
    assert len(t1) == 8


def test_multiplexing_saves_40_percent():
    t = bursty_trace(16, seed=0)
    acc = chip_accounting(t, cap_per_chip=50.0)
    assert acc["savings_frac"] >= 0.40, acc
