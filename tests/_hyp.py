"""hypothesis shim: real property testing when installed, fixed examples else.

Test modules import ``given``/``settings``/``st`` from here instead of from
``hypothesis`` so the tier-1 suite collects and runs on a clean interpreter.
When hypothesis is missing, the fallback draws a deterministic batch of
examples per test from a seeded RNG — far weaker than real shrinking search,
but the same properties get exercised on the same code paths.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import random
    from types import SimpleNamespace

    _FALLBACK_EXAMPLES = 20
    _FALLBACK_SEED = 0xC0FFEE

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

        def map(self, fn):
            return _Strategy(lambda rng: fn(self.draw(rng)))

    def _sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def _integers(min_value, max_value):
        lo, hi = int(min_value), int(max_value)
        return _Strategy(lambda rng: rng.randint(lo, hi))

    def _floats(min_value, max_value, **_kw):
        lo, hi = float(min_value), float(max_value)
        return _Strategy(lambda rng: rng.uniform(lo, hi))

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def _lists(elements, min_size=0, max_size=10, unique=False):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            out, seen, attempts = [], set(), 0
            while len(out) < n and attempts < 20 * (n + 1):
                attempts += 1
                v = elements.draw(rng)
                if unique:
                    if v in seen:
                        continue
                    seen.add(v)
                out.append(v)
            return out
        return _Strategy(draw)

    def _tuples(*strategies):
        return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))

    st = SimpleNamespace(sampled_from=_sampled_from, integers=_integers,
                         floats=_floats, booleans=_booleans, lists=_lists,
                         tuples=_tuples)

    def settings(**_kw):
        def deco(fn):
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            def runner():
                rng = random.Random(_FALLBACK_SEED)
                for _ in range(_FALLBACK_EXAMPLES):
                    fn(**{k: s.draw(rng) for k, s in strategies.items()})
            # plain zero-arg function: pytest must not mistake the wrapped
            # test's strategy params for fixtures (no functools.wraps — it
            # sets __wrapped__ and inspect would recover the old signature)
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner
        return deco
