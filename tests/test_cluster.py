"""Multi-engine fabric: one controller, N ServeEngines, live migration.

Tier-1 keeps the pure pieces (bucket/scheduler transfer, telemetry counter
resets, delta-push invalidation, placement) plus ONE engine-stepping
integration test of the drain-and-transfer path; the full adversarial
migration scenarios are `slow` (see tests/test_replay.py).
"""
import math

import numpy as np
import pytest

from repro.control.controller import RateController
from repro.control.telemetry import SchedulerTelemetry
from repro.core.engine import TokenBucket
from repro.serve.multiplex import jain_index
from repro.serve.replay import make_replay_cluster
from repro.serve.scheduler import Request, TenantScheduler


# ---------------------------------------------------------------------------
# jain_index degenerate intervals (satellite regression)
# ---------------------------------------------------------------------------


def test_jain_index_defined_as_one_on_degenerate_idle_interval():
    """Regression: an all-zero (or NaN-from-0/0) rate vector is a
    degenerate idle interval — defined as perfectly fair, never NaN."""
    assert jain_index([]) == 1.0
    assert jain_index([0.0, 0.0, 0.0]) == 1.0
    assert jain_index(np.zeros(4)) == 1.0
    nan = float("nan")
    assert jain_index([nan, nan, nan]) == 1.0     # idle 0/0 rates
    assert math.isfinite(jain_index([nan, 3.0]))  # partial NaN: no poison
    assert jain_index([nan, 3.0]) == pytest.approx(0.5)
    assert jain_index([2.0, 2.0]) == 1.0          # non-degenerate untouched


# ---------------------------------------------------------------------------
# transferable state: bucket + scheduler export/import
# ---------------------------------------------------------------------------


def test_token_bucket_snapshot_restore_preserves_level():
    b = TokenBucket(10.0, 20.0)
    assert b.consume(15.0, now=0.0)
    snap = b.snapshot(now=1.0)                # settle: 5 + 10*1s = 15
    assert snap["tokens"] == pytest.approx(15.0)
    c = TokenBucket.restore(snap, now=1.0)
    assert c.rate == 10.0 and c.capacity == 20.0
    assert c.tokens == pytest.approx(15.0)    # the burn-down travelled
    assert c.wait_time(20.0, now=1.0) == pytest.approx(0.5)


def test_token_bucket_restore_without_now_keeps_virtual_clock():
    """Regression: restore(now=None) must keep the snapshot's own
    timestamp, never anchor to the wall clock — on a virtual clock that
    would freeze refill forever (wall monotonic >> virtual seconds)."""
    b = TokenBucket(10.0, 20.0)
    assert b.consume(20.0, now=0.0)           # empty at virtual t=0
    c = TokenBucket.restore(b.snapshot(), None)
    assert c.updated == pytest.approx(0.0)    # snapshot's clock, not wall
    # refill resumes on the virtual clock after the transfer
    assert c.wait_time(10.0, now=1.0) == pytest.approx(0.0)


def test_scheduler_export_import_roundtrip():
    a = TenantScheduler(charge_prompt=True)
    b = TenantScheduler(charge_prompt=True)
    a.add_tenant(7, weight=2.0, rate_tokens_per_s=10.0, burst=20.0)
    for k in range(3):
        a.submit(Request(tenant_id=7, prompt=[1], max_new_tokens=3,
                         req_id=k))
    assert a.buckets[7].consume(15.0, now=0.0)
    level = a.buckets[7].tokens
    state = a.export_tenant(7, now=0.0)
    # export is atomic: the source forgets everything
    assert 7 not in a.queues and 7 not in a.buckets and 7 not in a.weights

    b.add_tenant(1)
    b.vtime[1] = 42.0
    b.import_tenant(7, state, now=0.0)
    assert [r.req_id for r in b.queues[7]] == [0, 1, 2]   # FIFO preserved
    assert b.weights[7] == 2.0
    assert b.buckets[7].tokens == pytest.approx(level)    # no fresh burst
    assert b.vtime[7] == pytest.approx(42.0)  # re-join at dst min vtime
    # importing onto an active tenant is refused
    with pytest.raises(ValueError):
        b.import_tenant(7, state)
    # existed-then-dropped destination starts clean on re-import
    b.drop_tenant(7)
    b.import_tenant(7, state, now=1.0)
    assert len(b.queues[7]) == 3


def test_scheduler_telemetry_rebaselines_on_counter_reset():
    """A tenant folded out of a scheduler mid-run (migration) must read as
    a counter reset, not a hugely negative rate."""
    s = TenantScheduler()
    s.add_tenant(0)
    tel = SchedulerTelemetry(s, alpha=1.0)
    tel.update(now=0.0)
    s.account(0, 100)
    assert tel.update(now=1.0)[0].rate == pytest.approx(100.0)
    s.export_tenant(0)                        # ledger folds out
    obs = tel.update(now=2.0)
    assert 0 not in obs or obs[0].rate == 0.0
    s.add_tenant(0)                           # ...and the tenant returns
    s.account(0, 10)
    obs = tel.update(now=3.0)
    assert obs[0].rate == pytest.approx(10.0)
    assert obs[0].rate >= 0.0


# ---------------------------------------------------------------------------
# delta-push invalidation (stale-rate regression, cluster scale)
# ---------------------------------------------------------------------------


def test_invalidate_tenant_clears_delta_history_for_all_points():
    ctrl = RateController(10.0, push_mode="delta")
    ctrl._last_push = {("scheduler", 0, 0): 1.0, ("scheduler", 1, 0): 2.0,
                       ("engine", 0, 0): 4.0, ("scheduler", 0, 1): 3.0}
    ctrl.invalidate_tenant(0)
    assert ctrl._last_push == {("scheduler", 0, 1): 3.0}


def test_delta_push_after_migration_lands_fresh_rate():
    """PR 2's stale-rate regression at cluster scale: a tenant migrating
    back to a scheduler it was dropped from must get a fresh push on the
    next tick — delta mode must not judge the target 'unchanged'."""
    a = TenantScheduler(charge_prompt=True)
    b = TenantScheduler(charge_prompt=True)
    ctrl = RateController(100.0, push_mode="delta", alpha=1.0)
    ctrl.attach_scheduler(a)
    ctrl.attach_scheduler(b)
    a.add_tenant(0)
    now = 0.0
    ctrl.tick(now)                            # telemetry baseline
    for _ in range(4):                        # steady serving on A
        now += 1.0
        a.account(0, 8)
        a.submit(Request(tenant_id=0, prompt=[1], max_new_tokens=7))
        ctrl.tick(now)
    assert 0 in a.buckets and a.buckets[0].rate > 0

    state = a.export_tenant(0, now=now)       # A -> B
    b.import_tenant(0, state, now=now)
    ctrl.invalidate_tenant(0)
    now += 1.0
    b.account(0, 8)
    ctrl.tick(now)

    state = b.export_tenant(0, now=now)       # B -> A (dropped-from) again
    a.import_tenant(0, state, now=now)
    ctrl.invalidate_tenant(0)
    calls_before = ctrl.push_calls
    now += 1.0
    a.account(0, 8)
    ctrl.tick(now)
    # the push actually landed on A's enforcement point this tick...
    assert ctrl.push_calls > calls_before
    assert ("scheduler", 0, 0) in ctrl._last_push
    # ...and the live bucket carries that fresh rate, not a stale one
    assert 0 in a.buckets
    assert a.buckets[0].rate == pytest.approx(
        ctrl._last_push[("scheduler", 0, 0)])


# ---------------------------------------------------------------------------
# EngineCluster: placement + migration edge cases
# ---------------------------------------------------------------------------


def test_cluster_auto_placement_spreads_and_routes():
    cl = make_replay_cluster(capacity=50.0, engines=3, batch_slots=2)
    for t in range(5):
        cl.add_tenant(t)
    counts = [list(cl.placement.values()).count(k) for k in range(3)]
    assert max(counts) - min(counts) <= 1     # least-loaded spread
    idx = cl.submit(Request(tenant_id=3, prompt=[1], max_new_tokens=2))
    assert idx == cl.placement[3]
    assert cl.engines[idx].scheduler.pending(3) == 1
    # an unknown tenant auto-places on first submit
    idx9 = cl.submit(Request(tenant_id=9, prompt=[1], max_new_tokens=2))
    assert cl.placement[9] == idx9


def test_migrate_zero_inflight_finalizes_immediately():
    """Edge case: migrating a tenant with no in-flight requests transfers
    queue + bucket level atomically and needs no drain window."""
    cl = make_replay_cluster(capacity=50.0, engines=2, batch_slots=2)
    cl.add_tenant(0, engine=0)
    for k in range(4):
        cl.submit(Request(tenant_id=0, prompt=[1, 2], max_new_tokens=4,
                          req_id=k, arrival=0.0))
    cl.engines[0].scheduler.set_rate(0, 25.0, now=0.0)
    level = cl.engines[0].scheduler.buckets[0].tokens
    rec = cl.migrate(0, 1, now=0.0)
    assert rec.finalized and rec.inflight_at_move == 0
    assert rec.queued_moved == 4
    assert cl.migrations_completed == 1 and not cl.draining
    assert cl.placement[0] == 1
    assert [r.req_id for r in cl.engines[1].scheduler.queues[0]] == \
        [0, 1, 2, 3]
    assert cl.engines[1].scheduler.buckets[0].tokens == pytest.approx(level)
    assert 0 not in cl.engines[0].scheduler.queues
    cl.assert_ledger_conservation(0)
    # migrating to where the tenant already lives is a no-op
    assert cl.migrate(0, 1) is None
    # a non-quiesced destination is rejected BEFORE the destructive
    # export: the source must keep its queue intact
    cl.add_tenant(5, engine=0)
    cl.submit(Request(tenant_id=5, prompt=[1], max_new_tokens=2))
    cl.engines[1].scheduler.add_tenant(5)     # out-of-band registration
    with pytest.raises(ValueError):
        cl.migrate(5, 1)
    assert cl.engines[0].scheduler.pending(5) == 1
    assert cl.placement[5] == 0


def test_migrate_mid_burst_drains_bills_on_source_and_conserves():
    """The drain-and-transfer path on live engines: in-flight slots finish
    (and bill) on the source, the queue serves on the destination, and the
    cluster ledger equals request-level ground truth throughout."""
    cl = make_replay_cluster(capacity=60.0, engines=2, batch_slots=2,
                             push_mode="delta")
    cl.add_tenant(0, engine=0)
    cl.add_tenant(1, engine=1)
    vt = 0.0

    def pump(n_steps, submit=True):
        nonlocal vt
        for _ in range(n_steps):
            if submit:
                for t in (0, 1):
                    cl.submit(Request(tenant_id=t, prompt=[1, 2],
                                      max_new_tokens=6, arrival=vt))
            vt += 0.05
            cl.step(now=vt)

    pump(6)
    assert cl.engines[0].inflight(0) > 0      # mid-burst
    rec = cl.migrate(0, 1, now=vt)
    assert rec.inflight_at_move > 0 and not rec.finalized
    assert cl.draining == {0: 0}
    with pytest.raises(RuntimeError):         # no re-migration mid-drain
        cl.migrate(0, 0, now=vt)
    steps = 0
    while (cl.draining or cl.scheduler.pending(0)
           or any(e.inflight(0) for e in cl.engines)) and steps < 600:
        pump(1, submit=False)
        steps += 1
    assert not cl.draining and rec.finalized
    assert cl.migrations_completed == 1
    # conservation: cluster ledger == prompt+generated over all requests
    cl.assert_ledger_conservation(0)
    cl.assert_ledger_conservation(1)
    # the facade view is continuous across the move (carried + live)
    assert cl.scheduler.served_tokens[0] == cl.tenant_served_tokens(0)
    # the migrated tenant kept serving — on the destination
    assert cl.engines[1].scheduler.served_tokens.get(0, 0) > 0
    # all 6 of tenant 0's requests completed despite the move
    done0 = [r for r in cl.completed if r.tenant_id == 0]
    assert len(done0) == 6
