"""Failover-conformance: the NSM matrix re-run across kill-and-restore.

The checkpoint/restore claim (kill a stack module, restore it from the
last snapshot, no tenant loses or double-bills a byte) is only real if
the RESTORED stack is *numerically* the stack the conformance suite
certified — a crash must not perturb the wire protocol. This suite
re-runs every registry-discovered conformance case (same matrix, same
EF-residual-derived tolerances as test_nsm_conformance) with the twist
that the target stack arrives via ``fail_engine`` + ``recover_engine``
mid-stream: the engine routes traffic, a fabric checkpoint is taken,
MORE traffic lands (deliberately lost with the crash), the engine is
killed and re-materialized from the snapshot, and the case's verb then
executes through the recovered engine's routing.

Per case we also pin the bytes-plane ledger across the crash: the bytes
billed before the checkpoint survive exactly, the post-checkpoint op is
rolled back (bounded loss, never double-billing), post-recover traffic
lands on the restored module, and carried + live equals billed ground
truth exactly.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from test_nsm_conformance import (
    CASES, _compressed_atol, _ref, _run, _tol, _x,
)
from test_placement import FakeEngine

from repro.core.engine import CoreEngine
from repro.core.nqe import CommOp, payload_bytes
from repro.core.nsm import available_nsms, get_nsm
from repro.serve.cluster import EngineCluster

PRE_OPS = 3          # ops routed (and checkpointed) before the crash
LOST_OPS = 2         # ops routed after the checkpoint — lost with it
OP_BYTES = 2048


@pytest.fixture(scope="module")
def mesh():
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh(2, 2, pod=2)


def _failover_cluster(mesh, name):
    """Two-engine cluster (an engine cannot fail alone) whose first
    bytes-plane slot runs the case's target stack."""
    cores = [CoreEngine(mesh=mesh, default_nsm=name,
                        enforcement="account"),
             CoreEngine(mesh=mesh, default_nsm="xla",
                        enforcement="account")]
    cl = EngineCluster([FakeEngine(), FakeEngine()], core_engines=cores)
    cl.add_tenant(0, engine=0)
    return cl


def _route(engine, verb, axes, size_bytes=OP_BYTES, now=0.0):
    op = CommOp(verb=verb, axes=tuple(axes), tenant_id=0,
                size_bytes=size_bytes)
    engine.admit(op, now)
    return engine.route(op)


@pytest.mark.parametrize(
    "name,verb,axes,dtype", CASES,
    ids=[f"{n}-{v}-{'+'.join(a)}-{jnp.dtype(d).name}"
         for n, v, a, d in CASES])
def test_recovered_stack_matches_xla(mesh, name, verb, axes, dtype):
    cl = _failover_cluster(mesh, name)
    core = cl.core_engines[0]
    for _ in range(PRE_OPS):
        _route(core, verb, axes)
    billed_pre = core.billed_ground_truth(0)
    assert billed_pre == PRE_OPS * OP_BYTES

    snap = cl.checkpoint(now=1.0)
    for _ in range(LOST_OPS):                 # dies with the crash
        _route(core, verb, axes, now=2.0)
    assert core.billed_ground_truth(0) == billed_pre \
        + LOST_OPS * OP_BYTES

    rec = cl.fail_engine(0, now=3.0)
    cl.recover_engine(0, snap, now=3.0)
    assert rec.recovered
    # the recovered slot is the SAME engine, config intact, state
    # rolled back to the checkpoint: pre-checkpoint bytes survive, the
    # post-checkpoint ops are gone (lost, never double-billed)
    assert cl.core_engines[0] is core and core.default_nsm == name
    assert core.billed_ground_truth(0) == billed_pre
    assert cl.tenant_core_bytes(0) == billed_pre

    # the case's verb, executed through the recovered engine's routing
    x = _x(dtype)
    nsm = _route(core, verb, axes, size_bytes=payload_bytes(x), now=4.0)
    assert nsm is get_nsm(name)
    out = _run(mesh, nsm, verb, axes, x)
    ref = _ref(mesh, verb, axes, dtype, x)

    # same tolerance ladder as the native conformance suite
    if name == "compressed":
        atol = _compressed_atol(mesh, verb, axes, dtype, x, ref)
        if atol is not None:
            np.testing.assert_allclose(out, ref, rtol=0.0, atol=atol)
            _assert_bytes_conserved(cl, billed_pre, payload_bytes(x))
            return
    tol = _tol(name, dtype)
    np.testing.assert_allclose(out, ref, rtol=tol,
                               atol=tol * float(np.abs(ref).max()))
    _assert_bytes_conserved(cl, billed_pre, payload_bytes(x))


def _assert_bytes_conserved(cl, billed_pre, post_bytes):
    plane = next(p for p in cl.planes if p.name == "bytes")
    plane.ledger.assert_conservation(0, plane="bytes")
    assert cl.tenant_core_bytes(0) == billed_pre + post_bytes
    assert cl.tenant_core_bytes(0) == \
        cl.core_engines[0].billed_ground_truth(0)


def test_failover_matrix_covers_every_registered_stack():
    """The failover suite is only exhaustive if it tracks the registry:
    every non-native NSM must appear in the recovered-case matrix (the
    native stack itself is covered by the bytes-plane property suite)."""
    assert {n for n, _, _, _ in CASES} == set(available_nsms()) - {"xla"}
