"""GPipe pipeline over 'pod': equivalence vs sequential execution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distribution.pipeline import pipeline_forward
from repro.launch.mesh import make_host_mesh


@pytest.fixture(scope="module")
def pod_mesh():
    return make_host_mesh(2, 2, pod=2)


def _stage_fn(p, x):
    h = jnp.tanh(x @ p["w1"])
    return h @ p["w2"] + x


def _params(key, n_stages, d, h):
    k1, k2 = jax.random.split(key)
    return {"w1": jax.random.normal(k1, (n_stages, d, h)) * 0.3,
            "w2": jax.random.normal(k2, (n_stages, h, d)) * 0.3}


def test_pipeline_matches_sequential(pod_mesh):
    d, h, b, n_micro = 16, 32, 8, 4
    params = _params(jax.random.PRNGKey(0), 2, d, h)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, d))
    y = jax.jit(lambda p, v: pipeline_forward(
        p, v, _stage_fn, mesh=pod_mesh, n_micro=n_micro))(params, x)
    # sequential reference
    ref = x
    for s in range(2):
        ps = jax.tree.map(lambda a: a[s], params)
        ref = _stage_fn(ps, ref)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_pipeline_differentiable(pod_mesh):
    d, h, b, n_micro = 8, 16, 4, 2
    params = _params(jax.random.PRNGKey(2), 2, d, h)
    x = jax.random.normal(jax.random.PRNGKey(3), (b, d))

    def loss_pp(p):
        return jnp.mean(pipeline_forward(p, x, _stage_fn, mesh=pod_mesh,
                                         n_micro=n_micro) ** 2)

    def loss_seq(p):
        ref = x
        for s in range(2):
            ps = jax.tree.map(lambda a: a[s], p)
            ref = _stage_fn(ps, ref)
        return jnp.mean(ref ** 2)

    g1 = jax.jit(jax.grad(loss_pp))(params)
    g2 = jax.jit(jax.grad(loss_seq))(params)
    for a, c in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=1e-4, atol=1e-5)
