"""Test fixtures. 8 host devices (NOT 512 — that's dry-run-only; see
launch/dryrun.py) so collective/NSM semantics can be exercised for real."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import pytest  # noqa: E402


def pytest_collection_modifyitems(config, items):
    """Deselect `slow` tests from the default (tier-1) run.

    Stays out of the way when the user expressed intent: an explicit `-m`
    expression (CI's slow job runs `-m slow`) or a test named by node id
    (`pytest tests/test_replay.py::test_x` must run it, not report
    '1 deselected')."""
    if config.option.markexpr:
        return
    if any("::" in arg for arg in config.invocation_params.args):
        return
    kept, dropped = [], []
    for item in items:
        (dropped if "slow" in item.keywords else kept).append(item)
    if dropped:
        config.hook.pytest_deselected(items=dropped)
        items[:] = kept


@pytest.fixture(scope="session")
def mesh1():
    from repro.launch.mesh import make_single_device_mesh
    return make_single_device_mesh()


@pytest.fixture(scope="session")
def mesh8():
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh(2, 4)


@pytest.fixture(scope="session")
def mesh_pod():
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh(2, 2, pod=2)


@pytest.fixture(scope="session")
def rcfg_small():
    from repro.configs import RunConfig
    return RunConfig(attn_q_block=16, attn_kv_block=16)
