"""TokenBucket snapshot/restore properties (hypothesis, shimmed).

The placement autopilot exercises ``snapshot``/``restore`` on every plan it
applies (serve-plane scheduler buckets AND bytes-plane CoreEngine buckets
travel with each migrated tenant), so the transfer semantics are pinned as
properties rather than a handful of examples:

  * a snapshot/restore round trip preserves rate, capacity and *level*
    exactly, under arbitrary virtual-clock advance on either side;
  * the restored bucket is behaviourally indistinguishable from the
    original (same ``wait_time`` for any demand at any future instant);
  * restoring "onto a live bucket" (the scheduler's import path replaces
    the destination's bucket object) yields an independent bucket — no
    aliasing back to the source;
  * the level is clamped to capacity on restore, so a tampered or
    re-burst snapshot can never smuggle extra burst through a migration.

Runs under real hypothesis when installed, the deterministic fallback of
``tests/_hyp.py`` otherwise.
"""
import math

import pytest

from repro.core.engine import TokenBucket

from _hyp import given, settings, st

_RATES = st.floats(min_value=0.1, max_value=1e4)
_CAPS = st.floats(min_value=1.0, max_value=1e5)
_TIMES = st.floats(min_value=0.0, max_value=100.0)
_FRACS = st.floats(min_value=0.0, max_value=1.0)


def _burned(rate, cap, frac, t0):
    """A bucket that consumed ``frac`` of its capacity at time ``t0``."""
    b = TokenBucket(rate, cap)
    b.consume(frac * cap, now=t0)
    return b


@settings(max_examples=60)
@given(rate=_RATES, cap=_CAPS, frac=_FRACS, t0=_TIMES, dt=_TIMES)
def test_roundtrip_preserves_level_rate_capacity(rate, cap, frac, t0, dt):
    b = _burned(rate, cap, frac, t0)
    snap = b.snapshot(now=t0 + dt)           # settle on the virtual clock
    c = TokenBucket.restore(snap, now=t0 + dt)
    assert c.rate == b.rate
    assert c.capacity == b.capacity
    assert c.tokens == pytest.approx(b.tokens, rel=1e-9, abs=1e-9)
    assert 0.0 <= c.tokens <= c.capacity + 1e-9


@settings(max_examples=60)
@given(rate=_RATES, cap=_CAPS, frac=_FRACS, t0=_TIMES, dt=_TIMES,
       dt2=_TIMES, want=_FRACS)
def test_restored_bucket_is_behaviourally_identical(rate, cap, frac, t0,
                                                    dt, dt2, want):
    """Same wait_time for any demand at any later virtual instant — a
    migration is invisible to the tenant's admission future."""
    b = _burned(rate, cap, frac, t0)
    c = TokenBucket.restore(b.snapshot(now=t0 + dt), now=t0 + dt)
    later = t0 + dt + dt2
    n = want * cap * 2.0                     # may exceed capacity: inf case
    wb, wc = b.wait_time(n, now=later), c.wait_time(n, now=later)
    if math.isinf(wb) or math.isinf(wc):
        assert wb == wc
    else:
        assert wc == pytest.approx(wb, rel=1e-9, abs=1e-9)


@settings(max_examples=60)
@given(rate=_RATES, cap=_CAPS, frac=_FRACS, t0=_TIMES)
def test_restore_onto_live_bucket_is_independent(rate, cap, frac, t0):
    """The import path swaps the destination's bucket object for the
    restored one; draining the restored bucket must never touch the
    source (no shared state across engines after a migration)."""
    b = _burned(rate, cap, frac, t0)
    before = b.snapshot(now=t0)
    c = TokenBucket.restore(b.snapshot(now=t0), now=t0)
    c.consume(c.tokens, now=t0)              # drain the migrant dry
    c.set_rate(rate * 2.0, burst=cap * 0.5, now=t0)
    after = b.snapshot(now=t0)
    assert after == before                   # source untouched


@settings(max_examples=60)
@given(rate=_RATES, cap=_CAPS, frac=_FRACS, t0=_TIMES,
       shrink=st.floats(min_value=0.1, max_value=1.0))
def test_restore_clamps_level_to_capacity(rate, cap, frac, t0, shrink):
    """A snapshot whose level exceeds the (possibly shrunk) capacity is
    clamped: migration can never mint burst."""
    b = _burned(rate, cap, frac, t0)
    snap = b.snapshot(now=t0)
    snap = dict(snap, capacity=snap["capacity"] * shrink)
    c = TokenBucket.restore(snap, now=t0)
    assert c.tokens <= c.capacity + 1e-9


@settings(max_examples=60)
@given(rate=_RATES, cap=_CAPS, frac=_FRACS, t0=_TIMES, dt=_TIMES)
def test_restore_without_now_keeps_snapshot_clock(rate, cap, frac, t0, dt):
    """restore(None) anchors to the snapshot's own timestamp (virtual
    clocks must not be re-anchored to the wall clock), so refill resumes
    exactly where the source left off."""
    b = _burned(rate, cap, frac, t0)
    snap = b.snapshot(now=t0)
    c = TokenBucket.restore(snap, None)
    assert c.updated == snap["updated"]
    # advancing both clocks by dt refills both identically
    assert c.wait_time(cap, now=t0 + dt) == \
        pytest.approx(b.wait_time(cap, now=t0 + dt), rel=1e-9, abs=1e-9)


@settings(max_examples=40)
@given(rate=_RATES, cap=_CAPS, fracs=st.lists(_FRACS, min_size=1,
                                              max_size=6))
def test_level_never_negative_nor_above_capacity_under_traffic(rate, cap,
                                                               fracs):
    """Invariant the autopilot relies on: however traffic and transfers
    interleave on the virtual clock, 0 <= level <= capacity."""
    b = TokenBucket(rate, cap)
    now = 0.0
    for f in fracs:
        now += f
        b.drain(f * cap * 1.5, now=now)      # may overdraw: drain clamps
        assert -1e-9 <= b.tokens <= b.capacity + 1e-9
        b = TokenBucket.restore(b.snapshot(now=now), now=now)
        assert -1e-9 <= b.tokens <= b.capacity + 1e-9
