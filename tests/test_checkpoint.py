"""Checkpoint manager: roundtrip, async, atomicity, resharding, GC."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.train.checkpoint import CheckpointManager


def _state(key=0):
    k = jax.random.PRNGKey(key)
    return {"w": jax.random.normal(k, (16, 8), jnp.float32),
            "b": jax.random.normal(k, (8,), jnp.bfloat16),
            "inner": {"c": jnp.arange(10, dtype=jnp.int32)},
            "step": jnp.int32(7)}


def test_roundtrip_with_bf16():
    with tempfile.TemporaryDirectory() as d:
        m = CheckpointManager(d)
        s = _state()
        m.save(3, s, blocking=True, extras={"note": "x"})
        tpl = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), s)
        back, extras = m.restore(tpl)
        assert extras == {"note": "x"}
        for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            assert a.dtype == b.dtype


def test_async_save_and_wait():
    with tempfile.TemporaryDirectory() as d:
        m = CheckpointManager(d)
        m.save(1, _state(), blocking=False)
        m.wait()
        assert m.latest_step() == 1


def test_keep_last_k_gc():
    with tempfile.TemporaryDirectory() as d:
        m = CheckpointManager(d, keep=2)
        for step in (1, 2, 3, 4):
            m.save(step, _state(step), blocking=True)
        assert m.steps() == [3, 4]


def test_restore_resharded(mesh8):
    """Checkpoint written unsharded restores onto a 2x4 mesh (elastic)."""
    with tempfile.TemporaryDirectory() as d:
        m = CheckpointManager(d)
        s = _state()
        m.save(1, s, blocking=True)
        tpl = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), s)
        sh = {"w": NamedSharding(mesh8, P("data", "model")),
              "b": NamedSharding(mesh8, P("model")),
              "inner": {"c": NamedSharding(mesh8, P())},
              "step": NamedSharding(mesh8, P())}
        back, _ = m.restore(tpl, shardings=sh)
        np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(s["w"]))
        assert back["w"].sharding.spec == P("data", "model")


def test_tmp_dir_never_visible_as_checkpoint():
    with tempfile.TemporaryDirectory() as d:
        m = CheckpointManager(d)
        os.makedirs(os.path.join(d, "step_000000009.tmp"))
        assert m.latest_step() is None
